"""L2 JAX model: the iterative-solver compute graph PARS3 accelerates.

The paper's motivating consumer is the MRS family of Krylov methods for
shifted skew-symmetric systems ``A x = b`` with ``A = alpha*I + S``,
``S = -S^T`` — the striking feature being *one SpMV and one inner product
per iteration* (§1). We implement the classical minimal-residual
iteration specialized to this class:

  p   = A r
  a   = (r, A r) / (A r, A r) = alpha * ||r||^2 / ||p||^2
        (the skew part drops out of the numerator: (r, S r) = 0)
  x  <- x + a r
  r  <- r - a p

which converges monotonically in ||r|| whenever ``alpha != 0`` (the field
of values of A lies on the vertical line Re = alpha). The SpMV is the
L1 Pallas band kernel; the vector updates are the fused L1 kernel.

Everything here is build-time Python: ``aot.py`` lowers these functions
once to HLO text, and the Rust coordinator replays them via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.band_spmv import band_spmv
from compile.kernels.fused_update import fused_update

_EPS = 1e-30


def spmv(lo, x, alpha, *, tile: int = 256):
    """Banded shifted skew-symmetric SpMV (L1 kernel wrapper)."""
    return band_spmv(lo, x, alpha, tile=tile)


def mrs_step(lo, x, r, alpha, *, tile: int = 256):
    """One minimal-residual iteration.

    Returns ``(x', r', rr)`` where ``rr = ||r||^2`` *before* the update —
    the Rust driver uses it for its convergence check, so each iteration
    costs exactly one SpMV plus two inner products, matching the paper's
    per-iteration budget.
    """
    p = spmv(lo, r, alpha, tile=tile)
    rr = jnp.dot(r, r)
    pp = jnp.dot(p, p)
    a = alpha.astype(x.dtype)[0] * rr / jnp.maximum(pp, _EPS)
    x2, r2 = fused_update(x, r, p, a[None], tile=tile)
    return x2, r2, rr[None]


def mrs_solve(lo, b, alpha, *, iters: int, tile: int = 256):
    """Run ``iters`` minimal-residual iterations from ``x0 = 0``.

    Returns ``(x, r, history)`` with ``history[k] = ||r_k||^2``. Used for
    whole-solve AOT artifacts and for pytest cross-checks; the Rust hot
    path prefers the single-step artifact so it owns the stopping rule.
    """

    def body(carry, _):
        x, r = carry
        x2, r2, rr = mrs_step(lo, x, r, alpha, tile=tile)
        return (x2, r2), rr[0]

    x0 = jnp.zeros_like(b)
    (x, r), hist = jax.lax.scan(body, (x0, b), None, length=iters)
    return x, r, hist


def make_spmv(n: int, beta: int, tile: int):
    """Jit-able ``(lo, x, alpha) -> (y,)`` closure + arg specs for AOT."""

    def fn(lo, x, alpha):
        return (spmv(lo, x, alpha, tile=tile),)

    return fn, (
        jax.ShapeDtypeStruct((beta, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def make_mrs_step(n: int, beta: int, tile: int):
    """Jit-able ``(lo, x, r, alpha) -> (x', r', rr)`` closure + arg specs."""

    def fn(lo, x, r, alpha):
        return mrs_step(lo, x, r, alpha, tile=tile)

    return fn, (
        jax.ShapeDtypeStruct((beta, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def mrs_chunk(lo, x, r, alpha, *, iters: int, tile: int = 256):
    """Run `iters` MRS iterations in one call (§Perf: amortizes PJRT
    dispatch + input transfer over `iters` solver steps while the Rust
    driver keeps the stopping rule at chunk granularity).

    Returns ``(x', r', hist)`` with ``hist[k] = ||r_k||^2`` before step k.
    """

    def body(carry, _):
        x, r = carry
        x2, r2, rr = mrs_step(lo, x, r, alpha, tile=tile)
        return (x2, r2), rr[0]

    (x2, r2), hist = jax.lax.scan(body, (x, r), None, length=iters)
    return x2, r2, hist


def make_mrs_chunk(n: int, beta: int, tile: int, iters: int):
    """Jit-able ``(lo, x, r, alpha) -> (x', r', hist)`` chunk closure."""

    def fn(lo, x, r, alpha):
        return mrs_chunk(lo, x, r, alpha, iters=iters, tile=tile)

    return fn, (
        jax.ShapeDtypeStruct((beta, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def make_mrs_solve(n: int, beta: int, tile: int, iters: int):
    """Jit-able whole-solve ``(lo, b, alpha) -> (x, r, hist)`` closure."""

    def fn(lo, b, alpha):
        return mrs_solve(lo, b, alpha, iters=iters, tile=tile)

    return fn, (
        jax.ShapeDtypeStruct((beta, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
