"""AOT export: lower the L2/L1 graph to HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run via ``make artifacts``:

  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per (function, n, beta) config plus
``manifest.json`` describing shapes/order of every input and output, which
``rust/src/runtime/artifacts.rs`` parses.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (n, beta, tile) configs exported for both spmv and mrs_step. Sizes are
# chosen so the band (beta * n f32) stays VMEM-scale and compile time stays
# sane on this box; the Rust coordinator picks the smallest config >= its
# problem and zero-pads (see runtime::artifacts).
CONFIGS = [
    (1024, 16, 128),
    (4096, 32, 256),
    (8192, 64, 256),
]

# Iterations fused into each mrs_chunk artifact (§Perf: amortizes PJRT
# dispatch + input transfer; the Rust driver checks convergence at chunk
# granularity).
CHUNK_ITERS = 8

# Whole-solve artifact (fixed iteration count) — one config is enough to
# prove the scan-fused path; step/chunk artifacts are the production path.
SOLVE_CONFIG = (1024, 16, 128, 64)  # n, beta, tile, iters


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_list(specs):
    return [{"shape": list(s.shape), "dtype": s.dtype.name} for s in specs]


def export_one(name, fn, specs, out_dir, kind, meta):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_specs = jax.eval_shape(fn, *specs)
    entry = {
        "name": name,
        "kind": kind,
        "file": fname,
        "inputs": _spec_list(specs),
        "outputs": _spec_list(jax.tree_util.tree_leaves(out_specs)),
        **meta,
    }
    print(f"  wrote {fname} ({len(text)} chars)")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for n, beta, tile in CONFIGS:
        meta = {"n": n, "beta": beta, "tile": tile}
        fn, specs = model.make_spmv(n, beta, tile)
        entries.append(
            export_one(f"spmv_n{n}_b{beta}", fn, specs, args.out_dir, "spmv", meta)
        )
        fn, specs = model.make_mrs_step(n, beta, tile)
        entries.append(
            export_one(f"mrs_step_n{n}_b{beta}", fn, specs, args.out_dir, "mrs_step", meta)
        )
        # §Perf: 8-iteration chunk — amortizes PJRT dispatch + transfers
        fn, specs = model.make_mrs_chunk(n, beta, tile, CHUNK_ITERS)
        entries.append(
            export_one(
                f"mrs_chunk_n{n}_b{beta}",
                fn,
                specs,
                args.out_dir,
                "mrs_chunk",
                {**meta, "iters": CHUNK_ITERS},
            )
        )

    n, beta, tile, iters = SOLVE_CONFIG
    fn, specs = model.make_mrs_solve(n, beta, tile, iters)
    entries.append(
        export_one(
            f"mrs_solve_n{n}_b{beta}_i{iters}",
            fn,
            specs,
            args.out_dir,
            "mrs_solve",
            {"n": n, "beta": beta, "tile": tile, "iters": iters},
        )
    )

    manifest = {"version": 1, "dtype": "f32", "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
