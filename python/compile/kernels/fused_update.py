"""L1 Pallas kernel: fused MRS vector update.

One minimal-residual iteration for a shifted skew-symmetric system ends
with two axpy-like passes::

  x <- x + a * r
  r <- r - a * p        (p = A r)

Done naively that is four reads + two writes over ``n``-vectors; fused it
is three reads + two writes in a single pass — the same "cut memory
passes" motivation the paper applies to the symmetric-pair reuse. Both
outputs are produced per row tile so the iterate and residual streams stay
tile-resident in VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_update_kernel(a_ref, x_ref, r_ref, p_ref, xo_ref, ro_ref):
    a = a_ref[0]
    r = r_ref[...]
    xo_ref[...] = x_ref[...] + a * r
    ro_ref[...] = r - a * p_ref[...]


def fused_update(
    x: jax.Array, r: jax.Array, p: jax.Array, a: jax.Array, *, tile: int = 256
) -> tuple[jax.Array, jax.Array]:
    """Return ``(x + a*r, r - a*p)`` in one fused pass.

    Args:
      x, r, p: ``(n,)`` iterate, residual, and ``A @ r``.
      a: ``(1,)`` step length.
      tile: row-tile size; must divide ``n``.
    """
    (n,) = x.shape
    if n % tile != 0:
        raise ValueError(f"tile {tile} must divide n {n}")
    dtype = x.dtype
    vec = pl.BlockSpec((tile,), lambda t: (t,))
    return pl.pallas_call(
        _fused_update_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1,), lambda t: (0,)), vec, vec, vec],
        out_specs=[vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), dtype),
            jax.ShapeDtypeStruct((n,), dtype),
        ],
        interpret=True,
    )(a.astype(dtype), x, r, p)
