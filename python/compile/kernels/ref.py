"""Pure-jnp correctness oracles for the Pallas kernels.

These implement the same DIA band convention as ``band_spmv.py`` with
straightforward (unblocked) jnp index arithmetic, plus a dense
materializer used by the tests to cross-check against ``jnp.matmul``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def band_spmv_ref(lo: jax.Array, x: jax.Array, alpha: jax.Array) -> jax.Array:
    """Reference ``y = (alpha*I + S) @ x`` for DIA lower band ``lo``."""
    beta, n = lo.shape
    y = alpha.astype(x.dtype)[0] * x
    for d in range(beta):
        k = d + 1
        if k >= n:
            break
        # Lower band: S[j+k, j] = lo[d, j].
        y = y.at[k:].add(lo[d, : n - k] * x[: n - k])
        # Mirrored upper band: S[j, j+k] = -lo[d, j].
        y = y.at[: n - k].add(-lo[d, : n - k] * x[k:])
    return y


def dense_from_band(lo: jax.Array, alpha: jax.Array) -> jax.Array:
    """Materialize ``alpha*I + S`` as a dense ``(n, n)`` matrix."""
    beta, n = lo.shape
    a = alpha.astype(lo.dtype)[0] * jnp.eye(n, dtype=lo.dtype)
    for d in range(beta):
        k = d + 1
        if k >= n:
            break
        diag = lo[d, : n - k]
        a = a + jnp.diag(diag, -k) - jnp.diag(diag, k)
    return a


def fused_update_ref(
    x: jax.Array, r: jax.Array, p: jax.Array, a: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Reference for the fused MRS vector update."""
    s = a.astype(x.dtype)[0]
    return x + s * r, r - s * p


def mrs_step_ref(lo, x, r, alpha, eps: float = 1e-30):
    """Reference single minimal-residual iteration (see model.mrs_step)."""
    p = band_spmv_ref(lo, r, alpha)
    rr = jnp.dot(r, r)
    pp = jnp.dot(p, p)
    a = alpha.astype(x.dtype)[0] * rr / jnp.maximum(pp, eps)
    return x + a * r, r - a * p, rr
