"""L1 Pallas kernel: banded shifted skew-symmetric SpMV.

This is the compute hot-spot of PARS3 after preprocessing: once RCM has
reordered the matrix into a band of half-bandwidth ``beta``, the middle
split is a (mostly dense) lower band stored in DIA ("diagonal") layout.

Storage convention (shared with the Rust side, see ``sparse::dia``):

  * ``A = alpha * I + S`` with ``S = -S^T`` (shifted skew-symmetric).
  * ``lo`` has shape ``(beta, n)`` with ``lo[d, j] = S[j + d + 1, j]``
    (the ``d+1``-th sub-diagonal, stored at its *column* index ``j``;
    entries with ``j + d + 1 >= n`` are zero padding).
  * The strictly upper triangle is implied: ``S[j, j + d + 1] = -lo[d, j]``.

The multiply is therefore, for each row ``i``::

  y[i] = alpha * x[i]
       + sum_d lo[d, i - d - 1] * x[i - d - 1]     (lower band, row i)
       - sum_d lo[d, i]         * x[i + d + 1]     (mirrored upper band)

which is exactly the paper's "single read of a symmetric pair drives two
multiplies" trick (eqs. (2)-(6)) — realized owner-computes: each row tile
reads the mirrored band columns instead of remote-accumulating into a
neighbour's output (see DESIGN.md §Hardware-Adaptation).

The kernel runs over a 1-D grid of row tiles. Inputs arrive pre-padded by
the wrapper so all in-kernel dynamic slices are in-bounds:

  * ``x_pad``  : ``(n + 2*beta,)``  with ``x_pad[beta + j] = x[j]``
  * ``lo_pad`` : ``(beta, n + beta)`` with ``lo_pad[d, beta + j] = lo[d, j]``

TPU mapping notes (structure, not interpret-mode wallclock): the row tile
of ``y`` plus its ``2*beta`` halo of ``x`` and a ``(beta, tile)`` band tile
live in VMEM; traffic is dominated by the band tile (``beta * tile`` f32),
streamed once per program — the memory-bound roofline for SpMV. The
``fori_loop`` over diagonals keeps the HLO size independent of ``beta``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _band_spmv_kernel(alpha_ref, lo_pad_ref, x_pad_ref, y_ref, *, beta: int, tile: int):
    """One row-tile of the banded skew-symmetric multiply."""
    t = pl.program_id(0)
    base = t * tile
    alpha = alpha_ref[0]

    # Diagonal split: y_tile = alpha * x_tile.
    x_c = pl.load(x_pad_ref, (pl.dslice(base + beta, tile),))
    acc = alpha * x_c

    def body(d, acc):
        # Lower band: row i uses lo[d, i-d-1] * x[i-d-1].
        lo_low = pl.load(lo_pad_ref, (d, pl.dslice(base + beta - d - 1, tile)))
        x_low = pl.load(x_pad_ref, (pl.dslice(base + beta - d - 1, tile),))
        # Mirrored upper band: row i uses -lo[d, i] * x[i+d+1].
        lo_up = pl.load(lo_pad_ref, (d, pl.dslice(base + beta, tile)))
        x_up = pl.load(x_pad_ref, (pl.dslice(base + beta + d + 1, tile),))
        return acc + lo_low * x_low - lo_up * x_up

    acc = jax.lax.fori_loop(0, beta, body, acc)
    pl.store(y_ref, (pl.dslice(0, tile),), acc)


def band_spmv(lo: jax.Array, x: jax.Array, alpha: jax.Array, *, tile: int = 256) -> jax.Array:
    """Compute ``y = (alpha*I + S) @ x`` for a DIA-stored lower band ``lo``.

    Args:
      lo: ``(beta, n)`` sub-diagonals of the skew-symmetric part ``S``.
      x: ``(n,)`` input vector.
      alpha: ``(1,)`` shift scalar (as an array so it stays an HLO input).
      tile: row-tile size; must divide ``n``.

    Returns:
      ``(n,)`` output vector.
    """
    beta, n = lo.shape
    if n % tile != 0:
        raise ValueError(f"tile {tile} must divide n {n}")
    dtype = x.dtype
    x_pad = jnp.pad(x, (beta, beta))
    lo_pad = jnp.pad(lo, ((0, 0), (beta, 0)))
    kernel = functools.partial(_band_spmv_kernel, beta=beta, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda t: (0,)),
            pl.BlockSpec(lo_pad.shape, lambda t: (0, 0)),
            pl.BlockSpec(x_pad.shape, lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((n,), dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(alpha.astype(dtype), lo_pad, x_pad)
