"""pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes (n, beta, tile) and value distributions; fixed
seeds keep the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.band_spmv import band_spmv
from compile.kernels.fused_update import fused_update
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_band(rng, n, beta, dtype=np.float32, scale=1.0):
    """Random DIA lower band with the trailing-pad invariant enforced."""
    lo = (rng.standard_normal((beta, n)) * scale).astype(dtype)
    for d in range(beta):
        k = d + 1
        if k <= n:
            lo[d, n - k :] = 0.0  # S[j+k, j] needs j+k < n
        else:
            lo[d, :] = 0.0
    return lo


@pytest.mark.parametrize(
    "n,beta,tile",
    [
        (128, 1, 32),
        (128, 8, 32),
        (128, 16, 128),
        (256, 3, 64),
        (512, 32, 64),
        (1024, 16, 128),
        (256, 64, 32),  # beta > tile
        (64, 63, 64),  # beta ~ n
    ],
)
def test_band_spmv_matches_ref(n, beta, tile):
    rng = np.random.default_rng(1234 + n + beta)
    lo = jnp.asarray(rand_band(rng, n, beta))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    alpha = jnp.asarray([0.7], dtype=jnp.float32)
    got = band_spmv(lo, x, alpha, tile=tile)
    want = ref.band_spmv_ref(lo, x, alpha)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,beta", [(64, 4), (96, 11), (128, 32)])
def test_ref_matches_dense(n, beta):
    """The oracle itself is checked against a dense materialization."""
    rng = np.random.default_rng(77)
    lo = jnp.asarray(rand_band(rng, n, beta))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    alpha = jnp.asarray([1.3], dtype=jnp.float32)
    a = ref.dense_from_band(lo, alpha)
    np.testing.assert_allclose(
        ref.band_spmv_ref(lo, x, alpha), a @ x, rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("n,beta", [(64, 8), (128, 16)])
def test_dense_is_shifted_skew_symmetric(n, beta):
    rng = np.random.default_rng(5)
    lo = jnp.asarray(rand_band(rng, n, beta))
    alpha = jnp.asarray([2.5], dtype=jnp.float32)
    a = ref.dense_from_band(lo, alpha)
    s = a - alpha[0] * jnp.eye(n)
    np.testing.assert_allclose(s, -s.T, atol=0.0)


def test_band_spmv_zero_alpha_pure_skew():
    """alpha=0: y = S x, so (x, y) = 0 (skew-symmetry invariant)."""
    rng = np.random.default_rng(9)
    n, beta = 256, 12
    lo = jnp.asarray(rand_band(rng, n, beta))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    y = band_spmv(lo, x, jnp.zeros(1, jnp.float32), tile=64)
    assert abs(float(jnp.dot(x, y))) < 1e-2 * float(jnp.linalg.norm(x) * jnp.linalg.norm(y) + 1)


def test_band_spmv_identity_band_zero():
    """Zero band: y = alpha x exactly."""
    n, beta = 128, 7
    lo = jnp.zeros((beta, n), jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32)
    y = band_spmv(lo, x, jnp.asarray([3.0], jnp.float32), tile=32)
    np.testing.assert_allclose(y, 3.0 * x, atol=0.0)


@settings(max_examples=25, deadline=None)
@given(
    nt=st.integers(1, 8),
    tile_log=st.integers(4, 7),
    beta=st.integers(1, 48),
    alpha=st.floats(-4.0, 4.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_band_spmv_hypothesis(nt, tile_log, beta, alpha, seed):
    """Shape/value sweep: n = nt * tile for tile in {16..128}."""
    tile = 1 << tile_log
    n = nt * tile
    beta = min(beta, n - 1)
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rand_band(rng, n, beta, scale=2.0))
    x = jnp.asarray((rng.standard_normal(n) * 3).astype(np.float32))
    a = jnp.asarray([alpha], dtype=jnp.float32)
    got = band_spmv(lo, x, a, tile=tile)
    want = ref.band_spmv_ref(lo, x, a)
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    np.testing.assert_allclose(got / scale, want / scale, rtol=1e-4, atol=1e-4)


def test_band_spmv_tile_must_divide():
    lo = jnp.zeros((4, 100), jnp.float32)
    x = jnp.zeros(100, jnp.float32)
    with pytest.raises(ValueError):
        band_spmv(lo, x, jnp.ones(1, jnp.float32), tile=64)


@settings(max_examples=20, deadline=None)
@given(
    nt=st.integers(1, 6),
    a=st.floats(-10.0, 10.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_update_hypothesis(nt, a, seed):
    tile = 64
    n = nt * tile
    rng = np.random.default_rng(seed)
    x, r, p = (jnp.asarray(rng.standard_normal(n).astype(np.float32)) for _ in range(3))
    aa = jnp.asarray([a], dtype=jnp.float32)
    gx, gr = fused_update(x, r, p, aa, tile=tile)
    wx, wr = ref.fused_update_ref(x, r, p, aa)
    np.testing.assert_allclose(gx, wx, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(gr, wr, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 5e-2)])
def test_band_spmv_dtype_sweep(dtype, tol):
    """dtype sweep: f32 (production) and bf16 (TPU-native) tolerance."""
    rng = np.random.default_rng(21)
    n, beta, tile = 256, 8, 64
    lo = jnp.asarray(rand_band(rng, n, beta)).astype(dtype)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32)).astype(dtype)
    alpha = jnp.asarray([1.5], dtype=dtype)
    got = np.asarray(band_spmv(lo, x, alpha, tile=tile), dtype=np.float32)
    want = np.asarray(
        ref.band_spmv_ref(
            lo.astype(jnp.float32), x.astype(jnp.float32), alpha.astype(jnp.float32)
        )
    )
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got / scale, want / scale, atol=tol)
