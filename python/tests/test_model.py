"""pytest: L2 model — MRS iteration correctness and convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from tests.test_kernel import rand_band

jax.config.update("jax_platform_name", "cpu")


def setup_system(n=128, beta=8, alpha=2.0, seed=3):
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rand_band(rng, n, beta, scale=0.3))
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    a = jnp.asarray([alpha], dtype=jnp.float32)
    return lo, b, a


def test_mrs_step_matches_ref():
    lo, b, alpha = setup_system()
    x = jnp.zeros_like(b)
    gx, gr, grr = model.mrs_step(lo, x, b, alpha, tile=32)
    wx, wr, wrr = ref.mrs_step_ref(lo, x, b, alpha)
    np.testing.assert_allclose(gx, wx, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gr, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(grr[0], wrr, rtol=1e-5)


def test_mrs_residual_monotone():
    """Minimal-residual property: ||r_k|| is non-increasing for alpha>0."""
    lo, b, alpha = setup_system(alpha=1.5)
    _, _, hist = model.mrs_solve(lo, b, alpha, iters=30, tile=32)
    h = np.asarray(hist)
    assert np.all(h[1:] <= h[:-1] * (1 + 1e-5))


def test_mrs_solves_system():
    """After enough iterations, A x ~= b (diagonally dominant shift)."""
    lo, b, alpha = setup_system(n=128, beta=4, alpha=3.0)
    x, r, hist = model.mrs_solve(lo, b, alpha, iters=200, tile=32)
    a = ref.dense_from_band(lo, alpha)
    res = np.linalg.norm(np.asarray(a @ x - b)) / np.linalg.norm(np.asarray(b))
    assert res < 1e-3, f"relative residual {res}"
    # the reported history matches the actual residual trajectory's start
    np.testing.assert_allclose(float(hist[0]), float(jnp.dot(b, b)), rtol=1e-5)


def test_mrs_residual_consistency():
    """r returned by the solve equals b - A x recomputed from scratch."""
    lo, b, alpha = setup_system(n=64, beta=6, alpha=2.0, seed=11)
    x, r, _ = model.mrs_solve(lo, b, alpha, iters=20, tile=32)
    a = ref.dense_from_band(lo, alpha)
    np.testing.assert_allclose(r, b - a @ x, rtol=1e-3, atol=1e-4)


def test_spmv_wrapper_default_tile():
    lo, b, alpha = setup_system(n=512, beta=8)
    got = model.spmv(lo, b, alpha)  # default tile=256 divides 512
    want = ref.band_spmv_ref(lo, b, alpha)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("alpha", [0.5, 1.0, 4.0])
def test_mrs_convergence_rate_improves_with_shift(alpha):
    """Larger shift => better conditioned => residual after k iters smaller."""
    lo, b, _ = setup_system(n=128, beta=4, seed=7)
    a = jnp.asarray([alpha], dtype=jnp.float32)
    _, _, hist = model.mrs_solve(lo, b, a, iters=25, tile=32)
    assert float(hist[-1]) < float(hist[0])
