//! Conjugate Gradient (comparison solver, paper §1).
//!
//! CG matches MRS's per-iteration budget (one SpMV, few dots) but
//! requires SPD coefficient matrices — the restriction the paper uses to
//! motivate the skew-symmetric MRS path. Included so the symmetric
//! variant of the kernels has a native consumer too.

use crate::kernel::Spmv;

/// CG result.
#[derive(Debug, Clone)]
pub struct CgResult {
    /// Final iterate.
    pub x: Vec<f64>,
    /// `||r_k||^2` history.
    pub history: Vec<f64>,
    /// Iterations performed.
    pub iters: usize,
    /// Converged within tolerance?
    pub converged: bool,
}

/// Solve `A x = b` for SPD `A` with plain CG.
pub fn cg_solve(kernel: &mut dyn Spmv, b: &[f64], max_iters: usize, tol: f64) -> CgResult {
    let n = kernel.n();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f64; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0f64; n];
    let bb = dot(b, b);
    let mut rr = bb;
    let mut history = vec![rr];
    let tol2 = tol * tol * bb;
    let mut iters = 0;
    while iters < max_iters && rr > tol2 {
        kernel.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            break; // not SPD (or breakdown)
        }
        let a = rr / pap;
        for i in 0..n {
            x[i] += a * p[i];
            r[i] -= a * ap[i];
        }
        let rr_new = dot(&r, &r);
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_new;
        history.push(rr);
        iters += 1;
    }
    CgResult { x, history, iters, converged: rr <= tol2 }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::SerialSss;
    use crate::sparse::{convert, Coo, Symmetry};

    /// SPD test matrix: diagonally dominant symmetric.
    fn spd(n: usize) -> SerialSss {
        let mut c = Coo::new(n);
        for i in 0..n as u32 {
            c.push(i, i, 4.0);
        }
        for i in 1..n as u32 {
            c.push(i, i - 1, -1.0);
            c.push(i - 1, i, -1.0);
        }
        SerialSss::new(convert::coo_to_sss(&c, Symmetry::Symmetric).unwrap())
    }

    #[test]
    fn solves_laplacian_like_system() {
        let mut k = spd(200);
        let b: Vec<f64> = (0..200).map(|i| ((i % 9) as f64) - 4.0).collect();
        let res = cg_solve(&mut k, &b, 500, 1e-10);
        assert!(res.converged, "iters={}", res.iters);
        let mut ax = vec![0.0; 200];
        k.apply(&res.x, &mut ax);
        let err: f64 = ax.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0, f64::max);
        assert!(err < 1e-7, "err {err}");
    }

    #[test]
    fn detects_non_spd() {
        // skew-symmetric part makes pAp = alpha*||p||^2 only; with
        // alpha<0 CG must bail out instead of diverging silently
        let mut c = Coo::new(10);
        for i in 0..10u32 {
            c.push(i, i, -1.0);
        }
        let mut k = SerialSss::new(convert::coo_to_sss(&c, Symmetry::Symmetric).unwrap());
        let res = cg_solve(&mut k, &vec![1.0; 10], 50, 1e-10);
        assert!(!res.converged);
    }
}
