//! Conjugate Gradient (comparison solver, paper §1).
//!
//! CG matches MRS's per-iteration budget (one SpMV, few dots) but
//! requires SPD coefficient matrices — the restriction the paper uses to
//! motivate the skew-symmetric MRS path. Included so the symmetric
//! variant of the kernels has a native consumer too.

use crate::kernel::{Spmv, VecBatch};
use crate::solver::compaction::BatchCompactor;

/// CG result.
#[derive(Debug, Clone)]
pub struct CgResult {
    /// Final iterate.
    pub x: Vec<f64>,
    /// `||r_k||^2` history.
    pub history: Vec<f64>,
    /// Iterations performed.
    pub iters: usize,
    /// Converged within tolerance?
    pub converged: bool,
}

/// Solve `A x = b` for SPD `A` with plain CG.
///
/// This is [`cg_solve_batch`] at width 1 — one recurrence
/// implementation serves both entry points (ROADMAP dedup item; the
/// k = 1 batch sweep runs the same per-column update the historical
/// scalar loop did, verified by the legacy-recurrence regression test
/// below).
pub fn cg_solve(kernel: &mut dyn Spmv, b: &[f64], max_iters: usize, tol: f64) -> CgResult {
    assert_eq!(b.len(), kernel.n());
    let bs = VecBatch::from_columns(&[b.to_vec()]);
    cg_solve_batch(kernel, &bs, max_iters, tol)
        .into_iter()
        .next()
        .expect("width-1 batch returns one result")
}

/// Multi-RHS CG: one fused [`Spmv::apply_batch`] per sweep serves all
/// `k` right-hand sides (one matrix traversal instead of `k`). Every
/// column runs its own scalar CG recurrence — step sizes, residual
/// histories, and stopping are per-column, and column `c` matches what
/// [`cg_solve`] would produce for `bs.col(c)` alone.
///
/// **Converged-column compaction:** when the active set shrinks below
/// half the current SpMV width, the working set is repacked via the
/// shared [`BatchCompactor`] (the surviving direction columns are
/// gathered into a narrower batch) so converged columns stop riding
/// the fused multiply. Per-column numerics are unchanged.
pub fn cg_solve_batch(
    kernel: &mut dyn Spmv,
    bs: &VecBatch,
    max_iters: usize,
    tol: f64,
) -> Vec<CgResult> {
    let n = kernel.n();
    assert_eq!(bs.n(), n);
    let k = bs.k();
    kernel.prepare_hint(k);

    struct Col {
        rr: f64,
        tol2: f64,
        history: Vec<f64>,
        iters: usize,
        active: bool,
    }
    let mut xs = VecBatch::zeros(n, k);
    let mut rs = bs.clone();
    let mut ps = bs.clone();
    let mut aps = VecBatch::zeros(n, k);
    let mut cols: Vec<Col> = (0..k)
        .map(|c| {
            let bb = dot(bs.col(c), bs.col(c));
            let tol2 = tol * tol * bb;
            Col { rr: bb, tol2, history: vec![bb], iters: 0, active: bb > tol2 }
        })
        .collect();

    let mut comp = BatchCompactor::new(n, k);
    let mut sweeps = 0;
    while sweeps < max_iters {
        if !comp.retain_live(kernel, |c| cols[c].active) {
            break;
        }
        comp.fused_apply(kernel, &ps, &mut aps);
        for j in 0..comp.work().len() {
            let c = comp.work()[j];
            let st = &mut cols[c];
            if !st.active {
                continue;
            }
            let ap = comp.result_col(&aps, j);
            let pap = dot(ps.col(c), ap);
            if pap <= 0.0 {
                st.active = false; // not SPD (or breakdown)
                continue;
            }
            let a = st.rr / pap;
            let xc = xs.col_mut(c);
            for (x, &p) in xc.iter_mut().zip(ps.col(c)) {
                *x += a * p;
            }
            let rc = rs.col_mut(c);
            for (r, &apv) in rc.iter_mut().zip(ap) {
                *r -= a * apv;
            }
            let rr_new = dot(rc, rc);
            let beta = rr_new / st.rr;
            let pc = ps.col_mut(c);
            for (p, &r) in pc.iter_mut().zip(rs.col(c)) {
                *p = r + beta * *p;
            }
            st.rr = rr_new;
            st.history.push(st.rr);
            st.iters += 1;
            if st.rr <= st.tol2 {
                st.active = false;
            }
        }
        sweeps += 1;
    }

    cols.into_iter()
        .enumerate()
        .map(|(c, st)| CgResult {
            x: xs.col(c).to_vec(),
            history: st.history,
            iters: st.iters,
            converged: st.rr <= st.tol2,
        })
        .collect()
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::SerialSss;
    use crate::sparse::{convert, Coo, Symmetry};

    /// SPD test matrix: diagonally dominant symmetric.
    fn spd(n: usize) -> SerialSss {
        let mut c = Coo::new(n);
        for i in 0..n as u32 {
            c.push(i, i, 4.0);
        }
        for i in 1..n as u32 {
            c.push(i, i - 1, -1.0);
            c.push(i - 1, i, -1.0);
        }
        SerialSss::new(convert::coo_to_sss(&c, Symmetry::Symmetric).unwrap())
    }

    /// The historical scalar recurrence, kept verbatim as the reference
    /// for the k = 1 delegation (deleted from the public path when
    /// `cg_solve` became `cg_solve_batch` at width 1).
    fn legacy_cg_solve(kernel: &mut dyn Spmv, b: &[f64], max_iters: usize, tol: f64) -> CgResult {
        let n = kernel.n();
        assert_eq!(b.len(), n);
        let mut x = vec![0.0f64; n];
        let mut r = b.to_vec();
        let mut p = r.clone();
        let mut ap = vec![0.0f64; n];
        let bb = dot(b, b);
        let mut rr = bb;
        let mut history = vec![rr];
        let tol2 = tol * tol * bb;
        let mut iters = 0;
        while iters < max_iters && rr > tol2 {
            kernel.apply(&p, &mut ap);
            let pap = dot(&p, &ap);
            if pap <= 0.0 {
                break;
            }
            let a = rr / pap;
            for i in 0..n {
                x[i] += a * p[i];
                r[i] -= a * ap[i];
            }
            let rr_new = dot(&r, &r);
            let beta = rr_new / rr;
            for i in 0..n {
                p[i] = r[i] + beta * p[i];
            }
            rr = rr_new;
            history.push(rr);
            iters += 1;
        }
        CgResult { x, history, iters, converged: rr <= tol2 }
    }

    #[test]
    fn scalar_solve_matches_the_legacy_recurrence() {
        for n in [80usize, 150] {
            let mut k = spd(n);
            let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 9) as f64 - 4.0).collect();
            let got = cg_solve(&mut k, &b, 500, 1e-10);
            let mut k_ref = spd(n);
            let want = legacy_cg_solve(&mut k_ref, &b, 500, 1e-10);
            assert_eq!(got.converged, want.converged);
            assert_eq!(got.iters, want.iters);
            assert_eq!(got.history.len(), want.history.len());
            for (a, c) in got.x.iter().zip(&want.x) {
                assert!((a - c).abs() < 1e-12, "{a} vs {c}");
            }
        }
    }

    #[test]
    fn solves_laplacian_like_system() {
        let mut k = spd(200);
        let b: Vec<f64> = (0..200).map(|i| ((i % 9) as f64) - 4.0).collect();
        let res = cg_solve(&mut k, &b, 500, 1e-10);
        assert!(res.converged, "iters={}", res.iters);
        let mut ax = vec![0.0; 200];
        k.apply(&res.x, &mut ax);
        let err: f64 = ax.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0, f64::max);
        assert!(err < 1e-7, "err {err}");
    }

    #[test]
    fn batch_solve_matches_independent_solves() {
        let mut k = spd(120);
        let bs = VecBatch::from_fn(120, 3, |i, c| ((i * (c + 3)) % 11) as f64 - 5.0);
        let results = cg_solve_batch(&mut k, &bs, 500, 1e-10);
        for (c, res) in results.iter().enumerate() {
            let mut k1 = spd(120);
            let want = cg_solve(&mut k1, bs.col(c), 500, 1e-10);
            assert_eq!(res.converged, want.converged, "col {c}");
            assert_eq!(res.iters, want.iters, "col {c}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-9, "col {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_solve_compaction_preserves_per_column_numerics() {
        // 5 columns, 3 zero: the active set (2) drops below half the
        // width after the first liveness check, forcing a repack.
        let mut k = spd(100);
        let mut cols = vec![vec![0.0; 100]; 5];
        cols[0] = (0..100).map(|i| ((i % 9) as f64) - 4.0).collect();
        cols[3] = (0..100).map(|i| ((i * 5) % 11) as f64 - 5.0).collect();
        let bs = VecBatch::from_columns(&cols);
        let results = cg_solve_batch(&mut k, &bs, 500, 1e-10);
        for (c, res) in results.iter().enumerate() {
            let mut k1 = spd(100);
            let want = cg_solve(&mut k1, bs.col(c), 500, 1e-10);
            assert_eq!(res.converged, want.converged, "col {c}");
            assert_eq!(res.iters, want.iters, "col {c}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-9, "col {c}: {a} vs {b}");
            }
        }
        for c in [1usize, 2, 4] {
            assert!(results[c].x.iter().all(|&v| v == 0.0), "col {c}");
        }
    }

    #[test]
    fn detects_non_spd() {
        // skew-symmetric part makes pAp = alpha*||p||^2 only; with
        // alpha<0 CG must bail out instead of diverging silently
        let mut c = Coo::new(10);
        for i in 0..10u32 {
            c.push(i, i, -1.0);
        }
        let mut k = SerialSss::new(convert::coo_to_sss(&c, Symmetry::Symmetric).unwrap());
        let res = cg_solve(&mut k, &vec![1.0; 10], 50, 1e-10);
        assert!(!res.converged);
    }
}
