//! Iterative solvers — the consumers PARS3 accelerates (paper §1).
//!
//! * [`mrs`] — minimal-residual iteration for shifted skew-symmetric
//!   systems (one SpMV + one inner product per iteration, the MRS-class
//!   budget the paper highlights).
//! * [`cg`] — Conjugate Gradient for SPD systems (the restrictive
//!   comparison point the paper mentions).
//! * [`compaction`] — shared converged-column compaction for the
//!   multi-RHS batch solvers (live-set filter, halving trigger, gather
//!   buffers).

pub mod cg;
pub mod compaction;
pub mod mrs;
pub mod mrs_krylov;

pub use compaction::BatchCompactor;
pub use mrs::{mrs_solve, mrs_solve_batch, MrsOptions, MrsResult};
pub use mrs_krylov::{mrs_krylov_solve, mrs_krylov_solve_batch, KrylovOptions};
