//! Iterative solvers — the consumers PARS3 accelerates (paper §1).
//!
//! * [`mrs`] — minimal-residual iteration for shifted skew-symmetric
//!   systems (one SpMV + one inner product per iteration, the MRS-class
//!   budget the paper highlights).
//! * [`cg`] — Conjugate Gradient for SPD systems (the restrictive
//!   comparison point the paper mentions).

pub mod cg;
pub mod mrs;
pub mod mrs_krylov;

pub use mrs::{mrs_solve, mrs_solve_batch, MrsOptions, MrsResult};
pub use mrs_krylov::{mrs_krylov_solve, mrs_krylov_solve_batch, KrylovOptions};
