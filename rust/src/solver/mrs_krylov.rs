//! Krylov MRS: full minimal-residual subspace method for shifted
//! skew-symmetric systems (Idema & Vuik 2007 / Jiang 2007 family).
//!
//! For `A = alpha*I + S` with `S = -S^T`, the Lanczos process on `S`
//! needs **no reorthogonalization against the diagonal**: `(v, S v) = 0`
//! identically, so the recurrence is two-term —
//!
//! `S v_k = beta_k v_{k+1} - beta_{k-1} v_{k-1}`
//!
//! giving a tridiagonal projected matrix `alpha*I + T` with zero
//! diagonal skew part. The residual is minimized over the whole Krylov
//! subspace by a MINRES-style QR update with Givens rotations — still
//! **one SpMV and one inner product (the norm) per iteration**, the
//! budget the paper's §1 emphasizes, but with the optimal-over-subspace
//! convergence the simple line-search iteration ([`crate::solver::mrs`])
//! lacks.

use crate::kernel::{Spmv, VecBatch};
use crate::solver::mrs::MrsResult;

/// Options for [`mrs_krylov_solve`].
#[derive(Debug, Clone)]
pub struct KrylovOptions {
    /// Shift `alpha`.
    pub alpha: f64,
    /// Maximum iterations.
    pub max_iters: usize,
    /// Relative residual tolerance.
    pub tol: f64,
}

impl Default for KrylovOptions {
    fn default() -> Self {
        Self { alpha: 1.0, max_iters: 1000, tol: 1e-10 }
    }
}

/// Solve `(alpha*I + S) x = b` where `kernel` applies the *full* A.
///
/// Internally applies `S v = A v - alpha v` so the Lanczos vectors see
/// the pure skew part.
///
/// This is [`mrs_krylov_solve_batch`] at width 1: the per-column state
/// of the batch recurrence is exactly the scalar recurrence, so one
/// maintained implementation serves both (the scalar numerics are
/// pinned against a frozen copy of the original loop in the tests).
pub fn mrs_krylov_solve(kernel: &mut dyn Spmv, b: &[f64], opts: &KrylovOptions) -> MrsResult {
    let bs = VecBatch::from_columns(&[b.to_vec()]);
    mrs_krylov_solve_batch(kernel, &bs, opts)
        .into_iter()
        .next()
        .expect("width-1 batch returns exactly one result")
}

/// Multi-RHS Krylov MRS: each column runs its own two-term skew
/// Lanczos + Givens recurrence (scalars per column), but every sweep
/// performs **one fused [`Spmv::apply_batch`]** over the `k` Lanczos
/// vectors — the matrix is traversed once per sweep, not once per RHS.
/// Column `c` matches [`mrs_krylov_solve`] run on `bs.col(c)` alone.
pub fn mrs_krylov_solve_batch(
    kernel: &mut dyn Spmv,
    bs: &VecBatch,
    opts: &KrylovOptions,
) -> Vec<MrsResult> {
    let n = kernel.n();
    assert_eq!(bs.n(), n);
    let k = bs.k();
    kernel.prepare_hint(k);

    struct Col {
        beta_prev: f64,
        c_prev: f64,
        s_prev: f64,
        c_pprev: f64,
        s_pprev: f64,
        phi_bar: f64,
        tol_abs: f64,
        history: Vec<f64>,
        iters: usize,
        active: bool,
    }
    let mut v_prev = VecBatch::zeros(n, k);
    let mut vs = VecBatch::zeros(n, k);
    let mut w1 = VecBatch::zeros(n, k);
    let mut w2 = VecBatch::zeros(n, k);
    let mut xs = VecBatch::zeros(n, k);
    let mut avs = VecBatch::zeros(n, k);
    let mut cols: Vec<Col> = (0..k)
        .map(|c| {
            let bnorm = norm(bs.col(c));
            if bnorm > 0.0 {
                let vc = vs.col_mut(c);
                for (v, &b) in vc.iter_mut().zip(bs.col(c)) {
                    *v = b / bnorm;
                }
            }
            Col {
                beta_prev: 0.0,
                c_prev: 1.0,
                s_prev: 0.0,
                c_pprev: 1.0,
                s_pprev: 0.0,
                phi_bar: bnorm,
                tol_abs: opts.tol * bnorm,
                history: vec![bnorm * bnorm],
                iters: 0,
                active: bnorm > 0.0,
            }
        })
        .collect();

    let mut sweeps = 0;
    while sweeps < opts.max_iters && cols.iter().any(|c| c.active && c.phi_bar.abs() > c.tol_abs)
    {
        kernel.apply_batch(&vs, &mut avs); // one fused SpMV per sweep
        for (c, st) in cols.iter_mut().enumerate() {
            if !st.active || st.phi_bar.abs() <= st.tol_abs {
                continue;
            }
            let av = avs.col_mut(c);
            // S v = A v - alpha v, then the two-term skew recurrence
            for ((a, &v), &vp) in av.iter_mut().zip(vs.col(c)).zip(v_prev.col(c)) {
                *a = *a - opts.alpha * v + st.beta_prev * vp;
            }
            let beta = norm(av);
            let tau = st.s_pprev * (-st.beta_prev);
            let mid = st.c_pprev * (-st.beta_prev);
            let delta = st.c_prev * mid + st.s_prev * opts.alpha;
            let gamma = -st.s_prev * mid + st.c_prev * opts.alpha;
            let rho = (gamma * gamma + beta * beta).sqrt();
            let (cr, sr) = if rho == 0.0 { (1.0, 0.0) } else { (gamma / rho, beta / rho) };

            if rho > f64::MIN_POSITIVE {
                let w1c = w1.col_mut(c);
                let w2c = w2.col_mut(c);
                for ((w1v, w2v), &v) in w1c.iter_mut().zip(w2c.iter_mut()).zip(vs.col(c)) {
                    let w_new = (v - delta * *w1v - tau * *w2v) / rho;
                    *w2v = *w1v;
                    *w1v = w_new;
                }
                let step = cr * st.phi_bar;
                let xc = xs.col_mut(c);
                for (x, &w) in xc.iter_mut().zip(w1.col(c)) {
                    *x += step * w;
                }
            }
            st.phi_bar = -sr * st.phi_bar;
            st.history.push(st.phi_bar * st.phi_bar);

            if beta > 0.0 {
                let vp = v_prev.col_mut(c);
                let vc = vs.col_mut(c);
                for ((pv, v), &a) in vp.iter_mut().zip(vc.iter_mut()).zip(av.iter()) {
                    *pv = *v;
                    *v = a / beta;
                }
            }
            st.beta_prev = beta;
            st.c_pprev = st.c_prev;
            st.s_pprev = st.s_prev;
            st.c_prev = cr;
            st.s_prev = sr;
            st.iters += 1;
            if beta == 0.0 {
                st.active = false; // invariant subspace found: exact solve
            }
        }
        sweeps += 1;
    }

    // true residuals, one fused multiply for the whole batch
    kernel.apply_batch(&xs, &mut avs);
    cols.into_iter()
        .enumerate()
        .map(|(c, st)| {
            let r: Vec<f64> =
                bs.col(c).iter().zip(avs.col(c)).map(|(b, a)| b - a).collect();
            let rn = norm(&r);
            MrsResult {
                x: xs.col(c).to_vec(),
                converged: rn <= st.tol_abs * 1.5,
                r,
                history: st.history,
                iters: st.iters,
            }
        })
        .collect()
}

#[inline]
fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::SerialSss;
    use crate::solver::mrs::{mrs_solve, MrsOptions};
    use crate::sparse::{convert, gen, Symmetry};

    fn system(n: usize, seed: u64, alpha: f64) -> (SerialSss, Vec<f64>) {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        (SerialSss::new(sss), b)
    }

    #[test]
    fn solves_shifted_system_accurately() {
        let (mut k, b) = system(150, 1, 2.0);
        let res = mrs_krylov_solve(
            &mut k,
            &b,
            &KrylovOptions { alpha: 2.0, max_iters: 400, tol: 1e-10 },
        );
        assert!(res.converged, "iters={}", res.iters);
        let mut ax = vec![0.0; 150];
        k.apply(&res.x, &mut ax);
        let err = ax.iter().zip(&b).map(|(a, c)| (a - c).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn residual_estimate_is_monotone() {
        let (mut k, b) = system(120, 2, 1.0);
        let res = mrs_krylov_solve(
            &mut k,
            &b,
            &KrylovOptions { alpha: 1.0, max_iters: 60, tol: 0.0 },
        );
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12));
        }
    }

    #[test]
    fn converges_no_slower_than_line_search_mrs() {
        // optimal-over-subspace must need <= iterations of the simple
        // minimal-residual line search for the same tolerance
        let (mut k1, b) = system(200, 3, 1.5);
        let (mut k2, _) = system(200, 3, 1.5);
        let tol = 1e-8;
        let res_ls = mrs_solve(&mut k1, &b, &MrsOptions { alpha: 1.5, max_iters: 3000, tol });
        let res_kr = mrs_krylov_solve(
            &mut k2,
            &b,
            &KrylovOptions { alpha: 1.5, max_iters: 3000, tol },
        );
        assert!(res_ls.converged && res_kr.converged);
        assert!(
            res_kr.iters <= res_ls.iters,
            "krylov {} vs line-search {}",
            res_kr.iters,
            res_ls.iters
        );
    }

    #[test]
    fn batch_solve_matches_independent_solves() {
        let (mut k, _) = system(130, 6, 2.0);
        let opts = KrylovOptions { alpha: 2.0, max_iters: 500, tol: 1e-9 };
        let bs = VecBatch::from_fn(130, 3, |i, c| ((i * 17 + c * 5) % 13) as f64 * 0.5 - 3.0);
        let results = mrs_krylov_solve_batch(&mut k, &bs, &opts);
        for (c, res) in results.iter().enumerate() {
            let (mut k1, _) = system(130, 6, 2.0);
            let want = mrs_krylov_solve(&mut k1, bs.col(c), &opts);
            assert_eq!(res.converged, want.converged, "col {c}");
            assert_eq!(res.iters, want.iters, "col {c}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-8, "col {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_zero_column_is_immediate_and_exact() {
        let (mut k, b) = system(60, 7, 1.0);
        let opts = KrylovOptions { alpha: 1.0, max_iters: 300, tol: 1e-9 };
        let bs = VecBatch::from_columns(&[vec![0.0; 60], b]);
        let results = mrs_krylov_solve_batch(&mut k, &bs, &opts);
        assert!(results[0].converged);
        assert_eq!(results[0].iters, 0);
        assert!(results[0].x.iter().all(|&v| v == 0.0));
        assert!(results[1].converged, "iters={}", results[1].iters);
    }

    #[test]
    fn zero_rhs_immediate() {
        let (mut k, _) = system(50, 4, 1.0);
        let res = mrs_krylov_solve(&mut k, &vec![0.0; 50], &KrylovOptions::default());
        assert!(res.converged);
        assert_eq!(res.iters, 0);
    }

    /// The original scalar Krylov MRS loop, frozen verbatim when the
    /// public entry point became a width-1 delegation to
    /// [`mrs_krylov_solve_batch`]. Exists only to pin the delegated
    /// numerics bit-for-bit (well, to 1e-12) against the legacy code.
    fn legacy_mrs_krylov_solve(
        kernel: &mut dyn Spmv,
        b: &[f64],
        opts: &KrylovOptions,
    ) -> MrsResult {
        let n = kernel.n();
        assert_eq!(b.len(), n);
        let bnorm = norm(b);
        let mut history = vec![bnorm * bnorm];
        if bnorm == 0.0 {
            return MrsResult {
                x: vec![0.0; n],
                r: vec![0.0; n],
                history,
                iters: 0,
                converged: true,
            };
        }

        // Lanczos vectors (two-term recurrence for skew S)
        let mut v_prev = vec![0.0f64; n];
        let mut v = b.iter().map(|&x| x / bnorm).collect::<Vec<_>>();
        let mut beta_prev = 0.0f64;

        // MINRES-style solution update vectors
        let mut w1 = vec![0.0f64; n]; // w_{k-1}
        let mut w2 = vec![0.0f64; n]; // w_{k-2}
        let mut x = vec![0.0f64; n];

        // Givens rotation state (two trailing rotations affect each column)
        let (mut c_prev, mut s_prev) = (1.0f64, 0.0f64);
        let (mut c_pprev, mut s_pprev) = (1.0f64, 0.0f64);
        let mut phi_bar = bnorm; // *signed* residual carry (|phi_bar| = ||r||)
        let mut av = vec![0.0f64; n];
        let mut iters = 0;
        let tol_abs = opts.tol * bnorm;

        while iters < opts.max_iters && phi_bar.abs() > tol_abs {
            // S v = A v - alpha v  (one SpMV)
            kernel.apply(&v, &mut av);
            for i in 0..n {
                av[i] -= opts.alpha * v[i];
            }
            // two-term skew Lanczos: u = S v + beta_prev * v_prev
            // (note the +: S^T = -S makes the usual minus a plus)
            for i in 0..n {
                av[i] += beta_prev * v_prev[i];
            }
            let beta = norm(&av); // the one inner product
            // column k of (alpha*I + T): [ -beta_prev (super), alpha (diag),
            // beta (sub) ]; apply the two trailing rotations G_{k-2}, G_{k-1}
            let tau = s_pprev * (-beta_prev); // fill-in two rows above
            let mid = c_pprev * (-beta_prev);
            let delta = c_prev * mid + s_prev * opts.alpha; // one row above
            let gamma = -s_prev * mid + c_prev * opts.alpha; // diagonal
            // new rotation annihilating the subdiagonal beta
            let rho = (gamma * gamma + beta * beta).sqrt();
            let (c, s) = if rho == 0.0 { (1.0, 0.0) } else { (gamma / rho, beta / rho) };

            // solution direction from R's 3-nonzero column (tau, delta, rho)
            if rho > f64::MIN_POSITIVE {
                for i in 0..n {
                    let w_new = (v[i] - delta * w1[i] - tau * w2[i]) / rho;
                    w2[i] = w1[i];
                    w1[i] = w_new;
                }
                // x += c * phi_bar * w  (signed carry — the MINRES update)
                let step = c * phi_bar;
                for i in 0..n {
                    x[i] += step * w1[i];
                }
            }
            phi_bar = -s * phi_bar;
            history.push(phi_bar * phi_bar);

            // advance Lanczos
            if beta > 0.0 {
                for i in 0..n {
                    let next = av[i] / beta;
                    v_prev[i] = v[i];
                    v[i] = next;
                }
            }
            beta_prev = beta;
            c_pprev = c_prev;
            s_pprev = s_prev;
            c_prev = c;
            s_prev = s;
            iters += 1;
            if beta == 0.0 {
                break; // invariant subspace found: exact solve
            }
        }

        // true residual
        kernel.apply(&x, &mut av);
        let r: Vec<f64> = b.iter().zip(&av).map(|(b, a)| b - a).collect();
        let rn = norm(&r);
        MrsResult { x, converged: rn <= tol_abs * 1.5, r, history, iters }
    }

    #[test]
    fn scalar_solve_matches_the_legacy_recurrence() {
        // the width-1 delegation must reproduce the frozen original
        // loop exactly: same iteration count, same convergence flag,
        // same residual history, solutions within 1e-12
        for (n, seed, alpha) in [(150usize, 1u64, 2.0f64), (120, 2, 1.0), (95, 8, 3.5)] {
            let (mut k_new, b) = system(n, seed, alpha);
            let (mut k_old, _) = system(n, seed, alpha);
            let opts = KrylovOptions { alpha, max_iters: 400, tol: 1e-10 };
            let got = mrs_krylov_solve(&mut k_new, &b, &opts);
            let want = legacy_mrs_krylov_solve(&mut k_old, &b, &opts);
            assert_eq!(got.iters, want.iters, "n={n} seed={seed}");
            assert_eq!(got.converged, want.converged, "n={n} seed={seed}");
            assert_eq!(got.history.len(), want.history.len(), "n={n} seed={seed}");
            for (a, b) in got.history.iter().zip(&want.history) {
                assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()), "history {a} vs {b}");
            }
            for (a, b) in got.x.iter().zip(&want.x) {
                assert!((a - b).abs() <= 1e-12, "x {a} vs {b}");
            }
            for (a, b) in got.r.iter().zip(&want.r) {
                assert!((a - b).abs() <= 1e-12, "r {a} vs {b}");
            }
        }
    }

    #[test]
    fn works_with_pars3_kernel() {
        let coo = gen::small_test_matrix(180, 5, 2.5);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let sss = convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap();
        let split = crate::kernel::Split3::with_outer_bw(&sss, 3).unwrap();
        let mut k = crate::kernel::pars3::Pars3Kernel::new(split, 6, false).unwrap();
        let b: Vec<f64> = (0..180).map(|i| (i as f64 * 0.11).sin()).collect();
        let res = mrs_krylov_solve(
            &mut k,
            &b,
            &KrylovOptions { alpha: 2.5, max_iters: 400, tol: 1e-9 },
        );
        assert!(res.converged, "iters={}", res.iters);
    }
}
