//! Minimal-residual iteration for shifted skew-symmetric systems
//! (MRS-class; Idema & Vuik 2007 / Jiang 2007 family).
//!
//! For `A = alpha*I + S`, `S = -S^T`, the line search that minimizes
//! `||r - a A r||` has the closed form `a = (r, Ar)/(Ar, Ar)` with
//! `(r, Ar) = alpha ||r||^2` — the skew part drops out of the numerator
//! because `(r, Sr) = 0`. Each iteration therefore costs exactly **one
//! SpMV and one extra inner product** (`||Ar||^2`; `||r||^2` is carried
//! over), which is the property the paper's §1 singles out: the SpMV
//! dominates, so accelerating it accelerates the whole solver.
//!
//! Mirrors `python/compile/model.py::mrs_step` — the Rust-native solver
//! and the AOT/PJRT artifact execute the same recurrence, and the
//! integration tests cross-check them.

use crate::kernel::{Spmv, VecBatch};
use crate::solver::compaction::BatchCompactor;

/// Options for [`mrs_solve`].
#[derive(Debug, Clone, PartialEq)]
pub struct MrsOptions {
    /// Shift `alpha` (must be nonzero for convergence).
    pub alpha: f64,
    /// Maximum iterations.
    pub max_iters: usize,
    /// Relative residual tolerance `||r|| / ||b||`.
    pub tol: f64,
}

impl Default for MrsOptions {
    fn default() -> Self {
        Self { alpha: 1.0, max_iters: 1000, tol: 1e-8 }
    }
}

/// Solve result.
#[derive(Debug, Clone, PartialEq)]
pub struct MrsResult {
    /// Final iterate.
    pub x: Vec<f64>,
    /// Final residual vector.
    pub r: Vec<f64>,
    /// `||r_k||^2` per iteration (index 0 = initial residual).
    pub history: Vec<f64>,
    /// Iterations performed.
    pub iters: usize,
    /// Converged within tolerance?
    pub converged: bool,
}

/// Run the minimal-residual iteration with any [`Spmv`] kernel.
///
/// The kernel must apply the *full* `A = alpha*I + S` (the diagonal
/// split carries the shift after preprocessing).
///
/// This is [`mrs_solve_batch`] at width 1 — one recurrence
/// implementation serves both entry points (ROADMAP dedup item; the
/// k = 1 batch sweep runs the same per-column update the historical
/// scalar loop did, verified by the legacy-recurrence regression test
/// below).
pub fn mrs_solve(kernel: &mut dyn Spmv, b: &[f64], opts: &MrsOptions) -> MrsResult {
    assert_eq!(b.len(), kernel.n());
    let bs = VecBatch::from_columns(&[b.to_vec()]);
    mrs_solve_batch(kernel, &bs, opts)
        .into_iter()
        .next()
        .expect("width-1 batch returns one result")
}

/// Multi-RHS minimal-residual iteration: solve `A x_c = b_c` for every
/// column of `bs` **with one fused SpMV per sweep** — each sweep calls
/// [`Spmv::apply_batch`] once, so the matrix is traversed (and, for
/// `pars3`, halos exchanged) once for all `k` right-hand sides instead
/// of once per RHS. Each column keeps its own line-search step,
/// residual history, and stopping decision; columns that converge stop
/// updating while the rest continue. Column `c` of the result is
/// numerically the same iteration [`mrs_solve`] would run on `b_c`
/// alone.
///
/// **Converged-column compaction:** when the active set shrinks below
/// half the current SpMV width, the working set is repacked (via the
/// shared [`BatchCompactor`]) so converged columns stop riding the
/// fused multiply (their `2k`-wide multiply-accumulates per matrix
/// entry are pure waste). Repacking gathers the surviving residual
/// columns into a narrower batch before each sweep; per-column
/// numerics are unchanged.
pub fn mrs_solve_batch(
    kernel: &mut dyn Spmv,
    bs: &VecBatch,
    opts: &MrsOptions,
) -> Vec<MrsResult> {
    let n = kernel.n();
    assert_eq!(bs.n(), n);
    let k = bs.k();
    kernel.prepare_hint(k);

    struct Col {
        rr: f64,
        tol2: f64,
        history: Vec<f64>,
        iters: usize,
        active: bool,
    }
    let mut xs = VecBatch::zeros(n, k);
    let mut rs = bs.clone();
    let mut ps = VecBatch::zeros(n, k);
    let mut cols: Vec<Col> = (0..k)
        .map(|c| {
            let bb = dot(bs.col(c), bs.col(c));
            let tol2 = opts.tol * opts.tol * bb;
            Col { rr: bb, tol2, history: vec![bb], iters: 0, active: bb > tol2 }
        })
        .collect();

    let mut comp = BatchCompactor::new(n, k);
    let mut sweeps = 0;
    while sweeps < opts.max_iters {
        if !comp.retain_live(kernel, |c| cols[c].active) {
            break;
        }
        comp.fused_apply(kernel, &rs, &mut ps); // the one fused hot-path SpMV
        for j in 0..comp.work().len() {
            let c = comp.work()[j];
            let st = &mut cols[c];
            if !st.active {
                continue;
            }
            let p = comp.result_col(&ps, j);
            let pp = dot(p, p);
            if pp <= f64::MIN_POSITIVE {
                st.active = false;
                continue;
            }
            let a = opts.alpha * st.rr / pp;
            let xc = xs.col_mut(c);
            for (x, &r) in xc.iter_mut().zip(rs.col(c)) {
                *x += a * r;
            }
            let rc = rs.col_mut(c);
            for (r, &pv) in rc.iter_mut().zip(p) {
                *r -= a * pv;
            }
            st.rr = dot(rc, rc);
            st.history.push(st.rr);
            st.iters += 1;
            if st.rr <= st.tol2 {
                st.active = false;
            }
        }
        sweeps += 1;
    }

    cols.into_iter()
        .enumerate()
        .map(|(c, st)| MrsResult {
            x: xs.col(c).to_vec(),
            r: rs.col(c).to_vec(),
            converged: st.rr <= st.tol2,
            history: st.history,
            iters: st.iters,
        })
        .collect()
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::SerialSss;
    use crate::sparse::{convert, gen, Symmetry};

    fn system(n: usize, seed: u64, alpha: f64) -> (SerialSss, Vec<f64>) {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        (SerialSss::new(sss), b)
    }

    /// The historical scalar recurrence, kept verbatim as the reference
    /// for the k = 1 delegation (deleted from the public path when
    /// `mrs_solve` became `mrs_solve_batch` at width 1).
    fn legacy_mrs_solve(kernel: &mut dyn Spmv, b: &[f64], opts: &MrsOptions) -> MrsResult {
        let n = kernel.n();
        assert_eq!(b.len(), n);
        let mut x = vec![0.0f64; n];
        let mut r = b.to_vec();
        let mut p = vec![0.0f64; n];
        let bb: f64 = dot(b, b);
        let mut rr = bb;
        let mut history = vec![rr];
        let tol2 = opts.tol * opts.tol * bb;
        let mut iters = 0;
        while iters < opts.max_iters && rr > tol2 {
            kernel.apply(&r, &mut p);
            let pp = dot(&p, &p);
            if pp <= f64::MIN_POSITIVE {
                break;
            }
            let a = opts.alpha * rr / pp;
            for i in 0..n {
                x[i] += a * r[i];
                r[i] -= a * p[i];
            }
            rr = dot(&r, &r);
            history.push(rr);
            iters += 1;
        }
        MrsResult { x, r, converged: rr <= tol2, history, iters }
    }

    #[test]
    fn scalar_solve_matches_the_legacy_recurrence() {
        // the k = 1 delegation must reproduce the historical scalar
        // path: same iteration count, same history, same iterate
        for (n, seed, alpha) in [(90usize, 11u64, 2.0f64), (140, 12, 3.5), (60, 13, 1.2)] {
            let (mut k, b) = system(n, seed, alpha);
            let opts = MrsOptions { alpha, max_iters: 600, tol: 1e-9 };
            let got = mrs_solve(&mut k, &b, &opts);
            let (mut k_ref, _) = system(n, seed, alpha);
            let want = legacy_mrs_solve(&mut k_ref, &b, &opts);
            assert_eq!(got.converged, want.converged);
            assert_eq!(got.iters, want.iters);
            assert_eq!(got.history.len(), want.history.len());
            for (a, c) in got.x.iter().zip(&want.x) {
                assert!((a - c).abs() < 1e-12, "{a} vs {c}");
            }
            for (a, c) in got.r.iter().zip(&want.r) {
                assert!((a - c).abs() < 1e-12, "{a} vs {c}");
            }
        }
    }

    #[test]
    fn residual_is_monotone() {
        let (mut k, b) = system(120, 1, 1.5);
        let res = mrs_solve(&mut k, &b, &MrsOptions { alpha: 1.5, max_iters: 50, tol: 0.0 });
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12), "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn solves_well_shifted_system() {
        let (mut k, b) = system(100, 2, 4.0);
        let opts = MrsOptions { alpha: 4.0, max_iters: 2000, tol: 1e-10 };
        let res = mrs_solve(&mut k, &b, &opts);
        assert!(res.converged, "iters={} rr={}", res.iters, res.history.last().unwrap());
        // verify residual against a fresh multiply
        let mut ax = vec![0.0; 100];
        k.apply(&res.x, &mut ax);
        let err: f64 = ax.iter().zip(&b).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let bn: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(err / bn < 1e-9, "rel err {}", err / bn);
    }

    #[test]
    fn larger_shift_converges_faster() {
        let (mut k1, b) = system(100, 3, 1.0);
        let (mut k4, _) = system(100, 3, 4.0);
        let r1 = mrs_solve(&mut k1, &b, &MrsOptions { alpha: 1.0, max_iters: 40, tol: 0.0 });
        let r4 = mrs_solve(&mut k4, &b, &MrsOptions { alpha: 4.0, max_iters: 40, tol: 0.0 });
        let f1 = r1.history.last().unwrap() / r1.history[0];
        let f4 = r4.history.last().unwrap() / r4.history[0];
        assert!(f4 < f1, "alpha=4 {f4} vs alpha=1 {f1}");
    }

    #[test]
    fn pars3_kernel_converges_same_as_serial() {
        // the paper's end-to-end story: swap the kernel, same math.
        // The matrix is Arc-shared between the two kernels — no clone.
        let coo = gen::small_test_matrix(150, 4, 2.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let sss = std::sync::Arc::new(
            convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap(),
        );
        let b: Vec<f64> = (0..150).map(|i| (i as f64 * 0.13).cos()).collect();
        let opts = MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 };

        let mut serial = SerialSss::new(sss.clone());
        let rs = mrs_solve(&mut serial, &b, &opts);

        let split = crate::kernel::Split3::with_outer_bw(&sss, 3).unwrap();
        let mut par = crate::kernel::pars3::Pars3Kernel::new(split, 5, false).unwrap();
        let rp = mrs_solve(&mut par, &b, &opts);

        assert_eq!(rs.converged, rp.converged);
        for (a, c) in rs.x.iter().zip(&rp.x) {
            assert!((a - c).abs() < 1e-6, "{a} vs {c}");
        }
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let (mut k, _) = system(50, 5, 1.0);
        let res = mrs_solve(&mut k, &vec![0.0; 50], &MrsOptions::default());
        assert!(res.converged);
        assert_eq!(res.iters, 0);
    }

    #[test]
    fn batch_solve_matches_independent_solves() {
        let (mut k, _) = system(100, 6, 2.5);
        let opts = MrsOptions { alpha: 2.5, max_iters: 500, tol: 1e-9 };
        let bs = VecBatch::from_fn(100, 3, |i, c| ((i * (c + 2) + 5) % 7) as f64 - 3.0);
        let results = mrs_solve_batch(&mut k, &bs, &opts);
        assert_eq!(results.len(), 3);
        for (c, res) in results.iter().enumerate() {
            let (mut k1, _) = system(100, 6, 2.5);
            let want = mrs_solve(&mut k1, bs.col(c), &opts);
            assert_eq!(res.converged, want.converged, "col {c}");
            assert_eq!(res.iters, want.iters, "col {c}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-9, "col {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_solve_compaction_preserves_per_column_numerics() {
        // 6 columns, 4 of them zero: after sweep 0 only 2 are active
        // (2*2 <= 6), so the working set compacts to width 2 — every
        // column must still match its independent solve exactly.
        let (mut k, b) = system(90, 8, 2.0);
        let opts = MrsOptions { alpha: 2.0, max_iters: 500, tol: 1e-9 };
        let mut cols = vec![vec![0.0; 90]; 6];
        cols[1] = b.clone();
        cols[4] = (0..90).map(|i| ((i * 3) % 7) as f64 - 3.0).collect();
        let bs = VecBatch::from_columns(&cols);
        let results = mrs_solve_batch(&mut k, &bs, &opts);
        assert_eq!(results.len(), 6);
        for (c, res) in results.iter().enumerate() {
            let (mut k1, _) = system(90, 8, 2.0);
            let want = mrs_solve(&mut k1, bs.col(c), &opts);
            assert_eq!(res.converged, want.converged, "col {c}");
            assert_eq!(res.iters, want.iters, "col {c}");
            assert_eq!(res.history.len(), want.history.len(), "col {c}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-9, "col {c}: {a} vs {b}");
            }
        }
        // the zero columns stayed untouched through the repacks
        for c in [0usize, 2, 3, 5] {
            assert!(results[c].x.iter().all(|&v| v == 0.0), "col {c}");
            assert_eq!(results[c].iters, 0, "col {c}");
        }
    }

    #[test]
    fn batch_solve_with_a_zero_column_leaves_it_untouched() {
        let (mut k, b) = system(60, 7, 1.5);
        let opts = MrsOptions { alpha: 1.5, max_iters: 400, tol: 1e-8 };
        let bs = VecBatch::from_columns(&[b, vec![0.0; 60]]);
        let results = mrs_solve_batch(&mut k, &bs, &opts);
        assert!(results[0].converged && results[0].iters > 0);
        assert!(results[1].converged);
        assert_eq!(results[1].iters, 0);
        assert!(results[1].x.iter().all(|&v| v == 0.0));
    }
}
