//! Converged-column compaction for multi-RHS solvers.
//!
//! Batch solvers ([`crate::solver::mrs::mrs_solve_batch`],
//! [`crate::solver::cg::cg_solve_batch`]) run one fused SpMV per sweep
//! across all `k` right-hand sides. Once columns converge they are pure
//! waste in that multiply — every matrix entry still drives
//! multiply-accumulates for them — so the solvers maintain a *working
//! set* of original column indices and, when the live set shrinks below
//! half the current SpMV width, repack the surviving columns into a
//! narrower batch. This module is that shared mechanism (previously
//! duplicated in both solvers): live-set filtering, the halving
//! trigger, the gather buffers, and the result-column mapping.
//! Per-column numerics are unchanged by construction — only fully
//! inactive columns are dropped from the multiply.

use crate::kernel::{Spmv, VecBatch};

/// Working-set manager for one batch solve: tracks which original
/// columns still ride the fused SpMV and owns the gather/result buffers
/// used once the set has been compacted.
pub struct BatchCompactor {
    n: usize,
    /// Full batch width `k` (the uncompacted SpMV width).
    width: usize,
    /// Original column indices still riding the fused multiply, in
    /// sweep order.
    work: Vec<usize>,
    /// Gathered input columns (compacted mode only).
    src_c: VecBatch,
    /// Fused-multiply output for the gathered columns.
    dst_c: VecBatch,
}

impl BatchCompactor {
    /// Start with all `k` columns in the working set.
    pub fn new(n: usize, k: usize) -> Self {
        Self {
            n,
            width: k,
            work: (0..k).collect(),
            src_c: VecBatch::zeros(n, 0),
            dst_c: VecBatch::zeros(n, 0),
        }
    }

    /// The working set: original column indices, in the order
    /// [`Self::result_col`] expects its `j` position argument.
    pub fn work(&self) -> &[usize] {
        &self.work
    }

    /// Whether the working set has been repacked below the full width
    /// (i.e. [`Self::fused_apply`] gathers into the narrow buffers).
    pub fn is_compacted(&self) -> bool {
        self.work.len() < self.width
    }

    /// Filter the working set down to columns `active(c)` reports live.
    /// Returns `false` when nothing is live (the solve is done). When
    /// the live set drops to half the current SpMV width or less, the
    /// working set is repacked: the kernel is re-hinted at the narrow
    /// width and the gather buffers are resized, so converged columns
    /// stop riding the fused multiply.
    pub fn retain_live(
        &mut self,
        kernel: &mut dyn Spmv,
        active: impl Fn(usize) -> bool,
    ) -> bool {
        let live: Vec<usize> = self.work.iter().copied().filter(|&c| active(c)).collect();
        if live.is_empty() {
            return false;
        }
        if live.len() * 2 <= self.work.len() && live.len() < self.work.len() {
            self.work = live;
            kernel.prepare_hint(self.work.len());
            self.src_c = VecBatch::zeros(self.n, self.work.len());
            self.dst_c = VecBatch::zeros(self.n, self.work.len());
        }
        true
    }

    /// One fused sweep over the working set: `dst = A · src` restricted
    /// to the working columns. Uncompacted, this is a single full-width
    /// `apply_batch(src, dst)`; compacted, the surviving `src` columns
    /// are gathered into the narrow buffer first and the result lands
    /// in the internal output buffer (read it via [`Self::result_col`]).
    pub fn fused_apply(&mut self, kernel: &mut dyn Spmv, src: &VecBatch, dst: &mut VecBatch) {
        if self.is_compacted() {
            for (j, &c) in self.work.iter().enumerate() {
                self.src_c.col_mut(j).copy_from_slice(src.col(c));
            }
            kernel.apply_batch(&self.src_c, &mut self.dst_c);
        } else {
            kernel.apply_batch(src, dst);
        }
    }

    /// The multiply result for working-set position `j` (original
    /// column `self.work()[j]`), reading from whichever buffer the last
    /// [`Self::fused_apply`] wrote.
    pub fn result_col<'a>(&'a self, dst: &'a VecBatch, j: usize) -> &'a [f64] {
        if self.is_compacted() {
            self.dst_c.col(j)
        } else {
            dst.col(self.work[j])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Records batch widths and hints; `y = 2x` per column.
    struct Probe {
        n: usize,
        widths: Vec<usize>,
        hints: Vec<usize>,
    }

    impl Spmv for Probe {
        fn n(&self) -> usize {
            self.n
        }
        fn apply(&mut self, x: &[f64], y: &mut [f64]) {
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi = 2.0 * xi;
            }
        }
        fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
            self.widths.push(xs.k());
            for c in 0..xs.k() {
                let (x, y) = (xs.col(c).to_vec(), ys.col_mut(c));
                for (yi, xi) in y.iter_mut().zip(&x) {
                    *yi = 2.0 * xi;
                }
            }
        }
        fn prepare_hint(&mut self, k: usize) {
            self.hints.push(k);
        }
        fn flops(&self) -> u64 {
            0
        }
        fn bytes(&self) -> u64 {
            0
        }
        fn name(&self) -> &'static str {
            "probe"
        }
    }

    #[test]
    fn full_width_sweeps_until_the_halving_trigger() {
        let n = 4;
        let mut k = Probe { n, widths: Vec::new(), hints: Vec::new() };
        let mut comp = BatchCompactor::new(n, 6);
        let src = VecBatch::from_fn(n, 6, |i, c| (i + 10 * c) as f64);
        let mut dst = VecBatch::zeros(n, 6);

        // all live: full-width multiply, results read from `dst`
        assert!(comp.retain_live(&mut k, |_| true));
        assert!(!comp.is_compacted());
        comp.fused_apply(&mut k, &src, &mut dst);
        assert_eq!(k.widths, vec![6]);
        for j in 0..6 {
            assert_eq!(comp.work()[j], j);
            assert_eq!(comp.result_col(&dst, j), dst.col(j));
        }

        // 4 of 6 live: above half, NO repack yet (4*2 > 6)
        let live4 = [true, true, false, true, false, true];
        assert!(comp.retain_live(&mut k, |c| live4[c]));
        assert!(!comp.is_compacted());
        assert_eq!(comp.work().len(), 6, "inactive columns still ride until the halving point");

        // 3 of 6 live: exactly half -> repack to width 3
        let live3 = [true, false, false, true, false, true];
        assert!(comp.retain_live(&mut k, |c| live3[c]));
        assert!(comp.is_compacted());
        assert_eq!(comp.work(), &[0, 3, 5]);
        assert_eq!(k.hints, vec![3], "kernel re-hinted at the narrow width");

        // compacted sweep: gathers cols 0,3,5 and multiplies width 3
        comp.fused_apply(&mut k, &src, &mut dst);
        assert_eq!(k.widths, vec![6, 3]);
        for (j, &c) in [0usize, 3, 5].iter().enumerate() {
            let got = comp.result_col(&dst, j);
            let want: Vec<f64> = src.col(c).iter().map(|v| 2.0 * v).collect();
            assert_eq!(got, &want[..], "gathered col {c} at position {j}");
        }
    }

    #[test]
    fn compaction_halves_again_and_stops_when_dry() {
        let n = 3;
        let mut k = Probe { n, widths: Vec::new(), hints: Vec::new() };
        let mut comp = BatchCompactor::new(n, 8);
        // 8 -> 4 (half) -> 2 (half of 4) -> done
        assert!(comp.retain_live(&mut k, |c| c < 4));
        assert_eq!(comp.work(), &[0, 1, 2, 3]);
        assert!(comp.retain_live(&mut k, |c| c < 2));
        assert_eq!(comp.work(), &[0, 1]);
        assert_eq!(k.hints, vec![4, 2]);
        assert!(!comp.retain_live(&mut k, |_| false), "no live columns ends the solve");
    }
}
