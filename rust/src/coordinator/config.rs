//! Coordinator configuration.
//!
//! Parsed from a minimal `key = value` TOML subset (the offline
//! environment has no `toml`/`serde`; see DESIGN.md §2). Unknown keys
//! are rejected so typos fail loudly. Example:
//!
//! ```text
//! # pars3.toml
//! scale = 0.25
//! alpha = 2.0
//! outer_bw = 3
//! ranks = [1, 2, 4, 8, 16, 32, 64]
//! artifacts_dir = "artifacts"
//! threaded = false
//! format = "auto"
//! reorder = "auto"
//! reorder_min_gain = 0.0
//! l2_kib = 256
//! backend = "auto"
//! plan = "auto"
//! plan_probe = 0
//! prepare_threads = 4
//! shards = 2
//! queue_depth = 64
//! max_cached_kernels = 32
//! seed = 42
//! ```

use crate::coordinator::planner::{BackendPolicy, PlanMode};
use crate::graph::reorder::ReorderPolicy;
use crate::kernel::{FormatPolicy, DEFAULT_L2_KIB};
use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// Runtime configuration for the coordinator and benches.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Synthetic-suite scale (1.0 = ~1/64 of the paper's matrices).
    pub scale: f64,
    /// Shift `alpha` of the generated systems.
    pub alpha: f64,
    /// Outer-split bandwidth (paper default 3).
    pub outer_bw: usize,
    /// Rank counts swept by scaling experiments.
    pub ranks: Vec<usize>,
    /// Directory containing AOT artifacts.
    pub artifacts_dir: PathBuf,
    /// Use real threads (true) or the deterministic emulated executor.
    pub threaded: bool,
    /// Band-interior storage policy: `auto` (fill-ratio heuristic),
    /// `dia` (force hybrid diagonal-major) or `sss` (paper layout).
    pub format: FormatPolicy,
    /// Reordering strategy run by `prepare`: `auto` (measure the
    /// candidates, decline when nothing clears the gain threshold),
    /// `rcm`, `rcm-bicriteria` (RCM++ start nodes) or `natural`.
    pub reorder: ReorderPolicy,
    /// `auto`'s decline gate: the fractional bandwidth improvement a
    /// reordering must clear over the natural order to be accepted
    /// (`0.0` = any strict improvement; must be in `[0, 1)`).
    pub reorder_min_gain: f64,
    /// Cache budget (KiB) the tile-blocked band kernels size their row
    /// tiles against (`kernel::blocking`); default 256 KiB ≈ a typical
    /// per-core L2.
    pub l2_kib: usize,
    /// Backend constraint: `auto` lets the planner score the registry
    /// backends; anything else pins the axis
    /// (`serial|csr|dgbmv|coloring|pars3|pjrt`).
    pub backend: BackendPolicy,
    /// `auto` = joint (reorder, format, backend) planning with every
    /// unpinned axis scored; `pinned` = legacy per-axis resolution.
    pub plan: PlanMode,
    /// Timed `apply` calls per backend candidate during planning
    /// (`0` = structural scoring only, no probe kernels built).
    pub plan_probe: usize,
    /// Prepare-pool width: BFS/RCM reordering and format construction
    /// run across this many workers (default: the machine's available
    /// parallelism). The computed permutation and formats are identical
    /// for every width; only prepare wall-clock changes.
    pub prepare_threads: usize,
    /// Worker shards in the request service (each owns a `Coordinator`
    /// and its kernel cache; matrices are assigned round-robin).
    pub shards: usize,
    /// Bounded request-queue depth per shard (backpressure: submission
    /// blocks when a shard's queue is full).
    pub queue_depth: usize,
    /// Per-coordinator (= per-shard) kernel-cache cap: past this many
    /// cached kernels the least-recently-used entry is evicted.
    /// `0` = unbounded.
    pub max_cached_kernels: usize,
    /// Generator seed.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            scale: 1.0,
            alpha: 2.0,
            outer_bw: 3,
            ranks: vec![1, 2, 4, 8, 16, 32, 64],
            artifacts_dir: PathBuf::from("artifacts"),
            threaded: false,
            format: FormatPolicy::Auto,
            reorder: ReorderPolicy::Auto,
            reorder_min_gain: 0.0,
            l2_kib: DEFAULT_L2_KIB,
            backend: BackendPolicy::Auto,
            plan: PlanMode::Auto,
            plan_probe: 0,
            prepare_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            shards: 2,
            queue_depth: 64,
            max_cached_kernels: 32,
            seed: 42,
        }
    }
}

impl Config {
    /// Load from a config file; missing file = defaults.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(Self::default());
        }
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Self::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "scale" => cfg.scale = value.parse().context("scale")?,
                "alpha" => cfg.alpha = value.parse().context("alpha")?,
                "outer_bw" => cfg.outer_bw = value.parse().context("outer_bw")?,
                "threaded" => cfg.threaded = value.parse().context("threaded")?,
                "format" => {
                    cfg.format = value.trim_matches('"').parse().context("format")?;
                }
                "reorder" => {
                    cfg.reorder = value.trim_matches('"').parse().context("reorder")?;
                }
                "reorder_min_gain" => {
                    cfg.reorder_min_gain = value.parse().context("reorder_min_gain")?;
                }
                "l2_kib" => cfg.l2_kib = value.parse().context("l2_kib")?,
                "backend" => {
                    cfg.backend = value.trim_matches('"').parse().context("backend")?;
                }
                "plan" => {
                    cfg.plan = value.trim_matches('"').parse().context("plan")?;
                }
                "plan_probe" => cfg.plan_probe = value.parse().context("plan_probe")?,
                "prepare_threads" => {
                    cfg.prepare_threads = value.parse().context("prepare_threads")?;
                }
                "shards" => cfg.shards = value.parse().context("shards")?,
                "queue_depth" => cfg.queue_depth = value.parse().context("queue_depth")?,
                "max_cached_kernels" => {
                    cfg.max_cached_kernels = value.parse().context("max_cached_kernels")?;
                }
                "seed" => cfg.seed = value.parse().context("seed")?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(value.trim_matches('"'));
                }
                "ranks" => {
                    let inner = value
                        .trim()
                        .strip_prefix('[')
                        .and_then(|v| v.strip_suffix(']'))
                        .with_context(|| format!("ranks must be a [list], got '{value}'"))?;
                    cfg.ranks = inner
                        .split(',')
                        .map(|t| t.trim().parse::<usize>().context("ranks entry"))
                        .collect::<Result<Vec<_>>>()?;
                }
                _ => bail!("line {}: unknown config key '{key}'", lineno + 1),
            }
        }
        if cfg.ranks.is_empty() || cfg.ranks.contains(&0) {
            bail!("ranks must be non-empty and positive");
        }
        if cfg.shards == 0 {
            bail!("shards must be >= 1");
        }
        if cfg.queue_depth == 0 {
            bail!("queue_depth must be >= 1");
        }
        if !(0.0..1.0).contains(&cfg.reorder_min_gain) {
            bail!("reorder_min_gain must be in [0, 1)");
        }
        if cfg.l2_kib == 0 {
            bail!("l2_kib must be >= 1");
        }
        if cfg.prepare_threads == 0 {
            bail!("prepare_threads must be >= 1");
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.scale > 0.0 && c.outer_bw >= 1 && !c.ranks.is_empty());
    }

    #[test]
    fn parses_full_config() {
        let c = Config::parse(
            "# comment\nscale = 0.5\nalpha = 3.0\nouter_bw = 5\nranks = [1, 2, 4]\nartifacts_dir = \"art\"\nthreaded = true\nformat = \"dia\"\nreorder = \"rcm-bicriteria\"\nreorder_min_gain = 0.1\nl2_kib = 512\nbackend = \"pars3\"\nplan = \"pinned\"\nplan_probe = 2\nprepare_threads = 3\nshards = 4\nqueue_depth = 16\nmax_cached_kernels = 8\nseed = 7\n",
        )
        .unwrap();
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.alpha, 3.0);
        assert_eq!(c.outer_bw, 5);
        assert_eq!(c.ranks, vec![1, 2, 4]);
        assert_eq!(c.artifacts_dir, PathBuf::from("art"));
        assert!(c.threaded);
        assert_eq!(c.format, FormatPolicy::Dia);
        assert_eq!(c.reorder, ReorderPolicy::RcmBiCriteria);
        assert_eq!(c.reorder_min_gain, 0.1);
        assert_eq!(c.l2_kib, 512);
        assert_eq!(c.backend, BackendPolicy::Pars3);
        assert_eq!(c.plan, PlanMode::Pinned);
        assert_eq!(c.plan_probe, 2);
        assert_eq!(c.prepare_threads, 3);
        assert_eq!(c.shards, 4);
        assert_eq!(c.queue_depth, 16);
        assert_eq!(c.max_cached_kernels, 8);
        assert_eq!(c.seed, 7);
        // bare (unquoted) values parse too
        assert_eq!(Config::parse("format = sss").unwrap().format, FormatPolicy::Sss);
        assert_eq!(
            Config::parse("reorder = natural").unwrap().reorder,
            ReorderPolicy::Natural
        );
        assert_eq!(
            Config::parse("backend = coloring").unwrap().backend,
            BackendPolicy::Coloring
        );
    }

    #[test]
    fn rejects_unknown_keys_and_bad_ranks() {
        assert!(Config::parse("foo = 1").is_err());
        assert!(Config::parse("ranks = [0]").is_err());
        assert!(Config::parse("ranks = []").is_err());
        assert!(Config::parse("scale 0.5").is_err());
        assert!(Config::parse("format = \"csr\"").is_err());
        assert!(Config::parse("reorder = \"symrcm\"").is_err());
        assert!(Config::parse("backend = \"gpu\"").is_err());
        assert!(Config::parse("plan = \"maybe\"").is_err());
        assert!(Config::parse("reorder_min_gain = 1.5").is_err());
        assert!(Config::parse("reorder_min_gain = -0.1").is_err());
        assert!(Config::parse("shards = 0").is_err());
        assert!(Config::parse("queue_depth = 0").is_err());
        assert!(Config::parse("l2_kib = 0").is_err());
        assert!(Config::parse("prepare_threads = 0").is_err());
    }

    #[test]
    fn missing_file_gives_defaults() {
        let c = Config::load("/nonexistent/pars3.toml").unwrap();
        assert_eq!(c, Config::default());
    }
}
