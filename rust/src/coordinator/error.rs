//! `Pars3Error` — the crate-wide typed error surface.
//!
//! Every failure a client can observe through the service
//! ([`crate::coordinator::Client`]), the coordinator, or the kernel
//! registry is one of these variants, so consumers match on structure
//! instead of scraping formatted strings (the old
//! `Response::Error(String)` surface). The type implements
//! `std::error::Error`, so `?` still converts it into the vendored
//! `anyhow::Error` wherever a caller keeps the loose [`crate::Result`]
//! (CLI, examples, reports); the reverse conversion exists too, so the
//! coordinator can absorb `anyhow`-producing internals (kernel
//! constructors, PJRT packing) without re-wrapping at every call site.

use crate::kernel::KERNEL_NAMES;
use std::fmt;

/// Typed failure of a prepare / multiply / solve request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Pars3Error {
    /// The handle's slot was never allocated on its shard. (A
    /// *released* matrix reports [`Self::StaleHandle`] instead — the
    /// release bumped its slot's generation.)
    UnknownMatrix {
        /// Shard the handle routes to.
        shard: usize,
        /// Slot index that was not found.
        slot: usize,
    },
    /// The handle's shard index exceeds the service's shard count.
    UnknownShard {
        /// Shard the handle routes to.
        shard: usize,
        /// Number of shards this service runs.
        shards: usize,
    },
    /// The handle was minted by a *different* `Service` instance
    /// (every service stamps its handles with a process-unique id, so
    /// cross-service use fails here instead of silently resolving
    /// against the wrong service's slot table).
    ForeignHandle {
        /// Service id stamped into the handle.
        handle_service: u64,
        /// Id of the service the request was sent to.
        service: u64,
    },
    /// The matrix under this handle was re-prepared: the slot is at a
    /// newer generation, so results computed for the held generation
    /// would silently target the wrong matrix. Re-`prepare` and retry
    /// with the fresh handle.
    StaleHandle {
        /// Shard the handle routes to.
        shard: usize,
        /// Slot index.
        slot: usize,
        /// Generation the caller's handle holds.
        held: u64,
        /// Generation the slot is currently at.
        current: u64,
    },
    /// Input vector/batch length does not match the prepared matrix.
    DimensionMismatch {
        /// The prepared matrix dimension.
        expected: usize,
        /// The caller's vector length (or batch row count).
        got: usize,
    },
    /// The requested backend cannot serve this request (feature not
    /// compiled in, no batch path, runtime failure).
    BackendUnavailable {
        /// Backend name (e.g. `"pjrt"`).
        backend: &'static str,
        /// Why it is unavailable.
        reason: String,
    },
    /// A kernel name outside [`KERNEL_NAMES`] was requested from the
    /// registry.
    UnknownKernel {
        /// The rejected name.
        name: String,
    },
    /// The input matrix failed preprocessing (e.g. not shifted
    /// skew-symmetric, empty band where one is required).
    InvalidMatrix(String),
    /// The shard's worker thread is gone — it panicked or the service
    /// shut down while the request was in flight.
    WorkerPoisoned {
        /// The dead shard.
        shard: usize,
    },
    /// `Ticket::wait` after `try_wait` already returned the result.
    TicketConsumed,
    /// The service was stopped ([`Service::stop`] or a remote `Stop`
    /// message): the request was refused, or was still queued when the
    /// shard drained its queue on shutdown. Distinct from
    /// [`Self::WorkerPoisoned`] — the service ended deliberately, not
    /// by a panic.
    ///
    /// [`Service::stop`]: crate::coordinator::Service::stop
    ServiceStopped,
    /// A socket-level failure on the remote-serving path (connect,
    /// read, write, accept). The payload names the operation and the
    /// underlying `std::io::Error`.
    Io(String),
    /// The remote peer sent bytes that do not decode as a valid frame
    /// or message (bad tag, truncated payload, trailing bytes,
    /// oversized frame). The connection is unusable after this.
    Protocol(String),
    /// Escape hatch for internal failures with no dedicated variant
    /// (kernel construction details, artifact I/O, ...). The payload is
    /// the full `anyhow`-style context chain.
    Internal(String),
}

impl fmt::Display for Pars3Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::UnknownMatrix { shard, slot } => {
                write!(f, "unknown matrix: shard {shard} has no slot {slot}")
            }
            Self::UnknownShard { shard, shards } => {
                write!(f, "unknown shard {shard}: this service runs {shards} shard(s)")
            }
            Self::ForeignHandle { handle_service, service } => write!(
                f,
                "foreign handle: minted by service {handle_service}, \
                 but this client serves service {service}"
            ),
            Self::StaleHandle { shard, slot, held, current } => write!(
                f,
                "stale handle: shard {shard} slot {slot} was re-prepared \
                 (handle holds generation {held}, slot is at {current})"
            ),
            Self::DimensionMismatch { expected, got } => {
                write!(f, "dimension mismatch: matrix expects length {expected}, got {got}")
            }
            Self::BackendUnavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            Self::UnknownKernel { name } => {
                write!(f, "unknown kernel '{name}'; available: {KERNEL_NAMES:?}")
            }
            Self::InvalidMatrix(why) => write!(f, "invalid matrix: {why}"),
            Self::WorkerPoisoned { shard } => write!(
                f,
                "service worker for shard {shard} is gone (panicked or shut down)"
            ),
            Self::TicketConsumed => {
                write!(f, "ticket already consumed (try_wait returned its result)")
            }
            Self::ServiceStopped => write!(f, "service stopped (request refused or dropped)"),
            Self::Io(why) => write!(f, "i/o error: {why}"),
            Self::Protocol(why) => write!(f, "protocol error: {why}"),
            Self::Internal(why) => write!(f, "{why}"),
        }
    }
}

impl Pars3Error {
    /// Wrap a socket-level failure with the operation that hit it
    /// (`std::io::Error` is neither `Clone` nor `Eq`, so the message is
    /// captured instead of the error value).
    pub fn io(op: &str, e: std::io::Error) -> Self {
        Self::Io(format!("{op}: {e}"))
    }

    /// A [`Self::Protocol`] decoding failure.
    pub fn protocol(why: impl Into<String>) -> Self {
        Self::Protocol(why.into())
    }
}

// Gives `?`-conversion INTO `anyhow::Error` (via its blanket
// `From<E: std::error::Error>`) for callers on the loose `crate::Result`.
impl std::error::Error for Pars3Error {}

// Absorb `anyhow`-producing internals. The chain is flattened with the
// alternate (`{:#}`) formatting so no context is lost.
impl From<anyhow::Error> for Pars3Error {
    fn from(e: anyhow::Error) -> Self {
        Self::Internal(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure() {
        let e = Pars3Error::StaleHandle { shard: 1, slot: 2, held: 3, current: 5 };
        let s = e.to_string();
        assert!(s.contains("stale") && s.contains("generation 3") && s.contains("at 5"), "{s}");
        assert!(Pars3Error::UnknownKernel { name: "nope".into() }
            .to_string()
            .contains("pars3"));
        assert!(Pars3Error::BackendUnavailable { backend: "pjrt", reason: "x".into() }
            .to_string()
            .contains("pjrt"));
        assert!(Pars3Error::ServiceStopped.to_string().contains("stopped"));
        let io = Pars3Error::io(
            "connect tcp://x:1",
            std::io::Error::new(std::io::ErrorKind::ConnectionRefused, "refused"),
        );
        assert!(io.to_string().contains("connect tcp://x:1"), "{io}");
        assert!(Pars3Error::protocol("bad tag 0x42").to_string().contains("bad tag"));
    }

    #[test]
    fn converts_both_ways_with_anyhow() {
        // anyhow -> Pars3Error keeps the context chain
        let a: anyhow::Error = anyhow::anyhow!("inner").context("outer");
        let p = Pars3Error::from(a);
        assert_eq!(p, Pars3Error::Internal("outer: inner".into()));
        // Pars3Error -> anyhow (what `?` does in CLI/report contexts)
        fn through() -> crate::Result<()> {
            Err(Pars3Error::DimensionMismatch { expected: 4, got: 7 })?;
            Ok(())
        }
        let msg = format!("{:#}", through().unwrap_err());
        assert!(msg.contains("expects length 4"), "{msg}");
    }
}
