//! Typed, handle-based, pipelined client API for the sharded service.
//!
//! The deployment model the paper targets is an iterative solver (or
//! many) repeatedly hitting one preprocessed matrix; preprocessing is
//! expensive, so it must amortize across a *stream* of requests — and
//! at service scale, across many concurrent streams. This module is
//! that surface:
//!
//! * [`MatrixHandle`] — a generational handle returned by `prepare`.
//!   It replaces string keys: the slot + generation pair makes a
//!   replaced registration detectable, so a request racing a
//!   re-`prepare` fails loudly with
//!   [`Pars3Error::StaleHandle`](crate::coordinator::Pars3Error)
//!   instead of silently computing against the wrong matrix.
//! * [`Ticket<T>`] — a one-shot future for a submitted request.
//!   Submission is non-blocking (up to the shard's bounded-queue
//!   backpressure), so one client can pipeline many requests and
//!   overlap a `prepare` on one shard with serving on another;
//!   [`Ticket::wait`]/[`Ticket::try_wait`] collect typed results.
//! * [`Client`] — a cheaply clonable front end over the service's
//!   shard queues. Clone it into as many threads as you like; all
//!   clones share the same shard pool and round-robin placement
//!   counter.
//!
//! ```no_run
//! # use pars3::coordinator::{Backend, Config, Service};
//! # fn demo(coo_a: pars3::sparse::Coo, x: Vec<f64>) -> Result<(), pars3::coordinator::Pars3Error> {
//! let svc = Service::start(Config::default());
//! let client = svc.client();
//! let h = client.prepare("a", coo_a).wait()?; // reorder + split, once
//! // pipelined: both requests are in flight before either wait
//! let t1 = client.spmv(&h, x.clone(), Backend::Pars3 { p: 4 });
//! let t2 = client.spmv(&h, x, Backend::Serial);
//! let (y1, y2) = (t1.wait()?, t2.wait()?);
//! # let _ = (y1, y2); Ok(()) }
//! ```

use crate::coordinator::error::Pars3Error;
use crate::coordinator::service::{CacheStats, MatrixInfo, ShardMsg};
use crate::coordinator::Backend;
use crate::kernel::VecBatch;
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;

/// Generational handle to a matrix prepared by the service.
///
/// `Copy` on purpose: handles are tokens, not resources. A handle stays
/// valid until the matrix under it is re-prepared
/// ([`Client::prepare_replace`]) or released ([`Client::release`]), at
/// which point every older-generation handle — including ones inside
/// in-flight tickets — resolves to [`Pars3Error::StaleHandle`]. Handles
/// are also stamped with the minting service's process-unique id, so
/// using one against a *different* service fails
/// [`Pars3Error::ForeignHandle`] instead of silently resolving against
/// the wrong slot table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatrixHandle {
    pub(crate) service: u64,
    pub(crate) shard: usize,
    pub(crate) slot: usize,
    pub(crate) generation: u64,
}

impl MatrixHandle {
    /// The shard whose worker owns this matrix.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The handle's generation (bumped by each re-`prepare` of the
    /// same slot; generation 1 is the first registration).
    pub fn generation(&self) -> u64 {
        self.generation
    }
}

enum TicketState<T> {
    /// Awaiting the shard worker's reply.
    Pending(Receiver<Result<T, Pars3Error>>),
    /// Resolved at submission time (dead shard, bad handle).
    Ready(Result<T, Pars3Error>),
    /// Aggregating several in-flight requests into one result
    /// (e.g. [`Client::cache_stats_all`]).
    Gather(Box<dyn Gather<T> + Send>),
    /// `try_wait` already surrendered the result.
    Taken,
}

/// A multi-part result source a [`Ticket`] can wrap: several in-flight
/// requests resolving into one aggregate value.
trait Gather<T> {
    /// Block until every part resolves (first error wins).
    fn wait(self: Box<Self>) -> Result<T, Pars3Error>;
    /// Non-blocking poll: `Some` once every part has resolved (or any
    /// part failed), `None` while at least one is still in flight.
    fn poll(&mut self) -> Option<Result<T, Pars3Error>>;
}

/// [`Gather`] over a homogeneous set of tickets, resolving to the
/// vector of their results in submission order.
struct GatherAll<E> {
    parts: Vec<GatherPart<E>>,
}

enum GatherPart<E> {
    Pending(Ticket<E>),
    Done(E),
}

impl<E: Send> Gather<Vec<E>> for GatherAll<E> {
    fn wait(self: Box<Self>) -> Result<Vec<E>, Pars3Error> {
        self.parts
            .into_iter()
            .map(|p| match p {
                GatherPart::Pending(t) => t.wait(),
                GatherPart::Done(v) => Ok(v),
            })
            .collect()
    }

    fn poll(&mut self) -> Option<Result<Vec<E>, Pars3Error>> {
        for p in &mut self.parts {
            if let GatherPart::Pending(t) = p {
                match t.try_wait() {
                    None => return None,
                    Some(Ok(v)) => *p = GatherPart::Done(v),
                    Some(Err(e)) => return Some(Err(e)),
                }
            }
        }
        let parts = std::mem::take(&mut self.parts);
        let all: Vec<E> = parts
            .into_iter()
            .map(|p| match p {
                GatherPart::Done(v) => v,
                GatherPart::Pending(_) => unreachable!("all parts resolved above"),
            })
            .collect();
        Some(Ok(all))
    }
}

/// A one-shot future for a submitted request.
///
/// Obtained from the submission methods on [`Client`]; the request is
/// already queued (and possibly executing) the moment the ticket
/// exists. [`wait`](Self::wait) blocks for the typed result;
/// [`try_wait`](Self::try_wait) polls without blocking so a client can
/// interleave submission, polling, and other work. Dropping a ticket
/// abandons the result (the worker still computes it; the reply is
/// discarded).
#[must_use = "the request is in flight; wait() or try_wait() collects its result"]
pub struct Ticket<T> {
    shard: usize,
    state: TicketState<T>,
}

impl<T> Ticket<T> {
    pub(crate) fn pending(shard: usize, rx: Receiver<Result<T, Pars3Error>>) -> Self {
        Self { shard, state: TicketState::Pending(rx) }
    }

    pub(crate) fn ready(shard: usize, result: Result<T, Pars3Error>) -> Self {
        Self { shard, state: TicketState::Ready(result) }
    }

    /// Aggregate a set of already-submitted tickets into one ticket
    /// resolving to their results in order (first error wins). The
    /// underlying requests are all in flight — and executing on their
    /// shards concurrently — before this returns. The combined ticket
    /// reports shard 0 (it spans every shard).
    pub(crate) fn gather_all<E>(parts: Vec<Ticket<E>>) -> Ticket<Vec<E>>
    where
        E: Send + 'static,
    {
        Ticket {
            shard: 0,
            state: TicketState::Gather(Box::new(GatherAll {
                parts: parts.into_iter().map(GatherPart::Pending).collect(),
            })),
        }
    }

    /// The shard serving this request.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until the result arrives. A dead worker (panicked shard or
    /// shut-down service) resolves to [`Pars3Error::WorkerPoisoned`];
    /// waiting after `try_wait` already returned the result resolves to
    /// [`Pars3Error::TicketConsumed`].
    pub fn wait(mut self) -> Result<T, Pars3Error> {
        match std::mem::replace(&mut self.state, TicketState::Taken) {
            TicketState::Pending(rx) => rx
                .recv()
                .unwrap_or(Err(Pars3Error::WorkerPoisoned { shard: self.shard })),
            TicketState::Ready(result) => result,
            TicketState::Gather(g) => g.wait(),
            TicketState::Taken => Err(Pars3Error::TicketConsumed),
        }
    }

    /// Non-blocking poll: `None` while the request is still in flight,
    /// `Some(result)` exactly once when it resolves (subsequent polls
    /// return `Some(Err(TicketConsumed))`).
    pub fn try_wait(&mut self) -> Option<Result<T, Pars3Error>> {
        match std::mem::replace(&mut self.state, TicketState::Taken) {
            TicketState::Pending(rx) => match rx.try_recv() {
                Ok(result) => Some(result),
                Err(TryRecvError::Empty) => {
                    self.state = TicketState::Pending(rx);
                    None
                }
                Err(TryRecvError::Disconnected) => {
                    Some(Err(Pars3Error::WorkerPoisoned { shard: self.shard }))
                }
            },
            TicketState::Ready(result) => Some(result),
            TicketState::Gather(mut g) => match g.poll() {
                Some(result) => Some(result),
                None => {
                    self.state = TicketState::Gather(g);
                    None
                }
            },
            TicketState::Taken => Some(Err(Pars3Error::TicketConsumed)),
        }
    }
}

/// One-shot reply channel for a single request.
type ReplyPair<T> = (Sender<Result<T, Pars3Error>>, Receiver<Result<T, Pars3Error>>);

/// Shared state between the [`Service`](crate::coordinator::Service)
/// and every [`Client`] clone: the shard request queues, their
/// occupancy gauges, and the round-robin placement counter for new
/// matrices.
pub(crate) struct ServiceShared {
    pub(crate) shards: Vec<SyncSender<ShardMsg>>,
    /// Per-shard queue-occupancy gauges: incremented at submission,
    /// decremented by the worker as it dequeues. Reported by
    /// [`Client::cache_stats`]/[`Client::cache_stats_all`].
    pub(crate) depths: Vec<Arc<std::sync::atomic::AtomicUsize>>,
    /// Process-unique id stamped into every handle this service mints.
    pub(crate) service_id: u64,
    /// Set by [`Service::stop`](crate::coordinator::Service::stop)
    /// before the shutdown messages are enqueued: clients refuse new
    /// submissions with [`Pars3Error::ServiceStopped`] instead of
    /// racing the closing queues.
    pub(crate) stopped: AtomicBool,
    next_shard: AtomicUsize,
}

impl ServiceShared {
    pub(crate) fn new(
        shards: Vec<SyncSender<ShardMsg>>,
        depths: Vec<Arc<std::sync::atomic::AtomicUsize>>,
        service_id: u64,
    ) -> Self {
        debug_assert_eq!(shards.len(), depths.len());
        Self {
            shards,
            depths,
            service_id,
            stopped: AtomicBool::new(false),
            next_shard: AtomicUsize::new(0),
        }
    }
}

/// Cheaply clonable, thread-safe front end to the sharded service.
#[derive(Clone)]
pub struct Client {
    inner: Arc<ServiceShared>,
}

impl Client {
    pub(crate) fn new(inner: Arc<ServiceShared>) -> Self {
        Self { inner }
    }

    /// Number of shards behind this client.
    pub fn num_shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Route a message to `shard`, producing a ticket for its reply.
    /// Submission applies backpressure: it blocks while the shard's
    /// bounded queue is full (and only then).
    fn dispatch<T>(
        &self,
        shard: usize,
        msg: ShardMsg,
        rx: Receiver<Result<T, Pars3Error>>,
    ) -> Ticket<T> {
        if self.inner.stopped.load(Ordering::SeqCst) {
            return Ticket::ready(shard, Err(Pars3Error::ServiceStopped));
        }
        let Some(queue) = self.inner.shards.get(shard) else {
            return Ticket::ready(
                shard,
                Err(Pars3Error::UnknownShard { shard, shards: self.inner.shards.len() }),
            );
        };
        // count the message as queued before it can possibly be
        // dequeued; a failed send (dead worker) never enqueued, so undo
        let gauge = &self.inner.depths[shard];
        gauge.fetch_add(1, Ordering::Relaxed);
        match queue.send(msg) {
            Ok(()) => Ticket::pending(shard, rx),
            Err(_) => {
                gauge.fetch_sub(1, Ordering::Relaxed);
                // A dead queue is a deliberate stop if the flag went up
                // while we were dispatching, a panic otherwise.
                let err = if self.inner.stopped.load(Ordering::SeqCst) {
                    Pars3Error::ServiceStopped
                } else {
                    Pars3Error::WorkerPoisoned { shard }
                };
                Ticket::ready(shard, Err(err))
            }
        }
    }

    fn reply<T>() -> ReplyPair<T> {
        channel()
    }

    /// Reject handles minted by a different service before they can
    /// resolve against this service's (unrelated) slot tables.
    fn guard<T>(&self, handle: &MatrixHandle) -> Result<(), Ticket<T>> {
        if handle.service != self.inner.service_id {
            return Err(Ticket::ready(
                handle.shard,
                Err(Pars3Error::ForeignHandle {
                    handle_service: handle.service,
                    service: self.inner.service_id,
                }),
            ));
        }
        Ok(())
    }

    /// Preprocess and register a matrix (reorder with the service's
    /// configured strategy — `Auto` by default, which may decline to
    /// reorder — then SSS conversion and the 3-way split) on a
    /// round-robin-chosen shard. The ticket resolves to the
    /// new [`MatrixHandle`] — submission returns immediately, so a
    /// client can overlap the (expensive) prepare with serving requests
    /// against already-registered matrices.
    pub fn prepare(&self, name: &str, coo: Coo) -> Ticket<MatrixHandle> {
        let shard = self.inner.next_shard.fetch_add(1, Ordering::Relaxed)
            % self.inner.shards.len().max(1);
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Prepare {
            replace: None,
            name: name.to_string(),
            coo: Box::new(coo),
            reply: tx,
        };
        self.dispatch(shard, msg, rx)
    }

    /// Re-prepare the matrix under an existing handle **in place**: the
    /// slot's generation is bumped, so every handle (and in-flight
    /// ticket) of the old generation resolves to
    /// [`Pars3Error::StaleHandle`] from that point on. Resolves to the
    /// fresh handle; a stale `handle` (someone replaced it first) is
    /// itself rejected with `StaleHandle`.
    pub fn prepare_replace(
        &self,
        handle: &MatrixHandle,
        name: &str,
        coo: Coo,
    ) -> Ticket<MatrixHandle> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Prepare {
            replace: Some((handle.slot, handle.generation)),
            name: name.to_string(),
            coo: Box::new(coo),
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Submit one multiply `y = A x` (reordered space, like
    /// [`Coordinator::spmv`](crate::coordinator::Coordinator::spmv)).
    pub fn spmv(&self, handle: &MatrixHandle, x: Vec<f64>, backend: Backend) -> Ticket<Vec<f64>> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Spmv {
            slot: handle.slot,
            generation: handle.generation,
            x,
            backend,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Submit an MRS solve.
    pub fn solve(
        &self,
        handle: &MatrixHandle,
        b: Vec<f64>,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<MrsResult> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Solve {
            slot: handle.slot,
            generation: handle.generation,
            b,
            opts,
            backend,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Submit a fused batch multiply (one matrix traversal for all
    /// columns of `xs`).
    pub fn spmv_batch(
        &self,
        handle: &MatrixHandle,
        xs: VecBatch,
        backend: Backend,
    ) -> Ticket<VecBatch> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::SpmvBatch {
            slot: handle.slot,
            generation: handle.generation,
            xs,
            backend,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Submit a multi-RHS MRS solve (one fused SpMV per sweep).
    pub fn solve_batch(
        &self,
        handle: &MatrixHandle,
        bs: VecBatch,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<Vec<MrsResult>> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::SolveBatch {
            slot: handle.slot,
            generation: handle.generation,
            bs,
            opts,
            backend,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Query the preprocessing metadata of the matrix under `handle`:
    /// dimension, stored NNZ, pre/post-reorder bandwidth, the resolved
    /// [`PlanChoice`](crate::coordinator::planner::PlanChoice) triple
    /// and the full
    /// [`PlanReport`](crate::coordinator::planner::PlanReport)
    /// evidence (per-axis candidates, scores, decline reasons). After
    /// `prepare_replace` this reflects the replacement's plan, not the
    /// original's.
    pub fn describe(&self, handle: &MatrixHandle) -> Ticket<MatrixInfo> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Describe {
            slot: handle.slot,
            generation: handle.generation,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Unregister the matrix under `handle`: its cached kernels are
    /// evicted, the `Prepared` matrix memory is dropped, and the slot
    /// is freed for reuse by a later `prepare` (without this, a
    /// long-running service accumulates one retained matrix per
    /// `prepare`, forever). Releasing bumps the slot generation, so the
    /// released handle — and every copy of it — resolves to
    /// [`Pars3Error::StaleHandle`] from then on; a slot reused by a
    /// later `prepare` continues the generation sequence, so old
    /// handles can never alias the new occupant.
    pub fn release(&self, handle: &MatrixHandle) -> Ticket<()> {
        if let Err(t) = self.guard(handle) {
            return t;
        }
        let (tx, rx) = Self::reply();
        let msg = ShardMsg::Release {
            slot: handle.slot,
            generation: handle.generation,
            reply: tx,
        };
        self.dispatch(handle.shard, msg, rx)
    }

    /// Query one shard's kernel-cache counters (the amortization
    /// metric: `built` stalling while requests flow means cache hits)
    /// plus its queue depth at report time.
    pub fn cache_stats(&self, shard: usize) -> Ticket<CacheStats> {
        let (tx, rx) = Self::reply();
        self.dispatch(shard, ShardMsg::CacheStats { reply: tx }, rx)
    }

    /// Query **every** shard's cache/queue counters in one call: the
    /// per-shard requests are all dispatched (and execute concurrently)
    /// before this returns, and the ticket resolves to one
    /// [`CacheStats`] per shard in shard order. The metrics-scrape
    /// entry point for a monitoring consumer.
    pub fn cache_stats_all(&self) -> Ticket<Vec<CacheStats>> {
        let parts: Vec<Ticket<CacheStats>> =
            (0..self.num_shards()).map(|s| self.cache_stats(s)).collect();
        Ticket::gather_all(parts)
    }
}

/// The full typed request surface, abstracted over transport.
///
/// Implemented by the in-process [`Client`] (shard queues) and by
/// [`RemoteClient`](crate::net::RemoteClient) (TCP/UDS), with the same
/// submit-then-`Ticket` shape, so every caller — and in particular the
/// backend-sweep integration suite — runs unchanged against both. Local
/// tickets resolve from a shard worker's reply channel; remote tickets
/// resolve when the connection's reader thread matches the response's
/// request id. Either way, submission never blocks on the result.
pub trait ClientApi {
    /// See [`Client::prepare`].
    fn prepare(&self, name: &str, coo: Coo) -> Ticket<MatrixHandle>;
    /// See [`Client::prepare_replace`].
    fn prepare_replace(&self, handle: &MatrixHandle, name: &str, coo: Coo)
        -> Ticket<MatrixHandle>;
    /// See [`Client::release`].
    fn release(&self, handle: &MatrixHandle) -> Ticket<()>;
    /// See [`Client::spmv`].
    fn spmv(&self, handle: &MatrixHandle, x: Vec<f64>, backend: Backend) -> Ticket<Vec<f64>>;
    /// See [`Client::solve`].
    fn solve(
        &self,
        handle: &MatrixHandle,
        b: Vec<f64>,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<MrsResult>;
    /// See [`Client::spmv_batch`].
    fn spmv_batch(&self, handle: &MatrixHandle, xs: VecBatch, backend: Backend)
        -> Ticket<VecBatch>;
    /// See [`Client::solve_batch`].
    fn solve_batch(
        &self,
        handle: &MatrixHandle,
        bs: VecBatch,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<Vec<MrsResult>>;
    /// See [`Client::describe`].
    fn describe(&self, handle: &MatrixHandle) -> Ticket<MatrixInfo>;
    /// See [`Client::cache_stats`].
    fn cache_stats(&self, shard: usize) -> Ticket<CacheStats>;
    /// See [`Client::cache_stats_all`].
    fn cache_stats_all(&self) -> Ticket<Vec<CacheStats>>;
}

impl ClientApi for Client {
    fn prepare(&self, name: &str, coo: Coo) -> Ticket<MatrixHandle> {
        Client::prepare(self, name, coo)
    }
    fn prepare_replace(
        &self,
        handle: &MatrixHandle,
        name: &str,
        coo: Coo,
    ) -> Ticket<MatrixHandle> {
        Client::prepare_replace(self, handle, name, coo)
    }
    fn release(&self, handle: &MatrixHandle) -> Ticket<()> {
        Client::release(self, handle)
    }
    fn spmv(&self, handle: &MatrixHandle, x: Vec<f64>, backend: Backend) -> Ticket<Vec<f64>> {
        Client::spmv(self, handle, x, backend)
    }
    fn solve(
        &self,
        handle: &MatrixHandle,
        b: Vec<f64>,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<MrsResult> {
        Client::solve(self, handle, b, opts, backend)
    }
    fn spmv_batch(
        &self,
        handle: &MatrixHandle,
        xs: VecBatch,
        backend: Backend,
    ) -> Ticket<VecBatch> {
        Client::spmv_batch(self, handle, xs, backend)
    }
    fn solve_batch(
        &self,
        handle: &MatrixHandle,
        bs: VecBatch,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<Vec<MrsResult>> {
        Client::solve_batch(self, handle, bs, opts, backend)
    }
    fn describe(&self, handle: &MatrixHandle) -> Ticket<MatrixInfo> {
        Client::describe(self, handle)
    }
    fn cache_stats(&self, shard: usize) -> Ticket<CacheStats> {
        Client::cache_stats(self, shard)
    }
    fn cache_stats_all(&self) -> Ticket<Vec<CacheStats>> {
        Client::cache_stats_all(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_wait_and_try_wait_semantics() {
        // resolved at submission
        let t: Ticket<u32> = Ticket::ready(0, Ok(7));
        assert_eq!(t.wait(), Ok(7));

        // pending -> try_wait None -> value arrives -> Some -> consumed
        let (tx, rx) = channel();
        let mut t: Ticket<u32> = Ticket::pending(1, rx);
        assert_eq!(t.shard(), 1);
        assert!(t.try_wait().is_none());
        tx.send(Ok(9)).unwrap();
        assert_eq!(t.try_wait(), Some(Ok(9)));
        assert_eq!(t.try_wait(), Some(Err(Pars3Error::TicketConsumed)));
        assert_eq!(t.wait(), Err(Pars3Error::TicketConsumed));

        // dead worker: sender dropped before replying
        let (tx, rx) = channel::<Result<u32, Pars3Error>>();
        drop(tx);
        let t = Ticket::pending(3, rx);
        assert_eq!(t.wait(), Err(Pars3Error::WorkerPoisoned { shard: 3 }));
    }

    #[test]
    fn gathered_tickets_resolve_in_order_with_first_error_winning() {
        // all parts ready: wait() returns them in order
        let t = Ticket::gather_all(vec![Ticket::ready(0, Ok(1u32)), Ticket::ready(1, Ok(2))]);
        assert_eq!(t.wait(), Ok(vec![1, 2]));

        // try_wait: None while any part is in flight, Some when all land
        let (tx, rx) = channel();
        let mut t =
            Ticket::gather_all(vec![Ticket::ready(0, Ok(5u32)), Ticket::pending(1, rx)]);
        assert!(t.try_wait().is_none());
        tx.send(Ok(6)).unwrap();
        assert_eq!(t.try_wait(), Some(Ok(vec![5, 6])));
        assert_eq!(t.try_wait(), Some(Err(Pars3Error::TicketConsumed)));

        // a failed part resolves the whole gather to its error
        let t = Ticket::gather_all(vec![
            Ticket::ready(0, Ok(1u32)),
            Ticket::ready(1, Err(Pars3Error::TicketConsumed)),
        ]);
        assert_eq!(t.wait(), Err(Pars3Error::TicketConsumed));

        // zero parts: an empty aggregate, not a hang
        let t: Ticket<Vec<u32>> = Ticket::gather_all(Vec::new());
        assert_eq!(t.wait(), Ok(Vec::new()));
    }

    #[test]
    fn out_of_range_shard_resolves_to_unknown_shard() {
        let shared = Arc::new(ServiceShared::new(Vec::new(), Vec::new(), 7));
        let client = Client::new(shared);
        let fake = MatrixHandle { service: 7, shard: 5, slot: 0, generation: 1 };
        let err = client.spmv(&fake, vec![1.0], Backend::Serial).wait().unwrap_err();
        assert_eq!(err, Pars3Error::UnknownShard { shard: 5, shards: 0 });
    }

    #[test]
    fn foreign_handles_are_rejected_before_dispatch() {
        let client = Client::new(Arc::new(ServiceShared::new(Vec::new(), Vec::new(), 7)));
        let alien = MatrixHandle { service: 8, shard: 0, slot: 0, generation: 1 };
        let err = client.spmv(&alien, vec![1.0], Backend::Serial).wait().unwrap_err();
        assert_eq!(err, Pars3Error::ForeignHandle { handle_service: 8, service: 7 });
        let err = client.release(&alien).wait().unwrap_err();
        assert_eq!(err, Pars3Error::ForeignHandle { handle_service: 8, service: 7 });
    }
}
