//! Request-service loop: the long-running leader process.
//!
//! Models the deployment the paper targets — an iterative solver (or
//! several) repeatedly hitting the same preprocessed matrix. A worker
//! thread owns the [`Coordinator`]; clients submit requests over a
//! channel and receive results over a per-request reply channel. (The
//! offline environment has no tokio; a std::thread + mpsc loop provides
//! the same single-owner async boundary.)

use crate::coordinator::{Backend, Config, Coordinator, Prepared};
use crate::kernel::VecBatch;
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

/// A request to the service.
pub enum Request {
    /// Preprocess and register a matrix under a key.
    Prepare {
        /// Registration key.
        key: String,
        /// Full COO matrix (shifted skew-symmetric).
        coo: Coo,
    },
    /// Multiply against a registered matrix.
    Spmv {
        /// Matrix key.
        key: String,
        /// Input vector (RCM order).
        x: Vec<f64>,
        /// Backend to run.
        backend: Backend,
    },
    /// MRS-solve against a registered matrix.
    Solve {
        /// Matrix key.
        key: String,
        /// Right-hand side.
        b: Vec<f64>,
        /// Solver options.
        opts: MrsOptions,
        /// Backend to run.
        backend: Backend,
    },
    /// Fused batch multiply against a registered matrix (one matrix
    /// traversal for all columns).
    SpmvBatch {
        /// Matrix key.
        key: String,
        /// Column-major `n × k` input batch (RCM order).
        xs: VecBatch,
        /// Backend to run.
        backend: Backend,
    },
    /// Multi-RHS MRS-solve against a registered matrix (one fused SpMV
    /// per sweep across all right-hand sides).
    SolveBatch {
        /// Matrix key.
        key: String,
        /// Column-major `n × k` right-hand-side batch.
        bs: VecBatch,
        /// Solver options (shared by every column).
        opts: MrsOptions,
        /// Backend to run.
        backend: Backend,
    },
    /// Report the worker's kernel-cache counters (how many kernels are
    /// cached and how many were ever built — the amortization metric).
    CacheStats,
    /// Stop the service loop.
    Shutdown,
}

/// Service responses.
pub enum Response {
    /// Matrix registered; reports (n, nnz_lower, rcm_bw).
    Prepared {
        /// Dimension.
        n: usize,
        /// Stored lower NNZ.
        nnz: usize,
        /// Post-RCM bandwidth.
        rcm_bw: usize,
    },
    /// SpMV result.
    Spmv(Vec<f64>),
    /// Solve result.
    Solve(MrsResult),
    /// Batch SpMV result (column-major, same width as the request).
    SpmvBatch(VecBatch),
    /// Multi-RHS solve results, one per column.
    SolveBatch(Vec<MrsResult>),
    /// Kernel-cache counters.
    CacheStats {
        /// Kernels currently cached.
        cached: usize,
        /// Kernels ever constructed (cache misses).
        built: usize,
    },
    /// Request failed.
    Error(String),
}

type Envelope = (Request, Sender<Response>);

/// Handle to a running service.
pub struct Service {
    tx: Sender<Envelope>,
    worker: Option<JoinHandle<()>>,
}

impl Service {
    /// Spawn the worker thread.
    pub fn start(cfg: Config) -> Self {
        let (tx, rx) = channel::<Envelope>();
        let worker = std::thread::spawn(move || {
            let mut coord = Coordinator::new(cfg);
            let mut registry: HashMap<String, Prepared> = HashMap::new();
            while let Ok((req, reply)) = rx.recv() {
                let resp = match req {
                    Request::Shutdown => break,
                    Request::Prepare { key, coo } => match coord.prepare(&key, &coo) {
                        Ok(p) => {
                            let r = Response::Prepared {
                                n: p.n,
                                nnz: p.nnz_lower,
                                rcm_bw: p.rcm_bw,
                            };
                            // replacing a registration drops its cached
                            // kernels — they'd pin the old matrix and
                            // never be hit again (new Arc identity)
                            if let Some(old) = registry.insert(key, p) {
                                coord.evict(&old);
                            }
                            r
                        }
                        Err(e) => Response::Error(format!("{e:#}")),
                    },
                    Request::Spmv { key, x, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.spmv(p, &x, backend) {
                            Ok(y) => Response::Spmv(y),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                    Request::Solve { key, b, opts, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.solve(p, &b, &opts, backend) {
                            Ok(r) => Response::Solve(r),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                    Request::SpmvBatch { key, xs, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.spmv_batch(p, &xs, backend) {
                            Ok(ys) => Response::SpmvBatch(ys),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                    Request::SolveBatch { key, bs, opts, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.solve_batch(p, &bs, &opts, backend) {
                            Ok(rs) => Response::SolveBatch(rs),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                    Request::CacheStats => {
                        let (cached, built) = coord.kernel_cache_stats();
                        Response::CacheStats { cached, built }
                    }
                };
                let _ = reply.send(resp);
            }
        });
        Self { tx, worker: Some(worker) }
    }

    /// Submit a request and block for the response.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = channel();
        if self.tx.send((req, rtx)).is_err() {
            return Response::Error("service stopped".into());
        }
        rrx.recv().unwrap_or(Response::Error("service dropped reply".into()))
    }

    /// Stop the worker.
    pub fn shutdown(mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn prepare_then_spmv_and_solve() {
        let svc = Service::start(Config::default());
        let coo = gen::small_test_matrix(120, 21, 2.0);
        let Response::Prepared { n, .. } =
            svc.call(Request::Prepare { key: "m".into(), coo: coo.clone() })
        else {
            panic!("prepare failed")
        };
        assert_eq!(n, 120);

        let x: Vec<f64> = (0..120).map(|i| i as f64 * 0.01).collect();
        let Response::Spmv(y) = svc.call(Request::Spmv {
            key: "m".into(),
            x: x.clone(),
            backend: Backend::Pars3 { p: 4 },
        }) else {
            panic!("spmv failed")
        };
        assert_eq!(y.len(), 120);

        let Response::Solve(res) = svc.call(Request::Solve {
            key: "m".into(),
            b: x,
            opts: MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 },
            backend: Backend::Serial,
        }) else {
            panic!("solve failed")
        };
        assert!(res.converged);
        svc.shutdown();
    }

    #[test]
    fn batch_requests_roundtrip() {
        let svc = Service::start(Config::default());
        let coo = gen::small_test_matrix(90, 22, 2.0);
        let Response::Prepared { n, .. } =
            svc.call(Request::Prepare { key: "m".into(), coo })
        else {
            panic!("prepare failed")
        };
        assert_eq!(n, 90);

        let xs = VecBatch::from_fn(90, 3, |i, c| ((i + c * 7) % 5) as f64 - 2.0);
        let Response::SpmvBatch(ys) = svc.call(Request::SpmvBatch {
            key: "m".into(),
            xs: xs.clone(),
            backend: Backend::Pars3 { p: 3 },
        }) else {
            panic!("spmv batch failed")
        };
        assert_eq!((ys.n(), ys.k()), (90, 3));
        // cross-check column 0 against the single-vector path
        let Response::Spmv(y0) = svc.call(Request::Spmv {
            key: "m".into(),
            x: xs.col(0).to_vec(),
            backend: Backend::Pars3 { p: 3 },
        }) else {
            panic!("spmv failed")
        };
        for (a, b) in ys.col(0).iter().zip(&y0) {
            assert!((a - b).abs() < 1e-9);
        }

        let Response::SolveBatch(results) = svc.call(Request::SolveBatch {
            key: "m".into(),
            bs: xs,
            opts: MrsOptions { alpha: 2.0, max_iters: 400, tol: 1e-8 },
            backend: Backend::Serial,
        }) else {
            panic!("solve batch failed")
        };
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.converged));
        svc.shutdown();
    }

    #[test]
    fn repeated_solves_construct_the_kernel_exactly_once() {
        let svc = Service::start(Config::default());
        let coo = gen::small_test_matrix(100, 23, 2.0);
        let Response::Prepared { .. } =
            svc.call(Request::Prepare { key: "m".into(), coo: coo.clone() })
        else {
            panic!("prepare failed")
        };
        let Response::CacheStats { cached, built } = svc.call(Request::CacheStats) else {
            panic!("cache stats failed")
        };
        assert_eq!((cached, built), (0, 0));
        let b: Vec<f64> = (0..100).map(|i| ((i % 7) as f64) - 3.0).collect();
        for _ in 0..4 {
            let Response::Solve(res) = svc.call(Request::Solve {
                key: "m".into(),
                b: b.clone(),
                opts: MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 },
                backend: Backend::Pars3 { p: 3 },
            }) else {
                panic!("solve failed")
            };
            assert!(res.converged);
        }
        let Response::CacheStats { cached, built } = svc.call(Request::CacheStats) else {
            panic!("cache stats failed")
        };
        assert_eq!((cached, built), (1, 1), "4 solves must build the kernel once");

        // re-preparing under the same key evicts the stale kernels
        let Response::Prepared { .. } = svc.call(Request::Prepare { key: "m".into(), coo })
        else {
            panic!("re-prepare failed")
        };
        let Response::CacheStats { cached, built } = svc.call(Request::CacheStats) else {
            panic!("cache stats failed")
        };
        assert_eq!((cached, built), (0, 1), "re-prepare must drop the old kernel");
        svc.shutdown();
    }

    #[test]
    fn unknown_key_errors() {
        let svc = Service::start(Config::default());
        let resp = svc.call(Request::Spmv {
            key: "nope".into(),
            x: vec![],
            backend: Backend::Serial,
        });
        assert!(matches!(resp, Response::Error(_)));
    }
}
