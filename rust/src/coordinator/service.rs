//! Request-service loop: the long-running leader process.
//!
//! Models the deployment the paper targets — an iterative solver (or
//! several) repeatedly hitting the same preprocessed matrix. A worker
//! thread owns the [`Coordinator`]; clients submit requests over a
//! channel and receive results over a per-request reply channel. (The
//! offline environment has no tokio; a std::thread + mpsc loop provides
//! the same single-owner async boundary.)

use crate::coordinator::{Backend, Config, Coordinator, Prepared};
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

/// A request to the service.
pub enum Request {
    /// Preprocess and register a matrix under a key.
    Prepare {
        /// Registration key.
        key: String,
        /// Full COO matrix (shifted skew-symmetric).
        coo: Coo,
    },
    /// Multiply against a registered matrix.
    Spmv {
        /// Matrix key.
        key: String,
        /// Input vector (RCM order).
        x: Vec<f64>,
        /// Backend to run.
        backend: Backend,
    },
    /// MRS-solve against a registered matrix.
    Solve {
        /// Matrix key.
        key: String,
        /// Right-hand side.
        b: Vec<f64>,
        /// Solver options.
        opts: MrsOptions,
        /// Backend to run.
        backend: Backend,
    },
    /// Stop the service loop.
    Shutdown,
}

/// Service responses.
pub enum Response {
    /// Matrix registered; reports (n, nnz_lower, rcm_bw).
    Prepared {
        /// Dimension.
        n: usize,
        /// Stored lower NNZ.
        nnz: usize,
        /// Post-RCM bandwidth.
        rcm_bw: usize,
    },
    /// SpMV result.
    Spmv(Vec<f64>),
    /// Solve result.
    Solve(MrsResult),
    /// Request failed.
    Error(String),
}

type Envelope = (Request, Sender<Response>);

/// Handle to a running service.
pub struct Service {
    tx: Sender<Envelope>,
    worker: Option<JoinHandle<()>>,
}

impl Service {
    /// Spawn the worker thread.
    pub fn start(cfg: Config) -> Self {
        let (tx, rx) = channel::<Envelope>();
        let worker = std::thread::spawn(move || {
            let mut coord = Coordinator::new(cfg);
            let mut registry: HashMap<String, Prepared> = HashMap::new();
            while let Ok((req, reply)) = rx.recv() {
                let resp = match req {
                    Request::Shutdown => break,
                    Request::Prepare { key, coo } => match coord.prepare(&key, &coo) {
                        Ok(p) => {
                            let r = Response::Prepared {
                                n: p.n,
                                nnz: p.nnz_lower,
                                rcm_bw: p.rcm_bw,
                            };
                            registry.insert(key, p);
                            r
                        }
                        Err(e) => Response::Error(format!("{e:#}")),
                    },
                    Request::Spmv { key, x, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.spmv(p, &x, backend) {
                            Ok(y) => Response::Spmv(y),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                    Request::Solve { key, b, opts, backend } => match registry.get(&key) {
                        None => Response::Error(format!("unknown matrix '{key}'")),
                        Some(p) => match coord.solve(p, &b, &opts, backend) {
                            Ok(r) => Response::Solve(r),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                    },
                };
                let _ = reply.send(resp);
            }
        });
        Self { tx, worker: Some(worker) }
    }

    /// Submit a request and block for the response.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = channel();
        if self.tx.send((req, rtx)).is_err() {
            return Response::Error("service stopped".into());
        }
        rrx.recv().unwrap_or(Response::Error("service dropped reply".into()))
    }

    /// Stop the worker.
    pub fn shutdown(mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn prepare_then_spmv_and_solve() {
        let svc = Service::start(Config::default());
        let coo = gen::small_test_matrix(120, 21, 2.0);
        let Response::Prepared { n, .. } =
            svc.call(Request::Prepare { key: "m".into(), coo: coo.clone() })
        else {
            panic!("prepare failed")
        };
        assert_eq!(n, 120);

        let x: Vec<f64> = (0..120).map(|i| i as f64 * 0.01).collect();
        let Response::Spmv(y) = svc.call(Request::Spmv {
            key: "m".into(),
            x: x.clone(),
            backend: Backend::Pars3 { p: 4 },
        }) else {
            panic!("spmv failed")
        };
        assert_eq!(y.len(), 120);

        let Response::Solve(res) = svc.call(Request::Solve {
            key: "m".into(),
            b: x,
            opts: MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 },
            backend: Backend::Serial,
        }) else {
            panic!("solve failed")
        };
        assert!(res.converged);
        svc.shutdown();
    }

    #[test]
    fn unknown_key_errors() {
        let svc = Service::start(Config::default());
        let resp = svc.call(Request::Spmv {
            key: "nope".into(),
            x: vec![],
            backend: Backend::Serial,
        });
        assert!(matches!(resp, Response::Error(_)));
    }
}
