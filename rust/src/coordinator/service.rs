//! Sharded request service: a pool of worker threads, each owning a
//! [`Coordinator`] (and therefore its own kernel cache), serving the
//! typed handle-based client API ([`crate::coordinator::Client`]).
//!
//! Matrices are assigned to shards round-robin at `prepare` time and
//! stay put — the handle carries the shard, so every request for one
//! matrix lands on the worker whose cache holds its kernels (and, for
//! threaded `pars3`, its persistent rank threads). Independent request
//! streams on different shards execute concurrently; within one shard,
//! requests execute in submission order. Each shard's queue is bounded
//! ([`Config::queue_depth`]), so a flood of submissions blocks the
//! producer instead of growing memory without bound. (The offline
//! environment has no tokio; std threads + sync channels provide the
//! same ownership boundary.)
//!
//! Slots are generational: re-preparing under an existing handle bumps
//! the slot's generation, so older handles — including ones inside
//! in-flight tickets queued behind the re-prepare — fail with
//! [`Pars3Error::StaleHandle`] instead of computing against the wrong
//! matrix.

use crate::coordinator::client::{Client, MatrixHandle, ServiceShared};
use crate::coordinator::error::Pars3Error;
use crate::coordinator::planner::{PlanChoice, PlanReport};
use crate::coordinator::{Backend, Config, Coordinator, Prepared};
use crate::kernel::VecBatch;
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Process-unique service ids: stamped into every [`MatrixHandle`] so a
/// handle minted by one service can never resolve against another's
/// slot table (it fails `ForeignHandle` at the client instead).
static NEXT_SERVICE_ID: AtomicU64 = AtomicU64::new(1);

/// One shard's kernel-cache and queue counters (`built` stalling while
/// requests flow is the amortization metric: kernels are being reused,
/// not reconstructed; `queue_depth` is the load gauge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// The reporting shard.
    pub shard: usize,
    /// Kernels currently cached.
    pub cached: usize,
    /// Kernels ever constructed (cache misses, including rebuilds
    /// after LRU eviction).
    pub built: usize,
    /// Requests submitted to this shard but not yet dequeued when the
    /// shard produced this report — the backpressure gauge. Counts
    /// messages in the bounded queue **plus** producers currently
    /// blocked in `send`, so under backpressure it can read slightly
    /// above [`Config::queue_depth`].
    pub queue_depth: usize,
}

/// Preprocessing metadata for a registered matrix (what the one-time
/// `prepare` computed: dimension, stored NNZ, the bandwidth reduction —
/// Table 1's headline numbers — and the full planning evidence). Query
/// via [`Client::describe`](crate::coordinator::Client::describe).
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixInfo {
    /// Registration name.
    pub name: String,
    /// Dimension.
    pub n: usize,
    /// Stored lower-triangle NNZ.
    pub nnz_lower: usize,
    /// Bandwidth before reordering.
    pub bw_before: usize,
    /// Bandwidth after reordering.
    pub reordered_bw: usize,
    /// The (reorder, format, backend) triple the planner resolved for
    /// this matrix — what `auto`-backend requests execute against.
    pub choice: PlanChoice,
    /// The planning run's evidence: per-axis candidates with scores,
    /// chosen flags, probe timings and decline reasons, plus the full
    /// embedded reordering report.
    pub plan: PlanReport,
}

impl MatrixInfo {
    /// JSON encoding for the wire. `describe` is metadata, not the hot
    /// path, so the whole evidence tree travels as JSON (the f64
    /// vectors of `spmv`/`solve` stay raw — see [`crate::net::proto`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("nnz_lower".to_string(), Json::Num(self.nnz_lower as f64));
        m.insert("bw_before".to_string(), Json::Num(self.bw_before as f64));
        m.insert("reordered_bw".to_string(), Json::Num(self.reordered_bw as f64));
        m.insert("choice".to_string(), self.choice.to_json());
        m.insert("plan".to_string(), self.plan.to_json());
        Json::Obj(m)
    }

    /// Inverse of [`MatrixInfo::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(MatrixInfo {
            name: j.req("name")?.as_str()?.to_string(),
            n: j.req("n")?.as_usize()?,
            nnz_lower: j.req("nnz_lower")?.as_usize()?,
            bw_before: j.req("bw_before")?.as_usize()?,
            reordered_bw: j.req("reordered_bw")?.as_usize()?,
            choice: PlanChoice::from_json(j.req("choice")?)?,
            plan: PlanReport::from_json(j.req("plan")?)?,
        })
    }
}

/// A request routed to one shard worker. Each variant carries its own
/// typed reply channel — the wire format of the `Client`/`Ticket` API.
pub(crate) enum ShardMsg {
    Prepare {
        /// `None`: allocate a fresh slot. `Some((slot, generation))`:
        /// replace the matrix under an existing handle, bumping its
        /// generation (the caller's generation must still be current).
        replace: Option<(usize, u64)>,
        name: String,
        coo: Box<Coo>,
        reply: Sender<Result<MatrixHandle, Pars3Error>>,
    },
    Spmv {
        slot: usize,
        generation: u64,
        x: Vec<f64>,
        backend: Backend,
        reply: Sender<Result<Vec<f64>, Pars3Error>>,
    },
    Solve {
        slot: usize,
        generation: u64,
        b: Vec<f64>,
        opts: MrsOptions,
        backend: Backend,
        reply: Sender<Result<MrsResult, Pars3Error>>,
    },
    SpmvBatch {
        slot: usize,
        generation: u64,
        xs: VecBatch,
        backend: Backend,
        reply: Sender<Result<VecBatch, Pars3Error>>,
    },
    SolveBatch {
        slot: usize,
        generation: u64,
        bs: VecBatch,
        opts: MrsOptions,
        backend: Backend,
        reply: Sender<Result<Vec<MrsResult>, Pars3Error>>,
    },
    Describe {
        slot: usize,
        generation: u64,
        reply: Sender<Result<MatrixInfo, Pars3Error>>,
    },
    Release {
        slot: usize,
        generation: u64,
        reply: Sender<Result<(), Pars3Error>>,
    },
    CacheStats {
        reply: Sender<Result<CacheStats, Pars3Error>>,
    },
    Shutdown,
}

impl ShardMsg {
    /// Resolve this request's ticket with `err` without executing it —
    /// the graceful-shutdown path: requests still queued when the shard
    /// drains reply typed [`Pars3Error::ServiceStopped`] instead of
    /// leaving the ticket to a `WorkerPoisoned` channel drop.
    fn reject(self, err: Pars3Error) {
        match self {
            ShardMsg::Prepare { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::Spmv { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::Solve { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::SpmvBatch { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::SolveBatch { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::Describe { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::Release { reply, .. } => drop(reply.send(Err(err))),
            ShardMsg::CacheStats { reply } => drop(reply.send(Err(err))),
            ShardMsg::Shutdown => {}
        }
    }
}

/// A shard-local matrix slot. `prep` is `None` once released; the
/// generation is monotone across the slot's whole lifetime (bumped by
/// replace, release, and re-occupation), so no historical handle can
/// ever alias a later occupant.
struct Slot {
    generation: u64,
    prep: Option<Prepared>,
}

/// Look a handle up in a shard's slot table, rejecting unknown slots,
/// released slots, and stale generations.
fn resolve<'s>(
    slots: &'s [Slot],
    shard: usize,
    slot: usize,
    generation: u64,
) -> Result<&'s Prepared, Pars3Error> {
    let s = slots
        .get(slot)
        .ok_or(Pars3Error::UnknownMatrix { shard, slot })?;
    if s.generation != generation {
        return Err(Pars3Error::StaleHandle {
            shard,
            slot,
            held: generation,
            current: s.generation,
        });
    }
    s.prep.as_ref().ok_or(Pars3Error::UnknownMatrix { shard, slot })
}

fn shard_worker(
    shard: usize,
    service: u64,
    cfg: Config,
    rx: Receiver<ShardMsg>,
    depth: Arc<AtomicUsize>,
) {
    let mut coord = Coordinator::new(cfg);
    let mut slots: Vec<Slot> = Vec::new();
    // released slot indices, reused by later prepares (their generation
    // sequence continues, so freed handles never alias the new matrix)
    let mut free: Vec<usize> = Vec::new();
    while let Ok(msg) = rx.recv() {
        // the dequeued message no longer occupies the queue (the
        // counter was incremented by the client at submission)
        depth.fetch_sub(1, Ordering::Relaxed);
        match msg {
            ShardMsg::Shutdown => {
                // graceful drain: anything queued behind the shutdown
                // (FIFO, so it was submitted after stop began) resolves
                // to a typed ServiceStopped instead of a dropped channel
                loop {
                    match rx.try_recv() {
                        Ok(late) => {
                            depth.fetch_sub(1, Ordering::Relaxed);
                            late.reject(Pars3Error::ServiceStopped);
                        }
                        Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                    }
                }
                break;
            }
            ShardMsg::Prepare { replace, name, coo, reply } => {
                let result = (|| {
                    // validate the replace target BEFORE the expensive
                    // preprocessing (fail fast on stale handles) — the
                    // same slot -> generation -> occupancy checks every
                    // other handle lookup runs
                    if let Some((slot, held)) = replace {
                        resolve(&slots, shard, slot, held)?;
                    }
                    let prep = coord.prepare(&name, &coo)?;
                    let slot = match replace {
                        Some((slot, _)) => slot,
                        None => match free.pop() {
                            Some(slot) => slot,
                            None => {
                                slots.push(Slot { generation: 0, prep: None });
                                slots.len() - 1
                            }
                        },
                    };
                    let generation = slots[slot].generation + 1;
                    // replacing a registration drops its cached
                    // kernels — they'd pin the old matrix and never
                    // be hit again (new Arc identity)
                    let old =
                        std::mem::replace(&mut slots[slot], Slot { generation, prep: Some(prep) });
                    if let Some(old_prep) = old.prep {
                        coord.evict(&old_prep);
                    }
                    Ok(MatrixHandle { service, shard, slot, generation })
                })();
                let _ = reply.send(result);
            }
            ShardMsg::Describe { slot, generation, reply } => {
                let result = resolve(&slots, shard, slot, generation).map(|prep| MatrixInfo {
                    name: prep.name.clone(),
                    n: prep.n,
                    nnz_lower: prep.nnz_lower,
                    bw_before: prep.bw_before,
                    reordered_bw: prep.reordered_bw,
                    choice: prep.choice,
                    plan: prep.plan.clone(),
                });
                let _ = reply.send(result);
            }
            ShardMsg::Release { slot, generation, reply } => {
                let result = (|| {
                    let s = slots
                        .get_mut(slot)
                        .ok_or(Pars3Error::UnknownMatrix { shard, slot })?;
                    if s.generation != generation {
                        // double release lands here: the first release
                        // bumped the generation, so the handle is stale
                        return Err(Pars3Error::StaleHandle {
                            shard,
                            slot,
                            held: generation,
                            current: s.generation,
                        });
                    }
                    let Some(prep) = s.prep.take() else {
                        // current generation but empty slot: cannot
                        // happen under the monotone-bump protocol;
                        // defensively report unknown
                        return Err(Pars3Error::UnknownMatrix { shard, slot });
                    };
                    // bump the generation so every copy of the released
                    // handle is stale from here on, then free the slot
                    s.generation += 1;
                    coord.evict(&prep);
                    free.push(slot);
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            ShardMsg::Spmv { slot, generation, x, backend, reply } => {
                let result = resolve(&slots, shard, slot, generation)
                    .and_then(|prep| coord.spmv(prep, &x, backend));
                let _ = reply.send(result);
            }
            ShardMsg::Solve { slot, generation, b, opts, backend, reply } => {
                let result = resolve(&slots, shard, slot, generation)
                    .and_then(|prep| coord.solve(prep, &b, &opts, backend));
                let _ = reply.send(result);
            }
            ShardMsg::SpmvBatch { slot, generation, xs, backend, reply } => {
                let result = resolve(&slots, shard, slot, generation)
                    .and_then(|prep| coord.spmv_batch(prep, &xs, backend));
                let _ = reply.send(result);
            }
            ShardMsg::SolveBatch { slot, generation, bs, opts, backend, reply } => {
                let result = resolve(&slots, shard, slot, generation)
                    .and_then(|prep| coord.solve_batch(prep, &bs, &opts, backend));
                let _ = reply.send(result);
            }
            ShardMsg::CacheStats { reply } => {
                let (cached, built) = coord.kernel_cache_stats();
                let queue_depth = depth.load(Ordering::Relaxed);
                let _ = reply.send(Ok(CacheStats { shard, cached, built, queue_depth }));
            }
        }
    }
}

/// Handle to a running sharded service. [`Service::client`] mints
/// [`Client`]s; [`Service::stop`] (idempotent, `&self` so it works
/// through an `Arc` from a network front-end), [`Service::shutdown`],
/// or dropping stops every shard worker **gracefully**: requests
/// dequeued before the stop complete normally, requests still queued —
/// and every submission from then on — resolve to the typed
/// [`Pars3Error::ServiceStopped`] instead of hanging or reporting a
/// worker panic.
pub struct Service {
    shared: Arc<ServiceShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Service {
    /// Spawn `cfg.shards` worker threads, each with its own
    /// [`Coordinator`] and a bounded queue of `cfg.queue_depth`
    /// requests.
    pub fn start(cfg: Config) -> Self {
        let service_id = NEXT_SERVICE_ID.fetch_add(1, Ordering::Relaxed);
        let shards = cfg.shards.max(1);
        let depth = cfg.queue_depth.max(1);
        let mut senders = Vec::with_capacity(shards);
        let mut depths = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = sync_channel::<ShardMsg>(depth);
            let gauge = Arc::new(AtomicUsize::new(0));
            let worker_cfg = cfg.clone();
            let worker_gauge = gauge.clone();
            workers.push(std::thread::spawn(move || {
                shard_worker(shard, service_id, worker_cfg, rx, worker_gauge)
            }));
            senders.push(tx);
            depths.push(gauge);
        }
        Self {
            shared: Arc::new(ServiceShared::new(senders, depths, service_id)),
            workers: Mutex::new(workers),
        }
    }

    /// A new client over this service's shard pool. Clients (and their
    /// clones) are independent; all share the round-robin placement
    /// counter for `prepare`.
    pub fn client(&self) -> Client {
        Client::new(self.shared.clone())
    }

    /// Number of shard workers.
    pub fn num_shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Stop the service **gracefully** and join every shard worker.
    /// Takes `&self` so a network front-end holding the service in an
    /// `Arc` can stop it from a connection thread (a remote `Stop`
    /// message). The sequence:
    ///
    /// 1. The shared `stopped` flag flips, so every submission from any
    ///    [`Client`] clone from here on resolves
    ///    [`Pars3Error::ServiceStopped`] without touching a queue.
    /// 2. Each shard receives a shutdown message. FIFO order means
    ///    requests already queued ahead of it complete normally; the
    ///    worker then drains anything behind it, rejecting each with
    ///    `ServiceStopped`.
    /// 3. The workers are joined.
    ///
    /// Idempotent: later calls (including [`Drop`]) find the flag set
    /// and no workers left to join.
    pub fn stop(&self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        if workers.is_empty() {
            return;
        }
        for (tx, gauge) in self.shared.shards.iter().zip(&self.shared.depths) {
            // the worker decrements the gauge for every message it
            // dequeues, so count the shutdown too (send failure means
            // the worker is gone and will never decrement — undo)
            gauge.fetch_add(1, Ordering::Relaxed);
            // blocks only while the worker is alive and its queue is
            // full (it is draining); errors mean the worker already
            // exited — both are fine
            if tx.send(ShardMsg::Shutdown).is_err() {
                gauge.fetch_sub(1, Ordering::Relaxed);
            }
        }
        for w in workers {
            let _ = w.join();
        }
    }

    /// Stop every shard worker and join them (consuming spelling of
    /// [`Service::stop`]).
    pub fn shutdown(self) {
        self.stop();
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop(); // no-op when stop()/shutdown() already ran
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn one_shard_cfg() -> Config {
        Config { shards: 1, ..Config::default() }
    }

    #[test]
    fn prepare_then_spmv_and_solve() {
        let svc = Service::start(Config::default());
        let client = svc.client();
        let coo = gen::small_test_matrix(120, 21, 2.0);
        let h = client.prepare("m", coo).wait().unwrap();
        assert_eq!(h.generation(), 1);

        // the prepare metadata the old enum response carried inline is
        // queryable through the handle
        let info = client.describe(&h).wait().unwrap();
        assert_eq!((info.name.as_str(), info.n), ("m", 120));
        assert!(info.nnz_lower > 0 && info.reordered_bw <= info.bw_before);
        // the plan report rides along: the default all-auto config
        // scored every axis and chose a concrete triple
        assert_eq!(info.plan.reorder.bw_after, info.reordered_bw);
        assert_eq!(info.plan.reorder.candidates.len(), 3);
        assert_eq!(info.plan.reorder.candidates.iter().filter(|c| c.chosen).count(), 1);
        for ax in &info.plan.axes {
            assert!(!ax.pinned, "all-auto config must leave {} unpinned", ax.axis);
            assert!(ax.candidates.len() >= 2, "{} needs scored alternatives", ax.axis);
            assert_eq!(ax.candidates.iter().filter(|c| c.chosen).count(), 1);
        }
        // the chosen backend candidate in the report names the triple's
        // backend — the evidence and the decision cannot disagree
        let backend_axis = info.plan.axis("backend").expect("backend axis reported");
        assert_eq!(backend_axis.chosen, crate::coordinator::planner::backend_label(info.choice.backend));

        let x: Vec<f64> = (0..120).map(|i| i as f64 * 0.01).collect();
        let y = client.spmv(&h, x.clone(), Backend::Pars3 { p: 4 }).wait().unwrap();
        assert_eq!(y.len(), 120);

        let res = client
            .solve(&h, x, MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 }, Backend::Serial)
            .wait()
            .unwrap();
        assert!(res.converged);
        svc.shutdown();
    }

    #[test]
    fn batch_requests_roundtrip() {
        let svc = Service::start(Config::default());
        let client = svc.client();
        let coo = gen::small_test_matrix(90, 22, 2.0);
        let h = client.prepare("m", coo).wait().unwrap();

        let xs = VecBatch::from_fn(90, 3, |i, c| ((i + c * 7) % 5) as f64 - 2.0);
        let ys = client.spmv_batch(&h, xs.clone(), Backend::Pars3 { p: 3 }).wait().unwrap();
        assert_eq!((ys.n(), ys.k()), (90, 3));
        // cross-check column 0 against the single-vector path
        let y0 = client.spmv(&h, xs.col(0).to_vec(), Backend::Pars3 { p: 3 }).wait().unwrap();
        for (a, b) in ys.col(0).iter().zip(&y0) {
            assert!((a - b).abs() < 1e-9);
        }

        let results = client
            .solve_batch(
                &h,
                xs,
                MrsOptions { alpha: 2.0, max_iters: 400, tol: 1e-8 },
                Backend::Serial,
            )
            .wait()
            .unwrap();
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.converged));
        svc.shutdown();
    }

    #[test]
    fn pipelined_tickets_resolve_without_wait_ordering() {
        // the pipelining contract: a ticket submitted while another is
        // unresolved completes without anyone wait()ing on the first
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        let coo = gen::small_test_matrix(100, 2, 2.0);
        let h = client.prepare("m", coo).wait().unwrap();
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.2).sin()).collect();
        let t1 = client.spmv(&h, x.clone(), Backend::Serial);
        let t2 = client.spmv(&h, x.clone(), Backend::Serial);
        let mut t1 = t1;
        // wait on the LATER ticket first; FIFO within a shard means t1's
        // result is then already in its channel without t1.wait() ever
        // having been the thing that drove it
        let y2 = t2.wait().unwrap();
        let y1 = t1.try_wait().expect("t1 completed before t2 was even collected").unwrap();
        assert_eq!(y1, y2);
        svc.shutdown();
    }

    #[test]
    fn repeated_solves_hit_the_shard_local_kernel_cache() {
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        let coo = gen::small_test_matrix(100, 23, 2.0);
        let h = client.prepare("m", coo.clone()).wait().unwrap();
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!((stats.cached, stats.built), (0, 0));

        let b: Vec<f64> = (0..100).map(|i| ((i % 7) as f64) - 3.0).collect();
        // pipeline all four solves before collecting any result
        let opts = MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 };
        let tickets: Vec<_> = (0..4)
            .map(|_| client.solve(&h, b.clone(), opts.clone(), Backend::Pars3 { p: 3 }))
            .collect();
        for t in tickets {
            assert!(t.wait().unwrap().converged);
        }
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!((stats.cached, stats.built), (1, 1), "4 solves must build the kernel once");

        // re-preparing under the handle evicts the stale kernels
        let h2 = client.prepare_replace(&h, "m", coo).wait().unwrap();
        assert_eq!(h2.generation(), 2);
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!((stats.cached, stats.built), (0, 1), "re-prepare must drop the old kernel");
        svc.shutdown();
    }

    #[test]
    fn stale_and_unknown_handles_are_typed_errors() {
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        let coo = gen::small_test_matrix(80, 24, 2.0);
        let h1 = client.prepare("m", coo.clone()).wait().unwrap();

        // submit the replace FIRST, then a request with the old handle:
        // FIFO guarantees the worker sees the replace before the spmv,
        // which must then fail stale instead of touching the new matrix
        let replace = client.prepare_replace(&h1, "m", coo.clone());
        let against_old = client.spmv(&h1, vec![0.0; 80], Backend::Serial);
        let h2 = replace.wait().unwrap();
        assert_eq!((h2.slot, h2.generation), (h1.slot, h1.generation + 1));
        assert_eq!(
            against_old.wait().unwrap_err(),
            Pars3Error::StaleHandle { shard: h1.shard, slot: h1.slot, held: 1, current: 2 }
        );
        // the fresh handle works
        assert!(client.spmv(&h2, vec![0.0; 80], Backend::Serial).wait().is_ok());

        // replacing through the dead handle is itself rejected
        let err = client.prepare_replace(&h1, "m", coo).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::StaleHandle { held: 1, current: 2, .. }), "{err}");

        // a slot that never existed (same service, so it reaches the
        // worker's slot table and fails there)
        let fake = MatrixHandle { slot: 99, ..h2 };
        let err = client.spmv(&fake, vec![0.0; 80], Backend::Serial).wait().unwrap_err();
        assert_eq!(err, Pars3Error::UnknownMatrix { shard: h2.shard, slot: 99 });
        svc.shutdown();
    }

    #[test]
    fn release_frees_the_slot_for_reuse_and_stales_the_handle() {
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        let h1 = client.prepare("a", gen::small_test_matrix(70, 30, 2.0)).wait().unwrap();
        client.spmv(&h1, vec![1.0; 70], Backend::Serial).wait().unwrap();
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!((stats.cached, stats.built), (1, 1));

        client.release(&h1).wait().unwrap();
        // the matrix memory and its kernels are gone...
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!((stats.cached, stats.built), (0, 1), "release must evict the kernels");
        // ...every copy of the handle is stale...
        let err = client.spmv(&h1, vec![1.0; 70], Backend::Serial).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::StaleHandle { held: 1, current: 2, .. }), "{err}");
        // ...double release is stale too...
        let err = client.release(&h1).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::StaleHandle { .. }), "{err}");
        // ...and the next prepare REUSES the freed slot, generation
        // continuing past the released one (no aliasing possible)
        let h2 = client.prepare("b", gen::small_test_matrix(80, 31, 2.0)).wait().unwrap();
        assert_eq!(h2.slot, h1.slot, "freed slot must be reused");
        assert_eq!(h2.generation(), 3);
        client.spmv(&h2, vec![1.0; 80], Backend::Serial).wait().unwrap();
        svc.shutdown();
    }

    #[test]
    fn handles_from_another_service_are_rejected() {
        let svc_a = Service::start(one_shard_cfg());
        let svc_b = Service::start(one_shard_cfg());
        let coo = gen::small_test_matrix(60, 32, 2.0);
        let ha = svc_a.client().prepare("a", coo.clone()).wait().unwrap();
        // same shard/slot/generation exist on B, but the handle must
        // not resolve against B's (unrelated) slot table
        let hb = svc_b.client().prepare("b", coo).wait().unwrap();
        assert_eq!((ha.shard, ha.slot, ha.generation), (hb.shard, hb.slot, hb.generation));
        let err = svc_b.client().spmv(&ha, vec![0.0; 60], Backend::Serial).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::ForeignHandle { .. }), "{err}");
        // and a foreign prepare_replace cannot bump B's generations
        let err = svc_b
            .client()
            .prepare_replace(&ha, "evil", gen::small_test_matrix(60, 33, 2.0))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Pars3Error::ForeignHandle { .. }), "{err}");
        assert!(svc_b.client().spmv(&hb, vec![0.0; 60], Backend::Serial).wait().is_ok());
        svc_a.shutdown();
        svc_b.shutdown();
    }

    #[test]
    fn lru_cap_evicts_and_rebuilds_in_the_service_path() {
        // cap each shard's cache at 1 kernel: alternating matrices must
        // evict each other and rebuild on return (built keeps climbing),
        // while a single-matrix stream stays at one build
        let svc = Service::start(Config { shards: 1, max_cached_kernels: 1, ..Config::default() });
        let client = svc.client();
        let ha = client.prepare("a", gen::small_test_matrix(80, 27, 2.0)).wait().unwrap();
        let hb = client.prepare("b", gen::small_test_matrix(90, 28, 2.0)).wait().unwrap();
        let xa = vec![1.0; 80];
        let xb = vec![1.0; 90];

        client.spmv(&ha, xa.clone(), Backend::Serial).wait().unwrap();
        client.spmv(&ha, xa.clone(), Backend::Serial).wait().unwrap();
        let s = client.cache_stats(0).wait().unwrap();
        assert_eq!((s.cached, s.built), (1, 1), "one matrix: cache hit");

        client.spmv(&hb, xb, Backend::Serial).wait().unwrap(); // evicts a's kernel
        let s = client.cache_stats(0).wait().unwrap();
        assert_eq!((s.cached, s.built), (1, 2));

        client.spmv(&ha, xa, Backend::Serial).wait().unwrap(); // rebuild after eviction
        let s = client.cache_stats(0).wait().unwrap();
        assert_eq!((s.cached, s.built), (1, 3), "evicted kernel must rebuild");
        svc.shutdown();
    }

    #[test]
    fn cache_stats_all_aggregates_every_shard() {
        let svc = Service::start(Config { shards: 3, ..Config::default() });
        let client = svc.client();
        let h = client.prepare("m", gen::small_test_matrix(80, 40, 2.0)).wait().unwrap();
        client.spmv(&h, vec![1.0; 80], Backend::Serial).wait().unwrap();

        let all = client.cache_stats_all().wait().unwrap();
        assert_eq!(all.len(), 3, "one entry per shard");
        for (i, s) in all.iter().enumerate() {
            assert_eq!(s.shard, i, "entries arrive in shard order");
            // idle service: every queue has drained
            assert_eq!(s.queue_depth, 0);
        }
        // exactly the owning shard built a kernel
        assert_eq!(all.iter().map(|s| s.built).sum::<usize>(), 1);
        assert_eq!(all[h.shard()].built, 1);
        svc.shutdown();
    }

    #[test]
    fn describe_reports_the_configured_strategy() {
        use crate::graph::reorder::ReorderPolicy;
        let svc = Service::start(Config {
            shards: 1,
            reorder: ReorderPolicy::Natural,
            ..Config::default()
        });
        let client = svc.client();
        let h = client.prepare("m", gen::small_test_matrix(70, 41, 2.0)).wait().unwrap();
        let info = client.describe(&h).wait().unwrap();
        assert_eq!(info.plan.reorder.requested, ReorderPolicy::Natural);
        assert_eq!(info.plan.reorder.strategy, "natural");
        assert_eq!(info.reordered_bw, info.bw_before);
        // pinning reorder must not disable planning on the other axes
        let reorder_axis = info.plan.axis("reorder").unwrap();
        assert!(reorder_axis.pinned);
        for name in ["format", "backend"] {
            let ax = info.plan.axis(name).unwrap();
            assert!(!ax.pinned && ax.candidates.len() >= 2, "{name} stays planned");
        }
        // the measured roofline point of the chosen backend rides along
        let roof = info.plan.roofline.expect("describe carries the plan's roofline");
        assert!(roof.gflops > 0.0 && roof.gbytes > 0.0 && roof.achieved_fraction > 0.0);
        svc.shutdown();
    }

    #[test]
    fn describe_after_replace_reflects_the_new_plan() {
        // regression: re-preparing under a handle must surface the NEW
        // matrix's plan through describe, and the kernel cache (keyed on
        // the plan choice + matrix identity) must never serve a kernel
        // built for the replaced matrix's triple
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        // a banded matrix (reordering helps, dense interior) ...
        let h = client.prepare("a", gen::small_test_matrix(100, 42, 2.0)).wait().unwrap();
        let before = client.describe(&h).wait().unwrap();
        client.spmv(&h, vec![1.0; 100], Backend::Serial).wait().unwrap();

        // ... replaced by a different matrix with a different dimension
        let h2 = client.prepare_replace(&h, "b", gen::small_test_matrix(140, 43, 2.0)).wait().unwrap();
        let after = client.describe(&h2).wait().unwrap();
        assert_eq!((after.name.as_str(), after.n), ("b", 140));
        assert_ne!(
            (before.n, before.nnz_lower),
            (after.n, after.nnz_lower),
            "describe must reflect the replacement, not the original"
        );
        // the new registration carries its own complete plan evidence
        assert_eq!(after.plan.reorder.bw_after, after.reordered_bw);
        for ax in &after.plan.axes {
            assert_eq!(ax.candidates.iter().filter(|c| c.chosen).count(), 1, "{}", ax.axis);
        }
        // requests against the new handle execute at the new dimension —
        // the old 100-dim kernels were evicted with the old matrix
        let y = client.spmv(&h2, vec![1.0; 140], Backend::Serial).wait().unwrap();
        assert_eq!(y.len(), 140);
        let stats = client.cache_stats(0).wait().unwrap();
        assert_eq!(stats.cached, 1, "only the replacement's kernel remains cached");
        svc.shutdown();
    }

    #[test]
    fn invalid_matrix_is_a_typed_prepare_error() {
        let svc = Service::start(Config::default());
        let client = svc.client();
        let mut coo = Coo::new(4);
        coo.push(1, 0, 2.0);
        coo.push(0, 1, 2.0); // symmetric — must be rejected
        let err = client.prepare("bad", coo).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::InvalidMatrix(_)), "{err}");
        svc.shutdown();
    }

    #[test]
    fn stop_is_graceful_and_types_late_requests() {
        use crate::coordinator::client::Ticket;
        let svc = Service::start(one_shard_cfg());
        let client = svc.client();
        let h = client.prepare("m", gen::small_test_matrix(60, 50, 2.0)).wait().unwrap();

        // a request in flight when stop() is called was queued BEFORE
        // the shutdown message (FIFO), so it completes normally
        let inflight = client.spmv(&h, vec![1.0; 60], Backend::Serial);
        svc.stop();
        assert_eq!(inflight.wait().unwrap().len(), 60, "in-flight work completes on stop");

        // every submission after stop() fails typed, without hanging
        let err = client.spmv(&h, vec![1.0; 60], Backend::Serial).wait().unwrap_err();
        assert_eq!(err, Pars3Error::ServiceStopped);
        let err = client.prepare("late", gen::small_test_matrix(40, 51, 2.0)).wait().unwrap_err();
        assert_eq!(err, Pars3Error::ServiceStopped);
        let err = client.cache_stats(0).wait().unwrap_err();
        assert_eq!(err, Pars3Error::ServiceStopped);

        // a request that raced the flag and landed in the queue BEHIND
        // the shutdown message is drained with the same typed error.
        // Reconstruct that interleaving deterministically: queue both
        // messages, then run the worker loop inline.
        let (tx, rx) = sync_channel::<ShardMsg>(8);
        let gauge = Arc::new(AtomicUsize::new(2));
        let (reply, reply_rx) = std::sync::mpsc::channel();
        tx.send(ShardMsg::Shutdown).unwrap();
        tx.send(ShardMsg::CacheStats { reply }).unwrap();
        shard_worker(0, 999, one_shard_cfg(), rx, gauge.clone());
        let t: Ticket<CacheStats> = Ticket::pending(0, reply_rx);
        assert_eq!(t.wait().unwrap_err(), Pars3Error::ServiceStopped);
        assert_eq!(gauge.load(Ordering::Relaxed), 0, "drain must settle the queue gauge");

        // stop through an Arc (the network front-end shape: connection
        // threads share the service and stop it on a remote Stop)
        let svc = Arc::new(Service::start(one_shard_cfg()));
        let svc2 = svc.clone();
        std::thread::spawn(move || svc2.stop()).join().unwrap();
        assert_eq!(
            svc.client().cache_stats(0).wait().unwrap_err(),
            Pars3Error::ServiceStopped
        );
    }

    #[test]
    fn round_robin_spreads_matrices_across_shards() {
        let svc = Service::start(Config { shards: 2, ..Config::default() });
        let client = svc.client();
        let h0 = client.prepare("a", gen::small_test_matrix(60, 1, 2.0)).wait().unwrap();
        let h1 = client.prepare("b", gen::small_test_matrix(60, 2, 2.0)).wait().unwrap();
        let h2 = client.prepare("c", gen::small_test_matrix(60, 3, 2.0)).wait().unwrap();
        assert_ne!(h0.shard(), h1.shard());
        assert_eq!(h0.shard(), h2.shard(), "round-robin wraps");
        assert_eq!(svc.num_shards(), 2);
        assert_eq!(client.num_shards(), 2);
        svc.shutdown();
    }
}
