//! Measured preprocessing planner: pick the (reorder, format, backend)
//! **triple** jointly instead of through three independent `Auto` knobs.
//!
//! The paper's economics — preprocessing pays for itself over repeated
//! SpMVs (§4) — only hold if the preprocessing decisions are the right
//! ones *together*: RACE (Alappat et al., 1907.06487) shows
//! coloring-style kernels win exactly where RCM fails to band, i.e.
//! where the reorder quality gate (Asudeh et al.) declines, and the
//! DIA-vs-SSS storage choice shifts which backend is
//! bandwidth-optimal. [`Planner::plan`] therefore resolves all three
//! axes in one pass:
//!
//! 1. **reorder** — the candidate-scoring loop formerly private to
//!    [`crate::graph::reorder::Auto`] lives here as
//!    [`score_reorder_candidates`]: every strategy is scored by
//!    (bandwidth, envelope profile) and the natural order is kept
//!    unless the best reordering clears `reorder_min_gain`.
//! 2. **format** — DIA and SSS middle storage are scored by estimated
//!    bytes streamed per `apply` (the measured-candidate generalization
//!    of the old fixed 0.5 fill threshold, which
//!    [`FormatPolicy::Auto`] still applies on the direct registry
//!    path).
//! 3. **backend** — every registry kernel gets a structural byte proxy
//!    (nnz, bandwidth, [`Split3::row_work`] balance across ranks);
//!    with a probe budget (`plan_probe` / `--plan-probe`) the planner
//!    instead *times* a few real `apply` calls on each candidate
//!    kernel and scores by the minimum.
//!
//! [`crate::coordinator::Config`]'s `reorder`/`format`/`backend` act as
//! **constraints**: pinning one axis restricts the plan space on that
//! axis only — the others are still planned. `plan = "pinned"` turns
//! the planner off wholesale and resolves every axis by the legacy
//! per-axis rules (bit-for-bit the pre-planner behavior). Every plan
//! emits a [`PlanReport`] — per-axis candidates, scores, probe
//! timings, chosen flags, decline reasons — that flows through
//! [`crate::coordinator::Prepared`], [`crate::coordinator::MatrixInfo`]
//! / `Client::describe`, `Pars3Stats`, the kernel-cache key, and the
//! CLI output, so every prepared matrix carries the evidence for how
//! it was prepared.

use crate::coordinator::config::Config;
use crate::coordinator::error::Pars3Error;
use crate::coordinator::pipeline::Backend;
use crate::graph::bfs::{level_structure_with, LevelStructure};
use crate::graph::peripheral::{bi_criteria_start_from, pseudo_peripheral_ls_from};
use crate::graph::rcm::{bandwidth_under, profile_under};
use crate::graph::reorder::{
    rcm_per_component_with, CandidateScore, Natural, PrepareTimings, ReorderOutcome,
    ReorderPolicy, ReorderReport, ReorderStrategy,
};
use crate::graph::Adjacency;
use crate::kernel::dia::{DiaBand, FormatPolicy};
use crate::kernel::race::RaceStructure;
use crate::kernel::registry::{self, KernelConfig};
use crate::kernel::split3::Split3;
use crate::perf::Roofline;
use crate::sparse::{Coo, Sss};
use crate::util::json::Json;
use crate::util::pool::PrepPool;
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::time::Instant;

/// Whether `prepare` plans jointly or resolves each axis by the legacy
/// per-axis rules (config `plan = auto|pinned`, CLI `--plan`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PlanMode {
    /// Joint planning: every axis not pinned by config is scored and
    /// chosen by the planner.
    #[default]
    Auto,
    /// Legacy resolution: `reorder`/`format`/`backend` mean exactly
    /// what they meant before the planner existed (including their own
    /// per-axis `Auto` heuristics).
    Pinned,
}

impl PlanMode {
    /// Config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Auto => "auto",
            PlanMode::Pinned => "pinned",
        }
    }
}

impl fmt::Display for PlanMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PlanMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        Ok(match s {
            "auto" => PlanMode::Auto,
            "pinned" => PlanMode::Pinned,
            other => anyhow::bail!("unknown plan mode '{other}' (expected auto|pinned)"),
        })
    }
}

/// Backend **constraint** (config `backend = ...`, CLI `--backend`):
/// `Auto` leaves the axis to the planner, anything else pins it.
/// Thread counts are not part of the policy — the planner supplies `p`
/// when it resolves a parallel backend (see [`BackendPolicy::resolve`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendPolicy {
    /// Let the planner choose among the registry backends.
    #[default]
    Auto,
    /// Pin the serial SSS baseline.
    Serial,
    /// Pin plain CSR.
    Csr,
    /// Pin the dense-band `dgbmv` kernel.
    Dgbmv,
    /// Pin the graph-coloring phased kernel.
    Coloring,
    /// Pin the RACE-style recursive level-coloring kernel.
    Race,
    /// Pin the PARS3 3-way split kernel.
    Pars3,
    /// Pin the PJRT accelerator path (outside the registry; never part
    /// of the auto plan space and never probed).
    Pjrt,
}

impl BackendPolicy {
    /// Config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            BackendPolicy::Auto => "auto",
            BackendPolicy::Serial => "serial",
            BackendPolicy::Csr => "csr",
            BackendPolicy::Dgbmv => "dgbmv",
            BackendPolicy::Coloring => "coloring",
            BackendPolicy::Race => "race",
            BackendPolicy::Pars3 => "pars3",
            BackendPolicy::Pjrt => "pjrt",
        }
    }

    /// Concrete backend this policy pins (parallel backends get rank
    /// count `p`), or `None` for [`BackendPolicy::Auto`].
    pub fn resolve(self, p: usize) -> Option<Backend> {
        match self {
            BackendPolicy::Auto => None,
            BackendPolicy::Serial => Some(Backend::Serial),
            BackendPolicy::Csr => Some(Backend::Csr),
            BackendPolicy::Dgbmv => Some(Backend::Dgbmv),
            BackendPolicy::Coloring => Some(Backend::Coloring { p }),
            BackendPolicy::Race => Some(Backend::Race { p }),
            BackendPolicy::Pars3 => Some(Backend::Pars3 { p }),
            BackendPolicy::Pjrt => Some(Backend::Pjrt),
        }
    }
}

impl fmt::Display for BackendPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BackendPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        Ok(match s {
            "auto" => BackendPolicy::Auto,
            "serial" => BackendPolicy::Serial,
            "csr" => BackendPolicy::Csr,
            "dgbmv" => BackendPolicy::Dgbmv,
            "coloring" => BackendPolicy::Coloring,
            "race" => BackendPolicy::Race,
            "pars3" => BackendPolicy::Pars3,
            "pjrt" => BackendPolicy::Pjrt,
            other => anyhow::bail!(
                "unknown backend '{other}' \
                 (expected auto|serial|csr|dgbmv|coloring|race|pars3|pjrt)"
            ),
        })
    }
}

/// Human-readable label for a concrete [`Backend`] (parallel backends
/// include their rank count).
pub fn backend_label(b: Backend) -> String {
    match b {
        Backend::Serial => "serial".to_string(),
        Backend::Csr => "csr".to_string(),
        Backend::Dgbmv => "dgbmv".to_string(),
        Backend::Coloring { p } => format!("coloring(p={p})"),
        Backend::Race { p } => format!("race(p={p})"),
        Backend::Pars3 { p } => format!("pars3(p={p})"),
        Backend::Pjrt => "pjrt".to_string(),
    }
}

/// The plan space and per-axis pins [`Planner::plan`] works under —
/// built from a [`Config`] via [`PlanConstraints::from_config`].
#[derive(Debug, Clone)]
pub struct PlanConstraints {
    /// Joint planning vs legacy per-axis resolution.
    pub mode: PlanMode,
    /// Reorder axis: [`ReorderPolicy::Auto`] leaves it to the planner.
    pub reorder: ReorderPolicy,
    /// The reorder quality gate (fractional bandwidth improvement a
    /// reordering must clear over natural).
    pub reorder_min_gain: f64,
    /// Format axis: [`FormatPolicy::Auto`] leaves it to the planner.
    pub format: FormatPolicy,
    /// Backend axis: [`BackendPolicy::Auto`] leaves it to the planner.
    pub backend: BackendPolicy,
    /// Outer-split bandwidth for the 3-way split (paper default 3).
    pub outer_bw: usize,
    /// Rank count candidate parallel backends are planned at (clamped
    /// to the matrix size).
    pub threads: usize,
    /// Real threads vs deterministic emulated executors (probe kernels
    /// honor this so probe timings reflect the execution mode).
    pub threaded: bool,
    /// Number of timed `apply` calls per backend candidate; `0`
    /// disables probing and scores backends structurally.
    pub probe_spmvs: usize,
    /// Cache budget (KiB) probe kernels tile their band passes with
    /// (must match execution so probe timings transfer).
    pub l2_kib: usize,
    /// Prepare-pool width: BFS/RCM/format construction and the probe
    /// loop run across this many workers (the permutation is identical
    /// for every width — parallelism is an execution detail).
    pub prepare_threads: usize,
}

impl PlanConstraints {
    /// Derive the constraints a [`Config`] expresses. The planning
    /// rank count is the registry default
    /// ([`KernelConfig::default`]`.threads`); per-call overrides (CLI
    /// `--p`) apply at execution, not planning.
    pub fn from_config(cfg: &Config) -> Self {
        Self {
            mode: cfg.plan,
            reorder: cfg.reorder,
            reorder_min_gain: cfg.reorder_min_gain,
            format: cfg.format,
            backend: cfg.backend,
            outer_bw: cfg.outer_bw,
            threads: KernelConfig::default().threads,
            threaded: cfg.threaded,
            probe_spmvs: cfg.plan_probe,
            l2_kib: cfg.l2_kib,
            prepare_threads: cfg.prepare_threads,
        }
    }
}

/// The resolved (reorder, format, backend) triple. Part of the
/// kernel-cache key, so a re-plan can never be served a kernel built
/// for a different triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanChoice {
    /// Concrete reorder policy matching the chosen strategy (never
    /// `Auto` under [`PlanMode::Auto`]; verbatim config under
    /// [`PlanMode::Pinned`]). Pinning this policy through an old-style
    /// config reproduces the plan's permutation exactly.
    pub reorder: ReorderPolicy,
    /// Middle-split storage kernels are built with.
    pub format: FormatPolicy,
    /// Backend `spmv`/`solve` default to when the caller does not name
    /// one.
    pub backend: Backend,
}

impl PlanChoice {
    /// One-line `reorder=... format=... backend=...` label (also the
    /// `plan_triple` stamped into `Pars3Stats`).
    pub fn describe(&self) -> String {
        format!(
            "reorder={} format={} backend={}",
            self.reorder,
            self.format,
            backend_label(self.backend)
        )
    }

    /// JSON encoding for the wire.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("reorder".to_string(), Json::Str(self.reorder.name().to_string()));
        m.insert("format".to_string(), Json::Str(self.format.to_string()));
        m.insert("backend".to_string(), backend_to_json(self.backend));
        Json::Obj(m)
    }

    /// Inverse of [`PlanChoice::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(PlanChoice {
            reorder: j.req("reorder")?.as_str()?.parse()?,
            format: j.req("format")?.as_str()?.parse()?,
            backend: backend_from_json(j.req("backend")?)?,
        })
    }
}

/// Structured JSON form of a concrete [`Backend`]: `{"kind": ...}` plus
/// `"p"` for the parallel backends (the display label `pars3(p=8)` is
/// for humans; the wire wants something parseable without string
/// surgery).
pub fn backend_to_json(b: Backend) -> Json {
    let mut m = std::collections::BTreeMap::new();
    let (kind, p) = match b {
        Backend::Serial => ("serial", None),
        Backend::Csr => ("csr", None),
        Backend::Dgbmv => ("dgbmv", None),
        Backend::Coloring { p } => ("coloring", Some(p)),
        Backend::Race { p } => ("race", Some(p)),
        Backend::Pars3 { p } => ("pars3", Some(p)),
        Backend::Pjrt => ("pjrt", None),
    };
    m.insert("kind".to_string(), Json::Str(kind.to_string()));
    if let Some(p) = p {
        m.insert("p".to_string(), Json::Num(p as f64));
    }
    Json::Obj(m)
}

/// Inverse of [`backend_to_json`].
pub fn backend_from_json(j: &Json) -> anyhow::Result<Backend> {
    let p = || j.req("p")?.as_usize();
    Ok(match j.req("kind")?.as_str()? {
        "serial" => Backend::Serial,
        "csr" => Backend::Csr,
        "dgbmv" => Backend::Dgbmv,
        "coloring" => Backend::Coloring { p: p()? },
        "race" => Backend::Race { p: p()? },
        "pars3" => Backend::Pars3 { p: p()? },
        "pjrt" => Backend::Pjrt,
        other => anyhow::bail!("unknown backend kind '{other}'"),
    })
}

/// One scored candidate on one plan axis.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanCandidate {
    /// Candidate label (`"rcm"`, `"dia"`, `"pars3(p=8)"`, ...).
    pub name: String,
    /// Score the planner compared (lower is better): bandwidth for the
    /// reorder axis, estimated bytes per `apply` for format/backend,
    /// or the probe minimum in seconds when probing.
    pub score: f64,
    /// Human-readable evidence behind the score.
    pub detail: String,
    /// Minimum timed `apply` over the probe budget, when probed.
    pub probe_s: Option<f64>,
    /// Whether this candidate won its axis.
    pub chosen: bool,
}

/// Everything the planner weighed on one axis.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisReport {
    /// `"reorder"`, `"format"`, or `"backend"`.
    pub axis: &'static str,
    /// True when config/CLI pinned this axis (or `plan = "pinned"`
    /// disabled planning wholesale).
    pub pinned: bool,
    /// Label of the winning candidate.
    pub chosen: String,
    /// Every candidate scored, in scoring order, exactly one `chosen`
    /// on an unpinned axis.
    pub candidates: Vec<PlanCandidate>,
    /// Why the planner kept the status quo on an unpinned axis (the
    /// Asudeh-style decline gate for reorder, DIA rejection for
    /// format); `None` when a transforming candidate won or the axis
    /// was pinned.
    pub decline: Option<String>,
}

/// The [`ReorderReport`] generalized across all three plan axes: the
/// evidence record every prepared matrix carries.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanReport {
    /// Mode the plan was made under.
    pub mode: PlanMode,
    /// The full instrumented reorder report (bandwidth/profile
    /// before/after, per-component stats, candidate scores) — the
    /// pre-planner `ReorderReport` surface, unchanged.
    pub reorder: ReorderReport,
    /// Per-axis candidates, scores, and decline reasons, in
    /// reorder/format/backend order.
    pub axes: Vec<AxisReport>,
    /// Probe budget the plan ran with (0 = structural scoring only).
    pub probe_spmvs: usize,
    /// Measured roofline point of the chosen backend: the probe
    /// minimum when the backend axis was probed, otherwise a one-shot
    /// measurement taken at plan time. `None` only for PJRT (no CPU
    /// kernel to measure).
    pub roofline: Option<Roofline>,
}

impl PlanReport {
    /// Look up one axis by name.
    pub fn axis(&self, name: &str) -> Option<&AxisReport> {
        self.axes.iter().find(|a| a.axis == name)
    }

    /// One-line plan summary: mode, per-axis winner, candidate counts.
    pub fn summary(&self) -> String {
        let mut s = format!("plan[{}]", self.mode);
        for ax in &self.axes {
            let pin = if ax.pinned { ", pinned" } else { "" };
            s.push_str(&format!(
                " {}={} ({} candidate(s){})",
                ax.axis,
                ax.chosen,
                ax.candidates.len(),
                pin
            ));
        }
        if let Some(r) = &self.roofline {
            s.push_str(&format!(" | roofline {}", r.summary()));
        }
        s
    }

    /// Multi-line evidence dump: every candidate with score, probe
    /// timing, chosen flag, plus per-axis decline reasons.
    pub fn detail(&self) -> String {
        let mut s = String::new();
        for ax in &self.axes {
            s.push_str(&format!(
                "{} axis{}:\n",
                ax.axis,
                if ax.pinned { " (pinned)" } else { "" }
            ));
            for c in &ax.candidates {
                let mark = if c.chosen { '*' } else { ' ' };
                let probe = match c.probe_s {
                    Some(t) => format!(" probe {t:.3e}s"),
                    None => String::new(),
                };
                s.push_str(&format!(
                    "  {mark} {:<16} score {:>12.3}{probe}  {}\n",
                    c.name, c.score, c.detail
                ));
            }
            if let Some(d) = &ax.decline {
                s.push_str(&format!("    declined: {d}\n"));
            }
        }
        s
    }

    /// JSON encoding for the wire (`describe` responses carry the full
    /// evidence tree across process boundaries).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("mode".to_string(), Json::Str(self.mode.name().to_string()));
        m.insert("reorder".to_string(), self.reorder.to_json());
        m.insert("axes".to_string(), Json::Arr(self.axes.iter().map(|a| a.to_json()).collect()));
        m.insert("probe_spmvs".to_string(), Json::Num(self.probe_spmvs as f64));
        m.insert(
            "roofline".to_string(),
            match &self.roofline {
                Some(r) => r.to_json(),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// Inverse of [`PlanReport::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(PlanReport {
            mode: j.req("mode")?.as_str()?.parse()?,
            reorder: crate::graph::reorder::ReorderReport::from_json(j.req("reorder")?)?,
            axes: j
                .req("axes")?
                .as_arr()?
                .iter()
                .map(AxisReport::from_json)
                .collect::<anyhow::Result<_>>()?,
            probe_spmvs: j.req("probe_spmvs")?.as_usize()?,
            roofline: match j.req("roofline")? {
                Json::Null => None,
                r => Some(Roofline::from_json(r)?),
            },
        })
    }
}

/// Intern an axis name back to the `&'static str` the report structs
/// hold (there are exactly three axes, ever).
fn axis_named(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name {
        "reorder" => "reorder",
        "format" => "format",
        "backend" => "backend",
        other => anyhow::bail!("unknown plan axis '{other}'"),
    })
}

impl PlanCandidate {
    /// JSON encoding for the wire.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("score".to_string(), Json::Num(self.score));
        m.insert("detail".to_string(), Json::Str(self.detail.clone()));
        m.insert(
            "probe_s".to_string(),
            match self.probe_s {
                Some(t) => Json::Num(t),
                None => Json::Null,
            },
        );
        m.insert("chosen".to_string(), Json::Bool(self.chosen));
        Json::Obj(m)
    }

    /// Inverse of [`PlanCandidate::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(PlanCandidate {
            name: j.req("name")?.as_str()?.to_string(),
            score: j.req("score")?.as_f64()?,
            detail: j.req("detail")?.as_str()?.to_string(),
            probe_s: match j.req("probe_s")? {
                Json::Null => None,
                t => Some(t.as_f64()?),
            },
            chosen: matches!(j.req("chosen")?, Json::Bool(true)),
        })
    }
}

impl AxisReport {
    /// JSON encoding for the wire.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("axis".to_string(), Json::Str(self.axis.to_string()));
        m.insert("pinned".to_string(), Json::Bool(self.pinned));
        m.insert("chosen".to_string(), Json::Str(self.chosen.clone()));
        m.insert(
            "candidates".to_string(),
            Json::Arr(self.candidates.iter().map(|c| c.to_json()).collect()),
        );
        m.insert(
            "decline".to_string(),
            match &self.decline {
                Some(d) => Json::Str(d.clone()),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// Inverse of [`AxisReport::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(AxisReport {
            axis: axis_named(j.req("axis")?.as_str()?)?,
            pinned: matches!(j.req("pinned")?, Json::Bool(true)),
            chosen: j.req("chosen")?.as_str()?.to_string(),
            candidates: j
                .req("candidates")?
                .as_arr()?
                .iter()
                .map(PlanCandidate::from_json)
                .collect::<anyhow::Result<_>>()?,
            decline: match j.req("decline")? {
                Json::Null => None,
                d => Some(d.as_str()?.to_string()),
            },
        })
    }
}

/// Output of [`Planner::plan`]: the choice, the evidence, and the
/// preprocessed matrix artifacts (permutation, reordered SSS, 3-way
/// split with the chosen format already selected).
#[derive(Debug, Clone)]
pub struct Planned {
    /// The resolved (reorder, format, backend) triple.
    pub choice: PlanChoice,
    /// Per-axis evidence for the choice.
    pub report: PlanReport,
    /// Chosen permutation (`perm[old] = new`).
    pub perm: Vec<u32>,
    /// The reordered skew-symmetric matrix.
    pub sss: Sss,
    /// The 3-way band split, with [`PlanChoice::format`] selected.
    pub split: Split3,
}

/// The joint (reorder, format, backend) planner. Stateless; all inputs
/// arrive through [`PlanConstraints`].
pub struct Planner;

impl Planner {
    /// Plan and preprocess `coo` under `cons`: resolve every unpinned
    /// axis from scored candidates, honor every pinned axis, and
    /// return the preprocessed artifacts plus the [`PlanReport`]
    /// evidence.
    pub fn plan(coo: &Coo, cons: &PlanConstraints) -> Result<Planned, Pars3Error> {
        let pool = PrepPool::new(cons.prepare_threads);
        // Axis 1: reorder. `reorder_to_sss_with` already runs the
        // scoring loop (via `score_reorder_candidates_with` when the
        // policy is Auto), so both pinned and unpinned resolution share
        // it — BFS, CM visits, and format construction all on `pool`.
        let (perm, sss, rreport) =
            registry::reorder_to_sss_with(coo, cons.reorder, cons.reorder_min_gain, &pool)?;
        let reorder_pinned =
            cons.mode == PlanMode::Pinned || cons.reorder != ReorderPolicy::Auto;
        let reorder_axis = reorder_axis_report(&rreport, reorder_pinned, cons.reorder_min_gain);
        let chosen_reorder = match cons.mode {
            PlanMode::Pinned => cons.reorder,
            PlanMode::Auto => policy_named(rreport.strategy),
        };

        // Build the split with pure-SSS storage first; the format axis
        // decides what `select_format` installs. The configured tile
        // budget rides on the split so the DIA view built here (and
        // reused by every kernel over this preparation) blocks against
        // the same cache size the probes and execution will.
        let mut split = Split3::with_outer_bw_format_budget(
            &sss,
            cons.outer_bw,
            FormatPolicy::Sss,
            cons.l2_kib,
        )?;

        // Axis 2: format.
        let format_pinned =
            cons.mode == PlanMode::Pinned || cons.format != FormatPolicy::Auto;
        let (format_choice, format_axis) = if format_pinned {
            (cons.format, pinned_format_axis(&split, cons.format))
        } else {
            scored_format_axis(&split)
        };
        split.select_format(format_choice);

        // Axis 3: backend (scored against the split as it will be
        // executed, i.e. after format selection).
        let p = cons.threads.clamp(1, sss.n.max(1));
        let kcfg = KernelConfig {
            threads: p,
            outer_bw: cons.outer_bw,
            threaded: cons.threaded,
            format: format_choice,
            reorder: cons.reorder,
            reorder_min_gain: cons.reorder_min_gain,
            l2_kib: cons.l2_kib,
        };
        let backend_pinned =
            cons.mode == PlanMode::Pinned || cons.backend != BackendPolicy::Auto;
        let (backend_choice, backend_axis, probed_roofline) = if backend_pinned {
            let b = cons.backend.resolve(p).unwrap_or(Backend::Pars3 { p });
            (b, pinned_backend_axis(b, &sss, &split, p), None)
        } else {
            scored_backend_axis(&sss, &split, p, &kcfg, cons, &pool)?
        };
        // every native plan carries a measured roofline point for its
        // chosen backend: reuse the probe's when one ran, else take a
        // one-shot measurement now (PJRT has no CPU kernel -> None)
        let roofline = probed_roofline
            .or_else(|| probe_backend(backend_choice, &sss, &split, &kcfg, 1).ok().map(|(_, r)| r));

        let report = PlanReport {
            mode: cons.mode,
            reorder: rreport,
            axes: vec![reorder_axis, format_axis, backend_axis],
            probe_spmvs: cons.probe_spmvs,
            roofline,
        };
        let choice = PlanChoice {
            reorder: chosen_reorder,
            format: format_choice,
            backend: backend_choice,
        };
        Ok(Planned { choice, report, perm, sss, split })
    }
}

/// Single-threaded [`score_reorder_candidates_with`].
pub fn score_reorder_candidates(g: &Adjacency, min_gain: f64) -> ReorderOutcome {
    score_reorder_candidates_with(g, min_gain, &PrepPool::serial())
}

/// The candidate-scoring loop behind [`ReorderPolicy::Auto`]
/// (extracted from `reorder::Auto` so the planner owns the scorer):
/// run every strategy, score by (bandwidth, envelope profile), keep
/// the natural order unless the best reordering clears `min_gain`.
///
/// The candidate strategies discover components in the same vertex
/// order, so their peripheral searches all begin with a BFS from the
/// same start vertices; that initial level structure is computed once
/// per component start and shared across candidates instead of
/// re-running BFS from scratch for each one. The returned outcome's
/// timings sum every candidate's work (that is what an Auto prepare
/// actually spent).
pub fn score_reorder_candidates_with(
    g: &Adjacency,
    min_gain: f64,
    pool: &PrepPool,
) -> ReorderOutcome {
    let natural = Natural.reorder_with(g, pool);
    let nat_bw = bandwidth_under(g, &natural.perm);
    let nat_profile = profile_under(g, &natural.perm);

    let start_ls: RefCell<HashMap<u32, LevelStructure>> = RefCell::new(HashMap::new());
    let initial_ls = |s: u32| -> LevelStructure {
        start_ls
            .borrow_mut()
            .entry(s)
            .or_insert_with(|| level_structure_with(g, s, pool))
            .clone()
    };

    // Rcm first so an exact (bw, profile) tie keeps the classic pick.
    let reorderers = [
        rcm_per_component_with(
            g,
            "rcm",
            &|g, s| pseudo_peripheral_ls_from(g, initial_ls(s), pool),
            pool,
        ),
        rcm_per_component_with(
            g,
            "rcm-bicriteria",
            &|g, s| bi_criteria_start_from(g, initial_ls(s), pool),
            pool,
        ),
    ];
    let mut scored: Vec<(ReorderOutcome, usize, u64)> = reorderers
        .into_iter()
        .map(|out| {
            let bw = bandwidth_under(g, &out.perm);
            let profile = profile_under(g, &out.perm);
            (out, bw, profile)
        })
        .collect();
    let best = scored
        .iter()
        .enumerate()
        .min_by_key(|(_, (_, bw, profile))| (*bw, *profile))
        .map(|(i, _)| i)
        .expect("two candidates");
    let best_bw = scored[best].1;

    // The decline gate: reordering must beat the natural bandwidth
    // by more than `min_gain` (strict at min_gain = 0), otherwise
    // the input ordering is kept.
    let accept = (best_bw as f64) < (nat_bw as f64) * (1.0 - min_gain);

    let mut candidates = vec![CandidateScore {
        strategy: natural.strategy,
        bandwidth: nat_bw,
        profile: nat_profile,
        chosen: !accept,
    }];
    for (i, (out, bw, profile)) in scored.iter().enumerate() {
        candidates.push(CandidateScore {
            strategy: out.strategy,
            bandwidth: *bw,
            profile: *profile,
            chosen: accept && i == best,
        });
    }
    // Auto's prepare cost is every candidate it weighed, not just the
    // winner's own run.
    let timings = PrepareTimings {
        bfs_ms: natural.timings.bfs_ms
            + scored.iter().map(|(o, _, _)| o.timings.bfs_ms).sum::<f64>(),
        rcm_ms: natural.timings.rcm_ms
            + scored.iter().map(|(o, _, _)| o.timings.rcm_ms).sum::<f64>(),
        threads: pool.threads(),
        ..PrepareTimings::default()
    };
    let mut winner = if accept { scored.swap_remove(best).0 } else { natural };
    winner.candidates = candidates;
    winner.timings = timings;
    winner
}

/// Concrete policy naming a strategy the scorer picked.
fn policy_named(strategy: &str) -> ReorderPolicy {
    match strategy {
        "rcm" => ReorderPolicy::Rcm,
        "rcm-bicriteria" => ReorderPolicy::RcmBiCriteria,
        _ => ReorderPolicy::Natural,
    }
}

fn reorder_axis_report(rreport: &ReorderReport, pinned: bool, min_gain: f64) -> AxisReport {
    let candidates: Vec<PlanCandidate> = rreport
        .candidates
        .iter()
        .map(|c| PlanCandidate {
            name: c.strategy.to_string(),
            score: c.bandwidth as f64,
            detail: format!("bw {}, profile {}", c.bandwidth, c.profile),
            probe_s: None,
            chosen: c.chosen,
        })
        .collect();
    let decline = if pinned {
        None
    } else {
        let nat = rreport.candidates.iter().find(|c| c.strategy == "natural");
        let best = rreport
            .candidates
            .iter()
            .filter(|c| c.strategy != "natural")
            .min_by_key(|c| (c.bandwidth, c.profile));
        match (nat, best) {
            (Some(nat), Some(best)) if nat.chosen => Some(format!(
                "reordering declined: best candidate '{}' bw {} vs natural bw {} \
                 (min_gain {min_gain:.2})",
                best.strategy, best.bandwidth, nat.bandwidth
            )),
            _ => None,
        }
    };
    AxisReport {
        axis: "reorder",
        pinned,
        chosen: rreport.strategy.to_string(),
        candidates,
        decline,
    }
}

/// Estimated bytes one `apply` streams through a pure-SSS middle.
fn sss_middle_bytes(split: &Split3) -> f64 {
    (split.middle.nnz_lower() * 12 + (split.n + 1) * 8) as f64
}

fn pinned_format_axis(split: &Split3, policy: FormatPolicy) -> AxisReport {
    // Evidence only: what the pinned policy resolves to under the
    // legacy rule (Auto = 0.5 fill threshold, Dia = every diagonal,
    // Sss = never).
    let resolved = DiaBand::from_policy(&split.middle, policy);
    let (score, detail) = match &resolved {
        Some(d) => (
            d.bytes() as f64,
            format!(
                "resolves to dia: {} dense diagonal(s), fill {:.2}",
                d.diags.len(),
                d.fill_ratio()
            ),
        ),
        None => (
            sss_middle_bytes(split),
            format!("resolves to sss: {} middle nnz", split.middle.nnz_lower()),
        ),
    };
    AxisReport {
        axis: "format",
        pinned: true,
        chosen: policy.to_string(),
        candidates: vec![PlanCandidate {
            name: policy.to_string(),
            score,
            detail,
            probe_s: None,
            chosen: true,
        }],
        decline: None,
    }
}

fn scored_format_axis(split: &Split3) -> (FormatPolicy, AxisReport) {
    let sss_score = sss_middle_bytes(split);
    let dia_view = DiaBand::from_policy(&split.middle, FormatPolicy::Dia);
    let (dia_score, dia_detail) = match &dia_view {
        Some(d) => (
            d.bytes() as f64,
            format!("{} dense diagonal(s), fill {:.2}", d.diags.len(), d.fill_ratio()),
        ),
        None => (
            f64::INFINITY,
            "no dense diagonal available (band interior is empty)".to_string(),
        ),
    };
    let pick_dia = dia_score < sss_score;
    let candidates = vec![
        PlanCandidate {
            name: "dia".to_string(),
            score: dia_score,
            detail: dia_detail,
            probe_s: None,
            chosen: pick_dia,
        },
        PlanCandidate {
            name: "sss".to_string(),
            score: sss_score,
            detail: format!("{} middle nnz", split.middle.nnz_lower()),
            probe_s: None,
            chosen: !pick_dia,
        },
    ];
    let decline = if pick_dia {
        None
    } else if dia_view.is_none() {
        Some("dia declined: band interior has no off-diagonal entries".to_string())
    } else {
        Some(format!(
            "dia declined: ~{} B/apply vs sss ~{} B/apply",
            dia_score as u64, sss_score as u64
        ))
    };
    let choice = if pick_dia { FormatPolicy::Dia } else { FormatPolicy::Sss };
    (
        choice,
        AxisReport {
            axis: "format",
            pinned: false,
            chosen: choice.to_string(),
            candidates,
            decline,
        },
    )
}

/// Byte-equivalent charge for one phase barrier in the structural
/// backend proxy: a synchronization point costs roughly what streaming
/// a couple of KiB does, so a backend needing `k` barriers per apply
/// pays `k` of these on top of its traffic estimate. This is what
/// separates RACE's fixed 2-phase schedule from greedy coloring's
/// one-barrier-per-color ladder. The constant is the fallback; with a
/// probe budget the planner measures the real round-trip instead
/// ([`measured_barrier_cost_bytes`]).
const BARRIER_COST_BYTES: f64 = 2048.0;

/// Barrier rounds the calibration times (enough to average out
/// scheduler noise, cheap enough to run once per plan).
const BARRIER_CAL_ROUNDS: usize = 64;

/// Measure the byte-equivalent cost of one barrier round-trip on the
/// **real** persistent rank world: time `BARRIER_CAL_ROUNDS` barriers
/// across `p` rank threads (after one warmup job absorbs thread
/// start-up), take the slowest rank, and convert seconds to bytes at
/// the machine's measured streaming rate. Only run when the plan has a
/// probe budget — calibration spins up `p` threads and a memory sweep,
/// which a probe-free structural plan must not pay; those plans keep
/// the [`BARRIER_COST_BYTES`] constant.
fn measured_barrier_cost_bytes(p: usize) -> Option<f64> {
    use crate::mpisim::comm::{PersistentWorld, RankReport};
    if p < 2 {
        // a 1-rank barrier is a no-op; the constant is closer to truth
        return None;
    }
    let world = PersistentWorld::new(p);
    world.run_job(|ctx| {
        ctx.barrier();
        RankReport::default()
    });
    let reports = world.run_job(|ctx| {
        let t0 = Instant::now();
        for _ in 0..BARRIER_CAL_ROUNDS {
            ctx.barrier();
        }
        RankReport { seconds: t0.elapsed().as_secs_f64(), ..Default::default() }
    });
    let per_barrier_s =
        reports.iter().map(|r| r.seconds).fold(0.0f64, f64::max) / BARRIER_CAL_ROUNDS as f64;
    let bytes = per_barrier_s * crate::perf::membench::peak_gbytes() * 1e9;
    (bytes.is_finite() && bytes > 0.0).then_some(bytes)
}

/// Structural proxy for one backend: estimated bytes streamed per
/// `apply`, with the parallel kernels credited for splitting the
/// matrix across `p` ranks and PARS3 charged for its halo exchange
/// plus the worst rank's share of [`Split3::row_work`] (load balance —
/// an even row split only helps if the work is evenly banded). Phased
/// kernels additionally pay `barrier_bytes` per barrier (the measured
/// round-trip when calibration ran, else [`BARRIER_COST_BYTES`]): the
/// greedy coloring one per color, RACE one per parity phase (≤ 2).
fn structural_backend_score(
    b: Backend,
    sss: &Sss,
    split: &Split3,
    p: usize,
    barrier_bytes: f64,
) -> f64 {
    let n = sss.n as f64;
    let nnz = sss.nnz_lower() as f64;
    let bw = sss.bandwidth() as f64;
    let pf = p as f64;
    match b {
        Backend::Serial => 12.0 * nnz + 16.0 * n,
        // CSR stores both triangles.
        Backend::Csr => 24.0 * nnz + 16.0 * n,
        // Dense band: (bw+1) stored diagonals regardless of fill.
        Backend::Dgbmv => 8.0 * n * (bw + 1.0) + 16.0 * n,
        // Coloring re-streams x across phase barriers: charge the full
        // both-triangle traffic split across ranks, plus one barrier
        // per color class.
        Backend::Coloring { .. } => {
            let colors = crate::graph::coloring::color_rows(sss).num_colors as f64;
            24.0 * nnz / pf + 16.0 * n + colors * barrier_bytes
        }
        // RACE streams the stored triangle once in level order (the
        // level-induced locality keeps x resident), scaled by the
        // schedule's measured load balance, plus its ≤ 2 parity
        // barriers.
        Backend::Race { .. } => {
            let st = RaceStructure::build(sss, p);
            12.0 * nnz * st.overall_balance() / pf
                + 16.0 * n / pf
                + st.phases() as f64 * barrier_bytes
        }
        // PARS3: the slowest rank's middle share, plus per-rank halo
        // windows of one bandwidth, plus its slice of the vectors.
        Backend::Pars3 { .. } => {
            12.0 * max_chunk_work(split, p) as f64 + 8.0 * pf * bw + 16.0 * n / pf
        }
        Backend::Pjrt => f64::INFINITY,
    }
}

/// Largest per-rank work sum under an even contiguous row split —
/// the balance figure the PARS3 proxy charges.
fn max_chunk_work(split: &Split3, p: usize) -> usize {
    let work = split.row_work();
    if work.is_empty() {
        return 0;
    }
    let chunk = work.len().div_ceil(p).max(1);
    work.chunks(chunk).map(|c| c.iter().sum::<usize>()).max().unwrap_or(0)
}

fn pinned_backend_axis(b: Backend, sss: &Sss, split: &Split3, p: usize) -> AxisReport {
    let score = structural_backend_score(b, sss, split, p, BARRIER_COST_BYTES);
    AxisReport {
        axis: "backend",
        pinned: true,
        chosen: backend_label(b),
        candidates: vec![PlanCandidate {
            name: backend_label(b),
            score,
            detail: "pinned by constraints".to_string(),
            probe_s: None,
            chosen: true,
        }],
        decline: None,
    }
}

fn scored_backend_axis(
    sss: &Sss,
    split: &Split3,
    p: usize,
    kcfg: &KernelConfig,
    cons: &PlanConstraints,
    pool: &PrepPool,
) -> Result<(Backend, AxisReport, Option<Roofline>), Pars3Error> {
    let backends = [
        Backend::Serial,
        Backend::Csr,
        Backend::Dgbmv,
        Backend::Coloring { p },
        Backend::Race { p },
        Backend::Pars3 { p },
    ];
    // With a probe budget the barrier charge in the structural proxy is
    // calibrated on the real persistent world; structural-only plans
    // keep the constant (calibration costs threads + a memory sweep).
    let barrier_bytes = if cons.probe_spmvs > 0 {
        measured_barrier_cost_bytes(p).unwrap_or(BARRIER_COST_BYTES)
    } else {
        BARRIER_COST_BYTES
    };
    // Candidates are scored concurrently on the prepare pool. Probe
    // timings stay comparative — every candidate runs under the same
    // contention — and the results come back in candidate order, so
    // the first-minimum tie-break below is unchanged.
    let mut cands: Vec<(Backend, PlanCandidate, Option<Roofline>)> =
        pool.map_items(backends.len(), |i| {
            let b = backends[i];
            let structural = structural_backend_score(b, sss, split, p, barrier_bytes);
            let (score, probe_s, detail, roof) = if cons.probe_spmvs > 0 {
                match probe_backend(b, sss, split, kcfg, cons.probe_spmvs) {
                    Ok((t, roof)) => (
                        t,
                        Some(t),
                        format!(
                            "probe min over {} apply(s); {}; structural ~{} B/apply",
                            cons.probe_spmvs,
                            roof.summary(),
                            structural as u64
                        ),
                        Some(roof),
                    ),
                    // A candidate that cannot even build disqualifies
                    // itself; the failure is the evidence.
                    Err(e) => (f64::INFINITY, None, format!("probe failed: {e}"), None),
                }
            } else {
                (structural, None, format!("structural ~{} B/apply", structural as u64), None)
            };
            (
                b,
                PlanCandidate { name: backend_label(b), score, detail, probe_s, chosen: false },
                roof,
            )
        });
    // First minimum wins ties, keeping the registry order (serial
    // first) deterministic.
    let mut best = 0;
    for i in 1..cands.len() {
        if cands[i].1.score < cands[best].1.score {
            best = i;
        }
    }
    cands[best].1.chosen = true;
    let choice = cands[best].0;
    let roofline = cands[best].2;
    let axis = AxisReport {
        axis: "backend",
        pinned: false,
        chosen: backend_label(choice),
        candidates: cands.into_iter().map(|(_, c, _)| c).collect(),
        decline: None,
    };
    Ok((choice, axis, roofline))
}

/// Build one candidate kernel directly through the registry (never the
/// coordinator cache — probes must not pollute cache stats) and time
/// `spmvs` real `apply` calls on a deterministic vector; the score is
/// the minimum, returned alongside the corresponding [`Roofline`]
/// point (from the kernel's own `flops()`/`bytes()` accounting).
fn probe_backend(
    b: Backend,
    sss: &Sss,
    split: &Split3,
    kcfg: &KernelConfig,
    spmvs: usize,
) -> Result<(f64, Roofline), Pars3Error> {
    let mut kernel = match b {
        Backend::Pars3 { .. } => registry::build_from_split(split.clone(), kcfg)?,
        _ => {
            let name = b.kernel_name().ok_or(Pars3Error::BackendUnavailable {
                backend: "pjrt",
                reason: "pjrt kernels are built outside the registry and cannot be probed"
                    .to_string(),
            })?;
            registry::build_from_sss(name, sss.clone(), kcfg)?
        }
    };
    let n = sss.n;
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut y = vec![0.0; n];
    let mut best = f64::INFINITY;
    for _ in 0..spmvs.max(1) {
        let t0 = Instant::now();
        kernel.apply(&x, &mut y);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&y);
    Ok((best, Roofline::from_seconds(best, kernel.flops(), kernel.bytes())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn constraints() -> PlanConstraints {
        PlanConstraints::from_config(&Config::default())
    }

    #[test]
    fn all_auto_plans_every_axis_with_scored_candidates() {
        let coo = gen::small_test_matrix(120, 9, 2.0);
        let planned = Planner::plan(&coo, &constraints()).unwrap();
        let rep = &planned.report;
        assert_eq!(rep.mode, PlanMode::Auto);
        assert_eq!(rep.axes.len(), 3);
        for ax in &rep.axes {
            assert!(!ax.pinned, "{} must be unpinned under all-auto", ax.axis);
            assert!(ax.candidates.len() >= 2, "{}: too few candidates", ax.axis);
            assert_eq!(
                ax.candidates.iter().filter(|c| c.chosen).count(),
                1,
                "{}: exactly one chosen",
                ax.axis
            );
            let chosen = ax.candidates.iter().find(|c| c.chosen).unwrap();
            assert_eq!(chosen.name, ax.chosen);
            assert!(chosen.score.is_finite());
        }
        // every axis resolves to something concrete
        assert_ne!(planned.choice.reorder, ReorderPolicy::Auto);
        assert_ne!(planned.choice.format, FormatPolicy::Auto);
        assert!(planned.report.summary().contains("plan[auto]"));
        assert!(planned.choice.describe().starts_with("reorder="));
        // even without a probe budget, the plan carries a measured
        // roofline point for its chosen (native) backend
        let roof = planned.report.roofline.expect("native plan must carry a roofline");
        assert!(roof.gflops > 0.0 && roof.gbytes > 0.0 && roof.peak_gbytes > 0.0);
        assert!(planned.report.summary().contains("roofline"));
    }

    #[test]
    fn plan_report_round_trips_through_json() {
        // a probed plan fills every optional field: probe timings,
        // roofline, decline reasons (when the gate declines)
        let coo = gen::small_test_matrix(90, 13, 2.0);
        let mut cons = constraints();
        cons.probe_spmvs = 2;
        let planned = Planner::plan(&coo, &cons).unwrap();
        let text = planned.report.to_json().dump();
        let back = PlanReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, planned.report);
        let choice = PlanChoice::from_json(&planned.choice.to_json()).unwrap();
        assert_eq!(choice, planned.choice);
        // every backend spelling survives the structured form
        for b in [
            Backend::Serial,
            Backend::Csr,
            Backend::Dgbmv,
            Backend::Coloring { p: 3 },
            Backend::Race { p: 5 },
            Backend::Pars3 { p: 8 },
            Backend::Pjrt,
        ] {
            assert_eq!(backend_from_json(&backend_to_json(b)).unwrap(), b);
        }
        assert!(axis_named("storage").is_err());
    }

    #[test]
    fn pinning_one_axis_keeps_planning_on_the_others() {
        let coo = gen::small_test_matrix(140, 11, 2.0);
        let mut cons = constraints();
        cons.format = FormatPolicy::Sss;
        let planned = Planner::plan(&coo, &cons).unwrap();
        let fmt = planned.report.axis("format").unwrap();
        assert!(fmt.pinned);
        assert_eq!(fmt.candidates.len(), 1);
        assert_eq!(planned.choice.format, FormatPolicy::Sss);
        assert_eq!(planned.split.format_name(), "sss");
        for name in ["reorder", "backend"] {
            let ax = planned.report.axis(name).unwrap();
            assert!(!ax.pinned, "{name} stays planned");
            assert!(ax.candidates.len() >= 2, "{name} still scores candidates");
            assert_eq!(ax.candidates.iter().filter(|c| c.chosen).count(), 1);
        }
    }

    #[test]
    fn pinned_mode_resolves_every_axis_by_legacy_rules() {
        let coo = gen::small_test_matrix(100, 3, 2.0);
        let mut cons = constraints();
        cons.mode = PlanMode::Pinned;
        let planned = Planner::plan(&coo, &cons).unwrap();
        // verbatim config: per-axis Auto heuristics stay in charge
        assert_eq!(planned.choice.reorder, ReorderPolicy::Auto);
        assert_eq!(planned.choice.format, FormatPolicy::Auto);
        assert_eq!(planned.choice.backend, Backend::Pars3 { p: 8 });
        assert!(planned.report.axes.iter().all(|a| a.pinned));
        // the reorder quality gate still ran and left its evidence
        assert_eq!(planned.report.reorder.candidates.len(), 3);
    }

    #[test]
    fn format_choice_matches_the_byte_scores_and_the_split() {
        let coo = gen::small_test_matrix(150, 7, 2.0);
        let planned = Planner::plan(&coo, &constraints()).unwrap();
        let fmt = planned.report.axis("format").unwrap();
        let chosen = fmt.candidates.iter().find(|c| c.chosen).unwrap();
        for c in &fmt.candidates {
            assert!(chosen.score <= c.score, "{} beaten by {}", chosen.name, c.name);
        }
        assert_eq!(planned.split.format_name(), chosen.name);
    }

    #[test]
    fn probe_budget_times_every_backend_candidate() {
        let coo = gen::small_test_matrix(90, 5, 2.0);
        let mut cons = constraints();
        cons.probe_spmvs = 2;
        let planned = Planner::plan(&coo, &cons).unwrap();
        assert_eq!(planned.report.probe_spmvs, 2);
        let be = planned.report.axis("backend").unwrap();
        assert!(be.candidates.iter().all(|c| c.probe_s.is_some()));
        assert!(be.candidates.iter().all(|c| c.score >= 0.0 && c.score.is_finite()));
        // probed candidates log their roofline numbers as evidence
        assert!(be.candidates.iter().all(|c| c.detail.contains("GF/s")), "{be:?}");
        assert!(planned.report.roofline.is_some());
    }

    #[test]
    fn race_is_a_scored_candidate_and_beats_greedy_coloring() {
        let coo = gen::small_test_matrix(150, 13, 2.0);
        let planned = Planner::plan(&coo, &constraints()).unwrap();
        let be = planned.report.axis("backend").unwrap();
        let race = be
            .candidates
            .iter()
            .find(|c| c.name.starts_with("race"))
            .expect("race must be in the planner's backend candidate list");
        assert!(race.score.is_finite(), "race score: {}", race.score);
        // the 2-phase schedule structurally dominates the greedy
        // one-barrier-per-color baseline on every matrix
        let coloring = be.candidates.iter().find(|c| c.name.starts_with("coloring")).unwrap();
        assert!(
            race.score < coloring.score,
            "race {} vs coloring {}",
            race.score,
            coloring.score
        );
    }

    #[test]
    fn planner_auto_chooses_race_on_a_small_world_matrix() {
        use crate::sparse::skew;
        use crate::util::SmallRng;
        // ring + 40% long-range rewires: RCM cannot band this, so the
        // pars3 halo term blows up while RACE's level schedule stays
        // two phases — the planner must pick race on structural scores
        let mut rng = SmallRng::seed_from_u64(42);
        let edges = gen::small_world(400, 3, 0.4, &mut rng);
        let coo = skew::coo_from_pattern(400, &edges, 1.5, &mut rng);
        let planned = Planner::plan(&coo, &constraints()).unwrap();
        assert!(
            matches!(planned.choice.backend, Backend::Race { .. }),
            "expected race, planner chose {}",
            backend_label(planned.choice.backend)
        );
        let be = planned.report.axis("backend").unwrap();
        let chosen = be.candidates.iter().find(|c| c.chosen).unwrap();
        assert!(chosen.name.starts_with("race") && chosen.score.is_finite());
    }

    #[test]
    fn prepare_threads_never_change_the_plan_or_permutation() {
        let coo = gen::small_test_matrix(160, 21, 2.0);
        let mut c1 = constraints();
        c1.prepare_threads = 1;
        let mut c4 = constraints();
        c4.prepare_threads = 4;
        let p1 = Planner::plan(&coo, &c1).unwrap();
        let p4 = Planner::plan(&coo, &c4).unwrap();
        assert_eq!(p1.perm, p4.perm, "permutation must be pool-width invariant");
        assert_eq!(p1.choice, p4.choice, "plan choice must be pool-width invariant");
        assert_eq!(p4.report.reorder.timings.threads, 4);
        assert_eq!(p1.report.reorder.timings.threads, 1);
        // outside the wall-clock timings the reorder evidence is identical
        let mut r1 = p1.report.reorder.clone();
        let mut r4 = p4.report.reorder.clone();
        r1.timings = Default::default();
        r4.timings = Default::default();
        assert_eq!(r1, r4);
    }

    #[test]
    fn backend_and_plan_policies_roundtrip_their_spellings() {
        for s in ["auto", "serial", "csr", "dgbmv", "coloring", "race", "pars3", "pjrt"] {
            let p: BackendPolicy = s.parse().unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert!("gpu".parse::<BackendPolicy>().is_err());
        for s in ["auto", "pinned"] {
            let m: PlanMode = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!("maybe".parse::<PlanMode>().is_err());
        assert_eq!(BackendPolicy::Coloring.resolve(4), Some(Backend::Coloring { p: 4 }));
        assert_eq!(BackendPolicy::Auto.resolve(4), None);
    }
}
