//! The preprocessing + execution pipeline.
//!
//! Preprocessing (plan → reorder → SSS → 3-way split) happens once per
//! matrix in [`Coordinator::prepare`], which delegates the joint
//! (reorder, format, backend) decision to
//! [`crate::coordinator::planner::Planner`]; every multiply/solve after
//! that constructs its kernel through the unified registry
//! ([`crate::kernel::registry`]) under the prepared [`PlanChoice`] —
//! there is no per-backend construction logic here. The PJRT backend is
//! additionally gated behind the `pjrt` feature; without it,
//! [`Backend::Pjrt`] requests fail with a clear error instead of
//! dragging XLA into the build.

use crate::coordinator::error::Pars3Error;
use crate::coordinator::planner::{PlanChoice, PlanConstraints, PlanReport, Planned, Planner};
use crate::coordinator::Config;
use crate::kernel::pars3::Pars3Plan;
use crate::kernel::registry::{self, KernelConfig};
use crate::kernel::{ConflictMap, Split3, Spmv, VecBatch};
use crate::solver::mrs::{mrs_solve, mrs_solve_batch, MrsOptions, MrsResult};
use crate::sparse::{Coo, Sss};
use crate::Result;
use anyhow::bail;
use std::collections::HashMap;
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use crate::runtime::{Manifest, PjrtRuntime};
#[cfg(feature = "pjrt")]
use crate::sparse::DiaBand;
#[cfg(feature = "pjrt")]
use anyhow::Context;

/// Which executor serves the repeated multiplies. Every registry kernel
/// ([`crate::kernel::KERNEL_NAMES`]) has a variant, so the typed client
/// API reaches the full kernel inventory; PJRT executes outside the
/// registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Paper Alg. 1 (serial SSS).
    Serial,
    /// Plain CSR baseline.
    Csr,
    /// LAPACK-style dense band (`dgbmv`).
    Dgbmv,
    /// Graph-coloring phased baseline (Elafrou et al.) at `p` ranks.
    Coloring {
        /// Rank count.
        p: usize,
    },
    /// RACE-style recursive level-coloring kernel at `p` ranks.
    Race {
        /// Rank count.
        p: usize,
    },
    /// PARS3 parallel kernel at a given rank count.
    Pars3 {
        /// Rank count.
        p: usize,
    },
    /// AOT Pallas band kernel via PJRT (dense-band path; `pjrt` feature).
    Pjrt,
}

impl Backend {
    /// Registry kernel name for the native backends (`None` for PJRT,
    /// which executes outside the [`Spmv`] registry).
    pub fn kernel_name(&self) -> Option<&'static str> {
        match self {
            Backend::Serial => Some("serial_sss"),
            Backend::Csr => Some("csr"),
            Backend::Dgbmv => Some("dgbmv"),
            Backend::Coloring { .. } => Some("coloring"),
            Backend::Race { .. } => Some("race"),
            Backend::Pars3 { .. } => Some("pars3"),
            Backend::Pjrt => None,
        }
    }
}

/// A matrix after one-time preprocessing (paper §3.1.2 stages).
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Matrix name (for reports).
    pub name: String,
    /// Dimension.
    pub n: usize,
    /// Stored lower NNZ.
    pub nnz_lower: usize,
    /// Bandwidth before reordering.
    pub bw_before: usize,
    /// Bandwidth after reordering (Table 1's "RCM Bandwith" when the
    /// chosen strategy is RCM-family).
    pub reordered_bw: usize,
    /// The reordering permutation used (`perm[old] = new`).
    pub perm: Vec<u32>,
    /// The (reorder, format, backend) triple the planner resolved —
    /// part of every kernel-cache key derived from this preparation.
    pub choice: PlanChoice,
    /// Evidence for the choice: per-axis candidates, scores, probe
    /// timings, decline reasons, plus the embedded
    /// [`ReorderReport`](crate::graph::reorder::ReorderReport).
    pub plan: PlanReport,
    /// Reordered matrix in SSS form, shared (not cloned) with every
    /// kernel built from this preparation.
    pub sss: Arc<Sss>,
    /// The 3-way split of the band, shared with every PARS3 plan.
    pub split: Arc<Split3>,
}

impl Prepared {
    /// Conflict map at `p` ranks (Θ(NNZ)).
    pub fn conflicts(&self, p: usize) -> ConflictMap {
        ConflictMap::analyze(&self.split, p)
    }

    /// Build a PARS3 plan at `p` ranks.
    pub fn plan(&self, p: usize) -> Result<Pars3Plan> {
        Pars3Plan::new(self.split.clone(), p)
    }
}

/// Kernel-cache key: `Sss` allocation address, requested backend, the
/// preparation's [`PlanChoice`] (a re-plan must never be served a
/// kernel built for the old triple), and the config knobs (`threaded`,
/// `outer_bw`, `l2_kib`) that affect construction.
type CacheKey = (usize, Backend, PlanChoice, bool, usize, usize);

/// One kernel-cache entry: the built kernel plus the `Arc<Sss>` whose
/// pointer is the entry's identity key. Pinning the `Arc` here makes
/// the pointer key sound: a `pars3` kernel only retains the
/// `Arc<Split3>`, so without the pin the `Sss` allocation could be
/// dropped and its address handed to a later `prepare` (ABA), silently
/// aliasing this entry.
struct CachedKernel {
    kernel: Box<dyn Spmv>,
    _identity: Arc<Sss>,
    /// Tick of the most recent `cached_kernel` hit (LRU eviction order).
    last_used: u64,
}

/// The coordinator: owns config, the per-matrix kernel cache and
/// (lazily, behind the `pjrt` feature) the PJRT runtime.
pub struct Coordinator {
    /// Active configuration.
    pub cfg: Config,
    /// Built kernels keyed by (matrix identity, backend). The matrix
    /// identity is the `Arc<Sss>` pointer of the [`Prepared`] handle;
    /// each entry also **pins** that `Arc`, so the allocation (and
    /// therefore its address) cannot be freed and recycled by a later
    /// `prepare` while the entry lives — the key can never alias a
    /// different matrix. Repeated `spmv`/`solve` calls against the same
    /// preparation reuse the kernel (for `pars3`'s threaded mode: the
    /// same persistent rank threads) instead of paying the Θ(NNZ) plan
    /// + thread spawns per request.
    kernels: HashMap<CacheKey, CachedKernel>,
    /// Total kernels ever constructed through the cache (test/metric).
    kernel_builds: usize,
    /// Monotone access clock for LRU ordering.
    tick: u64,
    #[cfg(feature = "pjrt")]
    runtime: Option<PjrtRuntime>,
}

impl Coordinator {
    /// Create from config. The PJRT runtime is created on first use so
    /// native-only flows never touch XLA.
    pub fn new(cfg: Config) -> Self {
        Self {
            cfg,
            kernels: HashMap::new(),
            kernel_builds: 0,
            tick: 0,
            #[cfg(feature = "pjrt")]
            runtime: None,
        }
    }

    /// Preprocess a full COO matrix: plan the (reorder, format,
    /// backend) triple under the config's constraints
    /// ([`Planner::plan`]), reorder with the chosen strategy (Θ(NNZ)
    /// per candidate), convert to SSS, 3-way split at the configured
    /// outer bandwidth with the chosen middle-split format.
    ///
    /// The default all-`auto` config implements the paper's §4.1
    /// future-work note — "a future work that can recognize and
    /// exploit original matrix patterns": if the input is *already*
    /// banded at least as tightly as the best reordering achieves
    /// (Fig. 5's pre-banded case, gated by
    /// [`Config::reorder_min_gain`]), the identity ordering is kept
    /// and the permutation cost disappears from the pipeline — and
    /// the same measured-candidate treatment now extends to the
    /// storage format and the backend. Every [`Prepared`] carries the
    /// full [`PlanReport`] evidence.
    pub fn prepare(&self, name: &str, coo: &Coo) -> Result<Prepared, Pars3Error> {
        let bw_before = coo.bandwidth();
        let cons = PlanConstraints::from_config(&self.cfg);
        let Planned { choice, report, perm, sss, mut split } = Planner::plan(coo, &cons)?;
        let reordered_bw = sss.bandwidth();
        split.reorder_strategy = Some(report.reorder.strategy);
        split.plan_triple = Some(choice.describe());
        Ok(Prepared {
            name: name.to_string(),
            n: sss.n,
            nnz_lower: sss.nnz_lower(),
            bw_before,
            reordered_bw,
            perm,
            choice,
            plan: report,
            sss: Arc::new(sss),
            split: Arc::new(split),
        })
    }

    /// Construct the [`Spmv`] kernel serving a native backend, via the
    /// unified registry (the single dispatch point — no per-call-site
    /// kernel construction anywhere else in the crate).
    pub fn kernel(&self, prep: &Prepared, backend: Backend) -> Result<Box<dyn Spmv>, Pars3Error> {
        let Some(name) = backend.kernel_name() else {
            return Err(Pars3Error::BackendUnavailable {
                backend: "pjrt",
                reason: "executes outside the Spmv registry; call spmv/solve directly".into(),
            });
        };
        let threads = match backend {
            Backend::Pars3 { p } | Backend::Coloring { p } | Backend::Race { p } => p,
            _ => 1,
        };
        let cfg = KernelConfig {
            threads,
            outer_bw: self.cfg.outer_bw,
            threaded: self.cfg.threaded,
            // the *planned* format, not the raw config: the plan is
            // what the prepared split was actually built with
            format: prep.choice.format,
            reorder: self.cfg.reorder,
            reorder_min_gain: self.cfg.reorder_min_gain,
            l2_kib: self.cfg.l2_kib,
        };
        match backend {
            // reuse the 3-way split `prepare` already computed instead
            // of re-deriving it from the SSS form (its middle-split
            // format was selected there); both hand-offs are Arc
            // clones — the matrix data itself is never copied
            Backend::Pars3 { .. } => registry::build_from_split(prep.split.clone(), &cfg),
            _ => registry::build_from_sss(name, prep.sss.clone(), &cfg),
        }
    }

    /// Cache key for a preparation: the `Arc<Sss>` allocation identity,
    /// the requested backend, the preparation's [`PlanChoice`], and
    /// every remaining [`Config`] knob that changes what
    /// [`Self::kernel`] builds — so mutating the public `cfg` between
    /// requests builds a new kernel instead of silently serving one
    /// constructed under the old settings, and a *re-planned* matrix
    /// (whose triple changed) can never be served a kernel built for
    /// the old triple.
    fn cache_key(&self, prep: &Prepared, backend: Backend) -> CacheKey {
        (
            Arc::as_ptr(&prep.sss) as usize,
            backend,
            prep.choice,
            self.cfg.threaded,
            self.cfg.outer_bw,
            self.cfg.l2_kib,
        )
    }

    /// The cached kernel for `(prep, backend)`, building it on first
    /// use. Every native `spmv`/`solve` entry point goes through here,
    /// so a request stream against one prepared matrix constructs each
    /// backend's kernel exactly once. An unhealthy kernel (a threaded
    /// `pars3` executor poisoned by a rank panic) is evicted and
    /// rebuilt instead of wedging the `(matrix, backend)` pair forever.
    ///
    /// The cache is capped at [`Config::max_cached_kernels`] entries
    /// (`0` = unbounded): inserting past the cap evicts the
    /// least-recently-used entry, so a coordinator serving thousands of
    /// matrices holds a bounded working set and a re-requested evictee
    /// is transparently rebuilt (one extra `kernel_builds` tick — the
    /// metric the service's cache-stats report exposes).
    pub fn cached_kernel(
        &mut self,
        prep: &Prepared,
        backend: Backend,
    ) -> Result<&mut dyn Spmv, Pars3Error> {
        let key = self.cache_key(prep, backend);
        if self.kernels.get(&key).is_some_and(|e| !e.kernel.healthy()) {
            self.kernels.remove(&key);
        }
        self.tick += 1;
        // entry() is unusable here: building the kernel re-borrows
        // `self` while an entry guard would hold `self.kernels`
        #[allow(clippy::map_entry)]
        if !self.kernels.contains_key(&key) {
            let built = self.kernel(prep, backend)?;
            self.kernels.insert(
                key,
                CachedKernel {
                    kernel: built,
                    _identity: prep.sss.clone(),
                    last_used: self.tick,
                },
            );
            self.kernel_builds += 1;
            let cap = self.cfg.max_cached_kernels;
            while cap > 0 && self.kernels.len() > cap {
                // evict the least-recently-used entry; the one just
                // inserted holds the newest tick so it never goes
                let lru = self
                    .kernels
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(&k, _)| k)
                    .expect("cache is non-empty past the cap");
                self.kernels.remove(&lru);
            }
        }
        let entry = self.kernels.get_mut(&key).expect("just inserted");
        entry.last_used = self.tick;
        Ok(entry.kernel.as_mut())
    }

    /// `(currently cached, ever built)` kernel counts.
    pub fn kernel_cache_stats(&self) -> (usize, usize) {
        (self.kernels.len(), self.kernel_builds)
    }

    /// Drop every cached kernel for this preparation (all backends and
    /// config variants). Call when a matrix registration is replaced so
    /// dead kernels don't pin the old matrix's memory (and, for
    /// threaded `pars3`, its persistent rank threads). [`Service`] does
    /// this on re-`Prepare`; direct `Coordinator` users discarding a
    /// [`Prepared`] should too — `prepare` itself takes `&self` and
    /// cannot evict (see ROADMAP: cache eviction policy).
    pub fn evict(&mut self, prep: &Prepared) {
        let id = Arc::as_ptr(&prep.sss) as usize;
        self.kernels.retain(|&(p, ..), _| p != id);
    }

    /// Drop the entire kernel cache (every matrix, backend and config
    /// variant). The coarse recovery hatch for long-lived coordinators.
    pub fn clear_kernel_cache(&mut self) {
        self.kernels.clear();
    }

    /// One multiply `y = A x` on the chosen backend (x/y in the
    /// reordered space).
    /// Uses the kernel cache: repeated calls against the same
    /// preparation reuse one kernel (and, when threaded, its persistent
    /// rank threads).
    pub fn spmv(
        &mut self,
        prep: &Prepared,
        x: &[f64],
        backend: Backend,
    ) -> Result<Vec<f64>, Pars3Error> {
        if x.len() != prep.n {
            return Err(Pars3Error::DimensionMismatch { expected: prep.n, got: x.len() });
        }
        match backend {
            Backend::Pjrt => self.spmv_pjrt(prep, x).map_err(|e| {
                Pars3Error::BackendUnavailable { backend: "pjrt", reason: format!("{e:#}") }
            }),
            _ => {
                let k = self.cached_kernel(prep, backend)?;
                let mut y = vec![0.0; prep.n];
                k.apply(x, &mut y);
                Ok(y)
            }
        }
    }

    /// One fused batch multiply `ys = A xs` (column-major `n × k`) on a
    /// native backend: the matrix is traversed once for the whole
    /// batch. PJRT executes single vectors only.
    pub fn spmv_batch(
        &mut self,
        prep: &Prepared,
        xs: &VecBatch,
        backend: Backend,
    ) -> Result<VecBatch, Pars3Error> {
        if backend == Backend::Pjrt {
            return Err(Pars3Error::BackendUnavailable {
                backend: "pjrt",
                reason: "no batch path; use spmv per column".into(),
            });
        }
        if xs.n() != prep.n {
            return Err(Pars3Error::DimensionMismatch { expected: prep.n, got: xs.n() });
        }
        let k = self.cached_kernel(prep, backend)?;
        k.prepare_hint(xs.k());
        let mut ys = VecBatch::zeros(prep.n, xs.k());
        k.apply_batch(xs, &mut ys);
        Ok(ys)
    }

    /// Multi-RHS MRS solve: every column of `bs` is solved against the
    /// same prepared matrix with **one fused SpMV per sweep** (see
    /// [`mrs_solve_batch`]) — the serving-path entry point for
    /// block-Krylov / many-scenario workloads.
    pub fn solve_batch(
        &mut self,
        prep: &Prepared,
        bs: &VecBatch,
        opts: &MrsOptions,
        backend: Backend,
    ) -> Result<Vec<MrsResult>, Pars3Error> {
        if backend == Backend::Pjrt {
            return Err(Pars3Error::BackendUnavailable {
                backend: "pjrt",
                reason: "no batch path; use solve per RHS".into(),
            });
        }
        if bs.n() != prep.n {
            return Err(Pars3Error::DimensionMismatch { expected: prep.n, got: bs.n() });
        }
        let k = self.cached_kernel(prep, backend)?;
        Ok(mrs_solve_batch(k, bs, opts))
    }

    /// MRS solve with the chosen backend as the repeated-multiply kernel.
    pub fn solve(
        &mut self,
        prep: &Prepared,
        b: &[f64],
        opts: &MrsOptions,
        backend: Backend,
    ) -> Result<MrsResult, Pars3Error> {
        if b.len() != prep.n {
            return Err(Pars3Error::DimensionMismatch { expected: prep.n, got: b.len() });
        }
        match backend {
            Backend::Pjrt => self.solve_pjrt(prep, b, opts).map_err(|e| {
                Pars3Error::BackendUnavailable { backend: "pjrt", reason: format!("{e:#}") }
            }),
            _ => {
                let k = self.cached_kernel(prep, backend)?;
                Ok(mrs_solve(k, b, opts))
            }
        }
    }

    /// Access (creating on demand) the PJRT runtime.
    #[cfg(feature = "pjrt")]
    pub fn runtime(&mut self) -> Result<&mut PjrtRuntime> {
        if self.runtime.is_none() {
            let manifest = Manifest::load(&self.cfg.artifacts_dir)?;
            self.runtime = Some(PjrtRuntime::new(manifest)?);
        }
        Ok(self.runtime.as_mut().unwrap())
    }

    /// Pack a prepared band into the f32 DIA inputs of an artifact.
    /// The band width comes from the post-reorder report's bandwidth
    /// ([`Prepared::reordered_bw`]) — whatever strategy produced the
    /// band, not specifically RCM.
    #[cfg(feature = "pjrt")]
    fn pack_dia(&mut self, prep: &Prepared, kind: &str) -> Result<(String, Vec<f32>, f64, usize)> {
        if prep.reordered_bw == 0 {
            bail!("matrix has empty band");
        }
        let dia = DiaBand::from_sss(&prep.sss, prep.reordered_bw)
            .context("PJRT path requires a constant-diagonal (shifted) matrix")?;
        let rt = self.runtime()?;
        let spec = rt.manifest().best_fit(kind, prep.n, prep.reordered_bw)?;
        let (name, n_pad, beta_pad) = (spec.name.clone(), spec.n, spec.beta);
        let lo = dia.to_f32_padded(beta_pad, n_pad)?;
        Ok((name, lo, dia.alpha, n_pad))
    }

    /// `y = A x` through the AOT Pallas band kernel.
    #[cfg(feature = "pjrt")]
    pub fn spmv_pjrt(&mut self, prep: &Prepared, x: &[f64]) -> Result<Vec<f64>> {
        let (name, lo, alpha, n_pad) = self.pack_dia(prep, "spmv")?;
        let mut x32 = vec![0.0f32; n_pad];
        for (k, &v) in x.iter().enumerate() {
            x32[k] = v as f32;
        }
        let a32 = [alpha as f32];
        let rt = self.runtime()?;
        let art = rt.load(&name)?;
        let out = art.execute_f32(&[&lo, &x32, &a32])?;
        Ok(out[0][..prep.n].iter().map(|&v| v as f64).collect())
    }

    /// Stub when built without the `pjrt` feature.
    #[cfg(not(feature = "pjrt"))]
    pub fn spmv_pjrt(&mut self, _prep: &Prepared, _x: &[f64]) -> Result<Vec<f64>> {
        bail!("built without the 'pjrt' feature: rebuild with `--features pjrt`")
    }

    /// MRS solve through the AOT artifacts: the Rust driver owns the
    /// stopping rule; iterations run inside PJRT (one SpMV + fused
    /// update each).
    ///
    /// §Perf hot path: prefers the `mrs_chunk` artifact (8 fused
    /// iterations per call, amortizing dispatch + transfers) over the
    /// single-step one, and hoists the band literal — the dominant
    /// per-call copy — out of the loop.
    #[cfg(feature = "pjrt")]
    pub fn solve_pjrt(
        &mut self,
        prep: &Prepared,
        b: &[f64],
        opts: &MrsOptions,
    ) -> Result<MrsResult> {
        let _ = opts.alpha; // artifact carries the shift in its band input
        // prefer the chunked artifact; fall back to single-step
        let (name, lo, _alpha, n_pad, chunk) = {
            match self.pack_dia(prep, "mrs_chunk") {
                Ok((name, lo, alpha, n_pad)) => {
                    let rt = self.runtime()?;
                    let iters = rt.manifest().by_name(&name)?.iters.unwrap_or(1);
                    (name, lo, alpha, n_pad, iters)
                }
                Err(_) => {
                    let (name, lo, alpha, n_pad) = self.pack_dia(prep, "mrs_step")?;
                    (name, lo, alpha, n_pad, 1)
                }
            }
        };
        let alpha32 = [_alpha as f32];
        let mut x = vec![0.0f32; n_pad];
        let mut r = vec![0.0f32; n_pad];
        for (k, &v) in b.iter().enumerate() {
            r[k] = v as f32;
        }
        let bb: f64 = b.iter().map(|v| v * v).sum();
        let tol2 = (opts.tol * opts.tol * bb) as f32;
        let mut history = Vec::with_capacity(opts.max_iters + 1);
        let mut iters = 0;
        let rt = self.runtime()?;
        let art = rt.load(&name)?;
        // hoisted out of the loop: the band is iteration-invariant
        let lo_lit = art.literal_for(0, &lo)?;
        let alpha_lit = art.literal_for(3, &alpha32)?;
        let mut rr = bb as f32;
        history.push(rr as f64);
        while iters < opts.max_iters && rr > tol2 {
            let x_lit = art.literal_for(1, &x)?;
            let r_lit = art.literal_for(2, &r)?;
            let out = art.execute_literals(&[&lo_lit, &x_lit, &r_lit, &alpha_lit])?;
            x = out[0].clone();
            r = out[1].clone();
            // out[2] reports ||r_k||^2 *before* each fused step; append
            // the intermediate history, then track the post-update
            // residual for the stopping rule
            for &h in out[2].iter().skip(1) {
                history.push(h as f64);
            }
            rr = r.iter().map(|v| v * v).sum();
            history.push(rr as f64);
            iters += chunk;
        }
        Ok(MrsResult {
            x: x[..prep.n].iter().map(|&v| v as f64).collect(),
            r: r[..prep.n].iter().map(|&v| v as f64).collect(),
            converged: rr <= tol2,
            history,
            iters,
        })
    }

    /// Stub when built without the `pjrt` feature.
    #[cfg(not(feature = "pjrt"))]
    pub fn solve_pjrt(
        &mut self,
        _prep: &Prepared,
        _b: &[f64],
        _opts: &MrsOptions,
    ) -> Result<MrsResult> {
        bail!("built without the 'pjrt' feature: rebuild with `--features pjrt`")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn coordinator() -> Coordinator {
        Coordinator::new(Config::default())
    }

    #[test]
    fn prepare_reduces_bandwidth() {
        let coo = gen::small_test_matrix(300, 11, 2.0);
        let c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        assert!(prep.reordered_bw <= prep.bw_before);
        assert_eq!(prep.nnz_lower, prep.split.nnz_middle() + prep.split.nnz_outer());
        // the plan report rides along and agrees with the pipeline
        assert_eq!(prep.plan.reorder.bw_after, prep.reordered_bw);
        assert_eq!(prep.split.reorder_strategy, Some(prep.plan.reorder.strategy));
        assert_eq!(prep.split.plan_triple, Some(prep.choice.describe()));
        assert!(!prep.plan.reorder.components.is_empty());
        // all-auto config: every axis was planned with >= 2 candidates
        for ax in &prep.plan.axes {
            assert!(!ax.pinned, "{} axis", ax.axis);
            assert!(ax.candidates.len() >= 2, "{} axis", ax.axis);
            assert_eq!(ax.candidates.iter().filter(|c| c.chosen).count(), 1);
        }
    }

    #[test]
    fn prepare_honors_the_configured_reorder_strategy() {
        use crate::graph::reorder::ReorderPolicy;
        let coo = gen::small_test_matrix(200, 30, 2.0);
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.17).sin()).collect();
        let mut answers: Vec<Vec<f64>> = Vec::new();
        for policy in [
            ReorderPolicy::Natural,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Auto,
        ] {
            let mut c = Coordinator::new(Config { reorder: policy, ..Config::default() });
            let prep = c.prepare("t", &coo).unwrap();
            assert_eq!(prep.plan.reorder.requested, policy);
            if policy == ReorderPolicy::Natural {
                assert_eq!(prep.plan.reorder.strategy, "natural");
                assert_eq!(prep.reordered_bw, prep.bw_before);
            } else {
                assert!(prep.reordered_bw <= prep.bw_before, "{policy}");
            }
            // every strategy serves the same operator: permute x into
            // the strategy's ordering, multiply, un-permute the result
            let mut xp = vec![0.0; 200];
            for (old, &new) in prep.perm.iter().enumerate() {
                xp[new as usize] = x[old];
            }
            let yp = c.spmv(&prep, &xp, Backend::Pars3 { p: 3 }).unwrap();
            let mut y = vec![0.0; 200];
            for (old, &new) in prep.perm.iter().enumerate() {
                y[old] = yp[new as usize];
            }
            answers.push(y);
        }
        for y in &answers[1..] {
            for (r, (a, b)) in y.iter().zip(&answers[0]).enumerate() {
                assert!((a - b).abs() < 1e-9, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn backends_agree_natively() {
        let coo = gen::small_test_matrix(200, 12, 1.5);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.21).sin()).collect();
        let y0 = c.spmv(&prep, &x, Backend::Serial).unwrap();
        for backend in [
            Backend::Csr,
            Backend::Dgbmv,
            Backend::Coloring { p: 3 },
            Backend::Race { p: 3 },
            Backend::Pars3 { p: 4 },
        ] {
            let y1 = c.spmv(&prep, &x, backend).unwrap();
            for (a, b) in y0.iter().zip(&y1) {
                assert!((a - b).abs() < 1e-10, "{backend:?}");
            }
        }
    }

    #[test]
    fn dimension_mismatch_is_typed() {
        use crate::coordinator::Pars3Error;
        let coo = gen::small_test_matrix(60, 25, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let err = c.spmv(&prep, &vec![0.0; 59], Backend::Serial).unwrap_err();
        assert_eq!(err, Pars3Error::DimensionMismatch { expected: 60, got: 59 });
        let opts = MrsOptions { alpha: 2.0, max_iters: 10, tol: 1e-8 };
        let err = c.solve(&prep, &vec![0.0; 7], &opts, Backend::Serial).unwrap_err();
        assert_eq!(err, Pars3Error::DimensionMismatch { expected: 60, got: 7 });
        let xs = VecBatch::zeros(10, 2);
        let err = c.spmv_batch(&prep, &xs, Backend::Serial).unwrap_err();
        assert_eq!(err, Pars3Error::DimensionMismatch { expected: 60, got: 10 });
    }

    #[test]
    fn lru_cap_evicts_least_recently_used_kernel() {
        let coo = gen::small_test_matrix(90, 26, 2.0);
        let mut c = Coordinator::new(Config { max_cached_kernels: 2, ..Config::default() });
        let prep = c.prepare("t", &coo).unwrap();
        let x = vec![1.0; 90];
        c.spmv(&prep, &x, Backend::Serial).unwrap(); // build serial
        c.spmv(&prep, &x, Backend::Csr).unwrap(); // build csr
        c.spmv(&prep, &x, Backend::Serial).unwrap(); // touch serial: csr is now LRU
        assert_eq!(c.kernel_cache_stats(), (2, 2));
        c.spmv(&prep, &x, Backend::Dgbmv).unwrap(); // past the cap: evicts csr
        assert_eq!(c.kernel_cache_stats(), (2, 3));
        c.spmv(&prep, &x, Backend::Serial).unwrap(); // serial survived the evict
        assert_eq!(c.kernel_cache_stats(), (2, 3), "touched entry must not be evicted");
        c.spmv(&prep, &x, Backend::Csr).unwrap(); // evictee rebuilds transparently
        assert_eq!(c.kernel_cache_stats(), (2, 4));
    }

    #[test]
    fn solve_serial_and_pars3_agree() {
        let coo = gen::small_test_matrix(150, 13, 3.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let b: Vec<f64> = (0..150).map(|i| ((i % 7) as f64) - 3.0).collect();
        let opts = MrsOptions { alpha: 3.0, max_iters: 200, tol: 1e-8 };
        let r0 = c.solve(&prep, &b, &opts, Backend::Serial).unwrap();
        let r1 = c.solve(&prep, &b, &opts, Backend::Pars3 { p: 3 }).unwrap();
        assert!(r0.converged && r1.converged);
        for (a, b) in r0.x.iter().zip(&r1.x) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn batch_spmv_agrees_with_columnwise_spmv() {
        let coo = gen::small_test_matrix(140, 15, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let xs = VecBatch::from_fn(140, 4, |i, col| ((i + col * 3) % 7) as f64 - 3.0);
        for backend in [Backend::Serial, Backend::Pars3 { p: 4 }] {
            let ys = c.spmv_batch(&prep, &xs, backend).unwrap();
            for col in 0..4 {
                let want = c.spmv(&prep, xs.col(col), backend).unwrap();
                for (r, (a, b)) in ys.col(col).iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < 1e-9, "{backend:?} col {col} row {r}");
                }
            }
        }
    }

    #[test]
    fn solve_batch_matches_columnwise_solve() {
        let coo = gen::small_test_matrix(120, 16, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let opts = MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 };
        let bs = VecBatch::from_fn(120, 3, |i, col| ((i * (col + 2)) % 9) as f64 - 4.0);
        let results = c.solve_batch(&prep, &bs, &opts, Backend::Pars3 { p: 3 }).unwrap();
        assert_eq!(results.len(), 3);
        for (col, res) in results.iter().enumerate() {
            let want = c.solve(&prep, bs.col(col), &opts, Backend::Pars3 { p: 3 }).unwrap();
            assert_eq!(res.converged, want.converged, "col {col}");
            for (a, b) in res.x.iter().zip(&want.x) {
                assert!((a - b).abs() < 1e-6, "col {col}");
            }
        }
    }

    #[test]
    fn prepared_matrix_is_shared_with_kernels_not_cloned() {
        let coo = gen::small_test_matrix(80, 17, 1.5);
        let c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let before_sss = Arc::strong_count(&prep.sss);
        let before_split = Arc::strong_count(&prep.split);
        let k_serial = c.kernel(&prep, Backend::Serial).unwrap();
        assert_eq!(Arc::strong_count(&prep.sss), before_sss + 1, "serial shares the Sss");
        let k_pars3 = c.kernel(&prep, Backend::Pars3 { p: 2 }).unwrap();
        assert_eq!(Arc::strong_count(&prep.split), before_split + 1, "pars3 shares the split");
        drop((k_serial, k_pars3));
        assert_eq!(Arc::strong_count(&prep.sss), before_sss);
        assert_eq!(Arc::strong_count(&prep.split), before_split);
    }

    #[test]
    fn repeated_requests_build_each_kernel_exactly_once() {
        let coo = gen::small_test_matrix(120, 18, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        assert_eq!(c.kernel_cache_stats(), (0, 0));
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.11).sin()).collect();
        for _ in 0..3 {
            c.spmv(&prep, &x, Backend::Pars3 { p: 4 }).unwrap();
        }
        assert_eq!(c.kernel_cache_stats(), (1, 1), "3 spmvs, one pars3 build");
        let opts = MrsOptions { alpha: 2.0, max_iters: 50, tol: 1e-6 };
        c.solve(&prep, &x, &opts, Backend::Pars3 { p: 4 }).unwrap();
        assert_eq!(c.kernel_cache_stats(), (1, 1), "solve reuses the spmv kernel");
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (2, 2), "serial is a second entry");
        c.spmv(&prep, &x, Backend::Pars3 { p: 2 }).unwrap();
        assert_eq!(c.kernel_cache_stats(), (3, 3), "different p = different kernel");
        c.evict(&prep);
        assert_eq!(c.kernel_cache_stats(), (0, 3), "evict drops this matrix's kernels");
    }

    #[test]
    fn cache_distinguishes_config_changes() {
        let coo = gen::small_test_matrix(90, 24, 1.5);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let x = vec![1.0; 90];
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (1, 1));
        // mutating the public cfg must build a fresh kernel, not serve
        // the one constructed under the old settings
        c.cfg.threaded = true;
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (2, 2));
        // so must a tile-budget change (it alters the blocked traversal)
        c.cfg.l2_kib = 1;
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (3, 3));
        c.clear_kernel_cache();
        assert_eq!(c.kernel_cache_stats(), (0, 3));
    }

    #[test]
    fn cache_keys_on_the_plan_choice_so_a_replan_rebuilds() {
        use crate::kernel::FormatPolicy;
        let coo = gen::small_test_matrix(110, 27, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let x = vec![1.0; 110];
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (1, 1));
        // simulate a re-plan that resolved a different triple for the
        // same matrix allocation: the cache must treat it as a new
        // kernel, never serving one built for the old triple
        let mut replanned = prep.clone();
        replanned.choice.format = match prep.choice.format {
            FormatPolicy::Dia => FormatPolicy::Sss,
            _ => FormatPolicy::Dia,
        };
        c.spmv(&replanned, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (2, 2), "new triple, new kernel");
        c.spmv(&prep, &x, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (2, 2), "old triple still cached");
    }

    #[test]
    fn cache_distinguishes_matrices_by_identity() {
        let mut c = coordinator();
        let prep_a = c.prepare("a", &gen::small_test_matrix(80, 19, 1.5)).unwrap();
        let prep_b = c.prepare("b", &gen::small_test_matrix(90, 20, 1.5)).unwrap();
        let xa = vec![1.0; 80];
        let xb = vec![1.0; 90];
        c.spmv(&prep_a, &xa, Backend::Serial).unwrap();
        c.spmv(&prep_b, &xb, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (2, 2));
        // evicting one matrix leaves the other's kernel cached
        c.evict(&prep_a);
        assert_eq!(c.kernel_cache_stats().0, 1);
        c.spmv(&prep_b, &xb, Backend::Serial).unwrap();
        assert_eq!(c.kernel_cache_stats(), (1, 2), "b's kernel survived the evict");
    }

    #[test]
    fn format_policies_agree_through_the_coordinator() {
        use crate::kernel::FormatPolicy;
        let coo = gen::small_test_matrix(160, 21, 2.0);
        let x: Vec<f64> = (0..160).map(|i| (i as f64 * 0.19).cos()).collect();
        let mut outs = Vec::new();
        for format in [FormatPolicy::Sss, FormatPolicy::Dia] {
            let mut c = Coordinator::new(Config { format, ..Config::default() });
            let prep = c.prepare("t", &coo).unwrap();
            assert_eq!(
                prep.split.format_name(),
                if format == FormatPolicy::Dia { "dia" } else { "sss" }
            );
            outs.push(c.spmv(&prep, &x, Backend::Pars3 { p: 4 }).unwrap());
            outs.push(c.spmv(&prep, &x, Backend::Serial).unwrap());
        }
        for y in &outs[1..] {
            for (r, (a, b)) in y.iter().zip(&outs[0]).enumerate() {
                assert!((a - b).abs() < 1e-9, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rejects_non_skew_input() {
        let mut coo = Coo::new(4);
        coo.push(1, 0, 2.0);
        coo.push(0, 1, 2.0); // symmetric — must be rejected
        let c = coordinator();
        assert!(c.prepare("bad", &coo).is_err());
    }

    #[test]
    fn backend_kernel_names_cover_the_registry() {
        assert_eq!(Backend::Serial.kernel_name(), Some("serial_sss"));
        assert_eq!(Backend::Csr.kernel_name(), Some("csr"));
        assert_eq!(Backend::Dgbmv.kernel_name(), Some("dgbmv"));
        assert_eq!(Backend::Coloring { p: 2 }.kernel_name(), Some("coloring"));
        assert_eq!(Backend::Race { p: 2 }.kernel_name(), Some("race"));
        assert_eq!(Backend::Pars3 { p: 4 }.kernel_name(), Some("pars3"));
        assert_eq!(Backend::Pjrt.kernel_name(), None);
        // every registry kernel is reachable from a Backend, and every
        // native Backend maps into the registry inventory
        let native = [
            Backend::Serial,
            Backend::Csr,
            Backend::Dgbmv,
            Backend::Coloring { p: 2 },
            Backend::Race { p: 2 },
            Backend::Pars3 { p: 2 },
        ];
        let names: Vec<_> = native.iter().filter_map(Backend::kernel_name).collect();
        for name in &names {
            assert!(crate::kernel::KERNEL_NAMES.contains(name));
        }
        for name in crate::kernel::KERNEL_NAMES {
            assert!(names.contains(name), "{name} has no Backend variant");
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_fails_cleanly_without_feature() {
        let coo = gen::small_test_matrix(50, 14, 2.0);
        let mut c = coordinator();
        let prep = c.prepare("t", &coo).unwrap();
        let err = c.spmv(&prep, &vec![0.0; 50], Backend::Pjrt).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }
}
