//! L3 coordinator: the end-to-end pipeline
//! (ingest → plan the (reorder, format, backend) triple
//! ([`Planner`]) → reorder → 3-way split → conflict analysis →
//! distribute → repeated SpMV / MRS solve), plus config, the
//! crate-wide typed error, and the sharded request service with its
//! handle-based, pipelined client API.
//!
//! This is the paper's system glued together: preprocessing is done once
//! per matrix ([`Coordinator::prepare`]); the returned [`Prepared`]
//! handle then serves arbitrarily many multiplies/solves — the
//! amortization argument of §4 ("this overhead typically can be
//! amortized in many repeated runs with the same matrix"). At service
//! scale the same story is [`Client::prepare`] → [`MatrixHandle`] →
//! pipelined [`Ticket`]s against a pool of shard workers.

pub mod client;
pub mod config;
pub mod error;
pub mod pipeline;
pub mod planner;
pub mod service;

pub use client::{Client, ClientApi, MatrixHandle, Ticket};
pub use config::Config;
pub use error::Pars3Error;
pub use pipeline::{Backend, Coordinator, Prepared};
pub use planner::{
    AxisReport, BackendPolicy, PlanCandidate, PlanChoice, PlanConstraints, PlanMode, PlanReport,
    Planned, Planner,
};
pub use service::{CacheStats, MatrixInfo, Service};
