//! L3 coordinator: the end-to-end pipeline
//! (ingest → RCM reorder → 3-way split → conflict analysis → distribute
//! → repeated SpMV / MRS solve), plus config and a request-service loop.
//!
//! This is the paper's system glued together: preprocessing is done once
//! per matrix ([`Coordinator::prepare`]); the returned [`Prepared`]
//! handle then serves arbitrarily many multiplies/solves — the
//! amortization argument of §4 ("this overhead typically can be
//! amortized in many repeated runs with the same matrix").

pub mod config;
pub mod pipeline;
pub mod service;

pub use config::Config;
pub use pipeline::{Backend, Coordinator, Prepared};
pub use service::{Request, Response, Service};
