//! Synthetic matrix generators, including analogues of the paper's
//! SuiteSparse benchmark suite (Table 1).
//!
//! The paper evaluates on six FEM matrices of 17-78 MNNZ. Those exact
//! matrices are external data we substitute (DESIGN.md §2): each gets a
//! generator producing the same *structure class* at ~1/64 scale —
//! 2D/3D grid stencils (FEM meshes), multiple DOF per node (structural
//! problems like ldoor/audikw), and a controlled fraction of random
//! long-range couplings (what makes Serena/audikw's RCM bandwidth large).
//! The relative NNZ / RCM-bandwidth ordering of Table 1 is preserved,
//! which is what drives the paper's Figure 9 speedup ordering.
//!
//! Generators emit *lower-triangle symmetric patterns* (graph edges);
//! [`crate::sparse::skew::coo_from_pattern`] assigns skew values.

use crate::util::SmallRng;

/// A named synthetic benchmark instance.
#[derive(Debug, Clone)]
pub struct BenchMatrix {
    /// Analogue name, e.g. `"af_5_k101_like"`.
    pub name: &'static str,
    /// Paper's original row count (Table 1) for reference.
    pub paper_rows: usize,
    /// Paper's original NNZ (Table 1).
    pub paper_nnz: usize,
    /// Paper's post-RCM bandwidth (Table 1).
    pub paper_rcm_bw: usize,
    /// Our instance dimension.
    pub n: usize,
    /// Lower-triangle pattern edges `(i, j)`, `i > j`.
    pub lower_edges: Vec<(u32, u32)>,
}

impl BenchMatrix {
    /// Logical full-matrix NNZ (both triangles + dense diagonal).
    pub fn nnz_full(&self) -> usize {
        2 * self.lower_edges.len() + self.n
    }
}

fn push_edge(edges: &mut Vec<(u32, u32)>, a: usize, b: usize) {
    if a == b {
        return;
    }
    let (i, j) = if a > b { (a, b) } else { (b, a) };
    edges.push((i as u32, j as u32));
}

fn dedup(edges: &mut Vec<(u32, u32)>) {
    edges.sort_unstable();
    edges.dedup();
}

/// 2D grid graph with `dof` unknowns per node and coupling radius `r`
/// (Chebyshev distance) — an FEM-plate/shell-like pattern.
pub fn grid2d_pattern(nx: usize, ny: usize, r: usize, dof: usize) -> Vec<(u32, u32)> {
    let node = |x: usize, y: usize| (y * nx + x) * dof;
    let mut edges = Vec::new();
    for y in 0..ny {
        for x in 0..nx {
            let a = node(x, y);
            // intra-node DOF coupling (dense block)
            for da in 0..dof {
                for db in 0..da {
                    push_edge(&mut edges, a + da, a + db);
                }
            }
            for dy in 0..=r {
                for dx in -(r as isize)..=(r as isize) {
                    if dy == 0 && dx <= 0 {
                        continue; // count each neighbour pair once
                    }
                    let x2 = x as isize + dx;
                    let y2 = y + dy;
                    if x2 < 0 || x2 >= nx as isize || y2 >= ny {
                        continue;
                    }
                    let b = node(x2 as usize, y2);
                    for da in 0..dof {
                        for db in 0..dof {
                            push_edge(&mut edges, a + da, b + db);
                        }
                    }
                }
            }
        }
    }
    dedup(&mut edges);
    edges
}

/// 3D grid graph with coupling radius `r` — a solid-FEM-like pattern.
pub fn grid3d_pattern(nx: usize, ny: usize, nz: usize, r: usize, dof: usize) -> Vec<(u32, u32)> {
    let node = |x: usize, y: usize, z: usize| ((z * ny + y) * nx + x) * dof;
    let mut edges = Vec::new();
    let ir = r as isize;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let a = node(x, y, z);
                for da in 0..dof {
                    for db in 0..da {
                        push_edge(&mut edges, a + da, a + db);
                    }
                }
                for dz in 0..=ir {
                    for dy in -ir..=ir {
                        for dx in -ir..=ir {
                            // half-space to count pairs once
                            if dz < 0
                                || (dz == 0 && dy < 0)
                                || (dz == 0 && dy == 0 && dx <= 0)
                            {
                                continue;
                            }
                            let (x2, y2, z2) =
                                (x as isize + dx, y as isize + dy, z as isize + dz);
                            if x2 < 0
                                || y2 < 0
                                || x2 >= nx as isize
                                || y2 >= ny as isize
                                || z2 >= nz as isize
                            {
                                continue;
                            }
                            let b = node(x2 as usize, y2 as usize, z2 as usize);
                            for da in 0..dof {
                                for db in 0..dof {
                                    push_edge(&mut edges, a + da, b + db);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    dedup(&mut edges);
    edges
}

/// Random pattern with local banded structure: each row `i` couples to
/// ~`per_row` random columns within `[i - width, i)`.
pub fn random_banded_pattern(
    n: usize,
    per_row: usize,
    density: f64,
    rng: &mut SmallRng,
) -> Vec<(u32, u32)> {
    let width = (per_row as f64 / density).ceil() as usize;
    let mut edges = Vec::new();
    for i in 1..n {
        let w = width.min(i);
        for _ in 0..per_row.min(i) {
            let j = i - 1 - rng.gen_range_usize(0, w);
            push_edge(&mut edges, i, j);
        }
    }
    dedup(&mut edges);
    edges
}

/// Add `frac * existing` random long-range edges (blows up bandwidth the
/// way Serena/audikw_1's non-local couplings do).
pub fn add_long_range(edges: &mut Vec<(u32, u32)>, n: usize, frac: f64, rng: &mut SmallRng) {
    let extra = (edges.len() as f64 * frac) as usize;
    for _ in 0..extra {
        let i = rng.gen_range_usize(1, n);
        let j = rng.gen_range_usize(0, i);
        push_edge(edges, i, j);
    }
    dedup(edges);
}

/// Scramble vertex ids with a random permutation — destroys any natural
/// band structure so RCM has real work to do (paper Fig. 5's point:
/// already-banded inputs gain little).
pub fn scramble(edges: &[(u32, u32)], n: usize, rng: &mut SmallRng) -> Vec<(u32, u32)> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    for i in (1..n).rev() {
        let j = rng.gen_range_usize(0, i + 1);
        perm.swap(i, j);
    }
    let mut out: Vec<(u32, u32)> = edges
        .iter()
        .map(|&(a, b)| {
            let (pa, pb) = (perm[a as usize], perm[b as usize]);
            if pa > pb {
                (pa, pb)
            } else {
                (pb, pa)
            }
        })
        .collect();
    dedup(&mut out);
    out
}

/// The six Table-1 analogues at `scale` (1 = default ~1/64 of paper size).
///
/// Deterministic for a given `(name, scale)`: seeded per matrix.
pub fn paper_suite(scale: f64) -> Vec<BenchMatrix> {
    let s = scale.max(0.05);
    let dim2 = |base: usize| ((base as f64 * s.sqrt()).round() as usize).max(4);
    let dim3 = |base: usize| ((base as f64 * s.cbrt()).round() as usize).max(3);

    let mut suite = Vec::new();

    // boneS10: 3D trabecular bone micro-FE model, 3 DOF/node, moderate bw.
    {
        let mut rng = SmallRng::seed_from_u64(0xB0E5);
        let (nx, ny, nz) = (dim3(17), dim3(17), dim3(17));
        let edges = grid3d_pattern(nx, ny, nz, 1, 3);
        let n = nx * ny * nz * 3;
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "boneS10_like",
            paper_rows: 914_898,
            paper_nnz: 40_878_708,
            paper_rcm_bw: 13_727,
            n,
            lower_edges: edges,
        });
    }

    // Emilia_923: 3D geomechanical reservoir model, similar to boneS10 but
    // slightly wider couplings.
    {
        let mut rng = SmallRng::seed_from_u64(0xE117);
        let (nx, ny, nz) = (dim3(20), dim3(17), dim3(14));
        let edges = grid3d_pattern(nx, ny, nz, 1, 3);
        let n = nx * ny * nz * 3;
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "Emilia_923_like",
            paper_rows: 923_136,
            paper_nnz: 40_373_538,
            paper_rcm_bw: 14_672,
            n,
            lower_edges: edges,
        });
    }

    // ldoor: large thin shell (car door), 2D-dominant, small RCM bandwidth.
    {
        let mut rng = SmallRng::seed_from_u64(0x1D00);
        let (nx, ny) = (dim2(90), dim2(55));
        let edges = grid2d_pattern(nx, ny, 1, 3);
        let n = nx * ny * 3;
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "ldoor_like",
            paper_rows: 952_203,
            paper_nnz: 42_493_817,
            paper_rcm_bw: 8_707,
            n,
            lower_edges: edges,
        });
    }

    // af_5_k101: sheet-metal forming, very regular and strongly
    // elongated — by far the *smallest* relative RCM bandwidth in
    // Table 1 (1274 / 503625), which is why it scales best (19x).
    {
        let mut rng = SmallRng::seed_from_u64(0xAF51);
        let (nx, ny) = (dim2(160), dim2(11));
        let edges = grid2d_pattern(nx, ny, 1, 3);
        let n = nx * ny * 3;
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "af_5_k101_like",
            paper_rows: 503_625,
            paper_nnz: 17_550_675,
            paper_rcm_bw: 1_274,
            n,
            lower_edges: edges,
        });
    }

    // Serena: gas-reservoir model, largest matrix, *huge* RCM bandwidth
    // from non-local couplings.
    {
        let mut rng = SmallRng::seed_from_u64(0x5E7A);
        let (nx, ny, nz) = (dim3(20), dim3(19), dim3(19));
        let mut edges = grid3d_pattern(nx, ny, nz, 1, 3);
        let n = nx * ny * nz * 3;
        add_long_range(&mut edges, n, 0.08, &mut rng);
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "Serena_like",
            paper_rows: 1_391_349,
            paper_nnz: 64_131_971,
            paper_rcm_bw: 87_872,
            n,
            lower_edges: edges,
        });
    }

    // audikw_1: crankshaft solid FEM, densest rows (~82 nnz/row) and large
    // bandwidth.
    {
        let mut rng = SmallRng::seed_from_u64(0xAD1C);
        let (nx, ny, nz) = (dim3(17), dim3(17), dim3(17));
        let mut edges = grid3d_pattern(nx, ny, nz, 1, 3);
        let n = nx * ny * nz * 3;
        // densify: second-shell couplings for a fraction of nodes
        add_long_range(&mut edges, n, 0.018, &mut rng);
        let edges = scramble(&edges, n, &mut rng);
        suite.push(BenchMatrix {
            name: "audikw_1_like",
            paper_rows: 943_695,
            paper_nnz: 77_651_847,
            paper_rcm_bw: 35_102,
            n,
            lower_edges: edges,
        });
    }

    suite
}

/// Lower edges of a `g×g` 5-point mesh, scrambled (structurally
/// symmetric; natural bandwidth `g`, which no reordering beats by
/// much — the RACE case where kernel choice matters more than order).
pub fn mesh_pattern(g: usize, rng: &mut SmallRng) -> (usize, Vec<(u32, u32)>) {
    let n = g * g;
    let mut edges = Vec::new();
    for r in 0..g {
        for c in 0..g {
            let i = (r * g + c) as u32;
            if c > 0 {
                edges.push((i, i - 1));
            }
            if r > 0 {
                edges.push((i, i - g as u32));
            }
        }
    }
    (n, scramble(&edges, n, rng))
}

/// The five pattern families the planner-honesty and roofline benches
/// sweep, each `(name, n, lower_edges)`:
///
/// * `banded`       — already tightly banded (reordering should decline);
/// * `scattered`    — scrambled band + long-range edges (reordering wins);
/// * `disconnected` — disjoint banded blocks, scrambled;
/// * `symmetric`    — structurally symmetric 2D 5-point mesh;
/// * `small_world`  — ring + random shortcuts (level coloring's target).
pub fn pattern_families(
    n: usize,
    rng: &mut SmallRng,
) -> Vec<(&'static str, usize, Vec<(u32, u32)>)> {
    let banded = random_banded_pattern(n, 4, 0.5, rng);
    let mut scattered = banded.clone();
    add_long_range(&mut scattered, n, 0.05, rng);
    let scattered = scramble(&scattered, n, rng);
    let block = n / 3;
    let mut disconnected = Vec::new();
    for b in 0..3u32 {
        let base = b * block as u32;
        for (i, j) in random_banded_pattern(block, 3, 0.5, rng) {
            disconnected.push((i + base, j + base));
        }
    }
    let dn = 3 * block;
    let disconnected = scramble(&disconnected, dn, rng);
    let g = (n as f64).sqrt() as usize;
    let (mn, mesh) = mesh_pattern(g.max(6), rng);
    let sw = small_world(n, 3, 0.3, rng);
    vec![
        ("banded", n, banded),
        ("scattered", n, scattered),
        ("disconnected", dn, disconnected),
        ("symmetric", mn, mesh),
        ("small_world", n, sw),
    ]
}

/// Small-world pattern (Watts–Strogatz-style): a ring lattice where
/// every vertex couples to its `k_neighbors` nearest neighbours on each
/// side, plus `long_range_frac * k_neighbors * n` random long-range
/// shortcut edges (the rewires). The BFS level structure is shallow and
/// wide and no ordering bands the shortcuts — the family RACE-style
/// level coloring targets and RCM banding serves poorly.
pub fn small_world(
    n: usize,
    k_neighbors: usize,
    long_range_frac: f64,
    rng: &mut SmallRng,
) -> Vec<(u32, u32)> {
    let mut edges = Vec::new();
    for i in 0..n {
        for d in 1..=k_neighbors.min(n.saturating_sub(1) / 2) {
            push_edge(&mut edges, i, (i + d) % n);
        }
    }
    let extra = ((k_neighbors * n) as f64 * long_range_frac) as usize;
    for _ in 0..extra {
        let a = rng.gen_range_usize(0, n);
        let b = rng.gen_range_usize(0, n);
        push_edge(&mut edges, a, b);
    }
    dedup(&mut edges);
    edges
}

/// Convenience: a small, fully deterministic test matrix (shifted skew).
pub fn small_test_matrix(n: usize, seed: u64, alpha: f64) -> crate::sparse::Coo {
    let mut rng = SmallRng::seed_from_u64(seed);
    let mut edges = random_banded_pattern(n, 4, 0.5, &mut rng);
    add_long_range(&mut edges, n, 0.05, &mut rng);
    crate::sparse::skew::coo_from_pattern(n, &edges, alpha, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid2d_edge_count() {
        // 3x3 grid, r=1, dof=1: 12 rook edges + 8 diagonal edges = 20
        let e = grid2d_pattern(3, 3, 1, 1);
        assert_eq!(e.len(), 20);
        assert!(e.iter().all(|&(i, j)| i > j));
    }

    #[test]
    fn grid3d_edge_count_small() {
        // 2x2x2, r=1, dof=1: complete-ish 8-node stencil graph = C(8,2)=28
        let e = grid3d_pattern(2, 2, 2, 1, 1);
        assert_eq!(e.len(), 28);
    }

    #[test]
    fn dof_blocks_expand() {
        let e1 = grid2d_pattern(2, 2, 1, 1);
        let e3 = grid2d_pattern(2, 2, 1, 3);
        // every node edge -> 9 dof edges, plus 3 intra-node per node
        assert_eq!(e3.len(), e1.len() * 9 + 4 * 3);
    }

    #[test]
    fn scramble_preserves_edge_count() {
        let mut rng = SmallRng::seed_from_u64(1);
        let e = grid2d_pattern(5, 5, 1, 1);
        let s = scramble(&e, 25, &mut rng);
        assert_eq!(e.len(), s.len());
    }

    #[test]
    fn suite_has_six_matrices_ordered_like_table1() {
        let suite = paper_suite(0.2);
        assert_eq!(suite.len(), 6);
        let by_name = |n: &str| suite.iter().find(|m| m.name == n).unwrap();
        // af analogue is the smallest, Serena analogue the largest (rows)
        assert!(by_name("af_5_k101_like").n < by_name("Serena_like").n);
        for m in &suite {
            assert!(m.n > 0 && !m.lower_edges.is_empty(), "{} empty", m.name);
            assert!(m.lower_edges.iter().all(|&(i, j)| i > j && (i as usize) < m.n));
        }
    }

    #[test]
    fn pattern_families_are_well_formed() {
        let mut rng = SmallRng::seed_from_u64(11);
        let fams = pattern_families(120, &mut rng);
        assert_eq!(fams.len(), 5);
        let names: Vec<_> = fams.iter().map(|(f, ..)| *f).collect();
        assert_eq!(
            names,
            ["banded", "scattered", "disconnected", "symmetric", "small_world"]
        );
        for (f, n, edges) in &fams {
            assert!(*n > 0 && !edges.is_empty(), "{f} empty");
            assert!(
                edges.iter().all(|&(i, j)| i > j && (i as usize) < *n),
                "{f} malformed edges"
            );
        }
    }

    #[test]
    fn small_world_ring_plus_shortcuts() {
        let mut rng = SmallRng::seed_from_u64(21);
        let e = small_world(50, 2, 0.0, &mut rng);
        // pure ring lattice: exactly k*n edges, all well-formed
        assert_eq!(e.len(), 100);
        assert!(e.iter().all(|&(i, j)| i > j && (i as usize) < 50));
        let with_shortcuts = small_world(50, 2, 0.5, &mut rng);
        assert!(with_shortcuts.len() > e.len());
    }

    #[test]
    fn suite_deterministic() {
        let a = paper_suite(0.1);
        let b = paper_suite(0.1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.lower_edges, y.lower_edges);
        }
    }
}
