//! MatrixMarket coordinate-format I/O.
//!
//! Supports `real general`, `real symmetric`, and `real skew-symmetric`
//! headers (the SuiteSparse collection the paper draws from ships
//! skew-symmetric relatives in this format). Symmetric/skew files store
//! only one triangle; the reader expands to a full COO so the rest of the
//! pipeline is uniform.

use crate::sparse::Coo;
use crate::Result;
use anyhow::{anyhow, bail, ensure};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Symmetry field of the MatrixMarket header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmSymmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a MatrixMarket coordinate file into a full (expanded) COO matrix.
pub fn read_matrix_market<P: AsRef<Path>>(path: P) -> Result<(Coo, MmSymmetry)> {
    let file = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow!("open {:?}: {e}", path.as_ref()))?;
    read_from(std::io::BufReader::new(file))
}

/// Reader-generic parse (unit-testable without touching disk).
pub fn read_from<R: BufRead>(reader: R) -> Result<(Coo, MmSymmetry)> {
    let mut lines = reader.lines();
    let header = lines.next().ok_or_else(|| anyhow!("empty file"))??;
    let h = header.to_ascii_lowercase();
    ensure!(h.starts_with("%%matrixmarket"), "not a MatrixMarket file");
    ensure!(h.contains("matrix") && h.contains("coordinate"), "only coordinate matrices supported");
    ensure!(h.contains("real") || h.contains("integer"), "only real/integer values supported");
    let sym = if h.contains("skew-symmetric") {
        MmSymmetry::SkewSymmetric
    } else if h.contains("symmetric") {
        MmSymmetry::Symmetric
    } else if h.contains("general") {
        MmSymmetry::General
    } else {
        bail!("unsupported symmetry in header: {header}");
    };

    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| anyhow!("missing size line"))?;
    let mut it = size_line.split_whitespace();
    let nrows: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    let ncols: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    let nnz: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    ensure!(nrows == ncols, "only square matrices supported ({nrows}x{ncols})");

    let mut coo = Coo::with_capacity(nrows, nnz * 2);
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().ok_or_else(|| anyhow!("bad entry line: {t}"))?.parse()?;
        let j: usize = it.next().ok_or_else(|| anyhow!("bad entry line: {t}"))?.parse()?;
        let v: f64 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
        ensure!(i >= 1 && i <= nrows && j >= 1 && j <= ncols, "entry ({i},{j}) out of range");
        let (i, j) = (i as u32 - 1, j as u32 - 1);
        coo.push(i, j, v);
        match sym {
            MmSymmetry::Symmetric if i != j => coo.push(j, i, v),
            MmSymmetry::SkewSymmetric => {
                ensure!(i != j, "skew-symmetric file stores no diagonal");
                coo.push(j, i, -v);
            }
            _ => {}
        }
        seen += 1;
    }
    ensure!(seen == nnz, "header promised {nnz} entries, found {seen}");
    Ok((coo, sym))
}

/// Write a full COO matrix as `general` (exact round-trip of all entries).
pub fn write_matrix_market<P: AsRef<Path>>(path: P, coo: &Coo) -> Result<()> {
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by pars3")?;
    writeln!(w, "{} {} {}", coo.n, coo.n, coo.nnz())?;
    for k in 0..coo.nnz() {
        writeln!(w, "{} {} {:.17e}", coo.rows[k] + 1, coo.cols[k] + 1, coo.vals[k])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 2\n1 2 1.5\n3 1 -2.0\n";
        let (coo, sym) = read_from(Cursor::new(text)).unwrap();
        assert_eq!(sym, MmSymmetry::General);
        assert_eq!(coo.nnz(), 2);
        assert_eq!(coo.to_dense()[0][1], 1.5);
    }

    #[test]
    fn parse_skew_expands_mirror() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.0\n";
        let (coo, sym) = read_from(Cursor::new(text)).unwrap();
        assert_eq!(sym, MmSymmetry::SkewSymmetric);
        let d = coo.to_dense();
        assert_eq!(d[1][0], 3.0);
        assert_eq!(d[0][1], -3.0);
    }

    #[test]
    fn parse_symmetric_expands_mirror() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n2 1 3.0\n";
        let (coo, _) = read_from(Cursor::new(text)).unwrap();
        let d = coo.to_dense();
        assert_eq!(d[0][1], 3.0);
        assert_eq!(d[1][0], 3.0);
        assert_eq!(d[0][0], 1.0);
        assert_eq!(coo.nnz(), 3);
    }

    #[test]
    fn rejects_diagonal_in_skew() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n1 1 3.0\n";
        assert!(read_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_wrong_count() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 3.0\n";
        assert!(read_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let coo = crate::sparse::gen::small_test_matrix(20, 5, 1.0);
        let path = std::env::temp_dir().join("pars3_mmio_test.mtx");
        write_matrix_market(&path, &coo).unwrap();
        let (back, _) = read_matrix_market(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            crate::sparse::convert::coo_to_csr(&back),
            crate::sparse::convert::coo_to_csr(&coo)
        );
    }
}
