//! Skew-symmetric construction helpers.
//!
//! The paper's systems are `A = alpha*I + S` with `S = -S^T` (shifted
//! skew-symmetric), arising from Navier-Stokes, least squares, and
//! skew-symmetrizer preconditioning [Mehrmann & Manguoğlu 2021]. The
//! generators produce a *symmetric pattern* (a graph); this module turns
//! patterns into concrete shifted skew-symmetric matrices.

use crate::sparse::{Coo, Csr};
use crate::util::SmallRng;

/// Build a full COO matrix `alpha*I + S` from a lower-triangle edge
/// pattern: each `(i, j)` with `i > j` gets a random value `v` in
/// `[-1, 1)` at `(i, j)` and `-v` at `(j, i)`.
pub fn coo_from_pattern(
    n: usize,
    lower_edges: &[(u32, u32)],
    alpha: f64,
    rng: &mut SmallRng,
) -> Coo {
    let mut c = Coo::with_capacity(n, 2 * lower_edges.len() + n);
    if alpha != 0.0 {
        for i in 0..n as u32 {
            c.push(i, i, alpha);
        }
    }
    for &(i, j) in lower_edges {
        debug_assert!(i > j, "pattern edge ({i},{j}) must be strictly lower");
        let v = rng.gen_range_f64(-1.0, 1.0);
        c.push(i, j, v);
        c.push(j, i, -v);
    }
    c
}

/// Skew-symmetrize an arbitrary square CSR matrix: `S = (A - A^T) / 2`,
/// returned as full COO. The paper notes general matrices can be
/// preconditioned into near skew-symmetric form; this is the plain
/// algebraic projection onto the skew part.
pub fn skew_part(a: &Csr) -> Coo {
    let t = a.transpose();
    let mut out = Coo::with_capacity(a.n, 2 * a.nnz());
    for i in 0..a.n {
        for (j, v) in a.row(i) {
            if (j as usize) != i {
                out.push(i as u32, j, 0.5 * v);
            }
        }
        for (j, v) in t.row(i) {
            if (j as usize) != i {
                out.push(i as u32, j, -0.5 * v);
            }
        }
    }
    out.sum_duplicates();
    // drop numerically cancelled entries
    let mut w = 0usize;
    for k in 0..out.nnz() {
        if out.vals[k] != 0.0 {
            out.rows[w] = out.rows[k];
            out.cols[w] = out.cols[k];
            out.vals[w] = out.vals[k];
            w += 1;
        }
    }
    out.rows.truncate(w);
    out.cols.truncate(w);
    out.vals.truncate(w);
    out
}

/// Max violation of `A == -A^T` ignoring the diagonal (0.0 = exactly skew).
pub fn skew_violation(a: &Csr) -> f64 {
    let t = a.transpose();
    let mut worst = 0.0f64;
    for i in 0..a.n {
        for (j, v) in a.row(i) {
            if (j as usize) == i {
                continue;
            }
            worst = worst.max((v + t.get(i, j as usize)).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::convert;
        
    #[test]
    fn pattern_produces_shifted_skew() {
        let mut rng = SmallRng::seed_from_u64(7);
        let edges = vec![(1u32, 0u32), (3, 1), (4, 0), (4, 3)];
        let coo = coo_from_pattern(5, &edges, 2.0, &mut rng);
        let csr = convert::coo_to_csr(&coo);
        assert_eq!(skew_violation(&csr), 0.0);
        for i in 0..5 {
            assert_eq!(csr.get(i, i), 2.0);
        }
        assert_eq!(coo.nnz(), 13);
    }

    #[test]
    fn skew_part_of_general_matrix() {
        // A = [[1, 3], [1, 2]] -> S = [[0, 1], [-1, 0]]
        let mut c = Coo::new(2);
        c.push(0, 0, 1.0);
        c.push(0, 1, 3.0);
        c.push(1, 0, 1.0);
        c.push(1, 1, 2.0);
        let s = skew_part(&convert::coo_to_csr(&c));
        let d = s.to_dense();
        assert_eq!(d[0][1], 1.0);
        assert_eq!(d[1][0], -1.0);
        assert_eq!(d[0][0], 0.0);
        let csr = convert::coo_to_csr(&s);
        assert!(csr.is_skew_symmetric(1e-15));
    }

    #[test]
    fn skew_part_cancels_symmetric_input() {
        let mut c = Coo::new(3);
        c.push(0, 1, 2.0);
        c.push(1, 0, 2.0);
        let s = skew_part(&convert::coo_to_csr(&c));
        assert_eq!(s.nnz(), 0);
    }
}
