//! DIA (diagonal) band storage for shifted skew-symmetric matrices.
//!
//! This is the interchange format with the L1 Pallas kernel (see
//! `python/compile/kernels/band_spmv.py`): `A = alpha*I + S`, `S = -S^T`,
//! and only the sub-diagonals of `S` are stored densely:
//!
//! `lo[d][j] = S[j + d + 1][j]` for `d in 0..beta`, zero-padded where
//! `j + d + 1 >= n`.
//!
//! The dense-band layout wastes storage on explicit zeros inside the band
//! (the LAPACK `dgbmv` trade-off the paper discusses in §2) but gives the
//! PJRT/TPU path a fully regular access pattern.

use crate::sparse::{Sss, Symmetry};
use crate::Result;
use anyhow::ensure;

/// Dense banded shifted skew-symmetric matrix in DIA layout.
#[derive(Debug, Clone, PartialEq)]
pub struct DiaBand {
    /// Matrix dimension.
    pub n: usize,
    /// Half-bandwidth: number of stored sub-diagonals.
    pub beta: usize,
    /// Shift (constant diagonal of `A`).
    pub alpha: f64,
    /// Row-major `(beta, n)` sub-diagonal array (see module docs).
    pub lo: Vec<f64>,
}

impl DiaBand {
    /// All-zero band.
    pub fn zeros(n: usize, beta: usize, alpha: f64) -> Self {
        Self { n, beta, alpha, lo: vec![0.0; beta * n] }
    }

    /// Entry `lo[d][j]`.
    #[inline]
    pub fn get(&self, d: usize, j: usize) -> f64 {
        self.lo[d * self.n + j]
    }

    /// Set `lo[d][j] = v` (i.e. `S[j+d+1][j] = v`).
    #[inline]
    pub fn set(&mut self, d: usize, j: usize, v: f64) {
        self.lo[d * self.n + j] = v;
    }

    /// Build from a skew SSS matrix whose bandwidth fits in `beta`.
    pub fn from_sss(s: &Sss, beta: usize) -> Result<Self> {
        ensure!(s.sym == Symmetry::Skew, "DiaBand requires a skew SSS matrix");
        let bw = s.bandwidth();
        ensure!(bw <= beta, "matrix bandwidth {bw} exceeds beta {beta}");
        let alpha = s.dvalues.first().copied().unwrap_or(0.0);
        ensure!(
            s.dvalues.iter().all(|&v| (v - alpha).abs() < 1e-12),
            "shifted skew-symmetric form requires a constant diagonal"
        );
        let mut dia = DiaBand::zeros(s.n, beta, alpha);
        for i in 0..s.n {
            for (j, v) in s.row(i) {
                let d = i - j as usize - 1; // i = j + d + 1
                dia.set(d, j as usize, v);
            }
        }
        Ok(dia)
    }

    /// Convert back to SSS (drops explicit zeros).
    pub fn to_sss(&self) -> Sss {
        let mut row_ptr = vec![0usize; self.n + 1];
        let mut col_ind = Vec::new();
        let mut vals = Vec::new();
        for i in 0..self.n {
            // row i has entries at columns j = i - d - 1 for d in 0..beta
            for d in (0..self.beta.min(i)).rev() {
                let j = i - d - 1;
                let v = self.get(d, j);
                if v != 0.0 {
                    col_ind.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr[i + 1] = vals.len();
        }
        Sss {
            n: self.n,
            dvalues: vec![self.alpha; self.n],
            row_ptr,
            col_ind,
            vals,
            sym: Symmetry::Skew,
        }
    }

    /// Reference `y = (alpha*I + S) x` (mirrors the Python oracle).
    pub fn spmv_ref(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n;
        for i in 0..n {
            y[i] = self.alpha * x[i];
        }
        for d in 0..self.beta {
            let k = d + 1;
            if k >= n {
                break;
            }
            let row = &self.lo[d * n..d * n + (n - k)];
            for (j, &v) in row.iter().enumerate() {
                y[j + k] += v * x[j];
                y[j] -= v * x[j + k];
            }
        }
    }

    /// Flatten to f32 for the PJRT artifact input, zero-padding to
    /// `(beta_pad, n_pad)` when the artifact config is larger.
    pub fn to_f32_padded(&self, beta_pad: usize, n_pad: usize) -> Result<Vec<f32>> {
        ensure!(beta_pad >= self.beta && n_pad >= self.n, "padding smaller than matrix");
        let mut out = vec![0.0f32; beta_pad * n_pad];
        for d in 0..self.beta {
            for j in 0..self.n {
                out[d * n_pad + j] = self.get(d, j) as f32;
            }
        }
        Ok(out)
    }

    /// Fraction of stored band slots that are nonzero (density of the band).
    pub fn fill_ratio(&self) -> f64 {
        if self.lo.is_empty() {
            return 0.0;
        }
        let nz = self.lo.iter().filter(|v| **v != 0.0).count();
        nz as f64 / self.lo.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::convert;
    use crate::sparse::Coo;

    fn sample_sss() -> Sss {
        let mut c = Coo::new(5);
        for i in 0..5 {
            c.push(i, i, 1.25);
        }
        for (i, j, v) in [(1, 0, 2.0), (3, 1, -1.0), (4, 2, 0.5)] {
            c.push(i, j, v);
            c.push(j, i, -v);
        }
        convert::coo_to_sss(&c, Symmetry::Skew).unwrap()
    }

    #[test]
    fn from_sss_roundtrip() {
        let s = sample_sss();
        let dia = DiaBand::from_sss(&s, 2).unwrap();
        assert_eq!(dia.alpha, 1.25);
        assert_eq!(dia.to_sss(), s);
    }

    #[test]
    fn beta_too_small_rejected() {
        let s = sample_sss();
        assert!(DiaBand::from_sss(&s, 1).is_err());
    }

    #[test]
    fn spmv_matches_coo() {
        let s = sample_sss();
        let dia = DiaBand::from_sss(&s, 3).unwrap();
        let coo = convert::sss_to_coo(&s);
        let x: Vec<f64> = (0..5).map(|i| (i as f64) - 1.7).collect();
        let mut y0 = vec![0.0; 5];
        let mut y1 = vec![0.0; 5];
        coo.spmv_ref(&x, &mut y0);
        dia.spmv_ref(&x, &mut y1);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn f32_padding() {
        let s = sample_sss();
        let dia = DiaBand::from_sss(&s, 2).unwrap();
        let f = dia.to_f32_padded(4, 8).unwrap();
        assert_eq!(f.len(), 32);
        assert_eq!(f[0], 2.0); // lo[0][0] = S[1][0]
        assert!(dia.to_f32_padded(1, 8).is_err());
    }

    #[test]
    fn fill_ratio() {
        let s = sample_sss();
        let dia = DiaBand::from_sss(&s, 2).unwrap();
        assert!((dia.fill_ratio() - 0.3).abs() < 1e-12); // 3 of 10 slots
    }
}
