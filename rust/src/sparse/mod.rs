//! Sparse-matrix substrate: storage formats, conversions, I/O, generators.
//!
//! The paper leans on SPARSKIT for format plumbing and MATLAB for RCM;
//! this module is the from-scratch replacement. All formats share the
//! conventions:
//!
//! * indices are `u32` (column/row), pointers are `usize`;
//! * values are `f64` (the paper's "double precision");
//! * for skew-symmetric matrices only the **strictly lower triangle** is
//!   stored explicitly plus the diagonal (`A[i][j] = v`, `A[j][i] = -v`).

pub mod band;
pub mod convert;
pub mod coo;
pub mod csr;
pub mod dia;
pub mod gen;
pub mod mm_io;
pub mod skew;
pub mod sss;

pub use band::BandProfile;
pub use coo::Coo;
pub use csr::Csr;
pub use dia::DiaBand;
pub use sss::{Sss, Symmetry};
