//! Band-structure metrics: the quantities Figures 4-8 of the paper
//! visualize (bandwidth, envelope/profile, per-diagonal-distance density).

use crate::sparse::Sss;

/// Structural profile of a (lower-triangle) band matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct BandProfile {
    /// Matrix dimension.
    pub n: usize,
    /// Off-diagonal (lower) nonzeros.
    pub nnz_lower: usize,
    /// Max `i - j` over stored entries.
    pub bandwidth: usize,
    /// Envelope: `sum_i (i - min_col(i))` (the skyline profile).
    pub profile: u64,
    /// Histogram of nonzeros by diagonal distance `i - j` (index 0 = distance 1).
    pub dist_hist: Vec<usize>,
}

impl BandProfile {
    /// Compute the profile of an SSS matrix in one O(NNZ) pass.
    pub fn of(s: &Sss) -> Self {
        let mut bandwidth = 0usize;
        let mut profile = 0u64;
        let mut dist_hist = Vec::new();
        for i in 0..s.n {
            let mut min_col = i;
            for (j, _) in s.row(i) {
                let d = i - j as usize;
                bandwidth = bandwidth.max(d);
                min_col = min_col.min(j as usize);
                if d > dist_hist.len() {
                    dist_hist.resize(d, 0);
                }
                dist_hist[d - 1] += 1;
            }
            profile += (i - min_col) as u64;
        }
        Self { n: s.n, nnz_lower: s.nnz_lower(), bandwidth, profile, dist_hist }
    }

    /// Density of the band region: nnz / (slots inside the bandwidth).
    ///
    /// Slots = `sum_i min(i, bandwidth)`, i.e. the lower band area.
    pub fn band_density(&self) -> f64 {
        if self.bandwidth == 0 {
            return 0.0;
        }
        let b = self.bandwidth as u64;
        let n = self.n as u64;
        // sum_{i=0}^{n-1} min(i, b) = b*(b+1)/2 + (n - b - 1) * b   (for n > b)
        let slots = if n > b { b * (b + 1) / 2 + (n - b - 1) * b } else { n * (n - 1) / 2 };
        self.nnz_lower as f64 / slots as f64
    }

    /// Nonzero counts with distance <= `k` vs distance > `k` — the
    /// low/high bandwidth split of Fig. 6.
    pub fn split_counts(&self, k: usize) -> (usize, usize) {
        let near: usize = self.dist_hist.iter().take(k).sum();
        (near, self.nnz_lower - near)
    }

    /// Mean diagonal distance of nonzeros (band "spread").
    pub fn mean_distance(&self) -> f64 {
        if self.nnz_lower == 0 {
            return 0.0;
        }
        let sum: u64 = self
            .dist_hist
            .iter()
            .enumerate()
            .map(|(d, &c)| (d as u64 + 1) * c as u64)
            .sum();
        sum as f64 / self.nnz_lower as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::convert;
    use crate::sparse::{Coo, Symmetry};

    fn tridiag_plus(n: usize) -> Sss {
        let mut c = Coo::new(n);
        for i in 0..n as u32 {
            c.push(i, i, 1.0);
        }
        for i in 1..n as u32 {
            c.push(i, i - 1, 1.0);
            c.push(i - 1, i, -1.0);
        }
        // one far entry
        c.push((n - 1) as u32, 0, 7.0);
        c.push(0, (n - 1) as u32, -7.0);
        convert::coo_to_sss(&c, Symmetry::Skew).unwrap()
    }

    #[test]
    fn profile_counts() {
        let s = tridiag_plus(6);
        let p = BandProfile::of(&s);
        assert_eq!(p.bandwidth, 5);
        assert_eq!(p.nnz_lower, 6);
        assert_eq!(p.dist_hist[0], 5);
        assert_eq!(p.dist_hist[4], 1);
        let (near, far) = p.split_counts(2);
        assert_eq!((near, far), (5, 1));
    }

    #[test]
    fn mean_distance_and_density() {
        let s = tridiag_plus(6);
        let p = BandProfile::of(&s);
        assert!((p.mean_distance() - (5.0 + 5.0) / 6.0).abs() < 1e-12);
        assert!(p.band_density() > 0.0 && p.band_density() <= 1.0);
    }
}
