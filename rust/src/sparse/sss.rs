//! Symmetric Sparse Skyline (SSS) storage.
//!
//! The paper's kernel format (Fig. 3 / Alg. 1): the main diagonal is a
//! dense array `dvalues`, and only the strictly **lower** triangle is
//! compressed row-wise. The implied upper triangle is the mirror:
//! `A[j][i] = sign * A[i][j]` with `sign = +1` (symmetric) or `-1`
//! (skew-symmetric) — the single structure serves both, matching the
//! paper's remark that the approach "naturally applies" to symmetric
//! SpMV.

use crate::Result;
use anyhow::ensure;

/// Mirror convention for the implied upper triangle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Symmetry {
    /// `A[j][i] = A[i][j]`.
    Symmetric,
    /// `A[j][i] = -A[i][j]` (and the stored diagonal is the shift `alpha`).
    Skew,
}

impl Symmetry {
    /// Sign applied to the mirrored entry.
    #[inline]
    pub fn sign(self) -> f64 {
        match self {
            Symmetry::Symmetric => 1.0,
            Symmetry::Skew => -1.0,
        }
    }
}

/// Sparse matrix in SSS form (diagonal + strictly lower triangle).
#[derive(Debug, Clone, PartialEq)]
pub struct Sss {
    /// Matrix dimension.
    pub n: usize,
    /// Dense main diagonal (`alpha` per row for shifted skew-symmetric).
    pub dvalues: Vec<f64>,
    /// Row pointers into `col_ind`/`vals`, length `n+1`, lower triangle only.
    pub row_ptr: Vec<usize>,
    /// Column indices (each `< row`), ascending within a row.
    pub col_ind: Vec<u32>,
    /// Lower-triangle values.
    pub vals: Vec<f64>,
    /// Mirror convention.
    pub sym: Symmetry,
}

impl Sss {
    /// Stored off-diagonal entries (lower triangle only).
    pub fn nnz_lower(&self) -> usize {
        self.vals.len()
    }

    /// Logical nonzeros of the full matrix (both triangles + nonzero diag).
    pub fn nnz_logical(&self) -> usize {
        2 * self.nnz_lower() + self.dvalues.iter().filter(|v| **v != 0.0).count()
    }

    /// Entries of lower-triangle row `i` as `(col, val)`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_ind[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// Bandwidth of the stored lower triangle: `max (i - j)`.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for i in 0..self.n {
            for (j, _) in self.row(i) {
                bw = bw.max(i - j as usize);
            }
        }
        bw
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.dvalues.len() == self.n, "dvalues length != n");
        ensure!(self.row_ptr.len() == self.n + 1, "row_ptr length != n+1");
        ensure!(self.row_ptr[0] == 0, "row_ptr[0] != 0");
        ensure!(*self.row_ptr.last().unwrap() == self.nnz_lower(), "row_ptr end != nnz");
        ensure!(self.col_ind.len() == self.vals.len(), "col/val length mismatch");
        for i in 0..self.n {
            ensure!(self.row_ptr[i] <= self.row_ptr[i + 1], "row_ptr not monotone at {i}");
            let r = &self.col_ind[self.row_ptr[i]..self.row_ptr[i + 1]];
            for w in r.windows(2) {
                ensure!(w[0] < w[1], "row {i} columns not strictly ascending");
            }
            for &c in r {
                ensure!((c as usize) < i, "row {i}: column {c} not strictly lower");
            }
        }
        Ok(())
    }

    /// Count per-row lower nnz into `out` (used by distribution planning).
    pub fn row_counts(&self) -> Vec<usize> {
        (0..self.n).map(|i| self.row_ptr[i + 1] - self.row_ptr[i]).collect()
    }

    /// Floating-point ops of one SSS SpMV (Alg. 1): 1 diagonal multiply
    /// per row, 2 mul + 2 add per stored lower entry. The single cost
    /// model shared by every SSS-backed [`crate::kernel::Spmv`].
    pub fn spmv_flops(&self) -> u64 {
        (self.n + 4 * self.nnz_lower()) as u64
    }

    /// Matrix bytes touched by one SSS SpMV: dvalues + vals + col_ind
    /// + row_ptr, once each.
    pub fn spmv_bytes(&self) -> u64 {
        (self.n * 8 + self.nnz_lower() * (8 + 4) + (self.n + 1) * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::convert;
    use crate::sparse::Coo;

    pub(crate) fn sample_skew() -> Sss {
        // alpha = 2 on the diagonal, lower entries (2,0)=1.5, (3,1)=-0.5, (3,2)=4
        let mut c = Coo::new(4);
        for i in 0..4 {
            c.push(i, i, 2.0);
        }
        c.push(2, 0, 1.5);
        c.push(0, 2, -1.5);
        c.push(3, 1, -0.5);
        c.push(1, 3, 0.5);
        c.push(3, 2, 4.0);
        c.push(2, 3, -4.0);
        convert::coo_to_sss(&c, Symmetry::Skew).unwrap()
    }

    #[test]
    fn validate_and_counts() {
        let s = sample_skew();
        s.validate().unwrap();
        assert_eq!(s.nnz_lower(), 3);
        assert_eq!(s.nnz_logical(), 10);
        assert_eq!(s.row_counts(), vec![0, 0, 1, 2]);
    }

    #[test]
    fn bandwidth() {
        assert_eq!(sample_skew().bandwidth(), 2);
    }

    #[test]
    fn sign_convention() {
        assert_eq!(Symmetry::Skew.sign(), -1.0);
        assert_eq!(Symmetry::Symmetric.sign(), 1.0);
    }
}
