//! Coordinate (COO) format: parallel triplet arrays.
//!
//! The ingestion format: MatrixMarket files and the synthetic generators
//! produce COO, which is then converted to CSR/SSS. Also used for the
//! tiny "outer split" of the 3-way decomposition, where the paper notes
//! elements are few and scattered.

use crate::util::pool::PrepPool;
use crate::Result;
use anyhow::ensure;

/// Entry count below which a parallel permutation is not worth a spawn.
const MIN_PAR_NNZ: usize = 4096;

/// A sparse matrix in coordinate (triplet) form.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coo {
    /// Matrix dimension (square, `n x n`).
    pub n: usize,
    /// Row index of each entry.
    pub rows: Vec<u32>,
    /// Column index of each entry.
    pub cols: Vec<u32>,
    /// Value of each entry.
    pub vals: Vec<f64>,
}

impl Coo {
    /// Create an empty `n x n` COO matrix.
    pub fn new(n: usize) -> Self {
        Self { n, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Create with capacity for `nnz` entries.
    pub fn with_capacity(n: usize, nnz: usize) -> Self {
        Self {
            n,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry (no dedup; see [`Coo::sum_duplicates`]).
    pub fn push(&mut self, i: u32, j: u32, v: f64) {
        debug_assert!((i as usize) < self.n && (j as usize) < self.n);
        self.rows.push(i);
        self.cols.push(j);
        self.vals.push(v);
    }

    /// Validate structural invariants (indices in range, equal lengths).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.rows.len() == self.cols.len() && self.cols.len() == self.vals.len(),
            "COO triplet arrays have mismatched lengths"
        );
        for k in 0..self.nnz() {
            ensure!(
                (self.rows[k] as usize) < self.n && (self.cols[k] as usize) < self.n,
                "COO entry {k} ({}, {}) out of range for n={}",
                self.rows[k],
                self.cols[k],
                self.n
            );
        }
        Ok(())
    }

    /// Sort entries row-major (row, then column). Stable, O(nnz log nnz).
    pub fn sort_row_major(&mut self) {
        let mut order: Vec<usize> = (0..self.nnz()).collect();
        order.sort_by_key(|&k| (self.rows[k], self.cols[k]));
        self.permute_entries(&order);
    }

    /// Merge duplicate (i, j) entries by summing their values.
    /// Sorts row-major as a side effect.
    pub fn sum_duplicates(&mut self) {
        if self.nnz() == 0 {
            return;
        }
        self.sort_row_major();
        let mut w = 0usize;
        for k in 1..self.nnz() {
            if self.rows[k] == self.rows[w] && self.cols[k] == self.cols[w] {
                self.vals[w] += self.vals[k];
            } else {
                w += 1;
                self.rows[w] = self.rows[k];
                self.cols[w] = self.cols[k];
                self.vals[w] = self.vals[k];
            }
        }
        self.rows.truncate(w + 1);
        self.cols.truncate(w + 1);
        self.vals.truncate(w + 1);
    }

    /// Apply a symmetric permutation: entry (i, j) moves to
    /// (perm[i], perm[j]). `perm[old] = new`.
    pub fn permute_symmetric(&self, perm: &[u32]) -> Coo {
        self.permute_symmetric_with(perm, &PrepPool::serial())
    }

    /// [`Coo::permute_symmetric`] on a prepare pool: the triplet arrays
    /// are mapped in contiguous entry chunks and concatenated in chunk
    /// order, so the output entry order — and everything downstream of
    /// it — is identical to the serial mapping for every pool width.
    pub fn permute_symmetric_with(&self, perm: &[u32], pool: &PrepPool) -> Coo {
        debug_assert_eq!(perm.len(), self.n);
        let nnz = self.nnz();
        if pool.threads() == 1 || nnz < MIN_PAR_NNZ {
            let mut out = Coo::with_capacity(self.n, nnz);
            for k in 0..nnz {
                out.push(perm[self.rows[k] as usize], perm[self.cols[k] as usize], self.vals[k]);
            }
            return out;
        }
        let parts = pool.map_chunks(nnz, MIN_PAR_NNZ / 4, |_, r| {
            let mut rows = Vec::with_capacity(r.len());
            let mut cols = Vec::with_capacity(r.len());
            let mut vals = Vec::with_capacity(r.len());
            for k in r {
                rows.push(perm[self.rows[k] as usize]);
                cols.push(perm[self.cols[k] as usize]);
                vals.push(self.vals[k]);
            }
            (rows, cols, vals)
        });
        let mut out = Coo::with_capacity(self.n, nnz);
        for (rows, cols, vals) in parts {
            out.rows.extend_from_slice(&rows);
            out.cols.extend_from_slice(&cols);
            out.vals.extend_from_slice(&vals);
        }
        out
    }

    /// Matrix bandwidth: `max |i - j|` over stored entries.
    pub fn bandwidth(&self) -> usize {
        self.rows
            .iter()
            .zip(&self.cols)
            .map(|(&i, &j)| (i as i64 - j as i64).unsigned_abs() as usize)
            .max()
            .unwrap_or(0)
    }

    /// Dense materialization (test/debug helper; O(n^2) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n]; self.n];
        for k in 0..self.nnz() {
            d[self.rows[k] as usize][self.cols[k] as usize] += self.vals[k];
        }
        d
    }

    /// `y = A x` directly from triplets (slow reference path).
    pub fn spmv_ref(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for k in 0..self.nnz() {
            y[self.rows[k] as usize] += self.vals[k] * x[self.cols[k] as usize];
        }
    }

    fn permute_entries(&mut self, order: &[usize]) {
        self.rows = order.iter().map(|&k| self.rows[k]).collect();
        self.cols = order.iter().map(|&k| self.cols[k]).collect();
        self.vals = order.iter().map(|&k| self.vals[k]).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        let mut c = Coo::new(4);
        c.push(2, 1, 3.0);
        c.push(0, 0, 1.0);
        c.push(2, 1, 2.0);
        c.push(3, 0, -4.0);
        c
    }

    #[test]
    fn push_and_nnz() {
        let c = sample();
        assert_eq!(c.nnz(), 4);
        c.validate().unwrap();
    }

    #[test]
    fn sort_row_major_orders_entries() {
        let mut c = sample();
        c.sort_row_major();
        let pairs: Vec<_> = c.rows.iter().zip(&c.cols).collect();
        let mut sorted = pairs.clone();
        sorted.sort();
        assert_eq!(pairs, sorted);
    }

    #[test]
    fn sum_duplicates_merges() {
        let mut c = sample();
        c.sum_duplicates();
        assert_eq!(c.nnz(), 3);
        let d = c.to_dense();
        assert_eq!(d[2][1], 5.0);
        assert_eq!(d[0][0], 1.0);
        assert_eq!(d[3][0], -4.0);
    }

    #[test]
    fn permute_symmetric_moves_entries() {
        let c = sample();
        // reversal permutation
        let perm: Vec<u32> = vec![3, 2, 1, 0];
        let p = c.permute_symmetric(&perm);
        let d0 = c.to_dense();
        let d1 = p.to_dense();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(d0[i][j], d1[3 - i][3 - j]);
            }
        }
    }

    #[test]
    fn bandwidth_and_spmv() {
        let mut c = sample();
        c.sum_duplicates();
        assert_eq!(c.bandwidth(), 3);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        c.spmv_ref(&x, &mut y);
        assert_eq!(y, [1.0, 0.0, 10.0, -4.0]);
    }

    #[test]
    fn parallel_permutation_matches_serial() {
        // enough entries to cross the parallel threshold
        let n = 3000usize;
        let mut c = Coo::new(n);
        for i in 0..n as u32 {
            c.push(i, (i * 7 + 3) % n as u32, i as f64 * 0.5 - 1.0);
            c.push((i * 13 + 1) % n as u32, i, -(i as f64));
        }
        // reversal permutation
        let perm: Vec<u32> = (0..n as u32).rev().collect();
        let serial = c.permute_symmetric(&perm);
        for t in [2usize, 4, 8] {
            assert_eq!(c.permute_symmetric_with(&perm, &PrepPool::new(t)), serial, "threads={t}");
        }
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut c = Coo::new(2);
        c.rows.push(5);
        c.cols.push(0);
        c.vals.push(1.0);
        assert!(c.validate().is_err());
    }
}
