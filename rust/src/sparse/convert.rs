//! Format conversions (the SPARSKIT substitute, paper §3.1.2).
//!
//! All conversions are O(NNZ) (plus a sort for unsorted COO input) and
//! round-trip exactly; the tests below check every pair.

use crate::sparse::{Coo, Csr, Sss, Symmetry};
use crate::Result;
use anyhow::ensure;

/// COO -> CSR. Duplicates are summed; columns end up sorted per row.
pub fn coo_to_csr(coo: &Coo) -> Csr {
    let mut c = coo.clone();
    c.sum_duplicates();
    let mut row_ptr = vec![0usize; c.n + 1];
    for &r in &c.rows {
        row_ptr[r as usize + 1] += 1;
    }
    for i in 0..c.n {
        row_ptr[i + 1] += row_ptr[i];
    }
    Csr { n: c.n, row_ptr, col_ind: c.cols, vals: c.vals }
}

/// CSR -> COO (already deduped/sorted).
pub fn csr_to_coo(csr: &Csr) -> Coo {
    let mut out = Coo::with_capacity(csr.n, csr.nnz());
    for i in 0..csr.n {
        for (j, v) in csr.row(i) {
            out.push(i as u32, j, v);
        }
    }
    out
}

/// COO (full matrix, both triangles stored) -> SSS.
///
/// Verifies the mirror convention: for every strictly-lower entry
/// `(i, j, v)` the matching upper entry must equal `sign * v` (within
/// 1e-12), and vice versa; the diagonal is stored densely.
pub fn coo_to_sss(coo: &Coo, sym: Symmetry) -> Result<Sss> {
    let csr = coo_to_csr(coo);
    csr_to_sss(&csr, sym)
}

/// CSR (full matrix) -> SSS with mirror verification.
pub fn csr_to_sss(csr: &Csr, sym: Symmetry) -> Result<Sss> {
    let n = csr.n;
    let sign = sym.sign();
    let mut dvalues = vec![0.0f64; n];
    let mut row_ptr = vec![0usize; n + 1];
    let mut col_ind = Vec::new();
    let mut vals = Vec::new();
    for i in 0..n {
        for (j, v) in csr.row(i) {
            let j = j as usize;
            match j.cmp(&i) {
                std::cmp::Ordering::Equal => dvalues[i] = v,
                std::cmp::Ordering::Less => {
                    let mirror = csr.get(j, i);
                    ensure!(
                        (mirror - sign * v).abs() <= 1e-12 * (1.0 + v.abs()),
                        "entry ({i},{j})={v} has mirror {mirror}, violates {sym:?}"
                    );
                    col_ind.push(j as u32);
                    vals.push(v);
                }
                std::cmp::Ordering::Greater => {
                    // upper entry: verify its lower mirror exists
                    let mirror = csr.get(j, i);
                    ensure!(
                        (v - sign * mirror).abs() <= 1e-12 * (1.0 + v.abs()),
                        "upper entry ({i},{j})={v} missing lower mirror"
                    );
                }
            }
        }
        row_ptr[i + 1] = vals.len();
    }
    if sym == Symmetry::Skew {
        // Skew part has zero diagonal; dvalues carries only the shift.
        // (No check here: shifted skew-symmetric A = alpha*I + S stores alpha.)
    }
    Ok(Sss { n, dvalues, row_ptr, col_ind, vals, sym })
}

/// SSS -> COO, expanding the implied upper triangle and the diagonal.
pub fn sss_to_coo(sss: &Sss) -> Coo {
    let sign = sss.sym.sign();
    let mut out = Coo::with_capacity(sss.n, sss.nnz_logical());
    for i in 0..sss.n {
        if sss.dvalues[i] != 0.0 {
            out.push(i as u32, i as u32, sss.dvalues[i]);
        }
        for (j, v) in sss.row(i) {
            out.push(i as u32, j, v);
            out.push(j, i as u32, sign * v);
        }
    }
    out
}

/// SSS -> CSR (full expansion).
pub fn sss_to_csr(sss: &Sss) -> Csr {
    coo_to_csr(&sss_to_coo(sss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::skew;
    use crate::util::SmallRng;
        
    fn random_skew(n: usize, seed: u64) -> Coo {
        let mut rng = SmallRng::seed_from_u64(seed);
        let pattern = crate::sparse::gen::random_banded_pattern(n, 3, 0.6, &mut rng);
        skew::coo_from_pattern(n, &pattern, 1.5, &mut rng)
    }

    #[test]
    fn coo_csr_roundtrip() {
        let coo = random_skew(40, 1);
        let csr = coo_to_csr(&coo);
        csr.validate().unwrap();
        let back = csr_to_coo(&csr);
        assert_eq!(coo_to_csr(&back), csr);
    }

    #[test]
    fn coo_sss_roundtrip() {
        let coo = random_skew(40, 2);
        let sss = coo_to_sss(&coo, Symmetry::Skew).unwrap();
        sss.validate().unwrap();
        let back = sss_to_coo(&sss);
        assert_eq!(coo_to_csr(&back), coo_to_csr(&coo));
    }

    #[test]
    fn sss_to_csr_is_skew() {
        let coo = random_skew(30, 3);
        let sss = coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let csr = sss_to_csr(&sss);
        // remove the shift and check skew-symmetry
        let mut s = csr.clone();
        for i in 0..s.n {
            let lo = s.row_ptr[i];
            let hi = s.row_ptr[i + 1];
            for k in lo..hi {
                if s.col_ind[k] as usize == i {
                    s.vals[k] = 0.0;
                }
            }
        }
        assert!(s.is_skew_symmetric(1e-12));
    }

    #[test]
    fn symmetric_mirror_rejected_for_skew() {
        let mut c = Coo::new(3);
        c.push(1, 0, 2.0);
        c.push(0, 1, 2.0); // symmetric, not skew
        assert!(coo_to_sss(&c, Symmetry::Skew).is_err());
        assert!(coo_to_sss(&c, Symmetry::Symmetric).is_ok());
    }

    #[test]
    fn missing_mirror_rejected() {
        let mut c = Coo::new(3);
        c.push(1, 0, 2.0); // no (0,1) entry at all
        assert!(coo_to_sss(&c, Symmetry::Skew).is_err());
    }
}
