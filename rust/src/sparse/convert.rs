//! Format conversions (the SPARSKIT substitute, paper §3.1.2).
//!
//! All conversions are O(NNZ) (plus a sort for unsorted COO input) and
//! round-trip exactly; the tests below check every pair.

use crate::sparse::{Coo, Csr, Sss, Symmetry};
use crate::util::pool::PrepPool;
use crate::Result;
use anyhow::ensure;

/// Rows per slab floor for the parallel SSS build (below this a slab is
/// not worth a spawn).
const MIN_PAR_ROWS: usize = 2048;

/// COO -> CSR. Duplicates are summed; columns end up sorted per row.
pub fn coo_to_csr(coo: &Coo) -> Csr {
    let mut c = coo.clone();
    c.sum_duplicates();
    let mut row_ptr = vec![0usize; c.n + 1];
    for &r in &c.rows {
        row_ptr[r as usize + 1] += 1;
    }
    for i in 0..c.n {
        row_ptr[i + 1] += row_ptr[i];
    }
    Csr { n: c.n, row_ptr, col_ind: c.cols, vals: c.vals }
}

/// CSR -> COO (already deduped/sorted).
pub fn csr_to_coo(csr: &Csr) -> Coo {
    let mut out = Coo::with_capacity(csr.n, csr.nnz());
    for i in 0..csr.n {
        for (j, v) in csr.row(i) {
            out.push(i as u32, j, v);
        }
    }
    out
}

/// COO (full matrix, both triangles stored) -> SSS.
///
/// Verifies the mirror convention: for every strictly-lower entry
/// `(i, j, v)` the matching upper entry must equal `sign * v` (within
/// 1e-12), and vice versa; the diagonal is stored densely.
pub fn coo_to_sss(coo: &Coo, sym: Symmetry) -> Result<Sss> {
    coo_to_sss_with(coo, sym, &PrepPool::serial())
}

/// [`coo_to_sss`] on a prepare pool (the SSS assembly runs slab-parallel
/// via [`csr_to_sss_with`]; the COO->CSR sort stays serial — it is a
/// comparison sort whose output the slabs then split).
pub fn coo_to_sss_with(coo: &Coo, sym: Symmetry, pool: &PrepPool) -> Result<Sss> {
    let csr = coo_to_csr(coo);
    csr_to_sss_with(&csr, sym, pool)
}

/// CSR (full matrix) -> SSS with mirror verification.
pub fn csr_to_sss(csr: &Csr, sym: Symmetry) -> Result<Sss> {
    csr_to_sss_with(csr, sym, &PrepPool::serial())
}

/// [`csr_to_sss`] on a prepare pool. Each contiguous row slab builds
/// its own diagonal slice, per-row lower-entry counts, and packed
/// (col_ind, vals) run; the merge concatenates slabs in row order and
/// prefix-sums the counts into `row_ptr`, so the assembled arrays are
/// identical to the serial single-pass build for every pool width. A
/// failing slab reports its first bad row; applying `?` in slab order
/// makes the surfaced error the globally earliest one — the same error
/// (message included) the serial pass raises.
pub fn csr_to_sss_with(csr: &Csr, sym: Symmetry, pool: &PrepPool) -> Result<Sss> {
    let n = csr.n;
    let sign = sym.sign();
    type Slab = (Vec<f64>, Vec<usize>, Vec<u32>, Vec<f64>);
    let slabs = pool.map_chunks(n, MIN_PAR_ROWS, |_, r| -> Result<Slab> {
        let base = r.start;
        let mut dvalues = vec![0.0f64; r.len()];
        let mut counts = vec![0usize; r.len()];
        let mut col_ind = Vec::new();
        let mut vals = Vec::new();
        for i in r {
            for (j, v) in csr.row(i) {
                let j = j as usize;
                match j.cmp(&i) {
                    std::cmp::Ordering::Equal => dvalues[i - base] = v,
                    std::cmp::Ordering::Less => {
                        let mirror = csr.get(j, i);
                        ensure!(
                            (mirror - sign * v).abs() <= 1e-12 * (1.0 + v.abs()),
                            "entry ({i},{j})={v} has mirror {mirror}, violates {sym:?}"
                        );
                        col_ind.push(j as u32);
                        vals.push(v);
                        counts[i - base] += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        // upper entry: verify its lower mirror exists
                        let mirror = csr.get(j, i);
                        ensure!(
                            (v - sign * mirror).abs() <= 1e-12 * (1.0 + v.abs()),
                            "upper entry ({i},{j})={v} missing lower mirror"
                        );
                    }
                }
            }
        }
        Ok((dvalues, counts, col_ind, vals))
    });
    let mut dvalues = Vec::with_capacity(n);
    let mut row_ptr = vec![0usize; n + 1];
    let mut col_ind = Vec::new();
    let mut vals = Vec::new();
    let mut row = 0usize;
    for slab in slabs {
        let (dv, counts, ci, vs) = slab?;
        dvalues.extend_from_slice(&dv);
        for c in counts {
            row_ptr[row + 1] = row_ptr[row] + c;
            row += 1;
        }
        col_ind.extend_from_slice(&ci);
        vals.extend_from_slice(&vs);
    }
    if sym == Symmetry::Skew {
        // Skew part has zero diagonal; dvalues carries only the shift.
        // (No check here: shifted skew-symmetric A = alpha*I + S stores alpha.)
    }
    Ok(Sss { n, dvalues, row_ptr, col_ind, vals, sym })
}

/// SSS -> COO, expanding the implied upper triangle and the diagonal.
pub fn sss_to_coo(sss: &Sss) -> Coo {
    let sign = sss.sym.sign();
    let mut out = Coo::with_capacity(sss.n, sss.nnz_logical());
    for i in 0..sss.n {
        if sss.dvalues[i] != 0.0 {
            out.push(i as u32, i as u32, sss.dvalues[i]);
        }
        for (j, v) in sss.row(i) {
            out.push(i as u32, j, v);
            out.push(j, i as u32, sign * v);
        }
    }
    out
}

/// SSS -> CSR (full expansion).
pub fn sss_to_csr(sss: &Sss) -> Csr {
    coo_to_csr(&sss_to_coo(sss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::skew;
    use crate::util::SmallRng;
        
    fn random_skew(n: usize, seed: u64) -> Coo {
        let mut rng = SmallRng::seed_from_u64(seed);
        let pattern = crate::sparse::gen::random_banded_pattern(n, 3, 0.6, &mut rng);
        skew::coo_from_pattern(n, &pattern, 1.5, &mut rng)
    }

    #[test]
    fn coo_csr_roundtrip() {
        let coo = random_skew(40, 1);
        let csr = coo_to_csr(&coo);
        csr.validate().unwrap();
        let back = csr_to_coo(&csr);
        assert_eq!(coo_to_csr(&back), csr);
    }

    #[test]
    fn coo_sss_roundtrip() {
        let coo = random_skew(40, 2);
        let sss = coo_to_sss(&coo, Symmetry::Skew).unwrap();
        sss.validate().unwrap();
        let back = sss_to_coo(&sss);
        assert_eq!(coo_to_csr(&back), coo_to_csr(&coo));
    }

    #[test]
    fn sss_to_csr_is_skew() {
        let coo = random_skew(30, 3);
        let sss = coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let csr = sss_to_csr(&sss);
        // remove the shift and check skew-symmetry
        let mut s = csr.clone();
        for i in 0..s.n {
            let lo = s.row_ptr[i];
            let hi = s.row_ptr[i + 1];
            for k in lo..hi {
                if s.col_ind[k] as usize == i {
                    s.vals[k] = 0.0;
                }
            }
        }
        assert!(s.is_skew_symmetric(1e-12));
    }

    #[test]
    fn symmetric_mirror_rejected_for_skew() {
        let mut c = Coo::new(3);
        c.push(1, 0, 2.0);
        c.push(0, 1, 2.0); // symmetric, not skew
        assert!(coo_to_sss(&c, Symmetry::Skew).is_err());
        assert!(coo_to_sss(&c, Symmetry::Symmetric).is_ok());
    }

    #[test]
    fn missing_mirror_rejected() {
        let mut c = Coo::new(3);
        c.push(1, 0, 2.0); // no (0,1) entry at all
        assert!(coo_to_sss(&c, Symmetry::Skew).is_err());
    }

    #[test]
    fn parallel_sss_build_matches_serial() {
        // enough rows to split into several slabs (MIN_PAR_ROWS = 2048)
        let n = 6000usize;
        let mut rng = SmallRng::seed_from_u64(17);
        let pattern = crate::sparse::gen::random_banded_pattern(n, 5, 0.4, &mut rng);
        let coo = skew::coo_from_pattern(n, &pattern, 1.5, &mut rng);
        let serial = coo_to_sss(&coo, Symmetry::Skew).unwrap();
        for t in [2usize, 4, 8] {
            let par = coo_to_sss_with(&coo, Symmetry::Skew, &PrepPool::new(t)).unwrap();
            assert_eq!(par.row_ptr, serial.row_ptr, "threads={t}");
            assert_eq!(par.col_ind, serial.col_ind, "threads={t}");
            assert_eq!(par.vals, serial.vals, "threads={t}");
            assert_eq!(par.dvalues, serial.dvalues, "threads={t}");
        }
    }

    #[test]
    fn parallel_sss_build_surfaces_the_earliest_error() {
        // two bad mirrors far apart land in different slabs; the
        // parallel build must report the same (earliest) one as serial
        let n = 6000usize;
        let mut rng = SmallRng::seed_from_u64(23);
        let pattern = crate::sparse::gen::random_banded_pattern(n, 3, 0.7, &mut rng);
        let mut coo = skew::coo_from_pattern(n, &pattern, 1.5, &mut rng);
        for i in [100u32, 5900] {
            coo.push(i, i - 1, 3.25);
            coo.push(i - 1, i, 3.25); // symmetric pair violates skew
        }
        let serial_err = format!("{:#}", coo_to_sss(&coo, Symmetry::Skew).unwrap_err());
        for t in [2usize, 4] {
            let err = coo_to_sss_with(&coo, Symmetry::Skew, &PrepPool::new(t)).unwrap_err();
            assert_eq!(format!("{err:#}"), serial_err, "threads={t}");
        }
    }
}
