//! Compressed Row Storage (CRS/CSR).
//!
//! The general-matrix workhorse format (paper §1): row pointers into
//! column-index/value arrays. Used as the non-symmetric sanity baseline
//! and as the substrate the pattern graph is built from.

use crate::Result;
use anyhow::ensure;

/// A sparse `n x n` matrix in CSR form.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csr {
    /// Matrix dimension.
    pub n: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes row `i`'s entries. Length `n+1`.
    pub row_ptr: Vec<usize>,
    /// Column index per entry, sorted ascending within a row.
    pub col_ind: Vec<u32>,
    /// Value per entry.
    pub vals: Vec<f64>,
}

impl Csr {
    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Entries of row `i` as `(col, val)` pairs.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_ind[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.row_ptr.len() == self.n + 1, "row_ptr length != n+1");
        ensure!(self.row_ptr[0] == 0, "row_ptr[0] != 0");
        ensure!(*self.row_ptr.last().unwrap() == self.nnz(), "row_ptr end != nnz");
        ensure!(self.col_ind.len() == self.vals.len(), "col/val length mismatch");
        for i in 0..self.n {
            ensure!(self.row_ptr[i] <= self.row_ptr[i + 1], "row_ptr not monotone at {i}");
            let r = &self.col_ind[self.row_ptr[i]..self.row_ptr[i + 1]];
            for w in r.windows(2) {
                ensure!(w[0] < w[1], "row {i} columns not strictly ascending");
            }
            for &c in r {
                ensure!((c as usize) < self.n, "row {i} column {c} out of range");
            }
        }
        Ok(())
    }

    /// Value at (i, j), or 0.0 (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        match self.col_ind[lo..hi].binary_search(&(j as u32)) {
            Ok(k) => self.vals[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Matrix bandwidth: `max |i - j|` over stored entries.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for i in 0..self.n {
            for (j, _) in self.row(i) {
                bw = bw.max((i as i64 - j as i64).unsigned_abs() as usize);
            }
        }
        bw
    }

    /// Transpose (O(nnz)).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n + 1];
        for &c in &self.col_ind {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut col_ind = vec![0u32; self.nnz()];
        let mut vals = vec![0.0f64; self.nnz()];
        let mut next = counts;
        for i in 0..self.n {
            for (j, v) in self.row(i) {
                let dst = next[j as usize];
                col_ind[dst] = i as u32;
                vals[dst] = v;
                next[j as usize] += 1;
            }
        }
        Csr { n: self.n, row_ptr, col_ind, vals }
    }

    /// Structural + numeric skew-symmetry check: `A == -A^T`.
    pub fn is_skew_symmetric(&self, tol: f64) -> bool {
        let t = self.transpose();
        if t.row_ptr != self.row_ptr || t.col_ind != self.col_ind {
            return false;
        }
        self.vals.iter().zip(&t.vals).all(|(a, b)| (a + b).abs() <= tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::convert;
    use crate::sparse::Coo;

    fn sample() -> Csr {
        // [ 0  2  0 ]
        // [-2  0  5 ]
        // [ 0 -5  0 ]
        let mut c = Coo::new(3);
        c.push(0, 1, 2.0);
        c.push(1, 0, -2.0);
        c.push(1, 2, 5.0);
        c.push(2, 1, -5.0);
        convert::coo_to_csr(&c)
    }

    #[test]
    fn validate_ok() {
        sample().validate().unwrap();
    }

    #[test]
    fn get_and_row() {
        let a = sample();
        assert_eq!(a.get(1, 0), -2.0);
        assert_eq!(a.get(0, 0), 0.0);
        assert_eq!(a.row(1).count(), 2);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = sample();
        let tt = a.transpose().transpose();
        assert_eq!(a, tt);
    }

    #[test]
    fn skew_symmetry_detected() {
        let a = sample();
        assert!(a.is_skew_symmetric(0.0));
        let mut b = a.clone();
        b.vals[0] = 3.0;
        assert!(!b.is_skew_symmetric(1e-12));
    }

    #[test]
    fn bandwidth() {
        assert_eq!(sample().bandwidth(), 1);
    }
}
