//! The serving front-end: sockets in, sharded [`Service`] behind.
//!
//! One accept loop (non-blocking poll so it can observe the stop flag)
//! spawns two threads per connection:
//!
//! * a **reader** that decodes frames and submits each request into
//!   the service through the in-process non-blocking
//!   [`Client`](crate::coordinator::Client) — submission returns a
//!   [`Ticket`] immediately, so a burst of pipelined requests is
//!   in flight across shards before any response is produced;
//! * a **writer** that resolves tickets in submission order and writes
//!   the framed responses back. Within one shard, submission order is
//!   execution order (FIFO queues), so the writer never idles on a
//!   ticket whose work hasn't started.
//!
//! Backpressure composes: a full shard queue blocks the reader's
//! dispatch, which stops it draining the socket, which eventually
//! fills the peer's send buffer — exactly the bounded-queue behavior
//! the in-process client has, extended over TCP.
//!
//! A remote `Stop` request (or [`Server::stop`]) stops the service
//! gracefully: requests already dequeued complete, everything queued
//! or submitted later resolves to the typed
//! [`Pars3Error::ServiceStopped`], and the accept loop closes the
//! listener. Connection threads exit when their peer disconnects.

use crate::coordinator::{
    CacheStats, Client, Config, MatrixHandle, MatrixInfo, Pars3Error, Service, Ticket,
};
use crate::net::frame::{write_frame, FrameDecoder};
use crate::net::proto::{Request, Response};
use crate::net::{Conn, Listen};
use crate::kernel::VecBatch;
use crate::solver::mrs::MrsResult;
use std::io::Read;
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Accept-poll interval: long enough to cost nothing, short enough
/// that `stop` feels immediate.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

enum Acceptor {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl Acceptor {
    /// Non-blocking accept: `Ok(Some)` on a new (blocking-mode)
    /// connection, `Ok(None)` when no peer is waiting.
    fn poll_accept(&self) -> std::io::Result<Option<Box<dyn Conn>>> {
        match self {
            Acceptor::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    let _ = s.set_nodelay(true);
                    Ok(Some(Box::new(s)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
            Acceptor::Uds(l, _) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    Ok(Some(Box::new(s)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
        }
    }
}

impl Drop for Acceptor {
    fn drop(&mut self) {
        if let Acceptor::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// A response not yet produced: the request's ticket, tagged with the
/// id to echo. The writer resolves these in submission order.
enum Pending {
    Handle(u64, Ticket<MatrixHandle>),
    Unit(u64, Ticket<()>),
    Vec(u64, Ticket<Vec<f64>>),
    Batch(u64, Ticket<VecBatch>),
    Solve(u64, Ticket<MrsResult>),
    SolveBatch(u64, Ticket<Vec<MrsResult>>),
    Info(u64, Ticket<MatrixInfo>),
    StatsOne(u64, Ticket<CacheStats>),
    StatsAll(u64, Ticket<Vec<CacheStats>>),
    /// Already resolved at dispatch time (stop ack, protocol errors).
    Immediate(Response),
}

impl Pending {
    /// Block until the underlying ticket resolves; errors become typed
    /// [`Response::Error`] frames, never dropped connections.
    fn resolve(self) -> Response {
        fn finish<T>(id: u64, t: Ticket<T>, ok: impl FnOnce(T) -> Response) -> Response {
            match t.wait() {
                Ok(v) => ok(v),
                Err(err) => Response::Error { id, err },
            }
        }
        match self {
            Pending::Handle(id, t) => finish(id, t, |handle| Response::Handle { id, handle }),
            Pending::Unit(id, t) => finish(id, t, |()| Response::Unit { id }),
            Pending::Vec(id, t) => finish(id, t, |y| Response::Vec { id, y }),
            Pending::Batch(id, t) => finish(id, t, |ys| Response::Batch { id, ys }),
            Pending::Solve(id, t) => finish(id, t, |result| Response::Solve { id, result }),
            Pending::SolveBatch(id, t) => {
                finish(id, t, |results| Response::SolveBatch { id, results })
            }
            Pending::Info(id, t) => finish(id, t, |info| Response::Info { id, info }),
            Pending::StatsOne(id, t) => finish(id, t, |s| Response::Stats { id, stats: vec![s] }),
            Pending::StatsAll(id, t) => finish(id, t, |stats| Response::Stats { id, stats }),
            Pending::Immediate(resp) => resp,
        }
    }
}

/// A running network server over its own sharded [`Service`].
pub struct Server {
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    local: Listen,
}

impl Server {
    /// Bind `listen` and start serving `cfg`'s sharded service.
    /// `tcp://host:0` binds an ephemeral port — read the real address
    /// back from [`Server::local_addr`]. A UDS path left behind by a
    /// dead server is removed and re-bound.
    pub fn bind(listen: &Listen, cfg: Config) -> Result<Server, Pars3Error> {
        let (acceptor, local) = match listen {
            Listen::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| Pars3Error::io(&format!("bind {listen}"), e))?;
                l.set_nonblocking(true)
                    .map_err(|e| Pars3Error::io("set_nonblocking", e))?;
                let real = l
                    .local_addr()
                    .map_err(|e| Pars3Error::io("local_addr", e))?;
                (Acceptor::Tcp(l), Listen::Tcp(real.to_string()))
            }
            Listen::Uds(path) => {
                if path.exists() {
                    // either a stale socket from a dead server or a live
                    // one; binding over a live server is a deployment
                    // error the bind below would mask, so probe first
                    if std::os::unix::net::UnixStream::connect(path).is_ok() {
                        return Err(Pars3Error::Io(format!(
                            "bind {listen}: socket is already being served"
                        )));
                    }
                    let _ = std::fs::remove_file(path);
                }
                let l = UnixListener::bind(path)
                    .map_err(|e| Pars3Error::io(&format!("bind {listen}"), e))?;
                l.set_nonblocking(true)
                    .map_err(|e| Pars3Error::io("set_nonblocking", e))?;
                (Acceptor::Uds(l, path.clone()), Listen::Uds(path.clone()))
            }
        };

        let service = Arc::new(Service::start(cfg));
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let service = service.clone();
            let stop = stop.clone();
            std::thread::spawn(move || accept_loop(acceptor, service, stop))
        };
        Ok(Server { service, stop, accept: Some(accept), local })
    }

    /// The bound address (with the real port for `tcp://host:0`).
    pub fn local_addr(&self) -> &Listen {
        &self.local
    }

    /// Stop serving: the service stops gracefully (see
    /// [`Service::stop`]) and the accept loop closes the listener.
    /// Idempotent.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.service.stop();
    }

    /// Block until the server stops — via [`Server::stop`] or a remote
    /// `Stop` request. The foreground of `pars3 serve`.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(acceptor: Acceptor, service: Arc<Service>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match acceptor.poll_accept() {
            Ok(Some(conn)) => spawn_connection(conn, service.clone(), stop.clone()),
            Ok(None) => std::thread::sleep(ACCEPT_POLL),
            Err(_) => break,
        }
    }
    // dropping the acceptor closes the listener (and unlinks a UDS path)
}

/// Two detached threads per connection: reader (decode + dispatch) and
/// writer (resolve + encode). They exit when the peer disconnects —
/// reader on EOF, writer when the reader drops its channel.
fn spawn_connection(conn: Box<dyn Conn>, service: Arc<Service>, stop: Arc<AtomicBool>) {
    let Ok(write_half) = conn.try_clone_conn() else {
        return;
    };
    let (tx, rx) = channel::<Pending>();
    std::thread::spawn(move || writer_loop(write_half, rx));
    std::thread::spawn(move || reader_loop(conn, service, stop, tx));
}

fn reader_loop(
    mut conn: Box<dyn Conn>,
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
    replies: Sender<Pending>,
) {
    let client = service.client();
    let mut dec = FrameDecoder::new();
    let mut buf = [0u8; 64 * 1024];
    'conn: loop {
        let n = match conn.read(&mut buf) {
            Ok(0) | Err(_) => break, // peer closed (or reset); writer follows via channel drop
            Ok(n) => n,
        };
        dec.feed(&buf[..n]);
        loop {
            match dec.next_frame() {
                Ok(None) => break,
                Ok(Some((tag, payload))) => {
                    let req = match Request::decode(tag, &payload) {
                        Ok(req) => req,
                        Err(err) => {
                            // id 0 is reserved for connection-level
                            // failures (request ids start at 1)
                            let _ = replies.send(Pending::Immediate(Response::Error {
                                id: 0,
                                err,
                            }));
                            break 'conn;
                        }
                    };
                    if !dispatch(req, &client, &service, &stop, &replies) {
                        break 'conn;
                    }
                }
                Err(err) => {
                    let _ = replies.send(Pending::Immediate(Response::Error { id: 0, err }));
                    break 'conn;
                }
            }
        }
    }
}

/// Submit one request into the service. Returns `false` when the
/// connection should stop reading (reply channel gone).
fn dispatch(
    req: Request,
    client: &Client,
    service: &Arc<Service>,
    stop: &Arc<AtomicBool>,
    replies: &Sender<Pending>,
) -> bool {
    let pending = match req {
        Request::Prepare { id, name, coo } => Pending::Handle(id, client.prepare(&name, coo)),
        Request::PrepareReplace { id, handle, name, coo } => {
            Pending::Handle(id, client.prepare_replace(&handle, &name, coo))
        }
        Request::Release { id, handle } => Pending::Unit(id, client.release(&handle)),
        Request::Spmv { id, handle, x, backend } => {
            Pending::Vec(id, client.spmv(&handle, x, backend))
        }
        Request::SpmvBatch { id, handle, xs, backend } => {
            Pending::Batch(id, client.spmv_batch(&handle, xs, backend))
        }
        Request::Solve { id, handle, b, opts, backend } => {
            Pending::Solve(id, client.solve(&handle, b, opts, backend))
        }
        Request::SolveBatch { id, handle, bs, opts, backend } => {
            Pending::SolveBatch(id, client.solve_batch(&handle, bs, opts, backend))
        }
        Request::Describe { id, handle } => Pending::Info(id, client.describe(&handle)),
        Request::CacheStats { id, shard: Some(s) } => {
            Pending::StatsOne(id, client.cache_stats(s as usize))
        }
        Request::CacheStats { id, shard: None } => {
            Pending::StatsAll(id, client.cache_stats_all())
        }
        Request::Stop { id } => {
            // stop the service first (in-flight work completes, queued
            // work drains typed), then the listener; the ack goes out
            // through the normal reply path, after every response to a
            // request this connection submitted earlier
            service.stop();
            stop.store(true, Ordering::SeqCst);
            Pending::Immediate(Response::Unit { id })
        }
    };
    replies.send(pending).is_ok()
}

fn writer_loop(mut conn: Box<dyn Conn>, replies: Receiver<Pending>) {
    while let Ok(pending) = replies.recv() {
        let mut batch = vec![pending.resolve()];
        // drain whatever else resolved or queued meanwhile, then flush
        // once — pipelined bursts pay one syscall tail, not one per
        // response
        loop {
            match replies.try_recv() {
                Ok(p) => batch.push(p.resolve()),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        for resp in &batch {
            let (tag, payload) = resp.encode();
            if write_frame(&mut conn, tag, &payload).is_err() {
                conn.shutdown_conn();
                return;
            }
        }
        if conn.flush().is_err() {
            conn.shutdown_conn();
            return;
        }
    }
    conn.shutdown_conn();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::net::frame::write_frame;
    use crate::sparse::gen;
    use std::io::Write;
    use std::net::TcpStream;

    fn send(conn: &mut impl Write, req: &Request) {
        let (tag, payload) = req.encode();
        write_frame(conn, tag, &payload).unwrap();
        conn.flush().unwrap();
    }

    fn recv(conn: &mut impl Read, dec: &mut FrameDecoder) -> Response {
        let mut buf = [0u8; 4096];
        loop {
            if let Some((tag, payload)) = dec.next_frame().unwrap() {
                return Response::decode(tag, &payload).unwrap();
            }
            let n = conn.read(&mut buf).unwrap();
            assert!(n > 0, "server closed the connection mid-response");
            dec.feed(&buf[..n]);
        }
    }

    fn one_shard_cfg() -> Config {
        Config { shards: 1, ..Config::default() }
    }

    #[test]
    fn raw_frames_prepare_multiply_and_stop_over_tcp() {
        let server =
            Server::bind(&"tcp://127.0.0.1:0".parse().unwrap(), one_shard_cfg()).unwrap();
        let Listen::Tcp(addr) = server.local_addr().clone() else {
            panic!("tcp bind reported {:?}", server.local_addr());
        };
        assert!(!addr.ends_with(":0"), "ephemeral port resolved: {addr}");
        let mut conn = TcpStream::connect(&addr).unwrap();
        let mut dec = FrameDecoder::new();

        let n = 60;
        send(&mut conn, &Request::Prepare { id: 1, name: "m".into(), coo: gen::small_test_matrix(n, 5, 2.0) });
        let resp = recv(&mut conn, &mut dec);
        let Response::Handle { id: 1, handle } = resp else {
            panic!("expected handle, got {resp:?}");
        };
        send(
            &mut conn,
            &Request::Spmv { id: 2, handle: handle.clone(), x: vec![1.0; n], backend: Backend::Serial },
        );
        let Response::Vec { id: 2, y } = recv(&mut conn, &mut dec) else {
            panic!("expected spmv result");
        };
        assert_eq!(y.len(), n);

        // graceful stop over the wire: acknowledged in order, then every
        // later request gets the typed refusal rather than a dead socket
        send(&mut conn, &Request::Stop { id: 3 });
        let Response::Unit { id: 3 } = recv(&mut conn, &mut dec) else {
            panic!("stop not acknowledged");
        };
        send(
            &mut conn,
            &Request::Spmv { id: 4, handle, x: vec![1.0; n], backend: Backend::Serial },
        );
        let resp = recv(&mut conn, &mut dec);
        let Response::Error { id: 4, err: Pars3Error::ServiceStopped } = resp else {
            panic!("expected typed ServiceStopped, got {resp:?}");
        };

        // the accept loop observed the remote stop, so join returns
        server.join();
    }

    #[test]
    fn uds_socket_is_served_guarded_and_cleaned_up() {
        let dir = std::env::temp_dir().join(format!("pars3-uds-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("srv.sock");
        let listen = Listen::Uds(path.clone());

        let server = Server::bind(&listen, one_shard_cfg()).unwrap();
        assert!(path.exists());

        // binding over a *live* server is refused, not hijacked
        let err = Server::bind(&listen, one_shard_cfg()).unwrap_err();
        assert!(matches!(err, Pars3Error::Io(_)), "{err}");

        let mut conn = std::os::unix::net::UnixStream::connect(&path).unwrap();
        let mut dec = FrameDecoder::new();
        send(&mut conn, &Request::CacheStats { id: 1, shard: None });
        let Response::Stats { id: 1, stats } = recv(&mut conn, &mut dec) else {
            panic!("expected stats");
        };
        assert_eq!(stats.len(), 1, "one shard, one entry");

        server.stop();
        server.join();
        assert!(!path.exists(), "socket path unlinked on shutdown");

        // a stale path left by a dead server (here: a plain file nothing
        // is listening on) is swept aside and re-bound
        std::fs::write(&path, b"stale").unwrap();
        let server = Server::bind(&listen, one_shard_cfg()).unwrap();
        assert!(path.exists());
        drop(server); // Drop stops and joins
        assert!(!path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
