//! [`RemoteClient`]: the in-process client's surface over a socket.
//!
//! Submission has the same non-blocking shape as
//! [`Client`](crate::coordinator::Client): every method encodes one
//! request frame, registers a resolver under the request id, writes the
//! frame, and returns a [`Ticket`] immediately — so a caller can put a
//! burst of requests on the wire and only then start waiting, exactly
//! like the shard-queue pipelining the service tests rely on. A reader
//! thread matches each incoming response to its resolver by id.
//!
//! Failure stays typed end to end: a request the server rejects comes
//! back as the original [`Pars3Error`] (wire tag, not stringly); a torn
//! connection resolves every in-flight *and* every future ticket to
//! [`Pars3Error::Io`] instead of hanging.

use crate::coordinator::{
    Backend, CacheStats, ClientApi, MatrixHandle, MatrixInfo, Pars3Error, Ticket,
};
use crate::kernel::VecBatch;
use crate::net::frame::{write_frame, FrameDecoder};
use crate::net::proto::{Request, Response};
use crate::net::{Conn, Listen};
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use std::collections::HashMap;
use std::io::Read;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Called by the reader thread with the matched response (or the
/// connection-failure error); forwards the typed result into the
/// ticket's reply channel.
type Resolver = Box<dyn FnOnce(Result<Response, Pars3Error>) + Send>;

#[derive(Default)]
struct PendingMap {
    map: HashMap<u64, Resolver>,
    /// Set once when the connection dies; every later submission
    /// resolves to a clone of this immediately.
    dead: Option<Pars3Error>,
}

/// A connection to a [`Server`](crate::net::Server), speaking the same
/// typed, pipelined request surface as the in-process client.
pub struct RemoteClient {
    /// Write half. Requests from concurrent callers interleave at frame
    /// granularity, never inside a frame.
    conn: Mutex<Box<dyn Conn>>,
    /// Request ids are connection-local; 0 is reserved for
    /// connection-level server errors, so the counter starts at 1.
    next_id: AtomicU64,
    pending: Arc<Mutex<PendingMap>>,
    reader: Option<JoinHandle<()>>,
}

impl RemoteClient {
    /// Connect to a serving address (`tcp://host:port` or
    /// `uds:/path`).
    pub fn connect(addr: &Listen) -> Result<RemoteClient, Pars3Error> {
        let conn = crate::net::connect(addr)?;
        let read_half = conn
            .try_clone_conn()
            .map_err(|e| Pars3Error::io("clone connection", e))?;
        let pending = Arc::new(Mutex::new(PendingMap::default()));
        let reader = {
            let pending = pending.clone();
            std::thread::spawn(move || reader_loop(read_half, pending))
        };
        Ok(RemoteClient {
            conn: Mutex::new(conn),
            next_id: AtomicU64::new(1),
            pending,
            reader: Some(reader),
        })
    }

    /// Ask the server to stop its service gracefully (see
    /// [`Service::stop`](crate::coordinator::Service::stop)): in-flight
    /// work completes, queued and later work resolves to
    /// [`Pars3Error::ServiceStopped`], and the server's accept loop
    /// exits. The ticket resolves when the server acknowledges.
    pub fn stop(&self) -> Ticket<()> {
        self.submit(
            |id| Request::Stop { id },
            |resp| match resp {
                Response::Unit { .. } => Ok(()),
                other => Err(unexpected("stop", &other)),
            },
        )
    }

    /// Encode-register-write one request; the returned ticket resolves
    /// when the reader thread matches the response id.
    fn submit<T: Send + 'static>(
        &self,
        make: impl FnOnce(u64) -> Request,
        extract: fn(Response) -> Result<T, Pars3Error>,
    ) -> Ticket<T> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel::<Result<T, Pars3Error>>();
        {
            let mut p = self.pending.lock().unwrap();
            if let Some(err) = &p.dead {
                return Ticket::ready(0, Err(err.clone()));
            }
            // register before writing: the response cannot overtake a
            // request that is not on the wire yet
            p.map.insert(
                id,
                Box::new(move |r: Result<Response, Pars3Error>| {
                    let _ = tx.send(r.and_then(extract));
                }),
            );
        }
        let (tag, payload) = make(id).encode();
        let wrote = {
            let mut w = self.conn.lock().unwrap();
            write_frame(&mut *w, tag, &payload)
                .and_then(|()| w.flush().map_err(|e| Pars3Error::io("flush request", e)))
        };
        if let Err(err) = wrote {
            if let Some(resolve) = self.pending.lock().unwrap().map.remove(&id) {
                resolve(Err(err));
            }
        }
        Ticket::pending(0, rx)
    }
}

impl Drop for RemoteClient {
    fn drop(&mut self) {
        // unblocks the reader thread's blocking read
        self.conn.lock().unwrap().shutdown_conn();
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Short response descriptor for mismatch errors (never `Debug` — a
/// response can carry megabytes of vector data).
fn kind(resp: &Response) -> &'static str {
    match resp {
        Response::Handle { .. } => "handle",
        Response::Unit { .. } => "unit",
        Response::Vec { .. } => "vec",
        Response::Batch { .. } => "batch",
        Response::Solve { .. } => "solve",
        Response::SolveBatch { .. } => "solve-batch",
        Response::Info { .. } => "info",
        Response::Stats { .. } => "stats",
        Response::Error { .. } => "error",
    }
}

fn unexpected(what: &str, got: &Response) -> Pars3Error {
    Pars3Error::protocol(format!("unexpected {} response to {what}", kind(got)))
}

fn reader_loop(mut conn: Box<dyn Conn>, pending: Arc<Mutex<PendingMap>>) {
    let mut dec = FrameDecoder::new();
    let mut buf = [0u8; 64 * 1024];
    let fail: Pars3Error = 'conn: loop {
        let n = match conn.read(&mut buf) {
            Ok(0) => break 'conn Pars3Error::Io("server closed the connection".to_string()),
            Err(e) => break 'conn Pars3Error::io("read response", e),
            Ok(n) => n,
        };
        dec.feed(&buf[..n]);
        loop {
            let resp = match dec.next_frame() {
                Ok(None) => break,
                Err(err) => break 'conn err,
                Ok(Some((tag, payload))) => match Response::decode(tag, &payload) {
                    Ok(resp) => resp,
                    Err(err) => break 'conn err,
                },
            };
            match resp.id() {
                // id 0: the server reports a connection-level failure
                // (unparseable request) — framing is unrecoverable
                0 => {
                    break 'conn match resp {
                        Response::Error { err, .. } => err,
                        other => unexpected("connection-level frame", &other),
                    };
                }
                id => {
                    let resolver = pending.lock().unwrap().map.remove(&id);
                    if let Some(resolve) = resolver {
                        resolve(Ok(resp));
                    }
                    // no resolver: the write failed after registration
                    // and already resolved the ticket — drop the frame
                }
            }
        }
    };
    // the connection is gone: everything in flight, and everything
    // submitted from now on, resolves to the same typed error
    let mut p = pending.lock().unwrap();
    for (_, resolve) in p.map.drain() {
        resolve(Err(fail.clone()));
    }
    p.dead = Some(fail);
}

impl ClientApi for RemoteClient {
    fn prepare(&self, name: &str, coo: Coo) -> Ticket<MatrixHandle> {
        let name = name.to_string();
        self.submit(
            |id| Request::Prepare { id, name, coo },
            |resp| match resp {
                Response::Handle { handle, .. } => Ok(handle),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("prepare", &other)),
            },
        )
    }

    fn prepare_replace(
        &self,
        handle: &MatrixHandle,
        name: &str,
        coo: Coo,
    ) -> Ticket<MatrixHandle> {
        let (handle, name) = (handle.clone(), name.to_string());
        self.submit(
            |id| Request::PrepareReplace { id, handle, name, coo },
            |resp| match resp {
                Response::Handle { handle, .. } => Ok(handle),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("prepare_replace", &other)),
            },
        )
    }

    fn release(&self, handle: &MatrixHandle) -> Ticket<()> {
        let handle = handle.clone();
        self.submit(
            |id| Request::Release { id, handle },
            |resp| match resp {
                Response::Unit { .. } => Ok(()),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("release", &other)),
            },
        )
    }

    fn spmv(&self, handle: &MatrixHandle, x: Vec<f64>, backend: Backend) -> Ticket<Vec<f64>> {
        let handle = handle.clone();
        self.submit(
            |id| Request::Spmv { id, handle, x, backend },
            |resp| match resp {
                Response::Vec { y, .. } => Ok(y),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("spmv", &other)),
            },
        )
    }

    fn solve(
        &self,
        handle: &MatrixHandle,
        b: Vec<f64>,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<MrsResult> {
        let handle = handle.clone();
        self.submit(
            |id| Request::Solve { id, handle, b, opts, backend },
            |resp| match resp {
                Response::Solve { result, .. } => Ok(result),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("solve", &other)),
            },
        )
    }

    fn spmv_batch(
        &self,
        handle: &MatrixHandle,
        xs: VecBatch,
        backend: Backend,
    ) -> Ticket<VecBatch> {
        let handle = handle.clone();
        self.submit(
            |id| Request::SpmvBatch { id, handle, xs, backend },
            |resp| match resp {
                Response::Batch { ys, .. } => Ok(ys),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("spmv_batch", &other)),
            },
        )
    }

    fn solve_batch(
        &self,
        handle: &MatrixHandle,
        bs: VecBatch,
        opts: MrsOptions,
        backend: Backend,
    ) -> Ticket<Vec<MrsResult>> {
        let handle = handle.clone();
        self.submit(
            |id| Request::SolveBatch { id, handle, bs, opts, backend },
            |resp| match resp {
                Response::SolveBatch { results, .. } => Ok(results),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("solve_batch", &other)),
            },
        )
    }

    fn describe(&self, handle: &MatrixHandle) -> Ticket<MatrixInfo> {
        let handle = handle.clone();
        self.submit(
            |id| Request::Describe { id, handle },
            |resp| match resp {
                Response::Info { info, .. } => Ok(info),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("describe", &other)),
            },
        )
    }

    fn cache_stats(&self, shard: usize) -> Ticket<CacheStats> {
        let shard = shard as u64;
        self.submit(
            |id| Request::CacheStats { id, shard: Some(shard) },
            |resp| match resp {
                Response::Stats { stats, .. } => stats
                    .into_iter()
                    .next()
                    .ok_or_else(|| Pars3Error::protocol("empty stats response")),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("cache_stats", &other)),
            },
        )
    }

    fn cache_stats_all(&self) -> Ticket<Vec<CacheStats>> {
        self.submit(
            |id| Request::CacheStats { id, shard: None },
            |resp| match resp {
                Response::Stats { stats, .. } => Ok(stats),
                Response::Error { err, .. } => Err(err),
                other => Err(unexpected("cache_stats_all", &other)),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Config;
    use crate::net::Server;
    use crate::sparse::gen;

    #[test]
    fn remote_client_round_trips_over_uds() {
        let dir = std::env::temp_dir().join(format!("pars3-rc-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let listen = Listen::Uds(dir.join("rc.sock"));
        let server =
            Server::bind(&listen, Config { shards: 1, ..Config::default() }).unwrap();
        let client = RemoteClient::connect(&listen).unwrap();

        let n = 80;
        let h = client.prepare("remote", gen::small_test_matrix(n, 11, 2.0)).wait().unwrap();
        let y = client.spmv(&h, vec![1.0; n], Backend::Serial).wait().unwrap();
        assert_eq!(y.len(), n);
        let info = client.describe(&h).wait().unwrap();
        assert_eq!((info.name.as_str(), info.n), ("remote", n));
        client.release(&h).wait().unwrap();

        // graceful remote stop: acknowledged, then typed refusals
        client.stop().wait().unwrap();
        let err = client.spmv(&h, vec![1.0; n], Backend::Serial).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::ServiceStopped), "{err}");
        server.join();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_dead_connection_yields_typed_io_errors() {
        // a "server" that accepts and immediately hangs up
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = Listen::Tcp(l.local_addr().unwrap().to_string());
        let client = RemoteClient::connect(&addr).unwrap();
        let (sock, _) = l.accept().unwrap();

        let fake = MatrixHandle { service: 1, shard: 0, slot: 0, generation: 1 };
        let t = client.spmv(&fake, vec![1.0], Backend::Serial);
        drop(sock); // connection dies with the request in flight
        let err = t.wait().unwrap_err();
        assert!(matches!(err, Pars3Error::Io(_)), "{err}");

        // later submissions fail the same way instead of hanging
        let err = client.describe(&fake).wait().unwrap_err();
        assert!(matches!(err, Pars3Error::Io(_)), "{err}");
    }
}
