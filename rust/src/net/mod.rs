//! Out-of-process serving: wire protocol, TCP/UDS transport, and a
//! remote client with the in-process `Client`'s shape.
//!
//! The sharded [`Service`](crate::coordinator::Service) (PR 4) serves
//! in-process [`Client`](crate::coordinator::Client)s; this module puts
//! the same typed surface on a socket so the "heavy traffic" north star
//! stops being bounded by one process. The paper's band split already
//! bounds cross-rank traffic to halo rows, and distributed-memory RCM
//! (Azad et al.) shows even `prepare` tolerates a process boundary —
//! so the rank/shard abstractions promote to real transports:
//!
//! * [`frame`] — length-prefixed framing (4-byte LE length, 1-byte
//!   message tag, payload) with an incremental decoder that tolerates
//!   torn reads: a frame split at any byte boundary reassembles.
//! * [`proto`] — the binary message layer: every request/response of
//!   the typed client surface, with f64 vectors and batches encoded as
//!   raw little-endian bytes (no JSON float round-trip on the hot
//!   path; only `describe`'s evidence tree travels as JSON).
//! * [`server`] — accepts TCP and Unix-domain connections; one reader
//!   thread per connection submits into the sharded service through
//!   the non-blocking in-process `Client`, so a burst of pipelined
//!   requests is in flight across shards before the first response is
//!   written back.
//! * [`client`] — [`RemoteClient`]: `prepare`/`spmv`/`solve`/... with
//!   the same submit-then-[`Ticket`](crate::coordinator::Ticket) shape
//!   as the in-process client, behind the shared
//!   [`ClientApi`](crate::coordinator::ClientApi) trait, so the same
//!   backend-sweep suite runs against both transports.
//!
//! Responses are matched by request id, and each connection writes its
//! replies in submission order — within one shard that is execution
//! order anyway (FIFO queues), so pipelining survives the wire intact.

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::RemoteClient;
pub use server::Server;

use crate::coordinator::Pars3Error;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;

/// A serve/connect address: `tcp://host:port` or `uds:/path/to.sock`.
///
/// TCP reaches across machines; a Unix-domain socket stays on-box but
/// skips the TCP stack (no checksums, no Nagle, larger effective
/// buffers), which measurably matters at small-message rates — see
/// `benches/remote_throughput.rs` for the k=1 gap.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Listen {
    /// TCP address in `host:port` form.
    Tcp(String),
    /// Unix-domain socket path.
    Uds(PathBuf),
}

impl std::fmt::Display for Listen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Listen::Tcp(a) => write!(f, "tcp://{a}"),
            Listen::Uds(p) => write!(f, "uds:{}", p.display()),
        }
    }
}

impl std::str::FromStr for Listen {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if let Some(addr) = s.strip_prefix("tcp://") {
            if addr.is_empty() {
                anyhow::bail!("empty tcp address in '{s}'");
            }
            return Ok(Listen::Tcp(addr.to_string()));
        }
        if let Some(path) = s.strip_prefix("uds:") {
            if path.is_empty() {
                anyhow::bail!("empty socket path in '{s}'");
            }
            return Ok(Listen::Uds(PathBuf::from(path)));
        }
        anyhow::bail!("unknown listen address '{s}' (expected tcp://host:port or uds:/path)")
    }
}

/// The subset of socket behavior the server and client need, so one
/// connection loop serves both transports. (`try_clone` is inherent on
/// `TcpStream`/`UnixStream`, not a trait — this bridges it.)
pub(crate) trait Conn: Read + Write + Send {
    /// Independent handle to the same socket (reader/writer split).
    fn try_clone_conn(&self) -> std::io::Result<Box<dyn Conn>>;
    /// Shut down both directions, unblocking any reader.
    fn shutdown_conn(&self);
}

impl Conn for TcpStream {
    fn try_clone_conn(&self) -> std::io::Result<Box<dyn Conn>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn shutdown_conn(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

impl Conn for UnixStream {
    fn try_clone_conn(&self) -> std::io::Result<Box<dyn Conn>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn shutdown_conn(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

/// Open a client connection to `addr`.
pub(crate) fn connect(addr: &Listen) -> Result<Box<dyn Conn>, Pars3Error> {
    match addr {
        Listen::Tcp(a) => {
            let s = TcpStream::connect(a).map_err(|e| Pars3Error::io(&format!("connect {addr}"), e))?;
            // request/response round trips are latency-bound; don't let
            // Nagle batch our small frames
            let _ = s.set_nodelay(true);
            Ok(Box::new(s))
        }
        Listen::Uds(p) => {
            let s = UnixStream::connect(p)
                .map_err(|e| Pars3Error::io(&format!("connect {addr}"), e))?;
            Ok(Box::new(s))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addresses_parse_and_display() {
        let t: Listen = "tcp://127.0.0.1:7313".parse().unwrap();
        assert_eq!(t, Listen::Tcp("127.0.0.1:7313".to_string()));
        assert_eq!(t.to_string(), "tcp://127.0.0.1:7313");

        let u: Listen = "uds:/tmp/pars3.sock".parse().unwrap();
        assert_eq!(u, Listen::Uds(PathBuf::from("/tmp/pars3.sock")));
        assert_eq!(u.to_string(), "uds:/tmp/pars3.sock");

        assert!("7313".parse::<Listen>().is_err());
        assert!("tcp://".parse::<Listen>().is_err());
        assert!("uds:".parse::<Listen>().is_err());
        assert!("http://x".parse::<Listen>().is_err());
    }
}
