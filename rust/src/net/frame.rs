//! Length-prefixed framing: `[len: u32 LE][tag: u8][payload]`.
//!
//! `len` counts the tag byte plus the payload, so a frame occupies
//! `4 + len` bytes on the wire and `len >= 1` always. The decoder is
//! incremental: feed it whatever the socket returned — half a length
//! prefix, three frames and a torn fourth — and it yields exactly the
//! complete frames, keeping the remainder buffered. TCP guarantees no
//! particular read boundaries, so the codec must not assume any.

use crate::coordinator::Pars3Error;
use std::io::Write;

/// Upper bound on `len` (1 GiB): a corrupt or malicious length prefix
/// fails as a typed protocol error instead of a gigabyte allocation.
pub const MAX_FRAME: u32 = 1 << 30;

/// Write one frame. The caller batches `flush` as it sees fit.
pub fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> Result<(), Pars3Error> {
    let len = payload.len() as u64 + 1;
    if len > MAX_FRAME as u64 {
        return Err(Pars3Error::protocol(format!("frame too large: {len} bytes")));
    }
    let mut head = [0u8; 5];
    head[..4].copy_from_slice(&(len as u32).to_le_bytes());
    head[4] = tag;
    w.write_all(&head).map_err(|e| Pars3Error::io("write frame header", e))?;
    w.write_all(payload).map_err(|e| Pars3Error::io("write frame payload", e))?;
    Ok(())
}

/// Incremental frame decoder. [`feed`](Self::feed) raw bytes in, drain
/// complete `(tag, payload)` frames out with
/// [`next_frame`](Self::next_frame).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Read position inside `buf` (consumed frames are compacted away
    /// lazily, so feeding many small chunks does not repeatedly shift
    /// the tail).
    pos: usize,
}

impl FrameDecoder {
    /// Empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes from the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        // compact before growing: everything before `pos` is consumed
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Next complete frame, `Ok(None)` if more bytes are needed, or a
    /// [`Pars3Error::Protocol`] on a corrupt length prefix. After an
    /// error the stream has no recoverable framing — drop the
    /// connection.
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, Pars3Error> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]);
        if len == 0 || len > MAX_FRAME {
            return Err(Pars3Error::protocol(format!("bad frame length {len}")));
        }
        if avail.len() < 4 + len as usize {
            return Ok(None);
        }
        let tag = avail[4];
        let payload = avail[5..4 + len as usize].to_vec();
        self.pos += 4 + len as usize;
        Ok(Some((tag, payload)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tag: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, tag, payload).unwrap();
        out
    }

    #[test]
    fn frame_layout_is_len_tag_payload() {
        let bytes = encode(7, b"abc");
        assert_eq!(&bytes[..4], &4u32.to_le_bytes(), "len counts tag + payload");
        assert_eq!(bytes[4], 7);
        assert_eq!(&bytes[5..], b"abc");

        // empty payload is a valid frame (len = 1, just the tag)
        let bytes = encode(9, b"");
        assert_eq!(&bytes[..4], &1u32.to_le_bytes());
        assert_eq!(bytes.len(), 5);
    }

    #[test]
    fn decoder_survives_byte_at_a_time_feeding() {
        let mut wire = encode(1, b"hello");
        wire.extend(encode(2, b""));
        wire.extend(encode(3, &[0xff; 300]));

        let mut dec = FrameDecoder::new();
        let mut frames = Vec::new();
        for b in &wire {
            dec.feed(std::slice::from_ref(b));
            while let Some(f) = dec.next_frame().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], (1, b"hello".to_vec()));
        assert_eq!(frames[1], (2, Vec::new()));
        assert_eq!(frames[2], (3, vec![0xff; 300]));
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn decoder_survives_arbitrary_split_points() {
        let mut wire = encode(5, b"first");
        wire.extend(encode(6, b"second frame with more bytes"));
        // every possible single split of the two-frame stream
        for cut in 0..=wire.len() {
            let mut dec = FrameDecoder::new();
            let mut frames = Vec::new();
            for chunk in [&wire[..cut], &wire[cut..]] {
                dec.feed(chunk);
                while let Some(f) = dec.next_frame().unwrap() {
                    frames.push(f);
                }
            }
            assert_eq!(frames.len(), 2, "cut at {cut}");
            assert_eq!(frames[0].1, b"first", "cut at {cut}");
            assert_eq!(frames[1].1, b"second frame with more bytes", "cut at {cut}");
        }
    }

    #[test]
    fn torn_header_yields_nothing_until_complete() {
        let wire = encode(1, b"xy");
        let mut dec = FrameDecoder::new();
        dec.feed(&wire[..3]); // half the length prefix
        assert!(dec.next_frame().unwrap().is_none());
        dec.feed(&wire[3..5]); // length + tag, no payload yet
        assert!(dec.next_frame().unwrap().is_none());
        dec.feed(&wire[5..]);
        assert_eq!(dec.next_frame().unwrap(), Some((1, b"xy".to_vec())));
    }

    #[test]
    fn corrupt_length_prefix_is_a_typed_protocol_error() {
        let mut dec = FrameDecoder::new();
        dec.feed(&0u32.to_le_bytes()); // len 0: no room for the tag
        assert!(matches!(dec.next_frame(), Err(Pars3Error::Protocol(_))));

        let mut dec = FrameDecoder::new();
        dec.feed(&u32::MAX.to_le_bytes()); // 4 GiB "frame"
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("frame length"), "{err}");

        // the writer refuses to produce an oversized frame too
        let huge = vec![0u8; MAX_FRAME as usize];
        let mut out = Vec::new();
        assert!(matches!(write_frame(&mut out, 1, &huge), Err(Pars3Error::Protocol(_))));
    }

    #[test]
    fn long_sessions_compact_the_consumed_prefix() {
        let mut dec = FrameDecoder::new();
        let frame = encode(1, &[7u8; 100]);
        for _ in 0..200 {
            dec.feed(&frame);
            assert!(dec.next_frame().unwrap().is_some());
        }
        // consumed bytes must not accumulate without bound
        assert!(dec.buf.len() < 3 * frame.len(), "buffer grew to {}", dec.buf.len());
        assert_eq!(dec.pending(), 0);
    }
}
