//! Binary message layer: the full typed client surface on the wire.
//!
//! Every [`Request`]/[`Response`] encodes to one frame
//! ([`super::frame`]): the frame tag selects the message, the payload
//! is a flat little-endian encoding. Numeric vectors and batches —
//! the hot path — are raw LE `f64` bytes (count-prefixed), never text:
//! an `spmv` round trip moves `16n` bytes of payload plus a fixed
//! header, with no float formatting or parsing anywhere. Only
//! `describe`'s evidence tree ([`MatrixInfo`] with its embedded
//! [`PlanReport`](crate::coordinator::PlanReport)) travels as JSON —
//! it is metadata, produced once per matrix, and the tree is deep
//! enough that a hand-rolled binary layout would buy nothing but
//! maintenance risk. That JSON path is total even for non-finite
//! floats (see [`crate::util::json`]).
//!
//! Requests carry a connection-local `id`; the server echoes it in the
//! response, which is what lets a client pipeline many requests and
//! match results as they return.

use crate::coordinator::client::MatrixHandle;
use crate::coordinator::service::{CacheStats, MatrixInfo};
use crate::coordinator::{Backend, Pars3Error};
use crate::kernel::registry::KERNEL_NAMES;
use crate::kernel::VecBatch;
use crate::solver::mrs::{MrsOptions, MrsResult};
use crate::sparse::Coo;
use crate::util::json::Json;

/// A client-to-server message. `id` is connection-local and echoed in
/// the response.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Register a matrix (expensive preprocessing; resolves to a
    /// handle).
    Prepare { id: u64, name: String, coo: Coo },
    /// Re-prepare under an existing handle (generation bump).
    PrepareReplace { id: u64, handle: MatrixHandle, name: String, coo: Coo },
    /// Unregister the matrix under a handle.
    Release { id: u64, handle: MatrixHandle },
    /// One multiply `y = A x`.
    Spmv { id: u64, handle: MatrixHandle, x: Vec<f64>, backend: Backend },
    /// Fused batch multiply.
    SpmvBatch { id: u64, handle: MatrixHandle, xs: VecBatch, backend: Backend },
    /// MRS solve.
    Solve { id: u64, handle: MatrixHandle, b: Vec<f64>, opts: MrsOptions, backend: Backend },
    /// Multi-RHS MRS solve.
    SolveBatch { id: u64, handle: MatrixHandle, bs: VecBatch, opts: MrsOptions, backend: Backend },
    /// Preprocessing metadata for a handle.
    Describe { id: u64, handle: MatrixHandle },
    /// Cache/queue counters: one shard, or every shard (`None`).
    CacheStats { id: u64, shard: Option<u64> },
    /// Stop the service gracefully; the server acknowledges, then shuts
    /// down its listener.
    Stop { id: u64 },
}

/// A server-to-client message. Matched to its request by `id`.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// `Prepare`/`PrepareReplace` succeeded.
    Handle { id: u64, handle: MatrixHandle },
    /// `Release`/`Stop` succeeded.
    Unit { id: u64 },
    /// `Spmv` result.
    Vec { id: u64, y: Vec<f64> },
    /// `SpmvBatch` result.
    Batch { id: u64, ys: VecBatch },
    /// `Solve` result.
    Solve { id: u64, result: MrsResult },
    /// `SolveBatch` result.
    SolveBatch { id: u64, results: Vec<MrsResult> },
    /// `Describe` result.
    Info { id: u64, info: MatrixInfo },
    /// `CacheStats` result (one entry, or one per shard).
    Stats { id: u64, stats: Vec<CacheStats> },
    /// The request failed with a typed error.
    Error { id: u64, err: Pars3Error },
}

impl Response {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            Response::Handle { id, .. }
            | Response::Unit { id }
            | Response::Vec { id, .. }
            | Response::Batch { id, .. }
            | Response::Solve { id, .. }
            | Response::SolveBatch { id, .. }
            | Response::Info { id, .. }
            | Response::Stats { id, .. }
            | Response::Error { id, .. } => *id,
        }
    }
}

// ---- flat little-endian encoding primitives -------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_u64(out, xs.len() as u64);
    out.reserve(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    put_u64(out, xs.len() as u64);
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Cursor over a received payload; every read is bounds-checked into a
/// typed [`Pars3Error::Protocol`].
struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], Pars3Error> {
        if self.i + n > self.b.len() {
            return Err(Pars3Error::protocol(format!(
                "truncated payload: wanted {n} bytes at offset {} of {}",
                self.i,
                self.b.len()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, Pars3Error> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64, Pars3Error> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, Pars3Error> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn len(&mut self) -> Result<usize, Pars3Error> {
        let n = self.u64()?;
        // an element is at least one byte; a count beyond the payload
        // is corrupt, not a request for a huge allocation
        if n > self.b.len() as u64 {
            return Err(Pars3Error::protocol(format!("implausible count {n}")));
        }
        Ok(n as usize)
    }

    fn str(&mut self) -> Result<String, Pars3Error> {
        let n = self.len()?;
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|e| Pars3Error::protocol(format!("bad utf-8 string: {e}")))?;
        Ok(s.to_string())
    }

    fn f64s(&mut self) -> Result<Vec<f64>, Pars3Error> {
        let n = self.u64()?;
        if n.checked_mul(8).map(|bytes| bytes > (self.b.len() - self.i) as u64).unwrap_or(true) {
            return Err(Pars3Error::protocol(format!("implausible f64 count {n}")));
        }
        let raw = self.take(n as usize * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>, Pars3Error> {
        let n = self.u64()?;
        if n.checked_mul(4).map(|bytes| bytes > (self.b.len() - self.i) as u64).unwrap_or(true) {
            return Err(Pars3Error::protocol(format!("implausible u32 count {n}")));
        }
        let raw = self.take(n as usize * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> Result<(), Pars3Error> {
        if self.i != self.b.len() {
            return Err(Pars3Error::protocol(format!(
                "{} trailing bytes after message",
                self.b.len() - self.i
            )));
        }
        Ok(())
    }
}

// ---- domain encodings -----------------------------------------------

fn put_handle(out: &mut Vec<u8>, h: &MatrixHandle) {
    put_u64(out, h.service);
    put_u64(out, h.shard as u64);
    put_u64(out, h.slot as u64);
    put_u64(out, h.generation);
}

fn get_handle(d: &mut Dec) -> Result<MatrixHandle, Pars3Error> {
    Ok(MatrixHandle {
        service: d.u64()?,
        shard: d.u64()? as usize,
        slot: d.u64()? as usize,
        generation: d.u64()?,
    })
}

fn put_backend(out: &mut Vec<u8>, b: Backend) {
    let (kind, p) = match b {
        Backend::Serial => (0u8, 0),
        Backend::Csr => (1, 0),
        Backend::Dgbmv => (2, 0),
        Backend::Coloring { p } => (3, p),
        Backend::Race { p } => (4, p),
        Backend::Pars3 { p } => (5, p),
        Backend::Pjrt => (6, 0),
    };
    put_u8(out, kind);
    put_u64(out, p as u64);
}

fn get_backend(d: &mut Dec) -> Result<Backend, Pars3Error> {
    let kind = d.u8()?;
    let p = d.u64()? as usize;
    Ok(match kind {
        0 => Backend::Serial,
        1 => Backend::Csr,
        2 => Backend::Dgbmv,
        3 => Backend::Coloring { p },
        4 => Backend::Race { p },
        5 => Backend::Pars3 { p },
        6 => Backend::Pjrt,
        other => return Err(Pars3Error::protocol(format!("unknown backend kind {other}"))),
    })
}

fn put_coo(out: &mut Vec<u8>, coo: &Coo) {
    put_u64(out, coo.n as u64);
    put_u32s(out, &coo.rows);
    put_u32s(out, &coo.cols);
    put_f64s(out, &coo.vals);
}

fn get_coo(d: &mut Dec) -> Result<Coo, Pars3Error> {
    let n = d.u64()? as usize;
    let rows = d.u32s()?;
    let cols = d.u32s()?;
    let vals = d.f64s()?;
    if rows.len() != cols.len() || rows.len() != vals.len() {
        return Err(Pars3Error::protocol("ragged COO arrays"));
    }
    Ok(Coo { n, rows, cols, vals })
}

fn put_batch(out: &mut Vec<u8>, b: &VecBatch) {
    put_u64(out, b.n() as u64);
    put_u64(out, b.k() as u64);
    put_f64s(out, b.data());
}

fn get_batch(d: &mut Dec) -> Result<VecBatch, Pars3Error> {
    let n = d.u64()? as usize;
    let k = d.u64()? as usize;
    let data = d.f64s()?;
    if data.len() != n * k {
        return Err(Pars3Error::protocol(format!(
            "batch data length {} != n*k = {}",
            data.len(),
            n * k
        )));
    }
    let mut b = VecBatch::zeros(n, k);
    b.data_mut().copy_from_slice(&data);
    Ok(b)
}

fn put_opts(out: &mut Vec<u8>, o: &MrsOptions) {
    put_f64(out, o.alpha);
    put_u64(out, o.max_iters as u64);
    put_f64(out, o.tol);
}

fn get_opts(d: &mut Dec) -> Result<MrsOptions, Pars3Error> {
    Ok(MrsOptions { alpha: d.f64()?, max_iters: d.u64()? as usize, tol: d.f64()? })
}

fn put_mrs_result(out: &mut Vec<u8>, r: &MrsResult) {
    put_f64s(out, &r.x);
    put_f64s(out, &r.r);
    put_f64s(out, &r.history);
    put_u64(out, r.iters as u64);
    put_u8(out, r.converged as u8);
}

fn get_mrs_result(d: &mut Dec) -> Result<MrsResult, Pars3Error> {
    Ok(MrsResult {
        x: d.f64s()?,
        r: d.f64s()?,
        history: d.f64s()?,
        iters: d.u64()? as usize,
        converged: d.u8()? != 0,
    })
}

fn put_cache_stats(out: &mut Vec<u8>, s: &CacheStats) {
    put_u64(out, s.shard as u64);
    put_u64(out, s.cached as u64);
    put_u64(out, s.built as u64);
    put_u64(out, s.queue_depth as u64);
}

fn get_cache_stats(d: &mut Dec) -> Result<CacheStats, Pars3Error> {
    Ok(CacheStats {
        shard: d.u64()? as usize,
        cached: d.u64()? as usize,
        built: d.u64()? as usize,
        queue_depth: d.u64()? as usize,
    })
}

/// Intern a backend name received off the wire back to the `&'static`
/// spelling [`Pars3Error::BackendUnavailable`] holds. Unknown names
/// map to a fixed placeholder rather than leaking per-message
/// allocations.
fn intern_backend_name(name: &str) -> &'static str {
    for &k in KERNEL_NAMES {
        if k == name {
            return k;
        }
    }
    match name {
        "pjrt" => "pjrt",
        _ => "unknown-backend",
    }
}

fn put_error(out: &mut Vec<u8>, e: &Pars3Error) {
    match e {
        Pars3Error::UnknownMatrix { shard, slot } => {
            put_u8(out, 1);
            put_u64(out, *shard as u64);
            put_u64(out, *slot as u64);
        }
        Pars3Error::UnknownShard { shard, shards } => {
            put_u8(out, 2);
            put_u64(out, *shard as u64);
            put_u64(out, *shards as u64);
        }
        Pars3Error::ForeignHandle { handle_service, service } => {
            put_u8(out, 3);
            put_u64(out, *handle_service);
            put_u64(out, *service);
        }
        Pars3Error::StaleHandle { shard, slot, held, current } => {
            put_u8(out, 4);
            put_u64(out, *shard as u64);
            put_u64(out, *slot as u64);
            put_u64(out, *held);
            put_u64(out, *current);
        }
        Pars3Error::DimensionMismatch { expected, got } => {
            put_u8(out, 5);
            put_u64(out, *expected as u64);
            put_u64(out, *got as u64);
        }
        Pars3Error::BackendUnavailable { backend, reason } => {
            put_u8(out, 6);
            put_str(out, backend);
            put_str(out, reason);
        }
        Pars3Error::UnknownKernel { name } => {
            put_u8(out, 7);
            put_str(out, name);
        }
        Pars3Error::InvalidMatrix(why) => {
            put_u8(out, 8);
            put_str(out, why);
        }
        Pars3Error::WorkerPoisoned { shard } => {
            put_u8(out, 9);
            put_u64(out, *shard as u64);
        }
        Pars3Error::TicketConsumed => put_u8(out, 10),
        Pars3Error::ServiceStopped => put_u8(out, 11),
        Pars3Error::Io(why) => {
            put_u8(out, 12);
            put_str(out, why);
        }
        Pars3Error::Protocol(why) => {
            put_u8(out, 13);
            put_str(out, why);
        }
        Pars3Error::Internal(why) => {
            put_u8(out, 14);
            put_str(out, why);
        }
    }
}

fn get_error(d: &mut Dec) -> Result<Pars3Error, Pars3Error> {
    Ok(match d.u8()? {
        1 => Pars3Error::UnknownMatrix { shard: d.u64()? as usize, slot: d.u64()? as usize },
        2 => Pars3Error::UnknownShard { shard: d.u64()? as usize, shards: d.u64()? as usize },
        3 => Pars3Error::ForeignHandle { handle_service: d.u64()?, service: d.u64()? },
        4 => Pars3Error::StaleHandle {
            shard: d.u64()? as usize,
            slot: d.u64()? as usize,
            held: d.u64()?,
            current: d.u64()?,
        },
        5 => Pars3Error::DimensionMismatch {
            expected: d.u64()? as usize,
            got: d.u64()? as usize,
        },
        6 => Pars3Error::BackendUnavailable {
            backend: intern_backend_name(&d.str()?),
            reason: d.str()?,
        },
        7 => Pars3Error::UnknownKernel { name: d.str()? },
        8 => Pars3Error::InvalidMatrix(d.str()?),
        9 => Pars3Error::WorkerPoisoned { shard: d.u64()? as usize },
        10 => Pars3Error::TicketConsumed,
        11 => Pars3Error::ServiceStopped,
        12 => Pars3Error::Io(d.str()?),
        13 => Pars3Error::Protocol(d.str()?),
        14 => Pars3Error::Internal(d.str()?),
        other => return Err(Pars3Error::protocol(format!("unknown error tag {other}"))),
    })
}

// ---- message encode / decode ----------------------------------------

/// Request frame tags.
mod rtag {
    pub const PREPARE: u8 = 1;
    pub const PREPARE_REPLACE: u8 = 2;
    pub const RELEASE: u8 = 3;
    pub const SPMV: u8 = 4;
    pub const SPMV_BATCH: u8 = 5;
    pub const SOLVE: u8 = 6;
    pub const SOLVE_BATCH: u8 = 7;
    pub const DESCRIBE: u8 = 8;
    pub const CACHE_STATS: u8 = 9;
    pub const STOP: u8 = 10;
}

/// Response frame tags (high bit set).
mod ptag {
    pub const HANDLE: u8 = 0x81;
    pub const UNIT: u8 = 0x82;
    pub const VEC: u8 = 0x83;
    pub const BATCH: u8 = 0x84;
    pub const SOLVE: u8 = 0x85;
    pub const SOLVE_BATCH: u8 = 0x86;
    pub const INFO: u8 = 0x87;
    pub const STATS: u8 = 0x88;
    pub const ERROR: u8 = 0x8F;
}

impl Request {
    /// Encode to a `(frame tag, payload)` pair.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut out = Vec::new();
        match self {
            Request::Prepare { id, name, coo } => {
                put_u64(&mut out, *id);
                put_str(&mut out, name);
                put_coo(&mut out, coo);
                (rtag::PREPARE, out)
            }
            Request::PrepareReplace { id, handle, name, coo } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                put_str(&mut out, name);
                put_coo(&mut out, coo);
                (rtag::PREPARE_REPLACE, out)
            }
            Request::Release { id, handle } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                (rtag::RELEASE, out)
            }
            Request::Spmv { id, handle, x, backend } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                put_backend(&mut out, *backend);
                put_f64s(&mut out, x);
                (rtag::SPMV, out)
            }
            Request::SpmvBatch { id, handle, xs, backend } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                put_backend(&mut out, *backend);
                put_batch(&mut out, xs);
                (rtag::SPMV_BATCH, out)
            }
            Request::Solve { id, handle, b, opts, backend } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                put_backend(&mut out, *backend);
                put_opts(&mut out, opts);
                put_f64s(&mut out, b);
                (rtag::SOLVE, out)
            }
            Request::SolveBatch { id, handle, bs, opts, backend } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                put_backend(&mut out, *backend);
                put_opts(&mut out, opts);
                put_batch(&mut out, bs);
                (rtag::SOLVE_BATCH, out)
            }
            Request::Describe { id, handle } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                (rtag::DESCRIBE, out)
            }
            Request::CacheStats { id, shard } => {
                put_u64(&mut out, *id);
                match shard {
                    Some(s) => {
                        put_u8(&mut out, 1);
                        put_u64(&mut out, *s);
                    }
                    None => put_u8(&mut out, 0),
                }
                (rtag::CACHE_STATS, out)
            }
            Request::Stop { id } => {
                put_u64(&mut out, *id);
                (rtag::STOP, out)
            }
        }
    }

    /// Decode a received `(frame tag, payload)` pair.
    pub fn decode(tag: u8, payload: &[u8]) -> Result<Request, Pars3Error> {
        let mut d = Dec::new(payload);
        let req = match tag {
            rtag::PREPARE => {
                Request::Prepare { id: d.u64()?, name: d.str()?, coo: get_coo(&mut d)? }
            }
            rtag::PREPARE_REPLACE => Request::PrepareReplace {
                id: d.u64()?,
                handle: get_handle(&mut d)?,
                name: d.str()?,
                coo: get_coo(&mut d)?,
            },
            rtag::RELEASE => Request::Release { id: d.u64()?, handle: get_handle(&mut d)? },
            rtag::SPMV => Request::Spmv {
                id: d.u64()?,
                handle: get_handle(&mut d)?,
                backend: get_backend(&mut d)?,
                x: d.f64s()?,
            },
            rtag::SPMV_BATCH => Request::SpmvBatch {
                id: d.u64()?,
                handle: get_handle(&mut d)?,
                backend: get_backend(&mut d)?,
                xs: get_batch(&mut d)?,
            },
            rtag::SOLVE => Request::Solve {
                id: d.u64()?,
                handle: get_handle(&mut d)?,
                backend: get_backend(&mut d)?,
                opts: get_opts(&mut d)?,
                b: d.f64s()?,
            },
            rtag::SOLVE_BATCH => Request::SolveBatch {
                id: d.u64()?,
                handle: get_handle(&mut d)?,
                backend: get_backend(&mut d)?,
                opts: get_opts(&mut d)?,
                bs: get_batch(&mut d)?,
            },
            rtag::DESCRIBE => Request::Describe { id: d.u64()?, handle: get_handle(&mut d)? },
            rtag::CACHE_STATS => {
                let id = d.u64()?;
                let shard = match d.u8()? {
                    0 => None,
                    1 => Some(d.u64()?),
                    other => {
                        return Err(Pars3Error::protocol(format!("bad shard selector {other}")))
                    }
                };
                Request::CacheStats { id, shard }
            }
            rtag::STOP => Request::Stop { id: d.u64()? },
            other => return Err(Pars3Error::protocol(format!("unknown request tag {other:#x}"))),
        };
        d.done()?;
        Ok(req)
    }
}

impl Response {
    /// Encode to a `(frame tag, payload)` pair.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut out = Vec::new();
        match self {
            Response::Handle { id, handle } => {
                put_u64(&mut out, *id);
                put_handle(&mut out, handle);
                (ptag::HANDLE, out)
            }
            Response::Unit { id } => {
                put_u64(&mut out, *id);
                (ptag::UNIT, out)
            }
            Response::Vec { id, y } => {
                put_u64(&mut out, *id);
                put_f64s(&mut out, y);
                (ptag::VEC, out)
            }
            Response::Batch { id, ys } => {
                put_u64(&mut out, *id);
                put_batch(&mut out, ys);
                (ptag::BATCH, out)
            }
            Response::Solve { id, result } => {
                put_u64(&mut out, *id);
                put_mrs_result(&mut out, result);
                (ptag::SOLVE, out)
            }
            Response::SolveBatch { id, results } => {
                put_u64(&mut out, *id);
                put_u64(&mut out, results.len() as u64);
                for r in results {
                    put_mrs_result(&mut out, r);
                }
                (ptag::SOLVE_BATCH, out)
            }
            Response::Info { id, info } => {
                put_u64(&mut out, *id);
                put_str(&mut out, &info.to_json().dump());
                (ptag::INFO, out)
            }
            Response::Stats { id, stats } => {
                put_u64(&mut out, *id);
                put_u64(&mut out, stats.len() as u64);
                for s in stats {
                    put_cache_stats(&mut out, s);
                }
                (ptag::STATS, out)
            }
            Response::Error { id, err } => {
                put_u64(&mut out, *id);
                put_error(&mut out, err);
                (ptag::ERROR, out)
            }
        }
    }

    /// Decode a received `(frame tag, payload)` pair.
    pub fn decode(tag: u8, payload: &[u8]) -> Result<Response, Pars3Error> {
        let mut d = Dec::new(payload);
        let resp = match tag {
            ptag::HANDLE => Response::Handle { id: d.u64()?, handle: get_handle(&mut d)? },
            ptag::UNIT => Response::Unit { id: d.u64()? },
            ptag::VEC => Response::Vec { id: d.u64()?, y: d.f64s()? },
            ptag::BATCH => Response::Batch { id: d.u64()?, ys: get_batch(&mut d)? },
            ptag::SOLVE => Response::Solve { id: d.u64()?, result: get_mrs_result(&mut d)? },
            ptag::SOLVE_BATCH => {
                let id = d.u64()?;
                let n = d.len()?;
                let mut results = Vec::with_capacity(n);
                for _ in 0..n {
                    results.push(get_mrs_result(&mut d)?);
                }
                Response::SolveBatch { id, results }
            }
            ptag::INFO => {
                let id = d.u64()?;
                let text = d.str()?;
                let json = Json::parse(&text)
                    .map_err(|e| Pars3Error::protocol(format!("bad info json: {e:#}")))?;
                let info = MatrixInfo::from_json(&json)
                    .map_err(|e| Pars3Error::protocol(format!("bad info shape: {e:#}")))?;
                Response::Info { id, info }
            }
            ptag::STATS => {
                let id = d.u64()?;
                let n = d.len()?;
                let mut stats = Vec::with_capacity(n);
                for _ in 0..n {
                    stats.push(get_cache_stats(&mut d)?);
                }
                Response::Stats { id, stats }
            }
            ptag::ERROR => Response::Error { id: d.u64()?, err: get_error(&mut d)? },
            other => return Err(Pars3Error::protocol(format!("unknown response tag {other:#x}"))),
        };
        d.done()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handle() -> MatrixHandle {
        MatrixHandle { service: 42, shard: 1, slot: 3, generation: 7 }
    }

    #[test]
    fn every_request_round_trips() {
        let mut coo = Coo::new(4);
        coo.push(1, 0, 2.5);
        coo.push(3, 2, -1.25);
        let opts = MrsOptions { alpha: 2.0, max_iters: 500, tol: 1e-9 };
        let reqs = vec![
            Request::Prepare { id: 1, name: "m".into(), coo: coo.clone() },
            Request::PrepareReplace { id: 2, handle: handle(), name: "m2".into(), coo },
            Request::Release { id: 3, handle: handle() },
            Request::Spmv { id: 4, handle: handle(), x: vec![1.0, -2.0, 0.5], backend: Backend::Pars3 { p: 4 } },
            Request::SpmvBatch {
                id: 5,
                handle: handle(),
                xs: VecBatch::from_fn(3, 2, |i, c| (i * 2 + c) as f64),
                backend: Backend::Serial,
            },
            Request::Solve { id: 6, handle: handle(), b: vec![0.0; 3], opts: opts.clone(), backend: Backend::Race { p: 2 } },
            Request::SolveBatch {
                id: 7,
                handle: handle(),
                bs: VecBatch::zeros(2, 2),
                opts,
                backend: Backend::Csr,
            },
            Request::Describe { id: 8, handle: handle() },
            Request::CacheStats { id: 9, shard: Some(2) },
            Request::CacheStats { id: 10, shard: None },
            Request::Stop { id: 11 },
        ];
        for req in reqs {
            let (tag, payload) = req.encode();
            assert_eq!(Request::decode(tag, &payload).unwrap(), req, "tag {tag}");
        }
    }

    #[test]
    fn data_responses_round_trip() {
        let mrs = MrsResult {
            x: vec![1.0, 2.0],
            r: vec![1e-12, -1e-12],
            history: vec![4.0, 1.0, 0.25],
            iters: 3,
            converged: true,
        };
        let resps = vec![
            Response::Handle { id: 1, handle: handle() },
            Response::Unit { id: 2 },
            Response::Vec { id: 3, y: vec![0.5, -0.25, f64::MIN_POSITIVE] },
            Response::Batch { id: 4, ys: VecBatch::from_fn(2, 3, |i, c| (i + c) as f64 - 1.5) },
            Response::Solve { id: 5, result: mrs.clone() },
            Response::SolveBatch { id: 6, results: vec![mrs.clone(), MrsResult { converged: false, ..mrs }] },
            Response::Stats {
                id: 7,
                stats: vec![
                    CacheStats { shard: 0, cached: 1, built: 2, queue_depth: 3 },
                    CacheStats { shard: 1, cached: 0, built: 0, queue_depth: 0 },
                ],
            },
        ];
        for resp in resps {
            let (tag, payload) = resp.encode();
            let back = Response::decode(tag, &payload).unwrap();
            assert_eq!(back.id(), resp.id());
            assert_eq!(back, resp, "tag {tag}");
        }
    }

    #[test]
    fn every_error_variant_round_trips() {
        let errs = vec![
            Pars3Error::UnknownMatrix { shard: 1, slot: 2 },
            Pars3Error::UnknownShard { shard: 9, shards: 4 },
            Pars3Error::ForeignHandle { handle_service: 8, service: 7 },
            Pars3Error::StaleHandle { shard: 0, slot: 1, held: 2, current: 5 },
            Pars3Error::DimensionMismatch { expected: 100, got: 99 },
            Pars3Error::BackendUnavailable { backend: "pjrt", reason: "no plugin".into() },
            Pars3Error::UnknownKernel { name: "nope".into() },
            Pars3Error::InvalidMatrix("diagonal".into()),
            Pars3Error::WorkerPoisoned { shard: 3 },
            Pars3Error::TicketConsumed,
            Pars3Error::ServiceStopped,
            Pars3Error::Io("read: reset".into()),
            Pars3Error::Protocol("bad tag".into()),
            Pars3Error::Internal("context: inner".into()),
        ];
        for err in errs {
            let resp = Response::Error { id: 99, err: err.clone() };
            let (tag, payload) = resp.encode();
            assert_eq!(Response::decode(tag, &payload).unwrap(), resp, "{err}");
        }
        // an interned backend name off the wire is one of the known
        // statics; a fabricated one degrades to the placeholder
        assert_eq!(intern_backend_name("pars3"), "pars3");
        assert_eq!(intern_backend_name("made-up"), "unknown-backend");
    }

    #[test]
    fn floats_cross_the_wire_bit_exact() {
        // raw LE bytes, not text: denormals, -0.0, and exact ULP
        // patterns survive untouched
        let y = vec![
            f64::MIN_POSITIVE / 2.0, // subnormal
            -0.0,
            1.0 + f64::EPSILON,
            2.2250738585072014e-308,
            9.007199254740993e15, // 2^53 + 1, unrepresentable in text shortcuts
        ];
        let (tag, payload) = Response::Vec { id: 1, y: y.clone() }.encode();
        match Response::decode(tag, &payload).unwrap() {
            Response::Vec { y: back, .. } => {
                for (a, b) in y.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn truncated_and_oversized_payloads_are_typed_errors() {
        let (tag, payload) = Request::Spmv {
            id: 1,
            handle: handle(),
            x: vec![1.0; 8],
            backend: Backend::Serial,
        }
        .encode();
        // every prefix fails as Protocol, never panics
        for cut in 0..payload.len() {
            let err = Request::decode(tag, &payload[..cut]).unwrap_err();
            assert!(matches!(err, Pars3Error::Protocol(_)), "cut {cut}: {err}");
        }
        // trailing garbage is rejected too
        let mut long = payload.clone();
        long.push(0);
        assert!(matches!(Request::decode(tag, &long), Err(Pars3Error::Protocol(_))));
        // a count field claiming more elements than the payload holds
        let mut forged = Vec::new();
        put_u64(&mut forged, 1); // id
        put_u64(&mut forged, u64::MAX); // "length" of the name string
        let err = Request::decode(rtag::PREPARE, &forged).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err}");
        // unknown tags
        assert!(Request::decode(0x7f, &[]).is_err());
        assert!(Response::decode(0x01, &[]).is_err());
    }
}
