//! Experiment report generators — one function per paper table/figure
//! (see DESIGN.md §5). The CLI (`pars3 report ...`), the benches, and
//! the examples all call into here so every artifact is regenerated from
//! a single implementation.

use crate::coordinator::{Config, Coordinator, Prepared};
use crate::graph::coloring::color_rows;
use crate::kernel::conflict::ConflictMap;
use crate::kernel::serial_sss::sss_spmv;
use crate::kernel::Split3;
use crate::mpisim::CostModel;
use crate::sparse::band::BandProfile;
use crate::sparse::gen::{self, BenchMatrix};
use crate::sparse::skew;
use crate::util::SmallRng;
use crate::Result;

/// Render a GitHub-markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Generate + preprocess the six Table-1 analogues.
pub fn prepared_suite(cfg: &Config) -> Result<Vec<(BenchMatrix, Prepared)>> {
    let coord = Coordinator::new(cfg.clone());
    let mut out = Vec::new();
    for m in gen::paper_suite(cfg.scale) {
        let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
        let coo = skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng);
        let prep = coord.prepare(m.name, &coo)?;
        out.push((m, prep));
    }
    Ok(out)
}

/// **Table 1** — matrix characteristics: ours vs the paper's originals.
pub fn table1(suite: &[(BenchMatrix, Prepared)]) -> String {
    let rows: Vec<Vec<String>> = suite
        .iter()
        .map(|(m, p)| {
            vec![
                m.name.to_string(),
                p.n.to_string(),
                (2 * p.nnz_lower + p.n).to_string(),
                p.reordered_bw.to_string(),
                m.paper_rows.to_string(),
                m.paper_nnz.to_string(),
                m.paper_rcm_bw.to_string(),
                format!("{:.4}", p.reordered_bw as f64 / p.n as f64),
                format!("{:.4}", m.paper_rcm_bw as f64 / m.paper_rows as f64),
            ]
        })
        .collect();
    format!(
        "## Table 1 — benchmark matrix characteristics (analogues vs paper)\n\n{}",
        md_table(
            &[
                "Matrix", "Rows", "NNZ", "RCM bw", "paper rows", "paper NNZ", "paper RCM bw",
                "bw/n (ours)", "bw/n (paper)",
            ],
            &rows
        )
    )
}

/// **Figs. 1 & 5** — RCM effectiveness: bandwidth/profile before vs after.
pub fn rcm_report(suite: &[(BenchMatrix, Prepared)]) -> String {
    let rows: Vec<Vec<String>> = suite
        .iter()
        .map(|(m, p)| {
            let reduction = if p.bw_before > 0 {
                100.0 * (1.0 - p.reordered_bw as f64 / p.bw_before as f64)
            } else {
                0.0
            };
            vec![
                m.name.to_string(),
                p.bw_before.to_string(),
                p.reordered_bw.to_string(),
                format!("{reduction:.1}%"),
            ]
        })
        .collect();
    format!(
        "## Figs. 1/5 — RCM bandwidth reduction\n\n{}",
        md_table(&["Matrix", "bw before", "bw after RCM", "reduction"], &rows)
    )
}

/// **Fig. 2** — conflict regions under block distribution, per rank count.
pub fn conflict_report(suite: &[(BenchMatrix, Prepared)], ranks: &[usize]) -> String {
    let mut sections = String::from("## Fig. 2 — conflicting vs safe elements by rank count\n\n");
    for (m, p) in suite {
        let rows: Vec<Vec<String>> = ranks
            .iter()
            .map(|&pc| {
                let cm = ConflictMap::analyze(&p.split, pc);
                let conf = cm.total_conflicts();
                let total = p.split.nnz_middle() + p.split.nnz_outer();
                vec![
                    pc.to_string(),
                    conf.to_string(),
                    format!("{:.3}%", 100.0 * conf as f64 / total.max(1) as f64),
                    cm.rank0_conflicts().to_string(),
                ]
            })
            .collect();
        sections.push_str(&format!(
            "### {}\n\n{}\n",
            m.name,
            md_table(&["P", "conflicting nnz", "% of nnz", "rank-0 conflicts"], &rows)
        ));
    }
    sections
}

/// **Figs. 4/6/7/8** — 3-way split structure: sizes and densities, plus
/// an `outer_bw` sweep showing the paper's tunable boundary.
pub fn splits_report(suite: &[(BenchMatrix, Prepared)], outer_bws: &[usize]) -> String {
    let mut out = String::from("## Figs. 4/6/7/8 — band split structure\n\n");
    for (m, p) in suite {
        let prof = BandProfile::of(&p.sss);
        out.push_str(&format!(
            "### {} — band density {:.4}, mean |i-j| {:.1}\n\n",
            m.name,
            prof.band_density(),
            prof.mean_distance()
        ));
        let rows: Vec<Vec<String>> = outer_bws
            .iter()
            .map(|&ob| {
                let sp = Split3::with_outer_bw(&p.sss, ob).expect("split");
                let stats = sp.density_stats();
                let (dn, mn, on) = (stats[0].1, stats[1].1, stats[2].1);
                vec![
                    ob.to_string(),
                    sp.split_bw.to_string(),
                    dn.to_string(),
                    format!("{} ({:.4})", mn, stats[1].3),
                    format!("{} ({:.4})", on, stats[2].3),
                    format!("{:.3}%", 100.0 * on as f64 / (mn + on).max(1) as f64),
                ]
            })
            .collect();
        out.push_str(&md_table(
            &["outer_bw", "split_bw", "diag nnz", "middle nnz (density)", "outer nnz (density)", "outer share"],
            &rows,
        ));
        out.push('\n');
    }
    out
}

/// Speedup curves per matrix for **Figure 9**.
pub struct Fig9 {
    /// Rank counts.
    pub ranks: Vec<usize>,
    /// `(name, speedups aligned with ranks)`.
    pub series: Vec<(String, Vec<f64>)>,
}

/// **Figure 9** — strong scaling of PARS3 vs serial Alg. 1, from the
/// calibrated cost replay (DESIGN.md §2 hardware substitution).
pub fn fig9(suite: &[(BenchMatrix, Prepared)], ranks: &[usize], model: &CostModel) -> Fig9 {
    let mut series = Vec::new();
    for (m, p) in suite {
        let serial = model.serial_time(p.n, p.nnz_lower);
        let mut speedups = Vec::with_capacity(ranks.len());
        for &pc in ranks {
            let pc = pc.min(p.n);
            let cm = ConflictMap::analyze(&p.split, pc);
            let t = model.pars3_makespan(&cm, &p.split);
            speedups.push(model.speedup(serial, t));
        }
        series.push((m.name.to_string(), speedups));
    }
    Fig9 { ranks: ranks.to_vec(), series }
}

/// Markdown rendering of [`fig9`] with the ideal-speedup row.
pub fn fig9_report(f: &Fig9) -> String {
    let mut headers: Vec<String> = vec!["Matrix".into()];
    headers.extend(f.ranks.iter().map(|p| format!("P={p}")));
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows: Vec<Vec<String>> = f
        .series
        .iter()
        .map(|(name, sp)| {
            let mut row = vec![name.clone()];
            row.extend(sp.iter().map(|s| format!("{s:.2}x")));
            row
        })
        .collect();
    let mut ideal = vec!["(ideal)".to_string()];
    ideal.extend(f.ranks.iter().map(|p| format!("{p}.00x")));
    rows.push(ideal);
    format!(
        "## Figure 9 — strong scaling (speedup over serial Alg. 1)\n\n{}",
        md_table(&headers_ref, &rows)
    )
}

/// **§4.1 claim (X1)** — PARS3 vs the graph-coloring phased baseline.
pub fn coloring_compare(
    suite: &[(BenchMatrix, Prepared)],
    ranks: &[usize],
    model: &CostModel,
) -> String {
    let mut out = String::from(
        "## PARS3 vs conflict-free (graph-coloring) SSpMV [3]\n\nSpeedup over serial Alg. 1; phases = color count.\n\n",
    );
    for (m, p) in suite {
        let coloring = color_rows(&p.sss);
        let serial = model.serial_time(p.n, p.nnz_lower);
        let rows: Vec<Vec<String>> = ranks
            .iter()
            .map(|&pc| {
                let pc = pc.min(p.n);
                let cm = ConflictMap::analyze(&p.split, pc);
                let t_pars3 = model.pars3_makespan(&cm, &p.split);
                let t_color = model.coloring_makespan(&p.sss, &coloring, pc);
                vec![
                    pc.to_string(),
                    format!("{:.2}x", model.speedup(serial, t_pars3)),
                    format!("{:.2}x", model.speedup(serial, t_color)),
                    format!("{:.2}", t_color / t_pars3),
                ]
            })
            .collect();
        out.push_str(&format!(
            "### {} — {} phases\n\n{}\n",
            m.name,
            coloring.num_colors,
            md_table(&["P", "PARS3", "coloring [3]", "PARS3 advantage"], &rows)
        ));
    }
    out
}

/// **X2** — Θ(NNZ) complexity check: measured serial time per NNZ stays
/// flat across problem sizes. Uses constant-width banded matrices so the
/// structure (and cache behaviour) is size-invariant — the complexity
/// claim is about operation count, not locality (locality is the
/// `rcm_effect` bench's subject).
pub fn complexity_report(cfg: &Config, sizes: &[usize]) -> Result<String> {
    let coord = Coordinator::new(cfg.clone());
    let mut rows = Vec::new();
    for &n in sizes {
        let mut rng = SmallRng::seed_from_u64(cfg.seed);
        let edges = gen::random_banded_pattern(n, 6, 0.5, &mut rng);
        let coo = skew::coo_from_pattern(n, &edges, cfg.alpha, &mut rng);
        let prep = coord.prepare("cx", &coo)?;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut y = vec![0.0; n];
        let t = crate::perf::time_fn(2, 5, || {
            sss_spmv(&prep.sss, &x, &mut y);
            std::hint::black_box(&y);
        });
        rows.push(vec![
            n.to_string(),
            prep.nnz_lower.to_string(),
            format!("{:.3e}", t.min),
            format!("{:.3}", t.min / prep.nnz_lower as f64 * 1e9),
        ]);
    }
    Ok(format!(
        "## Θ(NNZ) check — serial Alg. 1 time scales linearly in NNZ\n\n{}",
        md_table(&["n", "nnz_lower", "seconds/apply", "ns per nnz"], &rows)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        Config { scale: 0.08, ..Config::default() }
    }

    #[test]
    fn md_table_shape() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn table1_and_rcm_reports_render() {
        let suite = prepared_suite(&tiny_cfg()).unwrap();
        assert_eq!(suite.len(), 6);
        let t1 = table1(&suite);
        assert!(t1.contains("af_5_k101_like") && t1.contains("Serena_like"));
        let r = rcm_report(&suite);
        assert!(r.contains("bw after RCM"));
    }

    #[test]
    fn fig9_series_are_monotone_at_small_p() {
        let suite = prepared_suite(&tiny_cfg()).unwrap();
        let model = CostModel::default();
        let f = fig9(&suite, &[1, 2, 4], &model);
        for (name, sp) in &f.series {
            assert!(sp[1] > sp[0] * 0.9, "{name}: {sp:?}");
        }
        let text = fig9_report(&f);
        assert!(text.contains("(ideal)"));
    }
}
