//! Graph substrate: adjacency structure, BFS level sets, pseudo-peripheral
//! vertex finding, Reverse Cuthill-McKee reordering, and greedy coloring
//! (the building block of the Elafrou et al. baseline).
//!
//! The paper uses MATLAB's `symrcm`; `rcm` here is the from-scratch
//! equivalent (George-Liu pseudo-peripheral start + CM + reversal).

pub mod adj;
pub mod bfs;
pub mod coloring;
pub mod peripheral;
pub mod rcm;

pub use adj::Adjacency;
pub use rcm::rcm;
