//! Graph substrate: adjacency structure, BFS level sets, start-node
//! finders (George-Liu and the RCM++ bi-criteria variant), Reverse
//! Cuthill-McKee reordering, the pluggable reordering strategies
//! ([`reorder`]), and greedy coloring (the building block of the
//! Elafrou et al. baseline).
//!
//! The paper uses MATLAB's `symrcm`; `rcm` here is the from-scratch
//! equivalent (George-Liu pseudo-peripheral start + CM + reversal),
//! and [`reorder`] wraps it — plus the bi-criteria variant, the
//! identity, and a measured `Auto` — behind one strategy trait with
//! per-component execution and a [`reorder::ReorderReport`] per run.

pub mod adj;
pub mod bfs;
pub mod coloring;
pub mod peripheral;
pub mod rcm;
pub mod reorder;

pub use adj::Adjacency;
pub use rcm::rcm;
pub use reorder::{ReorderPolicy, ReorderReport, ReorderStrategy};
