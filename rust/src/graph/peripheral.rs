//! Start-node finders for RCM-family reorderings.
//!
//! RCM quality depends heavily on the starting vertex: starting from a
//! vertex of (near-)maximal eccentricity produces long, narrow level
//! structures and hence small bandwidth. Two finders live here:
//!
//! * [`pseudo_peripheral`] — the classic George-Liu iteration, which
//!   walks to a minimum-degree vertex of the last BFS level until the
//!   eccentricity (level-structure *height*) stops growing.
//! * [`bi_criteria_start`] — the RCM++-style refinement (Hou et al.):
//!   a shortlist of low-degree last-level candidates is scored by
//!   height **and** width (the max level size lower-bounds the
//!   achievable bandwidth), accepting a candidate that grows the
//!   height *or* narrows the structure at equal height.

use crate::graph::bfs::{level_structure_with, LevelStructure};
use crate::graph::Adjacency;
use crate::util::pool::PrepPool;

/// Candidate-shortlist size for [`bi_criteria_start`] (RCM++ evaluates
/// a few low-degree last-level vertices, not just the minimum-degree
/// one; a handful captures most of the win at bounded cost).
const BI_CRITERIA_CANDIDATES: usize = 8;

/// Find a pseudo-peripheral vertex of `start`'s component.
pub fn pseudo_peripheral(g: &Adjacency, start: u32) -> u32 {
    pseudo_peripheral_ls(g, start).0
}

/// [`pseudo_peripheral`] on a prepare pool (the inner BFS sweeps run
/// level-parallel).
pub fn pseudo_peripheral_with(g: &Adjacency, start: u32, pool: &PrepPool) -> u32 {
    let ls0 = level_structure_with(g, start, pool);
    pseudo_peripheral_ls_from(g, ls0, pool).0
}

/// [`pseudo_peripheral`] returning the final root's level structure
/// too (callers that score the pick reuse it instead of re-running the
/// BFS).
pub fn pseudo_peripheral_ls(g: &Adjacency, start: u32) -> (u32, LevelStructure) {
    let pool = PrepPool::serial();
    let ls0 = level_structure_with(g, start, &pool);
    pseudo_peripheral_ls_from(g, ls0, &pool)
}

/// George-Liu iteration from a **precomputed** start level structure.
/// Splitting the initial BFS out lets `Auto`'s candidate scorer compute
/// it once per component start and share it between this finder and
/// [`bi_criteria_start_from`] instead of re-running BFS from scratch
/// per candidate strategy.
pub fn pseudo_peripheral_ls_from(
    g: &Adjacency,
    ls0: LevelStructure,
    pool: &PrepPool,
) -> (u32, LevelStructure) {
    let mut v = ls0.levels[0][0];
    let mut ls = ls0;
    loop {
        let last = match ls.last_level() {
            Some(l) => l,
            None => return (v, ls),
        };
        // minimum-degree vertex of the last level
        let u = *last.iter().min_by_key(|&&w| g.degree(w as usize)).unwrap();
        let ls_u = level_structure_with(g, u, pool);
        if ls_u.height() > ls.height() {
            v = u;
            ls = ls_u;
        } else {
            return (v, ls);
        }
    }
}

/// RCM++-style bi-criteria start finder: like George-Liu, but each
/// round evaluates a shortlist of low-degree last-level candidates and
/// accepts the one that is lexicographically best by **(height
/// descending, width ascending)** — strictly better than the current
/// root. Terminates because every accepted step strictly improves that
/// pair (height is bounded by the component size, width by 1 from
/// below).
pub fn bi_criteria_start(g: &Adjacency, start: u32) -> (u32, LevelStructure) {
    let pool = PrepPool::serial();
    let ls0 = level_structure_with(g, start, &pool);
    bi_criteria_start_from(g, ls0, &pool)
}

/// [`bi_criteria_start`] from a **precomputed** start level structure
/// on a prepare pool (see [`pseudo_peripheral_ls_from`] for why the
/// initial BFS is split out).
pub fn bi_criteria_start_from(
    g: &Adjacency,
    ls0: LevelStructure,
    pool: &PrepPool,
) -> (u32, LevelStructure) {
    let mut v = ls0.levels[0][0];
    let mut ls = ls0;
    loop {
        let last = match ls.last_level() {
            Some(l) => l,
            None => return (v, ls),
        };
        let mut cand: Vec<u32> = last.to_vec();
        cand.sort_unstable_by_key(|&w| (g.degree(w as usize), w));
        cand.truncate(BI_CRITERIA_CANDIDATES);
        // strictly better than (height, width) of the current root,
        // best-first among the improvements
        let better = |a: &LevelStructure, b: &LevelStructure| {
            a.height() > b.height() || (a.height() == b.height() && a.width() < b.width())
        };
        let mut best: Option<(u32, LevelStructure)> = None;
        for &u in &cand {
            let ls_u = level_structure_with(g, u, pool);
            if !better(&ls_u, &ls) {
                continue;
            }
            let beats_best = match &best {
                None => true,
                Some((_, b)) => better(&ls_u, b),
            };
            if beats_best {
                best = Some((u, ls_u));
            }
        }
        match best {
            Some((u, ls_u)) => {
                v = u;
                ls = ls_u;
            }
            None => return (v, ls),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_finds_endpoint() {
        let g = Adjacency::from_lower_edges(6, &[(1, 0), (2, 1), (3, 2), (4, 3), (5, 4)]);
        let p = pseudo_peripheral(&g, 2);
        assert!(p == 0 || p == 5, "got {p}");
    }

    #[test]
    fn star_center_moves_to_leaf() {
        let g = Adjacency::from_lower_edges(5, &[(1, 0), (2, 0), (3, 0), (4, 0)]);
        let p = pseudo_peripheral(&g, 0);
        assert_ne!(p, 0);
    }

    #[test]
    fn isolated_vertex_is_its_own_peripheral() {
        let g = Adjacency::from_lower_edges(2, &[]);
        assert_eq!(pseudo_peripheral(&g, 1), 1);
    }

    #[test]
    fn bi_criteria_finds_a_path_endpoint() {
        let g = Adjacency::from_lower_edges(6, &[(1, 0), (2, 1), (3, 2), (4, 3), (5, 4)]);
        let (p, ls) = bi_criteria_start(&g, 2);
        assert!(p == 0 || p == 5, "got {p}");
        assert_eq!((ls.height(), ls.width()), (5, 1));
    }

    #[test]
    fn bi_criteria_never_shrinks_the_height_george_liu_reaches() {
        // the bi-criteria accept rule is a superset of George-Liu's
        // (height must not decrease), so its final height is >= classic
        let g = Adjacency::from_lower_edges(
            7,
            &[(1, 0), (2, 0), (3, 1), (3, 2), (4, 3), (5, 3), (6, 4), (6, 5)],
        );
        for s in 0..7u32 {
            let (_, classic) = pseudo_peripheral_ls(&g, s);
            let (_, bi) = bi_criteria_start(&g, s);
            assert!(bi.height() >= classic.height(), "start {s}");
        }
    }

    #[test]
    fn bi_criteria_on_isolated_vertex() {
        let g = Adjacency::from_lower_edges(3, &[(1, 0)]);
        let (p, ls) = bi_criteria_start(&g, 2);
        assert_eq!(p, 2);
        assert_eq!(ls.height(), 0);
    }
}
