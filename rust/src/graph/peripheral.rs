//! George-Liu pseudo-peripheral vertex finder.
//!
//! RCM quality depends heavily on the starting vertex: starting from a
//! vertex of (near-)maximal eccentricity produces long, narrow level
//! structures and hence small bandwidth. The George-Liu iteration walks
//! to a minimum-degree vertex of the last BFS level until the
//! eccentricity stops growing.

use crate::graph::bfs::level_structure;
use crate::graph::Adjacency;

/// Find a pseudo-peripheral vertex of `start`'s component.
pub fn pseudo_peripheral(g: &Adjacency, start: u32) -> u32 {
    let mut v = start;
    let mut ls = level_structure(g, v);
    loop {
        let last = match ls.levels.last() {
            Some(l) if !l.is_empty() => l,
            _ => return v,
        };
        // minimum-degree vertex of the last level
        let u = *last.iter().min_by_key(|&&w| g.degree(w as usize)).unwrap();
        let ls_u = level_structure(g, u);
        if ls_u.height() > ls.height() {
            v = u;
            ls = ls_u;
        } else {
            return v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_finds_endpoint() {
        let g = Adjacency::from_lower_edges(6, &[(1, 0), (2, 1), (3, 2), (4, 3), (5, 4)]);
        let p = pseudo_peripheral(&g, 2);
        assert!(p == 0 || p == 5, "got {p}");
    }

    #[test]
    fn star_center_moves_to_leaf() {
        let g = Adjacency::from_lower_edges(5, &[(1, 0), (2, 0), (3, 0), (4, 0)]);
        let p = pseudo_peripheral(&g, 0);
        assert_ne!(p, 0);
    }

    #[test]
    fn isolated_vertex_is_its_own_peripheral() {
        let g = Adjacency::from_lower_edges(2, &[]);
        assert_eq!(pseudo_peripheral(&g, 1), 1);
    }
}
