//! Breadth-first level structures (the engine under RCM and the
//! pseudo-peripheral finder).

use crate::graph::Adjacency;

/// Rooted level structure: vertices grouped by BFS distance from a root.
#[derive(Debug, Clone)]
pub struct LevelStructure {
    /// `levels[d]` = vertices at distance `d` (only the root's component).
    pub levels: Vec<Vec<u32>>,
    /// Distance per vertex; `u32::MAX` for unreachable vertices.
    pub dist: Vec<u32>,
}

impl LevelStructure {
    /// Eccentricity of the root within its component.
    pub fn height(&self) -> usize {
        self.levels.len().saturating_sub(1)
    }

    /// Maximum level width (a lower bound on achievable bandwidth).
    pub fn width(&self) -> usize {
        self.levels.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// The deepest non-empty level (`None` for a degenerate structure)
    /// — the candidate pool of the start-node finders.
    pub fn last_level(&self) -> Option<&[u32]> {
        match self.levels.last() {
            Some(l) if !l.is_empty() => Some(l),
            _ => None,
        }
    }
}

/// BFS from `root`, returning the level structure of its component.
pub fn level_structure(g: &Adjacency, root: u32) -> LevelStructure {
    let mut dist = vec![u32::MAX; g.n];
    let mut levels: Vec<Vec<u32>> = vec![vec![root]];
    dist[root as usize] = 0;
    let mut frontier = vec![root];
    let mut d = 0u32;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            for &w in g.neighbors(v as usize) {
                if dist[w as usize] == u32::MAX {
                    dist[w as usize] = d + 1;
                    next.push(w);
                }
            }
        }
        d += 1;
        if next.is_empty() {
            break;
        }
        levels.push(next.clone());
        frontier = next;
    }
    LevelStructure { levels, dist }
}

/// Connected components; returns `comp[v]` and component count.
pub fn components(g: &Adjacency) -> (Vec<u32>, usize) {
    let mut comp = vec![u32::MAX; g.n];
    let mut c = 0u32;
    for s in 0..g.n {
        if comp[s] != u32::MAX {
            continue;
        }
        comp[s] = c;
        let mut stack = vec![s as u32];
        while let Some(v) = stack.pop() {
            for &w in g.neighbors(v as usize) {
                if comp[w as usize] == u32::MAX {
                    comp[w as usize] = c;
                    stack.push(w);
                }
            }
        }
        c += 1;
    }
    (comp, c as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path5() -> Adjacency {
        Adjacency::from_lower_edges(5, &[(1, 0), (2, 1), (3, 2), (4, 3)])
    }

    #[test]
    fn levels_of_path() {
        let ls = level_structure(&path5(), 0);
        assert_eq!(ls.height(), 4);
        assert_eq!(ls.width(), 1);
        assert_eq!(ls.dist, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn levels_from_center() {
        let ls = level_structure(&path5(), 2);
        assert_eq!(ls.height(), 2);
        assert_eq!(ls.width(), 2);
    }

    #[test]
    fn components_of_disconnected() {
        let g = Adjacency::from_lower_edges(5, &[(1, 0), (3, 2)]);
        let (comp, c) = components(&g);
        assert_eq!(c, 3); // {0,1}, {2,3}, {4}
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[2], comp[3]);
        assert_ne!(comp[0], comp[2]);
        assert_ne!(comp[4], comp[0]);
    }

    #[test]
    fn unreachable_marked() {
        let g = Adjacency::from_lower_edges(3, &[(1, 0)]);
        let ls = level_structure(&g, 0);
        assert_eq!(ls.dist[2], u32::MAX);
    }
}
