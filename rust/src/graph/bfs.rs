//! Breadth-first level structures (the engine under RCM and the
//! pseudo-peripheral finder).
//!
//! Both the serial and the level-synchronous parallel BFS live here;
//! [`level_structure_with`] is the parallel entry point (Azad et al.,
//! distributed-memory RCM: split the frontier, merge per-worker next
//! frontiers deterministically). The parallel expansion is bit-for-bit
//! identical to the serial one — see [`expand_frontier`].

use crate::graph::Adjacency;
use crate::util::pool::PrepPool;

/// Frontier size below which parallel expansion is not worth a spawn.
const MIN_PAR_FRONTIER: usize = 512;

/// Rooted level structure: vertices grouped by BFS distance from a root.
#[derive(Debug, Clone)]
pub struct LevelStructure {
    /// `levels[d]` = vertices at distance `d` (only the root's component).
    pub levels: Vec<Vec<u32>>,
    /// Distance per vertex; `u32::MAX` for unreachable vertices.
    pub dist: Vec<u32>,
}

impl LevelStructure {
    /// Eccentricity of the root within its component.
    pub fn height(&self) -> usize {
        self.levels.len().saturating_sub(1)
    }

    /// Maximum level width (a lower bound on achievable bandwidth).
    pub fn width(&self) -> usize {
        self.levels.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// The deepest non-empty level (`None` for a degenerate structure)
    /// — the candidate pool of the start-node finders.
    pub fn last_level(&self) -> Option<&[u32]> {
        match self.levels.last() {
            Some(l) if !l.is_empty() => Some(l),
            _ => None,
        }
    }
}

/// BFS from `root`, returning the level structure of its component.
pub fn level_structure(g: &Adjacency, root: u32) -> LevelStructure {
    level_structure_with(g, root, &PrepPool::serial())
}

/// Level-synchronous BFS from `root` on `pool`: each level's frontier
/// is expanded in parallel and the per-worker next frontiers are merged
/// in worker order, so the result is identical to [`level_structure`]
/// for every thread count.
pub fn level_structure_with(g: &Adjacency, root: u32, pool: &PrepPool) -> LevelStructure {
    let mut dist = vec![u32::MAX; g.n];
    let mut levels: Vec<Vec<u32>> = vec![vec![root]];
    dist[root as usize] = 0;
    let mut d = 0u32;
    loop {
        let next = {
            let frontier: &[u32] = levels.last().expect("levels starts non-empty");
            expand_frontier(g, frontier, &mut dist, d + 1, pool)
        };
        d += 1;
        if next.is_empty() {
            break;
        }
        levels.push(next);
    }
    LevelStructure { levels, dist }
}

/// Expand one BFS level: claim every unvisited neighbor of `frontier`
/// at distance `nd` and return the next frontier.
///
/// Parallel path determinism: workers only **read** `dist` (a snapshot
/// taken at level start) and collect candidate children per parent in
/// frontier order; the serial merge then claims first occurrences in
/// worker order. The concatenated candidate sequence visits (parent,
/// neighbor) pairs in exactly the serial scan order, and first-claim
/// filtering of duplicates reproduces the serial `next` bit for bit.
fn expand_frontier(
    g: &Adjacency,
    frontier: &[u32],
    dist: &mut [u32],
    nd: u32,
    pool: &PrepPool,
) -> Vec<u32> {
    if pool.threads() == 1 || frontier.len() < MIN_PAR_FRONTIER {
        let mut next = Vec::new();
        for &v in frontier {
            for &w in g.neighbors(v as usize) {
                if dist[w as usize] == u32::MAX {
                    dist[w as usize] = nd;
                    next.push(w);
                }
            }
        }
        return next;
    }
    let snapshot: &[u32] = dist;
    let found = pool.map_chunks(frontier.len(), MIN_PAR_FRONTIER / 4, |_, r| {
        let mut buf = Vec::new();
        for &v in &frontier[r] {
            for &w in g.neighbors(v as usize) {
                if snapshot[w as usize] == u32::MAX {
                    buf.push(w);
                }
            }
        }
        buf
    });
    let mut next = Vec::new();
    for buf in found {
        for w in buf {
            if dist[w as usize] == u32::MAX {
                dist[w as usize] = nd;
                next.push(w);
            }
        }
    }
    next
}

/// Connected components; returns `comp[v]` and component count.
pub fn components(g: &Adjacency) -> (Vec<u32>, usize) {
    let mut comp = vec![u32::MAX; g.n];
    let mut c = 0u32;
    for s in 0..g.n {
        if comp[s] != u32::MAX {
            continue;
        }
        comp[s] = c;
        let mut stack = vec![s as u32];
        while let Some(v) = stack.pop() {
            for &w in g.neighbors(v as usize) {
                if comp[w as usize] == u32::MAX {
                    comp[w as usize] = c;
                    stack.push(w);
                }
            }
        }
        c += 1;
    }
    (comp, c as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path5() -> Adjacency {
        Adjacency::from_lower_edges(5, &[(1, 0), (2, 1), (3, 2), (4, 3)])
    }

    #[test]
    fn levels_of_path() {
        let ls = level_structure(&path5(), 0);
        assert_eq!(ls.height(), 4);
        assert_eq!(ls.width(), 1);
        assert_eq!(ls.dist, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn levels_from_center() {
        let ls = level_structure(&path5(), 2);
        assert_eq!(ls.height(), 2);
        assert_eq!(ls.width(), 2);
    }

    #[test]
    fn components_of_disconnected() {
        let g = Adjacency::from_lower_edges(5, &[(1, 0), (3, 2)]);
        let (comp, c) = components(&g);
        assert_eq!(c, 3); // {0,1}, {2,3}, {4}
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[2], comp[3]);
        assert_ne!(comp[0], comp[2]);
        assert_ne!(comp[4], comp[0]);
    }

    #[test]
    fn unreachable_marked() {
        let g = Adjacency::from_lower_edges(3, &[(1, 0)]);
        let ls = level_structure(&g, 0);
        assert_eq!(ls.dist[2], u32::MAX);
    }

    #[test]
    fn parallel_levels_match_serial_on_wide_frontiers() {
        // complete binary tree (frontier doubles past the parallel
        // threshold) plus child→uncle links so a child is reachable
        // from two same-level parents that can land in different worker
        // chunks — the duplicate-claim case the ordered merge must get
        // right
        let n = 8191usize;
        let mut edges: Vec<(u32, u32)> = (1..n as u32).map(|i| (i, (i - 1) / 2)).collect();
        for p in 1..(n as u32 - 1) / 2 {
            edges.push((2 * p + 1, p + 1));
        }
        let g = Adjacency::from_lower_edges(n, &edges);
        let serial = level_structure(&g, 0);
        for t in [2usize, 3, 8] {
            let par = level_structure_with(&g, 0, &PrepPool::new(t));
            assert_eq!(par.dist, serial.dist, "threads={t}");
            assert_eq!(par.levels, serial.levels, "threads={t}");
        }
    }
}
