//! Pluggable, instrumented reordering strategies (the preprocessing
//! choice the whole PARS3 speedup story hinges on).
//!
//! The paper reorders with classic RCM unconditionally; two later
//! results motivate making the step a *strategy*:
//!
//! * **RCM++** (Hou et al.): the start node dominates RCM quality, and
//!   a bi-criteria pick — scoring candidate roots by level-structure
//!   *height* (deeper = narrower levels on average) **and** *width*
//!   (the max level size lower-bounds the achievable bandwidth) — beats
//!   the classic George-Liu height-only iteration.
//!   [`RcmBiCriteria`] implements that pick via
//!   [`crate::graph::peripheral::bi_criteria_start`].
//! * **"Is Sparse Matrix Reordering Effective for SpMV?"** (Asudeh et
//!   al.): reordering sometimes *hurts* (an already-banded matrix loses
//!   locality, and the permutation itself is not free), so a production
//!   service should measure candidates and be able to decline. [`Auto`]
//!   runs every candidate strategy, scores each by bandwidth then
//!   envelope/profile, and keeps the **natural** order unless the best
//!   reordering clears a configurable improvement threshold — the
//!   scoring loop itself lives with the other plan-axis scorers as
//!   [`crate::coordinator::planner::score_reorder_candidates`].
//!
//! Every strategy reorders **per connected component** (via
//! [`crate::graph::bfs::components`]-style discovery): each component
//! gets its own start node and occupies a contiguous index range, so
//! disconnected blocks get independent, tighter orderings and the
//! resulting permutation is always total. Every run emits a
//! [`ReorderReport`] — strategy chosen, bandwidth/profile before and
//! after, per-component stats, and the candidate scores Auto weighed —
//! which the planner embeds in its
//! [`PlanReport`](crate::coordinator::planner::PlanReport), flowing
//! into `Prepared`, `MatrixInfo`/`Client::describe`, `Pars3Stats`, and
//! the CLI output.

use crate::graph::bfs::{level_structure_with, LevelStructure};
use crate::graph::peripheral::{bi_criteria_start_from, pseudo_peripheral_ls_from};
use crate::graph::rcm::{bandwidth_under, profile_under};
use crate::graph::Adjacency;
use crate::util::pool::PrepPool;
use std::time::Instant;

/// Which reordering strategy `prepare` runs — the config/CLI selector
/// (`reorder = auto|rcm|rcm-bicriteria|natural`, `--reorder`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReorderPolicy {
    /// Run every candidate strategy, score by bandwidth + profile, keep
    /// the winner — including keeping the natural order when no
    /// reordering clears the improvement threshold.
    #[default]
    Auto,
    /// Classic RCM (George-Liu pseudo-peripheral start), per component.
    Rcm,
    /// RCM with the RCM++-style bi-criteria start-node selection.
    RcmBiCriteria,
    /// Identity: keep the input ordering.
    Natural,
}

impl ReorderPolicy {
    /// The policy's wire name (TOML/CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ReorderPolicy::Auto => "auto",
            ReorderPolicy::Rcm => "rcm",
            ReorderPolicy::RcmBiCriteria => "rcm-bicriteria",
            ReorderPolicy::Natural => "natural",
        }
    }
}

impl std::fmt::Display for ReorderPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ReorderPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "auto" => ReorderPolicy::Auto,
            "rcm" => ReorderPolicy::Rcm,
            "rcm-bicriteria" => ReorderPolicy::RcmBiCriteria,
            "natural" => ReorderPolicy::Natural,
            other => anyhow::bail!(
                "unknown reorder strategy '{other}' (expected auto|rcm|rcm-bicriteria|natural)"
            ),
        })
    }
}

/// Per-connected-component reordering statistics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ComponentStats {
    /// Start node the strategy picked (old vertex id).
    pub start: u32,
    /// Vertices in the component.
    pub size: usize,
    /// Level-structure height (eccentricity) rooted at `start`.
    pub height: usize,
    /// Level-structure width (max level size — a lower bound on the
    /// component's achievable bandwidth).
    pub width: usize,
    /// Bandwidth of the component under the final ordering.
    pub bw: usize,
}

/// One candidate strategy's score inside an [`Auto`] run (or the single
/// self-score of a directly-requested strategy).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CandidateScore {
    /// Candidate strategy name.
    pub strategy: &'static str,
    /// Bandwidth the candidate achieves.
    pub bandwidth: usize,
    /// Envelope/profile the candidate achieves.
    pub profile: u64,
    /// Whether this candidate's ordering was kept.
    pub chosen: bool,
}

/// Per-stage wall-clock timings of one prepare run (milliseconds).
///
/// `bfs_ms`/`rcm_ms` are stamped by the reorder strategies; `build_ms`
/// (permutation application + SSS conversion) is stamped by the kernel
/// registry's build path on top of the strategy's report. `serial_ms`
/// is `0.0` unless a caller (the `prepare_scaling` bench) explicitly
/// measured a single-threaded baseline to compare against. Timings are
/// measurements, not plan inputs — two runs of the same prepare differ
/// here and nowhere else, which is why the determinism tests zero this
/// struct before comparing reports.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PrepareTimings {
    /// Level-structure / peripheral-search BFS time.
    pub bfs_ms: f64,
    /// CM visit + reversal (the permutation computation proper).
    pub rcm_ms: f64,
    /// Permutation application + format construction (registry path).
    pub build_ms: f64,
    /// Single-threaded baseline for the same prepare, when measured
    /// (`0.0` = not measured).
    pub serial_ms: f64,
    /// Prepare-pool width the run used.
    pub threads: usize,
}

impl PrepareTimings {
    /// Total measured prepare time across the recorded stages.
    pub fn total_ms(&self) -> f64 {
        self.bfs_ms + self.rcm_ms + self.build_ms
    }

    /// Speedup vs the measured serial baseline (`None` when no baseline
    /// was recorded or the run was too fast to resolve).
    pub fn speedup(&self) -> Option<f64> {
        let total = self.total_ms();
        (self.serial_ms > 0.0 && total > 0.0).then(|| self.serial_ms / total)
    }

    /// One-line human summary for CLI/serve output.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "prepare timings: bfs {:.3} ms, rcm {:.3} ms, build {:.3} ms ({} thread(s)",
            self.bfs_ms, self.rcm_ms, self.build_ms, self.threads
        );
        if let Some(sp) = self.speedup() {
            s.push_str(&format!(", {sp:.2}x vs serial"));
        }
        s.push(')');
        s
    }

    /// JSON encoding for the wire.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("bfs_ms".to_string(), Json::Num(self.bfs_ms));
        m.insert("rcm_ms".to_string(), Json::Num(self.rcm_ms));
        m.insert("build_ms".to_string(), Json::Num(self.build_ms));
        m.insert("serial_ms".to_string(), Json::Num(self.serial_ms));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        Json::Obj(m)
    }

    /// Inverse of [`PrepareTimings::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(PrepareTimings {
            bfs_ms: j.req("bfs_ms")?.as_f64()?,
            rcm_ms: j.req("rcm_ms")?.as_f64()?,
            build_ms: j.req("build_ms")?.as_f64()?,
            serial_ms: j.req("serial_ms")?.as_f64()?,
            threads: j.req("threads")?.as_usize()?,
        })
    }
}

/// Instrumentation emitted by every reordering run.
///
/// (`PartialEq` only: the embedded [`PrepareTimings`] carry `f64`
/// wall-clock measurements.)
#[derive(Debug, Clone, PartialEq)]
pub struct ReorderReport {
    /// The policy the caller requested.
    pub requested: ReorderPolicy,
    /// The strategy whose ordering was actually kept (for `Auto` this
    /// is the winning candidate — possibly `"natural"` when the gate
    /// declined to reorder).
    pub strategy: &'static str,
    /// Pattern-graph bandwidth before reordering.
    pub bw_before: usize,
    /// Pattern-graph bandwidth after reordering.
    pub bw_after: usize,
    /// Envelope/profile before reordering.
    pub profile_before: u64,
    /// Envelope/profile after reordering.
    pub profile_after: u64,
    /// Max level-structure height across components.
    pub height: usize,
    /// Max level-structure width across components.
    pub width: usize,
    /// Per-component stats (one entry per connected component, in
    /// discovery order — each occupies a contiguous index range).
    pub components: Vec<ComponentStats>,
    /// Candidate scores (`Auto`: every strategy it weighed; direct
    /// strategies: their single self-score).
    pub candidates: Vec<CandidateScore>,
    /// Per-stage prepare timings (wall clock, milliseconds).
    pub timings: PrepareTimings,
}

/// Intern a strategy name back to its `&'static str` spelling (the
/// report structs hold static names; a deserializer has only owned
/// text, so the known spellings are the bridge).
pub fn strategy_named(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name {
        "natural" => "natural",
        "rcm" => "rcm",
        "rcm-bicriteria" => "rcm-bicriteria",
        other => anyhow::bail!("unknown reorder strategy name '{other}'"),
    })
}

impl ComponentStats {
    /// JSON encoding for the wire.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("start".to_string(), Json::Num(self.start as f64));
        m.insert("size".to_string(), Json::Num(self.size as f64));
        m.insert("height".to_string(), Json::Num(self.height as f64));
        m.insert("width".to_string(), Json::Num(self.width as f64));
        m.insert("bw".to_string(), Json::Num(self.bw as f64));
        Json::Obj(m)
    }

    /// Inverse of [`ComponentStats::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(ComponentStats {
            start: j.req("start")?.as_usize()? as u32,
            size: j.req("size")?.as_usize()?,
            height: j.req("height")?.as_usize()?,
            width: j.req("width")?.as_usize()?,
            bw: j.req("bw")?.as_usize()?,
        })
    }
}

impl CandidateScore {
    /// JSON encoding for the wire.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("strategy".to_string(), Json::Str(self.strategy.to_string()));
        m.insert("bandwidth".to_string(), Json::Num(self.bandwidth as f64));
        m.insert("profile".to_string(), Json::Num(self.profile as f64));
        m.insert("chosen".to_string(), Json::Bool(self.chosen));
        Json::Obj(m)
    }

    /// Inverse of [`CandidateScore::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(CandidateScore {
            strategy: strategy_named(j.req("strategy")?.as_str()?)?,
            bandwidth: j.req("bandwidth")?.as_usize()?,
            profile: j.req("profile")?.as_usize()? as u64,
            chosen: matches!(j.req("chosen")?, crate::util::json::Json::Bool(true)),
        })
    }
}

impl ReorderReport {
    /// One-line human summary for CLI/serve output.
    pub fn summary(&self) -> String {
        format!(
            "reorder {} (requested {}): bw {} -> {}, profile {} -> {}, {} component(s)",
            self.strategy,
            self.requested,
            self.bw_before,
            self.bw_after,
            self.profile_before,
            self.profile_after,
            self.components.len()
        )
    }

    /// JSON encoding for the wire (`Client::describe` now crosses
    /// process boundaries, and the report's evidence must arrive
    /// intact).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("requested".to_string(), Json::Str(self.requested.name().to_string()));
        m.insert("strategy".to_string(), Json::Str(self.strategy.to_string()));
        m.insert("bw_before".to_string(), Json::Num(self.bw_before as f64));
        m.insert("bw_after".to_string(), Json::Num(self.bw_after as f64));
        m.insert("profile_before".to_string(), Json::Num(self.profile_before as f64));
        m.insert("profile_after".to_string(), Json::Num(self.profile_after as f64));
        m.insert("height".to_string(), Json::Num(self.height as f64));
        m.insert("width".to_string(), Json::Num(self.width as f64));
        m.insert(
            "components".to_string(),
            Json::Arr(self.components.iter().map(|c| c.to_json()).collect()),
        );
        m.insert(
            "candidates".to_string(),
            Json::Arr(self.candidates.iter().map(|c| c.to_json()).collect()),
        );
        m.insert("timings".to_string(), self.timings.to_json());
        Json::Obj(m)
    }

    /// Inverse of [`ReorderReport::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(ReorderReport {
            requested: j.req("requested")?.as_str()?.parse()?,
            strategy: strategy_named(j.req("strategy")?.as_str()?)?,
            bw_before: j.req("bw_before")?.as_usize()?,
            bw_after: j.req("bw_after")?.as_usize()?,
            profile_before: j.req("profile_before")?.as_usize()? as u64,
            profile_after: j.req("profile_after")?.as_usize()? as u64,
            height: j.req("height")?.as_usize()?,
            width: j.req("width")?.as_usize()?,
            components: j
                .req("components")?
                .as_arr()?
                .iter()
                .map(ComponentStats::from_json)
                .collect::<anyhow::Result<_>>()?,
            candidates: j
                .req("candidates")?
                .as_arr()?
                .iter()
                .map(CandidateScore::from_json)
                .collect::<anyhow::Result<_>>()?,
            timings: PrepareTimings::from_json(j.req("timings")?)?,
        })
    }
}

/// The outcome of one strategy run: the permutation plus the stats the
/// report is assembled from.
#[derive(Debug, Clone)]
pub struct ReorderOutcome {
    /// Strategy whose ordering this is (for [`Auto`]: the winner).
    pub strategy: &'static str,
    /// Total permutation, `perm[old] = new`.
    pub perm: Vec<u32>,
    /// Per-component stats in discovery order.
    pub components: Vec<ComponentStats>,
    /// Candidate scores ([`Auto`] only; empty for direct strategies).
    pub candidates: Vec<CandidateScore>,
    /// Per-stage timings of this run (`build_ms` stamped later by the
    /// registry build path).
    pub timings: PrepareTimings,
}

/// A pluggable reordering strategy over the pattern graph.
///
/// Implementations must return a **total** permutation (`perm[old] =
/// new`, every position hit exactly once) and reorder per connected
/// component: each component's vertices map to a contiguous index
/// range, so its ordering is independent of every other component's.
/// The permutation must also be independent of the pool width —
/// parallelism is an execution detail, never a different ordering.
pub trait ReorderStrategy {
    /// Strategy name (report/CLI spelling).
    fn name(&self) -> &'static str;

    /// Compute the permutation and its per-component stats on the given
    /// prepare pool.
    fn reorder_with(&self, g: &Adjacency, pool: &PrepPool) -> ReorderOutcome;

    /// Single-threaded [`Self::reorder_with`].
    fn reorder(&self, g: &Adjacency) -> ReorderOutcome {
        self.reorder_with(g, &PrepPool::serial())
    }
}

/// Identity ordering (decline to reorder). Component stats are still
/// measured so `Auto`'s report shows what the input looked like.
#[derive(Debug, Clone, Copy, Default)]
pub struct Natural;

/// Classic per-component RCM: George-Liu pseudo-peripheral start, CM
/// visit in ascending-degree order, reversal within the component.
#[derive(Debug, Clone, Copy, Default)]
pub struct Rcm;

/// RCM with RCM++-style bi-criteria start selection: candidate roots
/// are scored by level-structure height *and* width instead of the
/// height-only George-Liu iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct RcmBiCriteria;

/// Measured strategy selection with a decline gate.
///
/// Runs [`Natural`], [`Rcm`] and [`RcmBiCriteria`], scores each
/// candidate by `(bandwidth, profile)`, and keeps the best reordering
/// **only** when its bandwidth beats the natural order by more than
/// `min_gain` (a fraction: `0.0` = accept any strict improvement,
/// `0.25` = require a 25% bandwidth reduction). Otherwise the natural
/// order is kept — reordering is not free, and on already-banded inputs
/// it buys nothing (Asudeh et al.).
#[derive(Debug, Clone, Copy, Default)]
pub struct Auto {
    /// Required fractional bandwidth improvement over natural, in
    /// `[0, 1)` (the `0.0` default accepts any strict improvement).
    pub min_gain: f64,
}

impl ReorderStrategy for Natural {
    fn name(&self) -> &'static str {
        "natural"
    }

    fn reorder_with(&self, g: &Adjacency, pool: &PrepPool) -> ReorderOutcome {
        let t0 = Instant::now();
        let n = g.n;
        let perm: Vec<u32> = (0..n as u32).collect();
        let mut components = Vec::new();
        let mut seen = vec![false; n];
        // shared BFS buffers: the whole scan is O(n + m) regardless of
        // the component count (no per-component allocations)
        let mut frontier: Vec<u32> = Vec::new();
        let mut next: Vec<u32> = Vec::new();
        for s in 0..n {
            if seen[s] {
                continue;
            }
            // one BFS per component measures its level structure
            // (rooted at the smallest vertex), size, and natural-order
            // bandwidth in a single pass
            frontier.clear();
            frontier.push(s as u32);
            seen[s] = true;
            let (mut size, mut bw, mut height, mut width) = (0usize, 0usize, 0usize, 0usize);
            loop {
                width = width.max(frontier.len());
                size += frontier.len();
                next.clear();
                for &v in &frontier {
                    for &w in g.neighbors(v as usize) {
                        bw = bw.max((v as usize).abs_diff(w as usize));
                        if !seen[w as usize] {
                            seen[w as usize] = true;
                            next.push(w);
                        }
                    }
                }
                if next.is_empty() {
                    break;
                }
                std::mem::swap(&mut frontier, &mut next);
                height += 1;
            }
            components.push(ComponentStats { start: s as u32, size, height, width, bw });
        }
        // the single measurement scan is the "BFS" stage of this
        // strategy; it has no CM visit to time
        let timings = PrepareTimings {
            bfs_ms: t0.elapsed().as_secs_f64() * 1e3,
            threads: pool.threads(),
            ..PrepareTimings::default()
        };
        ReorderOutcome { strategy: self.name(), perm, components, candidates: Vec::new(), timings }
    }
}

/// Shared per-component CM engine: discover components in vertex order,
/// let `pick` choose each component's start node (returning the level
/// structure it judged the start by), run the ascending-degree CM
/// visit, and reverse **within the component** — component `c` occupies
/// the contiguous range its discovery order assigns, so each block's
/// ordering is exactly the RCM of that component in isolation.
///
/// `pick` receives the pool so its peripheral-search BFS sweeps run
/// level-parallel; the CM visit runs on the same pool. `pick` time is
/// booked as `bfs_ms`, the visit + reversal as `rcm_ms`.
/// `pub(crate)` so the planner's `Auto` scorer can inject pick closures
/// that share one cached start-level structure across candidates.
pub(crate) fn rcm_per_component_with(
    g: &Adjacency,
    name: &'static str,
    pick: &dyn Fn(&Adjacency, u32) -> (u32, LevelStructure),
    pool: &PrepPool,
) -> ReorderOutcome {
    let n = g.n;
    let mut perm = vec![0u32; n];
    let mut visited = vec![false; n];
    let mut components = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    let mut base = 0usize;
    let (mut bfs_s, mut rcm_s) = (0.0f64, 0.0f64);
    for s in 0..n {
        if visited[s] {
            continue;
        }
        let t0 = Instant::now();
        let (root, ls) = pick(g, s as u32);
        bfs_s += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        order.clear();
        // the one shared CM engine (rcm::cm_visit_component_with)
        // expands the component's visit order — same rule and output as
        // classic cm_order for every pool width
        crate::graph::rcm::cm_visit_component_with(g, root, &mut visited, &mut order, pool);
        // RCM: reverse the CM visit within the component's range
        for (i, &old) in order.iter().rev().enumerate() {
            perm[old as usize] = (base + i) as u32;
        }
        let mut bw = 0usize;
        for &v in &order {
            let pv = perm[v as usize] as i64;
            for &w in g.neighbors(v as usize) {
                bw = bw.max((pv - perm[w as usize] as i64).unsigned_abs() as usize);
            }
        }
        rcm_s += t1.elapsed().as_secs_f64();
        components.push(ComponentStats {
            start: root,
            size: order.len(),
            height: ls.height(),
            width: ls.width(),
            bw,
        });
        base += order.len();
    }
    let timings = PrepareTimings {
        bfs_ms: bfs_s * 1e3,
        rcm_ms: rcm_s * 1e3,
        threads: pool.threads(),
        ..PrepareTimings::default()
    };
    ReorderOutcome { strategy: name, perm, components, candidates: Vec::new(), timings }
}

impl ReorderStrategy for Rcm {
    fn name(&self) -> &'static str {
        "rcm"
    }

    fn reorder_with(&self, g: &Adjacency, pool: &PrepPool) -> ReorderOutcome {
        rcm_per_component_with(
            g,
            self.name(),
            &|g, s| pseudo_peripheral_ls_from(g, level_structure_with(g, s, pool), pool),
            pool,
        )
    }
}

impl ReorderStrategy for RcmBiCriteria {
    fn name(&self) -> &'static str {
        "rcm-bicriteria"
    }

    fn reorder_with(&self, g: &Adjacency, pool: &PrepPool) -> ReorderOutcome {
        rcm_per_component_with(
            g,
            self.name(),
            &|g, s| bi_criteria_start_from(g, level_structure_with(g, s, pool), pool),
            pool,
        )
    }
}

impl ReorderStrategy for Auto {
    fn name(&self) -> &'static str {
        "auto"
    }

    fn reorder_with(&self, g: &Adjacency, pool: &PrepPool) -> ReorderOutcome {
        // The candidate-scoring loop lives with the other plan-axis
        // scorers in the planner; this strategy is the thin policy
        // adapter the registry path keeps using.
        crate::coordinator::planner::score_reorder_candidates_with(g, self.min_gain, pool)
    }
}

/// Construct the strategy a [`ReorderPolicy`] names. `min_gain` only
/// affects [`ReorderPolicy::Auto`].
pub fn strategy_for(policy: ReorderPolicy, min_gain: f64) -> Box<dyn ReorderStrategy> {
    match policy {
        ReorderPolicy::Auto => Box::new(Auto { min_gain }),
        ReorderPolicy::Rcm => Box::new(Rcm),
        ReorderPolicy::RcmBiCriteria => Box::new(RcmBiCriteria),
        ReorderPolicy::Natural => Box::new(Natural),
    }
}

/// Run the policy's strategy and assemble the full [`ReorderReport`]
/// (single-threaded; see [`reorder_with_report_with`]).
pub fn reorder_with_report(
    g: &Adjacency,
    policy: ReorderPolicy,
    min_gain: f64,
) -> (Vec<u32>, ReorderReport) {
    reorder_with_report_with(g, policy, min_gain, &PrepPool::serial())
}

/// Run the policy's strategy on a prepare pool and assemble the full
/// [`ReorderReport`]. The permutation is identical for every pool
/// width; only the recorded timings differ.
pub fn reorder_with_report_with(
    g: &Adjacency,
    policy: ReorderPolicy,
    min_gain: f64,
    pool: &PrepPool,
) -> (Vec<u32>, ReorderReport) {
    let out = strategy_for(policy, min_gain).reorder_with(g, pool);
    // Auto already measured every candidate (natural included), so its
    // scores are reused verbatim; only the direct strategies pay the
    // before/after measurement passes here.
    let (bw_before, profile_before, bw_after, profile_after, candidates) =
        if out.candidates.is_empty() {
            let bw_after = bandwidth_under(g, &out.perm);
            let profile_after = profile_under(g, &out.perm);
            let (bw_before, profile_before) = if out.strategy == "natural" {
                // identity ordering: before == after by definition
                (bw_after, profile_after)
            } else {
                let id: Vec<u32> = (0..g.n as u32).collect();
                (bandwidth_under(g, &id), profile_under(g, &id))
            };
            let self_score = vec![CandidateScore {
                strategy: out.strategy,
                bandwidth: bw_after,
                profile: profile_after,
                chosen: true,
            }];
            (bw_before, profile_before, bw_after, profile_after, self_score)
        } else {
            let natural = out
                .candidates
                .iter()
                .find(|c| c.strategy == "natural")
                .expect("auto always scores the natural order");
            let chosen = out
                .candidates
                .iter()
                .find(|c| c.chosen)
                .expect("auto always keeps exactly one candidate");
            let scores = (natural.bandwidth, natural.profile, chosen.bandwidth, chosen.profile);
            (scores.0, scores.1, scores.2, scores.3, out.candidates)
        };
    let report = ReorderReport {
        requested: policy,
        strategy: out.strategy,
        bw_before,
        bw_after,
        profile_before,
        profile_after,
        height: out.components.iter().map(|c| c.height).max().unwrap_or(0),
        width: out.components.iter().map(|c| c.width).max().unwrap_or(0),
        components: out.components,
        candidates,
        timings: out.timings,
    };
    (out.perm, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SmallRng;

    fn assert_total(perm: &[u32], n: usize) {
        assert_eq!(perm.len(), n);
        let mut seen = vec![false; n];
        for &p in perm {
            assert!(!seen[p as usize], "position {p} assigned twice");
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    fn scrambled_grid(seed: u64) -> Adjacency {
        let mut rng = SmallRng::seed_from_u64(seed);
        let edges = crate::sparse::gen::grid2d_pattern(12, 12, 1, 1);
        let scrambled = crate::sparse::gen::scramble(&edges, 144, &mut rng);
        Adjacency::from_lower_edges(144, &scrambled)
    }

    #[test]
    fn every_strategy_returns_a_total_permutation() {
        let g = Adjacency::from_lower_edges(7, &[(1, 0), (2, 1), (4, 3), (5, 4)]);
        for policy in [
            ReorderPolicy::Natural,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Auto,
        ] {
            let (perm, report) = reorder_with_report(&g, policy, 0.0);
            assert_total(&perm, 7);
            assert_eq!(report.components.len(), 3, "{policy}");
            assert_eq!(report.components.iter().map(|c| c.size).sum::<usize>(), 7);
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            ReorderPolicy::Auto,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Natural,
        ] {
            assert_eq!(p.to_string().parse::<ReorderPolicy>().unwrap(), p);
        }
        assert!("symrcm".parse::<ReorderPolicy>().is_err());
        assert_eq!(ReorderPolicy::default(), ReorderPolicy::Auto);
    }

    #[test]
    fn rcm_strategy_matches_classic_rcm_on_connected_graphs() {
        // one component: per-component reversal == the classic global
        // reversal, so the strategy reproduces `graph::rcm::rcm` exactly
        let g = scrambled_grid(11);
        assert_eq!(Rcm.reorder(&g).perm, crate::graph::rcm::rcm(&g));
    }

    #[test]
    fn bicriteria_never_loses_to_rcm_on_bandwidth_here() {
        // not a theorem — but on these fixtures the wider candidate
        // pool must not pick something worse than what it also sees
        for seed in [3u64, 7, 11, 19] {
            let g = scrambled_grid(seed);
            let bw_rcm = bandwidth_under(&g, &Rcm.reorder(&g).perm);
            let bw_bi = bandwidth_under(&g, &RcmBiCriteria.reorder(&g).perm);
            assert!(bw_bi <= bw_rcm + bw_rcm / 4, "seed {seed}: {bw_bi} vs {bw_rcm}");
        }
    }

    #[test]
    fn auto_declines_on_already_banded_input() {
        // path graph in natural order: bandwidth 1 is optimal, so no
        // reordering can clear any threshold — Auto must keep identity
        let edges = [(1, 0), (2, 1), (3, 2), (4, 3), (5, 4), (6, 5), (7, 6), (8, 7)];
        let g = Adjacency::from_lower_edges(9, &edges);
        for min_gain in [0.0, 0.25] {
            let (perm, report) = reorder_with_report(&g, ReorderPolicy::Auto, min_gain);
            assert_eq!(report.strategy, "natural", "min_gain {min_gain}");
            assert_eq!(perm, (0..9).collect::<Vec<u32>>());
            assert_eq!(report.bw_after, report.bw_before);
            let natural = report.candidates.iter().find(|c| c.strategy == "natural").unwrap();
            assert!(natural.chosen);
        }
    }

    #[test]
    fn auto_threshold_gates_marginal_improvements() {
        let g = scrambled_grid(4);
        // an absurd threshold declines even a huge win...
        let (perm, report) = reorder_with_report(&g, ReorderPolicy::Auto, 0.999);
        assert_eq!(report.strategy, "natural");
        assert_eq!(perm, (0..144).collect::<Vec<u32>>());
        // ...while the default accepts it
        let (_, report) = reorder_with_report(&g, ReorderPolicy::Auto, 0.0);
        assert_ne!(report.strategy, "natural");
        assert!(report.bw_after < report.bw_before);
        // every candidate was scored, exactly one chosen
        assert_eq!(report.candidates.len(), 3);
        assert_eq!(report.candidates.iter().filter(|c| c.chosen).count(), 1);
    }

    #[test]
    fn auto_never_increases_bandwidth_over_natural() {
        for seed in 0..8u64 {
            let mut rng = SmallRng::seed_from_u64(seed);
            let n = 30 + (seed as usize) * 17;
            let mut edges = crate::sparse::gen::random_banded_pattern(n, 3, 0.5, &mut rng);
            crate::sparse::gen::add_long_range(&mut edges, n, 0.05, &mut rng);
            let g = Adjacency::from_lower_edges(n, &edges);
            let id: Vec<u32> = (0..n as u32).collect();
            let (perm, report) = reorder_with_report(&g, ReorderPolicy::Auto, 0.0);
            assert!(bandwidth_under(&g, &perm) <= bandwidth_under(&g, &id), "seed {seed}");
            assert_eq!(report.bw_after, bandwidth_under(&g, &perm));
        }
    }

    #[test]
    fn components_are_reordered_independently() {
        // two banded components glued into one graph: the combined
        // permutation must restrict to exactly the permutation each
        // component gets in isolation (offset by the first block's size)
        let a_edges = [(1u32, 0u32), (2, 0), (3, 1), (4, 2), (4, 3)];
        let b_edges = [(1u32, 0u32), (2, 1), (3, 1), (3, 2)];
        let (na, nb) = (5usize, 4usize);
        let mut edges: Vec<(u32, u32)> = a_edges.to_vec();
        edges.extend(b_edges.iter().map(|&(i, j)| (i + na as u32, j + na as u32)));
        let g = Adjacency::from_lower_edges(na + nb, &edges);
        let ga = Adjacency::from_lower_edges(na, &a_edges);
        let gb = Adjacency::from_lower_edges(nb, &b_edges);
        for policy in [ReorderPolicy::Rcm, ReorderPolicy::RcmBiCriteria, ReorderPolicy::Auto] {
            let (perm, report) = reorder_with_report(&g, policy, 0.0);
            let (pa, _) = reorder_with_report(&ga, policy, 0.0);
            let (pb, _) = reorder_with_report(&gb, policy, 0.0);
            for v in 0..na {
                assert_eq!(perm[v], pa[v], "{policy} component A vertex {v}");
            }
            for v in 0..nb {
                assert_eq!(perm[na + v], na as u32 + pb[v], "{policy} component B vertex {v}");
            }
            assert_eq!(report.components.len(), 2);
            assert_eq!(report.components[0].size, na);
            assert_eq!(report.components[1].size, nb);
        }
    }

    #[test]
    fn report_measures_before_and_after() {
        let g = scrambled_grid(2);
        let (perm, report) = reorder_with_report(&g, ReorderPolicy::Rcm, 0.0);
        assert_eq!(report.requested, ReorderPolicy::Rcm);
        assert_eq!(report.strategy, "rcm");
        assert_eq!(report.bw_after, bandwidth_under(&g, &perm));
        assert_eq!(report.profile_after, profile_under(&g, &perm));
        assert!(report.profile_after <= report.profile_before);
        assert!(report.height >= 1 && report.width >= 1);
        assert!(report.summary().contains("rcm"));
        // direct strategies still expose their self-score
        assert_eq!(report.candidates.len(), 1);
        assert!(report.candidates[0].chosen);
    }

    #[test]
    fn report_round_trips_through_json() {
        // a multi-component Auto run exercises every field: component
        // stats, full candidate table, and the interned strategy names
        let g = Adjacency::from_lower_edges(7, &[(1, 0), (2, 1), (4, 3), (5, 4)]);
        let (_, report) = reorder_with_report(&g, ReorderPolicy::Auto, 0.0);
        let text = report.to_json().dump();
        let back =
            ReorderReport::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, report);
        assert!(strategy_named("symrcm").is_err());
    }
}
