//! Greedy row coloring for the conflict-free SSpMV baseline
//! (Elafrou, Goumas & Koziris, SC'19 — reference [3] of the paper).
//!
//! In symmetric/skew SSS SpMV, processing row `i` writes `y[i]` *and*
//! `y[j]` for every stored `(i, j)`. Two rows conflict if their write
//! sets intersect — equivalently, rows sharing a column (or one row's
//! index appearing as the other's column) race on `y`. Coloring the
//! conflict graph yields independent row sets ("phases") that can run in
//! parallel with a barrier between phases; more phases = more
//! synchronization = the scaling penalty the paper beats.

use crate::sparse::Sss;

/// Result of a row coloring.
#[derive(Debug, Clone)]
pub struct RowColoring {
    /// Color per row.
    pub color: Vec<u32>,
    /// Number of colors (phases).
    pub num_colors: usize,
    /// Rows grouped by color.
    pub classes: Vec<Vec<u32>>,
}

/// Greedy first-fit coloring of the SSS row-conflict graph.
///
/// Write set of row `i`: `{i} ∪ cols(i)`. Rows `a != b` conflict iff
/// `W(a) ∩ W(b) != ∅`. We track, per output index `y[k]`, the colors of
/// rows already writing `k`; a row takes the smallest color not used by
/// any writer of any of its write-set indices. Complexity
/// O(Σ_i |W(i)| * avg_writers) — fine for band matrices where each
/// column is written by at most `bandwidth` rows.
pub fn color_rows(s: &Sss) -> RowColoring {
    let n = s.n;
    // writers[k] = list of (row, color) already writing y[k]
    let mut writers: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
    let mut color = vec![u32::MAX; n];
    let mut forbidden: Vec<bool> = Vec::new();
    let mut num_colors = 0usize;

    for i in 0..n {
        forbidden.clear();
        forbidden.resize(num_colors + 1, false);
        let mark = |c: u32, forbidden: &mut Vec<bool>| {
            let c = c as usize;
            if c < forbidden.len() {
                forbidden[c] = true;
            }
        };
        for &(_, c) in &writers[i] {
            mark(c, &mut forbidden);
        }
        for (j, _) in s.row(i) {
            for &(_, c) in &writers[j as usize] {
                mark(c, &mut forbidden);
            }
        }
        let c = forbidden.iter().position(|&f| !f).unwrap() as u32;
        color[i] = c;
        num_colors = num_colors.max(c as usize + 1);
        writers[i].push((i as u32, c));
        for (j, _) in s.row(i) {
            writers[j as usize].push((i as u32, c));
        }
    }

    let mut classes = vec![Vec::new(); num_colors];
    for (i, &c) in color.iter().enumerate() {
        classes[c as usize].push(i as u32);
    }
    RowColoring { color, num_colors, classes }
}

/// Verify the coloring: no two same-colored rows share a write index.
pub fn verify_coloring(s: &Sss, coloring: &RowColoring) -> bool {
    let n = s.n;
    // per color, per output index: written?
    for class in &coloring.classes {
        let mut written = vec![false; n];
        for &i in class {
            let i = i as usize;
            if written[i] {
                return false;
            }
            written[i] = true;
            for (j, _) in s.row(i) {
                if written[j as usize] {
                    return false;
                }
                written[j as usize] = true;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{convert, gen, Symmetry};

    fn band_matrix(n: usize, seed: u64) -> Sss {
        let coo = gen::small_test_matrix(n, seed, 1.0);
        convert::coo_to_sss(&coo, Symmetry::Skew).unwrap()
    }

    #[test]
    fn coloring_is_valid() {
        let s = band_matrix(60, 2);
        let c = color_rows(&s);
        assert!(verify_coloring(&s, &c));
        assert_eq!(c.classes.iter().map(Vec::len).sum::<usize>(), 60);
    }

    #[test]
    fn diagonal_matrix_needs_one_color() {
        let mut coo = crate::sparse::Coo::new(5);
        for i in 0..5 {
            coo.push(i, i, 2.0);
        }
        let s = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let c = color_rows(&s);
        assert_eq!(c.num_colors, 1);
    }

    #[test]
    fn tridiagonal_needs_at_least_two_colors() {
        let mut coo = crate::sparse::Coo::new(6);
        for i in 0..6u32 {
            coo.push(i, i, 1.0);
        }
        for i in 1..6u32 {
            coo.push(i, i - 1, 1.0);
            coo.push(i - 1, i, -1.0);
        }
        let s = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let c = color_rows(&s);
        assert!(c.num_colors >= 2);
        assert!(verify_coloring(&s, &c));
    }

    #[test]
    fn denser_matrix_needs_more_colors() {
        let sparse = band_matrix(80, 3);
        let mut rng = crate::util::SmallRng::seed_from_u64(9);
        let mut edges = gen::random_banded_pattern(80, 10, 0.9, &mut rng);
        gen::add_long_range(&mut edges, 80, 0.2, &mut rng);
        let dense_coo = crate::sparse::skew::coo_from_pattern(80, &edges, 1.0, &mut rng);
        let dense = convert::coo_to_sss(&dense_coo, Symmetry::Skew).unwrap();
        let cs = color_rows(&sparse);
        let cd = color_rows(&dense);
        assert!(verify_coloring(&dense, &cd));
        assert!(cd.num_colors >= cs.num_colors);
    }
}
