//! Symmetric adjacency structure (the pattern graph of a sparse matrix).

use crate::sparse::{Coo, Sss};

/// Undirected graph in CSR adjacency form.
///
/// Built from a matrix pattern: vertex per row, edge `{i, j}` per
/// off-diagonal nonzero (symmetrized). Neighbour lists are sorted.
#[derive(Debug, Clone, PartialEq)]
pub struct Adjacency {
    /// Number of vertices.
    pub n: usize,
    /// Offsets into `neighbors`, length `n+1`.
    pub offsets: Vec<usize>,
    /// Concatenated sorted neighbour lists.
    pub neighbors: Vec<u32>,
}

impl Adjacency {
    /// Build from lower-triangle edges `(i, j)`, `i > j` (deduped or not).
    pub fn from_lower_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut deg = vec![0usize; n + 1];
        for &(i, j) in edges {
            deg[i as usize + 1] += 1;
            deg[j as usize + 1] += 1;
        }
        for v in 0..n {
            deg[v + 1] += deg[v];
        }
        let offsets = deg.clone();
        let mut neighbors = vec![0u32; edges.len() * 2];
        let mut next = deg;
        for &(i, j) in edges {
            neighbors[next[i as usize]] = j;
            next[i as usize] += 1;
            neighbors[next[j as usize]] = i;
            next[j as usize] += 1;
        }
        let mut g = Self { n, offsets, neighbors };
        g.sort_and_dedup();
        g
    }

    /// Build from a full COO matrix's off-diagonal pattern.
    pub fn from_coo(coo: &Coo) -> Self {
        let edges: Vec<(u32, u32)> = coo
            .rows
            .iter()
            .zip(&coo.cols)
            .filter(|(&i, &j)| i > j)
            .map(|(&i, &j)| (i, j))
            .collect();
        Self::from_lower_edges(coo.n, &edges)
    }

    /// Build from an SSS matrix (its stored lower triangle *is* the edge list).
    pub fn from_sss(s: &Sss) -> Self {
        let mut edges = Vec::with_capacity(s.nnz_lower());
        for i in 0..s.n {
            for (j, _) in s.row(i) {
                edges.push((i as u32, j));
            }
        }
        Self::from_lower_edges(s.n, &edges)
    }

    /// Neighbours of `v` (sorted).
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Number of (undirected) edges.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    fn sort_and_dedup(&mut self) {
        let mut new_neighbors = Vec::with_capacity(self.neighbors.len());
        let mut new_offsets = vec![0usize; self.n + 1];
        for v in 0..self.n {
            let mut lst: Vec<u32> = self.neighbors(v).to_vec();
            lst.sort_unstable();
            lst.dedup();
            new_neighbors.extend_from_slice(&lst);
            new_offsets[v + 1] = new_neighbors.len();
        }
        self.offsets = new_offsets;
        self.neighbors = new_neighbors;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_graph() {
        let g = Adjacency::from_lower_edges(4, &[(1, 0), (2, 1), (3, 2)]);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn duplicate_edges_merged() {
        let g = Adjacency::from_lower_edges(3, &[(1, 0), (1, 0), (2, 0)]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn from_coo_ignores_diagonal_and_upper_dups() {
        let mut c = Coo::new(3);
        c.push(0, 0, 1.0);
        c.push(2, 1, 5.0);
        c.push(1, 2, -5.0);
        let g = Adjacency::from_coo(&c);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(2), &[1]);
    }
}
