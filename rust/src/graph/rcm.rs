//! Reverse Cuthill-McKee reordering (the paper's preprocessing core).
//!
//! Classic CM: BFS from a pseudo-peripheral vertex, visiting each
//! frontier in ascending-degree order; RCM reverses the resulting
//! ordering, which provably never increases (and usually shrinks) the
//! envelope. Runs in Θ(NNZ) plus the per-vertex neighbour sorts
//! (O(E log d_max)), matching the paper's Θ(NNZ) claim for preprocessing.
//!
//! Disconnected graphs are handled component-by-component (each gets its
//! own pseudo-peripheral start), so the permutation is always total.

use crate::graph::peripheral::pseudo_peripheral_with;
use crate::graph::Adjacency;
use crate::util::pool::PrepPool;

/// CM level width below which parallel child collection is not worth a
/// spawn (mirrors the BFS frontier floor).
const MIN_PAR_LEVEL: usize = 512;

/// Compute the RCM permutation.
///
/// Returns `perm` with `perm[old] = new`: vertex `old` moves to position
/// `new` in the reordered matrix (the convention
/// [`crate::sparse::Coo::permute_symmetric`] expects).
pub fn rcm(g: &Adjacency) -> Vec<u32> {
    rcm_with(g, &PrepPool::serial())
}

/// [`rcm`] on a prepare pool: peripheral-search BFS and per-level child
/// sorting run across the workers, producing a permutation **bit-for-bit
/// identical** to the serial one for every thread count (see
/// [`cm_visit_component_with`] for the determinism argument).
pub fn rcm_with(g: &Adjacency, pool: &PrepPool) -> Vec<u32> {
    let order = cm_order_with(g, pool);
    // CM order lists old ids in visit sequence; RCM reverses it.
    let n = g.n;
    let mut perm = vec![0u32; n];
    for (pos, &old) in order.iter().rev().enumerate() {
        perm[old as usize] = pos as u32;
    }
    perm
}

/// The forward Cuthill-McKee visit order (old vertex ids in sequence).
pub fn cm_order(g: &Adjacency) -> Vec<u32> {
    cm_order_with(g, &PrepPool::serial())
}

/// [`cm_order`] on a prepare pool.
pub fn cm_order_with(g: &Adjacency, pool: &PrepPool) -> Vec<u32> {
    let n = g.n;
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);

    for s in 0..n {
        if visited[s] {
            continue;
        }
        let root = pseudo_peripheral_with(g, s as u32, pool);
        cm_visit_component_with(g, root, &mut visited, &mut order, pool);
    }
    order
}

/// Expand one component's CM visit order from `root`, appending to
/// `order`: BFS that visits each dequeued vertex's unvisited
/// neighbours in ascending degree order (ties broken by vertex id for
/// determinism). The single CM engine — shared by [`cm_order`] and the
/// per-component strategy runner in [`crate::graph::reorder`], so the
/// visit rule and tie-break can never drift apart. `scratch` is a
/// reusable neighbour buffer.
pub(crate) fn cm_visit_component(
    g: &Adjacency,
    root: u32,
    visited: &mut [bool],
    order: &mut Vec<u32>,
    scratch: &mut Vec<u32>,
) {
    visited[root as usize] = true;
    let mut head = order.len();
    order.push(root);
    while head < order.len() {
        let v = order[head];
        head += 1;
        scratch.clear();
        for &w in g.neighbors(v as usize) {
            if !visited[w as usize] {
                visited[w as usize] = true;
                scratch.push(w);
            }
        }
        scratch.sort_unstable_by_key(|&w| (g.degree(w as usize), w));
        order.extend_from_slice(scratch);
    }
}

/// Level-synchronous parallel CM component visit, identical in output
/// to [`cm_visit_component`] for every thread count.
///
/// The serial FIFO processes the queue level by level: every vertex
/// appended while the head is inside level `d`'s window belongs to
/// level `d+1`, so expanding the whole window `[lo, hi)` at once is the
/// same computation. Within a window, workers only **read** the
/// visited set (a snapshot taken at window start) and collect each
/// parent's not-yet-visited neighbours, sorting each parent's run by
/// `(degree, id)` in place; the serial merge then walks the runs in
/// window order and claims first occurrences. A child already claimed
/// by an earlier parent in the window appears in a later parent's
/// sorted run too, but deleting claimed entries from a sorted superset
/// preserves the relative order of the rest — exactly the serial
/// parent's sorted scratch — so the appended order is bit-for-bit the
/// serial one.
pub(crate) fn cm_visit_component_with(
    g: &Adjacency,
    root: u32,
    visited: &mut [bool],
    order: &mut Vec<u32>,
    pool: &PrepPool,
) {
    visited[root as usize] = true;
    let mut lo = order.len();
    order.push(root);
    let mut scratch: Vec<u32> = Vec::new();
    while lo < order.len() {
        let hi = order.len();
        let width = hi - lo;
        if pool.threads() == 1 || width < MIN_PAR_LEVEL {
            // serial window expansion: the classic per-parent claim
            for idx in lo..hi {
                let v = order[idx];
                scratch.clear();
                for &w in g.neighbors(v as usize) {
                    if !visited[w as usize] {
                        visited[w as usize] = true;
                        scratch.push(w);
                    }
                }
                scratch.sort_unstable_by_key(|&w| (g.degree(w as usize), w));
                order.extend_from_slice(&scratch);
            }
        } else {
            let runs = {
                let window: &[u32] = &order[lo..hi];
                let seen: &[bool] = visited;
                pool.map_chunks(width, MIN_PAR_LEVEL / 4, |_, r| {
                    let mut buf = Vec::new();
                    for &v in &window[r] {
                        let start = buf.len();
                        for &w in g.neighbors(v as usize) {
                            if !seen[w as usize] {
                                buf.push(w);
                            }
                        }
                        buf[start..].sort_unstable_by_key(|&w| (g.degree(w as usize), w));
                    }
                    buf
                })
            };
            for run in runs {
                for w in run {
                    if !visited[w as usize] {
                        visited[w as usize] = true;
                        order.push(w);
                    }
                }
            }
        }
        lo = hi;
    }
}

/// Bandwidth of the graph under a permutation (`perm[old] = new`).
pub fn bandwidth_under(g: &Adjacency, perm: &[u32]) -> usize {
    let mut bw = 0usize;
    for v in 0..g.n {
        let pv = perm[v] as i64;
        for &w in g.neighbors(v) {
            bw = bw.max((pv - perm[w as usize] as i64).unsigned_abs() as usize);
        }
    }
    bw
}

/// Envelope/profile of the graph under a permutation.
pub fn profile_under(g: &Adjacency, perm: &[u32]) -> u64 {
    let mut prof = 0u64;
    for v in 0..g.n {
        let pv = perm[v];
        let min_nb = g
            .neighbors(v)
            .iter()
            .map(|&w| perm[w as usize])
            .filter(|&p| p < pv)
            .min()
            .unwrap_or(pv);
        prof += (pv - min_nb) as u64;
    }
    prof
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SmallRng;

    fn identity_bandwidth(g: &Adjacency) -> usize {
        let id: Vec<u32> = (0..g.n as u32).collect();
        bandwidth_under(g, &id)
    }

    #[test]
    fn perm_is_a_permutation() {
        let mut rng = SmallRng::seed_from_u64(3);
        let edges = crate::sparse::gen::random_banded_pattern(50, 3, 0.5, &mut rng);
        let edges = crate::sparse::gen::scramble(&edges, 50, &mut rng);
        let g = Adjacency::from_lower_edges(50, &edges);
        let perm = rcm(&g);
        let mut seen = vec![false; 50];
        for &p in &perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
    }

    #[test]
    fn rcm_reduces_bandwidth_of_scrambled_grid() {
        let mut rng = SmallRng::seed_from_u64(11);
        let edges = crate::sparse::gen::grid2d_pattern(12, 12, 1, 1);
        let scrambled = crate::sparse::gen::scramble(&edges, 144, &mut rng);
        let g = Adjacency::from_lower_edges(144, &scrambled);
        let before = identity_bandwidth(&g);
        let perm = rcm(&g);
        let after = bandwidth_under(&g, &perm);
        assert!(after < before / 2, "before={before}, after={after}");
        // grid bandwidth should be near the grid width
        assert!(after <= 30, "after={after}");
    }

    #[test]
    fn rcm_on_path_gives_bandwidth_one() {
        let g = Adjacency::from_lower_edges(8, &[(1, 0), (2, 1), (3, 2), (4, 3), (5, 4), (6, 5), (7, 6)]);
        let perm = rcm(&g);
        assert_eq!(bandwidth_under(&g, &perm), 1);
    }

    #[test]
    fn handles_disconnected_graphs() {
        let g = Adjacency::from_lower_edges(6, &[(1, 0), (3, 2), (5, 4)]);
        let perm = rcm(&g);
        let mut seen = vec![false; 6];
        for &p in &perm {
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(bandwidth_under(&g, &perm), 1);
    }

    #[test]
    fn parallel_rcm_is_bit_identical_on_wide_levels() {
        // hub-and-spoke with a shared leaf layer: CM levels of width
        // ~2000 push past the parallel threshold, and leaves reachable
        // from many same-level parents exercise the claimed-duplicate
        // filtering in the ordered run merge
        let mut rng = SmallRng::seed_from_u64(9);
        let mids = 2000u32;
        let leaves = 4000u32;
        let n = (1 + mids + leaves) as usize;
        let mut edges: Vec<(u32, u32)> = (0..mids).map(|i| (1 + i, 0)).collect();
        for i in 0..mids {
            for _ in 0..3 {
                let leaf = 1 + mids + rng.gen_range_usize(0, leaves as usize) as u32;
                edges.push((leaf, 1 + i));
            }
        }
        let g = Adjacency::from_lower_edges(n, &edges);
        let serial = rcm(&g);
        for t in [2usize, 4, 8] {
            assert_eq!(rcm_with(&g, &PrepPool::new(t)), serial, "threads={t}");
        }
    }

    #[test]
    fn profile_never_worse_than_identity_on_scrambled() {
        let mut rng = SmallRng::seed_from_u64(4);
        let edges = crate::sparse::gen::grid2d_pattern(10, 10, 1, 1);
        let scrambled = crate::sparse::gen::scramble(&edges, 100, &mut rng);
        let g = Adjacency::from_lower_edges(100, &scrambled);
        let id: Vec<u32> = (0..100).collect();
        let perm = rcm(&g);
        assert!(profile_under(&g, &perm) <= profile_under(&g, &id));
    }
}
