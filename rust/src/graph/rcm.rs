//! Reverse Cuthill-McKee reordering (the paper's preprocessing core).
//!
//! Classic CM: BFS from a pseudo-peripheral vertex, visiting each
//! frontier in ascending-degree order; RCM reverses the resulting
//! ordering, which provably never increases (and usually shrinks) the
//! envelope. Runs in Θ(NNZ) plus the per-vertex neighbour sorts
//! (O(E log d_max)), matching the paper's Θ(NNZ) claim for preprocessing.
//!
//! Disconnected graphs are handled component-by-component (each gets its
//! own pseudo-peripheral start), so the permutation is always total.

use crate::graph::peripheral::pseudo_peripheral;
use crate::graph::Adjacency;

/// Compute the RCM permutation.
///
/// Returns `perm` with `perm[old] = new`: vertex `old` moves to position
/// `new` in the reordered matrix (the convention
/// [`crate::sparse::Coo::permute_symmetric`] expects).
pub fn rcm(g: &Adjacency) -> Vec<u32> {
    let order = cm_order(g);
    // CM order lists old ids in visit sequence; RCM reverses it.
    let n = g.n;
    let mut perm = vec![0u32; n];
    for (pos, &old) in order.iter().rev().enumerate() {
        perm[old as usize] = pos as u32;
    }
    perm
}

/// The forward Cuthill-McKee visit order (old vertex ids in sequence).
pub fn cm_order(g: &Adjacency) -> Vec<u32> {
    let n = g.n;
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut scratch: Vec<u32> = Vec::new();

    for s in 0..n {
        if visited[s] {
            continue;
        }
        let root = pseudo_peripheral(g, s as u32);
        cm_visit_component(g, root, &mut visited, &mut order, &mut scratch);
    }
    order
}

/// Expand one component's CM visit order from `root`, appending to
/// `order`: BFS that visits each dequeued vertex's unvisited
/// neighbours in ascending degree order (ties broken by vertex id for
/// determinism). The single CM engine — shared by [`cm_order`] and the
/// per-component strategy runner in [`crate::graph::reorder`], so the
/// visit rule and tie-break can never drift apart. `scratch` is a
/// reusable neighbour buffer.
pub(crate) fn cm_visit_component(
    g: &Adjacency,
    root: u32,
    visited: &mut [bool],
    order: &mut Vec<u32>,
    scratch: &mut Vec<u32>,
) {
    visited[root as usize] = true;
    let mut head = order.len();
    order.push(root);
    while head < order.len() {
        let v = order[head];
        head += 1;
        scratch.clear();
        for &w in g.neighbors(v as usize) {
            if !visited[w as usize] {
                visited[w as usize] = true;
                scratch.push(w);
            }
        }
        scratch.sort_unstable_by_key(|&w| (g.degree(w as usize), w));
        order.extend_from_slice(scratch);
    }
}

/// Bandwidth of the graph under a permutation (`perm[old] = new`).
pub fn bandwidth_under(g: &Adjacency, perm: &[u32]) -> usize {
    let mut bw = 0usize;
    for v in 0..g.n {
        let pv = perm[v] as i64;
        for &w in g.neighbors(v) {
            bw = bw.max((pv - perm[w as usize] as i64).unsigned_abs() as usize);
        }
    }
    bw
}

/// Envelope/profile of the graph under a permutation.
pub fn profile_under(g: &Adjacency, perm: &[u32]) -> u64 {
    let mut prof = 0u64;
    for v in 0..g.n {
        let pv = perm[v];
        let min_nb = g
            .neighbors(v)
            .iter()
            .map(|&w| perm[w as usize])
            .filter(|&p| p < pv)
            .min()
            .unwrap_or(pv);
        prof += (pv - min_nb) as u64;
    }
    prof
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SmallRng;

    fn identity_bandwidth(g: &Adjacency) -> usize {
        let id: Vec<u32> = (0..g.n as u32).collect();
        bandwidth_under(g, &id)
    }

    #[test]
    fn perm_is_a_permutation() {
        let mut rng = SmallRng::seed_from_u64(3);
        let edges = crate::sparse::gen::random_banded_pattern(50, 3, 0.5, &mut rng);
        let edges = crate::sparse::gen::scramble(&edges, 50, &mut rng);
        let g = Adjacency::from_lower_edges(50, &edges);
        let perm = rcm(&g);
        let mut seen = vec![false; 50];
        for &p in &perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
    }

    #[test]
    fn rcm_reduces_bandwidth_of_scrambled_grid() {
        let mut rng = SmallRng::seed_from_u64(11);
        let edges = crate::sparse::gen::grid2d_pattern(12, 12, 1, 1);
        let scrambled = crate::sparse::gen::scramble(&edges, 144, &mut rng);
        let g = Adjacency::from_lower_edges(144, &scrambled);
        let before = identity_bandwidth(&g);
        let perm = rcm(&g);
        let after = bandwidth_under(&g, &perm);
        assert!(after < before / 2, "before={before}, after={after}");
        // grid bandwidth should be near the grid width
        assert!(after <= 30, "after={after}");
    }

    #[test]
    fn rcm_on_path_gives_bandwidth_one() {
        let g = Adjacency::from_lower_edges(8, &[(1, 0), (2, 1), (3, 2), (4, 3), (5, 4), (6, 5), (7, 6)]);
        let perm = rcm(&g);
        assert_eq!(bandwidth_under(&g, &perm), 1);
    }

    #[test]
    fn handles_disconnected_graphs() {
        let g = Adjacency::from_lower_edges(6, &[(1, 0), (3, 2), (5, 4)]);
        let perm = rcm(&g);
        let mut seen = vec![false; 6];
        for &p in &perm {
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(bandwidth_under(&g, &perm), 1);
    }

    #[test]
    fn profile_never_worse_than_identity_on_scrambled() {
        let mut rng = SmallRng::seed_from_u64(4);
        let edges = crate::sparse::gen::grid2d_pattern(10, 10, 1, 1);
        let scrambled = crate::sparse::gen::scramble(&edges, 100, &mut rng);
        let g = Adjacency::from_lower_edges(100, &scrambled);
        let id: Vec<u32> = (0..100).collect();
        let perm = rcm(&g);
        assert!(profile_under(&g, &perm) <= profile_under(&g, &id));
    }
}
