//! `pars3` — leader entrypoint / CLI.
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! pars3 info                          # artifact + platform info
//! pars3 report <table1|rcm|conflicts|splits|fig9|coloring|complexity|all>
//! pars3 spmv   [--matrix NAME] [--p N] [--backend auto|serial|csr|dgbmv|coloring|race|pars3|pjrt]
//! pars3 solve  [--matrix NAME] [--p N] [--backend ...] [--tol T] [--iters K] [--rhs K]
//! pars3 serve                         # sharded service demo (pipelined clients)
//! pars3 serve --listen tcp://0.0.0.0:7313   # serve the wire protocol (also uds:/path.sock)
//! pars3 client --connect ADDR [--stop]      # remote smoke test / graceful shutdown
//! ```
//!
//! Global flags: `--config FILE` (default `pars3.toml`), `--scale S`,
//! `--ranks a,b,c`, `--threaded`, `--format auto|dia|sss` (band-interior
//! storage: hybrid diagonal-major vs pure SSS, `auto` = planner scores
//! both by bytes moved), `--reorder auto|rcm|rcm-bicriteria|natural`
//! (preprocessing strategy; `auto` measures the candidates and declines
//! when nothing clears `--reorder-min-gain`),
//! `--backend auto|serial|csr|dgbmv|coloring|race|pars3|pjrt` (`auto` =
//! execute on the planner's pick), `--plan auto|pinned` (`pinned`
//! restores legacy per-axis resolution), `--plan-probe N` (time N real
//! `apply` calls per backend candidate instead of structural proxies),
//! `--shards W` (service worker pool), `--queue-depth N` (per-shard
//! backpressure bound), `--max-cached-kernels N` (per-shard
//! kernel-cache LRU cap, 0 = unbounded), `--l2-kib K` (cache budget the
//! tile-blocked band kernels size their row tiles against),
//! `--prepare-threads N` (prepare-pool width for BFS/RCM and format
//! construction; the permutation is identical for every width).

use pars3::coordinator::{Backend, ClientApi, Config, Coordinator, Service};
use pars3::mpisim::CostModel;
use pars3::net::{Listen, RemoteClient, Server};
use pars3::report;
use pars3::solver::mrs::MrsOptions;
use pars3::sparse::{gen, skew};
use pars3::util::SmallRng;
use pars3::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed command line.
struct Args {
    cmd: String,
    sub: Option<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut it = std::env::args().skip(1).peekable();
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut sub = None;
    let mut flags = std::collections::HashMap::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else if sub.is_none() {
            sub = Some(a);
        }
    }
    Args { cmd, sub, flags }
}

fn load_config(args: &Args) -> Result<Config> {
    let path = args.flags.get("config").map(String::as_str).unwrap_or("pars3.toml");
    let mut cfg = Config::load(path)?;
    if let Some(s) = args.flags.get("scale") {
        cfg.scale = s.parse()?;
    }
    if let Some(r) = args.flags.get("ranks") {
        cfg.ranks = r.split(',').map(|t| t.trim().parse()).collect::<std::result::Result<_, _>>()?;
    }
    if args.flags.contains_key("threaded") {
        cfg.threaded = true;
    }
    if let Some(f) = args.flags.get("format") {
        cfg.format = f.parse()?;
    }
    if let Some(r) = args.flags.get("reorder") {
        cfg.reorder = r.parse()?;
    }
    if let Some(g) = args.flags.get("reorder-min-gain") {
        cfg.reorder_min_gain = g.parse()?;
    }
    if let Some(b) = args.flags.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(m) = args.flags.get("plan") {
        cfg.plan = m.parse()?;
    }
    if let Some(n) = args.flags.get("plan-probe") {
        cfg.plan_probe = n.parse()?;
    }
    if let Some(d) = args.flags.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if let Some(w) = args.flags.get("shards") {
        cfg.shards = w.parse()?;
    }
    if let Some(q) = args.flags.get("queue-depth") {
        cfg.queue_depth = q.parse()?;
    }
    if let Some(m) = args.flags.get("max-cached-kernels") {
        cfg.max_cached_kernels = m.parse()?;
    }
    if let Some(l) = args.flags.get("l2-kib") {
        cfg.l2_kib = l.parse()?;
    }
    if let Some(t) = args.flags.get("prepare-threads") {
        cfg.prepare_threads = t.parse()?;
    }
    // flag overrides must obey the same invariants the TOML path enforces
    if cfg.shards == 0 {
        anyhow::bail!("--shards must be >= 1");
    }
    if cfg.queue_depth == 0 {
        anyhow::bail!("--queue-depth must be >= 1");
    }
    if !(0.0..1.0).contains(&cfg.reorder_min_gain) {
        anyhow::bail!("--reorder-min-gain must be in [0, 1)");
    }
    if cfg.l2_kib == 0 {
        anyhow::bail!("--l2-kib must be >= 1");
    }
    if cfg.prepare_threads == 0 {
        anyhow::bail!("--prepare-threads must be >= 1");
    }
    Ok(cfg)
}

/// Resolve the requested execution backend: `None` means `auto` — run
/// on whatever the planner chose (`prep.choice.backend`). The
/// `--backend` flag was already folded into `cfg.backend` by
/// [`load_config`], so this just applies `--p` to the policy.
fn backend_of(args: &Args, cfg: &Config, default_p: usize) -> Result<Option<Backend>> {
    let p: usize = args.flags.get("p").map(|v| v.parse()).transpose()?.unwrap_or(default_p);
    Ok(cfg.backend.resolve(p))
}

fn pick_matrix(cfg: &Config, name: &str) -> Result<(String, pars3::sparse::Coo)> {
    let suite = gen::paper_suite(cfg.scale);
    let m = suite
        .iter()
        .find(|m| m.name == name || m.name.trim_end_matches("_like") == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown matrix '{name}'; available: {}",
                suite.iter().map(|m| m.name).collect::<Vec<_>>().join(", ")
            )
        })?;
    let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
    Ok((m.name.to_string(), skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng)))
}

fn run() -> Result<()> {
    let args = parse_args();
    let cfg = load_config(&args)?;
    match args.cmd.as_str() {
        "info" => cmd_info(cfg),
        "report" => cmd_report(cfg, args.sub.as_deref().unwrap_or("all")),
        "spmv" => cmd_spmv(cfg, &args),
        "solve" => cmd_solve(cfg, &args),
        "serve" => cmd_serve(cfg, &args),
        "client" => cmd_client(cfg, &args),
        _ => {
            println!(
                "pars3 — Parallel 3-Way Banded Skew-SSpMV (paper reproduction)\n\n\
                 usage: pars3 <info|report|spmv|solve|serve|client> [flags]\n\
                 report subcommands: table1 rcm conflicts splits fig9 coloring complexity all\n\
                 flags: --config F --scale S --ranks 1,2,4 --threaded --matrix NAME --p N\n\
                        --backend auto|serial|csr|dgbmv|coloring|race|pars3|pjrt\n\
                        --format auto|dia|sss --reorder auto|rcm|rcm-bicriteria|natural\n\
                        --reorder-min-gain G --plan auto|pinned --plan-probe N\n\
                        --tol T --iters K --rhs K --artifacts DIR --shards W --queue-depth N\n\
                        --max-cached-kernels N --l2-kib K --prepare-threads N\n\
                        --listen tcp://host:port|uds:/path (serve)\n\
                        --connect tcp://host:port|uds:/path [--stop] (client)"
            );
            Ok(())
        }
    }
}

fn cmd_info(cfg: Config) -> Result<()> {
    println!("config: {cfg:?}");
    println!("kernels: {:?}", pars3::kernel::KERNEL_NAMES);
    #[cfg(feature = "pjrt")]
    {
        let mut coord = Coordinator::new(cfg);
        match coord.runtime() {
            Ok(rt) => {
                println!("PJRT platform: {}", rt.platform());
                println!("artifacts:");
                let arts: Vec<_> = rt.manifest().artifacts.clone();
                for a in arts {
                    println!(
                        "  {:28} kind={:9} n={:6} beta={:3} tile={}",
                        a.name, a.kind, a.n, a.beta, a.tile
                    );
                }
            }
            Err(e) => println!("PJRT runtime unavailable: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = cfg;
        println!("PJRT runtime: disabled (rebuild with `--features pjrt`)");
    }
    Ok(())
}

fn cmd_report(cfg: Config, which: &str) -> Result<()> {
    let suite = report::prepared_suite(&cfg)?;
    // calibrate the cost replay on the largest analogue (most stable)
    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 3);
    let ranks = &cfg.ranks;
    let mut out = String::new();
    if matches!(which, "table1" | "all") {
        out.push_str(&report::table1(&suite));
        out.push('\n');
    }
    if matches!(which, "rcm" | "all") {
        out.push_str(&report::rcm_report(&suite));
        out.push('\n');
    }
    if matches!(which, "conflicts" | "all") {
        out.push_str(&report::conflict_report(&suite, ranks));
        out.push('\n');
    }
    if matches!(which, "splits" | "all") {
        out.push_str(&report::splits_report(&suite, &[1, 3, 8, 16]));
        out.push('\n');
    }
    if matches!(which, "fig9" | "all") {
        let f = report::fig9(&suite, ranks, &model);
        out.push_str(&report::fig9_report(&f));
        out.push('\n');
    }
    if matches!(which, "coloring" | "all") {
        out.push_str(&report::coloring_compare(&suite, ranks, &model));
        out.push('\n');
    }
    if matches!(which, "complexity" | "all") {
        out.push_str(&report::complexity_report(&cfg, &[500, 1000, 2000, 4000])?);
        out.push('\n');
    }
    if out.is_empty() {
        anyhow::bail!("unknown report '{which}'");
    }
    println!("{out}");
    Ok(())
}

fn cmd_spmv(cfg: Config, args: &Args) -> Result<()> {
    let name = args.flags.get("matrix").map(String::as_str).unwrap_or("af_5_k101_like");
    let requested = backend_of(args, &cfg, 8)?;
    let (name, coo) = pick_matrix(&cfg, name)?;
    let mut coord = Coordinator::new(cfg);
    let prep = coord.prepare(&name, &coo)?;
    // `--backend auto` (or none configured) executes on the planner's pick
    let backend = requested.unwrap_or(prep.choice.backend);
    println!(
        "{name}: n={} nnz_lower={} bw {} -> {} ({}), middle format {}",
        prep.n,
        prep.nnz_lower,
        prep.bw_before,
        prep.reordered_bw,
        prep.plan.reorder.strategy,
        prep.split.format_name()
    );
    println!("{}", prep.plan.summary());
    println!("{}", prep.plan.detail());
    println!("{}", prep.plan.reorder.timings.summary());
    let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.37).sin()).collect();
    let t0 = std::time::Instant::now();
    let y = coord.spmv(&prep, &x, backend)?;
    let dt = t0.elapsed().as_secs_f64();
    let norm: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("backend {backend:?}: ||y|| = {norm:.6e}  ({dt:.6}s incl. plan)");
    // measured roofline for the executed backend: re-apply on the (now
    // cached) kernel so build cost doesn't pollute the rate, and use the
    // kernel's own flops()/bytes() accounting (pjrt has no CPU kernel)
    if backend != Backend::Pjrt {
        let mut k = coord.kernel(&prep, backend)?;
        let mut y2 = vec![0.0; prep.n];
        let t1 = std::time::Instant::now();
        k.apply(&x, &mut y2);
        let roof = pars3::perf::Roofline::from_seconds(
            t1.elapsed().as_secs_f64(),
            k.flops(),
            k.bytes(),
        );
        println!("| metric | GF/s | GB/s | peak GB/s | achieved | AI flop/B |");
        println!("|--------|------|------|-----------|----------|-----------|");
        println!(
            "| roofline | {:.3} | {:.3} | {:.2} | {:.1}% | {:.4} |",
            roof.gflops,
            roof.gbytes,
            roof.peak_gbytes,
            100.0 * roof.achieved_fraction,
            roof.arithmetic_intensity
        );
    }
    // cross-check against serial
    let y0 = coord.spmv(&prep, &x, Backend::Serial)?;
    let err = y.iter().zip(&y0).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    println!("max |y - y_serial| = {err:.3e}");
    Ok(())
}

fn cmd_solve(cfg: Config, args: &Args) -> Result<()> {
    let name = args.flags.get("matrix").map(String::as_str).unwrap_or("af_5_k101_like");
    let requested = backend_of(args, &cfg, 8)?;
    let tol: f64 = args.flags.get("tol").map(|v| v.parse()).transpose()?.unwrap_or(1e-8);
    let iters: usize = args.flags.get("iters").map(|v| v.parse()).transpose()?.unwrap_or(500);
    let rhs: usize = args.flags.get("rhs").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let alpha = cfg.alpha;
    let (name, coo) = pick_matrix(&cfg, name)?;
    let mut coord = Coordinator::new(cfg);
    let prep = coord.prepare(&name, &coo)?;
    let backend = requested.unwrap_or(prep.choice.backend);
    println!("{}", prep.plan.summary());
    let mut rng = SmallRng::seed_from_u64(17);
    let opts = MrsOptions { alpha, max_iters: iters, tol };
    if rhs > 1 {
        // multi-RHS path: one fused SpMV per sweep serves every column
        if backend == Backend::Pjrt {
            anyhow::bail!("--rhs > 1 supports serial/pars3 backends");
        }
        let bs = pars3::kernel::VecBatch::from_fn(prep.n, rhs, |_, _| {
            rng.gen_range_f64(-1.0, 1.0)
        });
        let t0 = std::time::Instant::now();
        let results = if args.flags.get("solver").map(String::as_str) == Some("krylov") {
            let kopts = pars3::solver::KrylovOptions { alpha, max_iters: iters, tol };
            let mut k = coord.kernel(&prep, backend)?;
            pars3::solver::mrs_krylov_solve_batch(&mut *k, &bs, &kopts)
        } else {
            coord.solve_batch(&prep, &bs, &opts, backend)?
        };
        let dt = t0.elapsed().as_secs_f64();
        let converged = results.iter().filter(|r| r.converged).count();
        let max_iters_used = results.iter().map(|r| r.iters).max().unwrap_or(0);
        println!(
            "{name}: backend {backend:?} rhs={rhs} converged {converged}/{rhs} \
             max_iters={max_iters_used} ({dt:.3}s, one fused SpMV per sweep)"
        );
        return Ok(());
    }
    let b: Vec<f64> = (0..prep.n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
    let t0 = std::time::Instant::now();
    let res = if args.flags.get("solver").map(String::as_str) == Some("krylov") {
        // full Krylov MRS (Idema-Vuik family) over the same registry
        // kernel the line-search solver uses
        let kopts = pars3::solver::KrylovOptions { alpha, max_iters: iters, tol };
        if backend == Backend::Pjrt {
            anyhow::bail!("--solver krylov supports serial/pars3 backends");
        }
        let mut k = coord.kernel(&prep, backend)?;
        pars3::solver::mrs_krylov_solve(&mut *k, &b, &kopts)
    } else {
        coord.solve(&prep, &b, &opts, backend)?
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name}: backend {backend:?} converged={} iters={} rel_res={:.3e} ({dt:.3}s)",
        res.converged,
        res.iters,
        (res.history.last().unwrap() / res.history[0]).sqrt()
    );
    Ok(())
}

fn cmd_serve(cfg: Config, args: &Args) -> Result<()> {
    // --listen puts the sharded service on a real socket; without it,
    // the in-process pipelining demo below runs as before
    if let Some(spec) = args.flags.get("listen") {
        let listen: Listen = spec.parse()?;
        let server = Server::bind(&listen, cfg)?;
        println!(
            "pars3 serving on {} (stop with `pars3 client --connect {} --stop`)",
            server.local_addr(),
            server.local_addr()
        );
        server.join();
        println!("service stopped.");
        return Ok(());
    }
    println!(
        "starting sharded service ({} shard(s), queue depth {}; demo: pipelined clients)...",
        cfg.shards, cfg.queue_depth
    );
    let scale = cfg.scale;
    let alpha = cfg.alpha;
    let seed = cfg.seed;
    let svc = Service::start(cfg);
    let client = svc.client();
    let suite = gen::paper_suite(scale);
    let m = &suite[3]; // af analogue: fastest
    let mut rng = SmallRng::seed_from_u64(seed ^ m.n as u64);
    let coo = skew::coo_from_pattern(m.n, &m.lower_edges, alpha, &mut rng);
    let handle = client.prepare(m.name, coo).wait()?;
    let info = client.describe(&handle).wait()?;
    println!(
        "prepared '{}' on shard {} (generation {}): n={} nnz={} reordered_bw={}",
        info.name,
        handle.shard(),
        handle.generation(),
        info.n,
        info.nnz_lower,
        info.reordered_bw
    );
    println!("{}", info.plan.summary());
    // pipelined: every request is in flight before the first wait
    let tickets: Vec<_> = (0..3)
        .map(|c| {
            let x: Vec<f64> = (0..m.n).map(|i| ((i + c) as f64 * 0.11).cos()).collect();
            client.spmv(&handle, x, Backend::Pars3 { p: 4 })
        })
        .collect();
    for (c, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Ok(y) => {
                let norm: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
                println!("client {c}: spmv ok, ||y|| = {norm:.6e}");
            }
            Err(e) => println!("client {c}: error {e}"),
        }
    }
    for stats in client.cache_stats_all().wait()? {
        println!(
            "shard {} kernel cache: {} cached, {} built, queue depth {} \
             (3 pipelined spmvs -> 1 build on the owning shard)",
            stats.shard, stats.cached, stats.built, stats.queue_depth
        );
    }
    svc.shutdown();
    println!("service stopped.");
    Ok(())
}

fn cmd_client(cfg: Config, args: &Args) -> Result<()> {
    let addr: Listen = args
        .flags
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("client needs --connect tcp://host:port or uds:/path"))?
        .parse()?;
    let client = RemoteClient::connect(&addr)?;
    if args.flags.contains_key("stop") {
        client.stop().wait()?;
        println!("server at {addr} acknowledged stop");
        return Ok(());
    }
    // remote smoke: prepare a generated matrix server-side, pipeline a
    // burst of multiplies, and verify the defining skew-symmetric
    // identity x'Ax = 0 on the returned vectors
    let n = 800;
    let handle =
        client.prepare("remote-smoke", gen::small_test_matrix(n, cfg.seed, cfg.alpha)).wait()?;
    let info = client.describe(&handle).wait()?;
    println!(
        "prepared '{}' remotely: n={} nnz_lower={} bw {} -> {}",
        info.name, info.n, info.nnz_lower, info.bw_before, info.reordered_bw
    );
    println!("{}", info.plan.summary());
    // pipelined: every request is on the wire before the first wait
    let inputs: Vec<Vec<f64>> =
        (0..4).map(|c| (0..n).map(|i| ((i + c) as f64 * 0.13).sin()).collect()).collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| client.spmv(&handle, x.clone(), Backend::Pars3 { p: 4 }))
        .collect();
    for (c, (x, t)) in inputs.iter().zip(tickets).enumerate() {
        let y = t.wait()?;
        let norm: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        let xay: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        println!("spmv {c}: ||y|| = {norm:.6e}, x'Ax = {xay:.3e}");
    }
    client.release(&handle).wait()?;
    println!("remote session ok over {addr}");
    Ok(())
}
