//! RACE-style recursive level-coloring SSpMV (Alappat et al. [RACE]).
//!
//! The strongest published competitor to banded preprocessing on
//! scattered matrices: instead of coloring individual rows (the greedy
//! distance-2 baseline in [`crate::kernel::coloring_spmv`], which needs
//! one barrier *per color* and streams `x` in cache-hostile order),
//! build a BFS **level structure** and group consecutive levels so that
//!
//! * rows in the same group stay in level order (the level-induced
//!   reordering — consecutive levels reference each other, so a group
//!   is a cache-friendly working set);
//! * groups alternate between an **even** and an **odd** parity phase.
//!   Every group spans >= 2 levels, so two same-parity groups are
//!   separated by >= 2 whole levels. The SSS row kernel writes
//!   `{i} ∪ cols(i)`, and stored edges connect rows whose BFS levels
//!   differ by at most 1, hence a row's writes land within one level of
//!   its own — two rows >= 3 levels apart can never touch the same
//!   output index, even through a shared neighbour (the distance-2
//!   conflict). Same-parity groups therefore have **disjoint write
//!   sets** and run fully parallel; one barrier ends each parity phase,
//!   for at most **2 barriers per multiply** regardless of the matrix.
//!
//! Recursion supplies the parallelism the raw level count cannot: a
//! group whose row work exceeds the per-thread balance target is split
//! at its most work-balanced level boundary (both halves keep >= 2
//! levels), repeatedly — the recursion depth is the number of rounds.
//! A group that is still oversized once it is down to < 4 levels cannot
//! be split by levels any further; its level-ordered rows are then
//! chunked across ranks at the balance target. Cross-chunk writes
//! inside one such group may collide; the executors accumulate through
//! the atomic [`Window`] (exactly like the coloring baseline), so the
//! relaxation is numerically safe — full RACE would recurse with
//! sub-level BFS structures here, which is future refinement, not a
//! correctness gap.
//!
//! Execution modes mirror `coloring_spmv.rs`: deterministic emulated
//! scalar/batch paths, plus threaded scalar/batch paths on a
//! **persistent** `mpisim` world ([`RaceThreaded`], matching
//! [`crate::kernel::pars3::Pars3Threaded`]) so repeated multiplies pay
//! thread-spawn cost zero times.

use crate::graph::bfs::components;
use crate::graph::peripheral::pseudo_peripheral_ls;
use crate::graph::Adjacency;
use crate::kernel::batch::VecBatch;
use crate::kernel::pars3::Pars3Stats;
use crate::mpisim::{InputSlot, PersistentWorld, RankCtx, RankReport, Window, World};
use crate::perf::Roofline;
use crate::sparse::Sss;
use crate::Result;
use anyhow::ensure;
use std::sync::Arc;

/// Minimum levels per group once more than one group exists: a gap
/// group this tall separates same-parity groups by >= 3 levels, which
/// defeats both direct-edge (distance-1) and shared-neighbour
/// (distance-2) write conflicts.
pub const MIN_GROUP_LEVELS: usize = 2;

/// One group: the consecutive level range `[lo, hi)` and its row work.
#[derive(Debug, Clone, Copy)]
pub struct RaceGroup {
    /// First level (inclusive).
    pub lo: usize,
    /// One past the last level.
    pub hi: usize,
    /// Total row work of the group's rows.
    pub work: usize,
}

/// The level grouping + rank assignment, independent of the matrix
/// ownership so the planner's structural score can build one from a
/// borrowed [`Sss`] without cloning the matrix.
#[derive(Debug, Clone)]
pub struct RaceStructure {
    /// BFS levels, every component merged by depth (cross-component
    /// rows never conflict, so sharing a level index is safe). Each
    /// component is rooted at a pseudo-peripheral vertex for maximal
    /// height, reusing `graph/bfs.rs::level_structure` via
    /// [`pseudo_peripheral_ls`].
    pub levels: Vec<Vec<u32>>,
    /// Level index per row.
    pub level_of: Vec<u32>,
    /// Groups in level order (consecutive, disjoint, covering).
    pub groups: Vec<RaceGroup>,
    /// Rounds of recursive group splitting (>= 1 for any nonempty
    /// matrix; the first round inspects the single all-levels group).
    pub depth: usize,
    /// `assign[phase][rank]` — rows owned by the rank in that parity
    /// phase, concatenated in (group, level, discovery) order.
    pub assign: Vec<Vec<Vec<u32>>>,
    /// Row work per phase per rank (the balance evidence).
    pub phase_work: Vec<Vec<usize>>,
    /// Per-thread balance target: `ceil(total_work / p)`.
    pub balance_target: usize,
    /// Largest single-row work unit (the granularity floor).
    pub max_row_work: usize,
    /// Largest contiguous unit (whole group or chunk of an oversized
    /// group) handed to one rank — the recursion's balance guarantee is
    /// `max_unit_work <= balance_target + max_row_work`.
    pub max_unit_work: usize,
}

impl RaceStructure {
    /// Build the level structure, recursive grouping, and rank
    /// assignment for `p` ranks.
    pub fn build(s: &Sss, p: usize) -> Self {
        let p = p.max(1);
        let n = s.n;
        let g = Adjacency::from_sss(s);

        // BFS level structure per component, merged by depth.
        let (comp, ncomp) = components(&g);
        let mut first = vec![u32::MAX; ncomp];
        for v in (0..n).rev() {
            first[comp[v] as usize] = v as u32;
        }
        let mut levels: Vec<Vec<u32>> = Vec::new();
        let mut level_of = vec![0u32; n];
        for &start in &first {
            let (_, ls) = pseudo_peripheral_ls(&g, start);
            for (d, lv) in ls.levels.iter().enumerate() {
                if levels.len() <= d {
                    levels.push(Vec::new());
                }
                for &v in lv {
                    level_of[v as usize] = d as u32;
                }
                levels[d].extend_from_slice(lv);
            }
        }

        // Row work: diagonal term + forward and mirror update per
        // stored entry (what the phased row kernel actually executes).
        let work: Vec<usize> =
            (0..n).map(|i| 1 + 2 * (s.row_ptr[i + 1] - s.row_ptr[i])).collect();
        let level_work: Vec<usize> = levels
            .iter()
            .map(|lv| lv.iter().map(|&r| work[r as usize]).sum())
            .collect();
        let total: usize = level_work.iter().sum();
        let balance_target = total.div_ceil(p);
        let max_row_work = work.iter().copied().max().unwrap_or(0);

        // Recursive splitting: each round bisects every group that is
        // over the balance target and still has >= 2 * MIN_GROUP_LEVELS
        // levels, at its most work-balanced level boundary.
        let mut groups: Vec<(usize, usize)> =
            if levels.is_empty() { Vec::new() } else { vec![(0, levels.len())] };
        let mut depth = 1usize;
        loop {
            let mut next = Vec::with_capacity(groups.len() * 2);
            let mut split_any = false;
            for &(lo, hi) in &groups {
                let gw: usize = level_work[lo..hi].iter().sum();
                if gw > balance_target && hi - lo >= 2 * MIN_GROUP_LEVELS {
                    let mut best = (usize::MAX, lo + MIN_GROUP_LEVELS);
                    let mut acc: usize = level_work[lo..lo + MIN_GROUP_LEVELS].iter().sum();
                    for m in lo + MIN_GROUP_LEVELS..=hi - MIN_GROUP_LEVELS {
                        let diff = (2 * acc).abs_diff(gw);
                        if diff < best.0 {
                            best = (diff, m);
                        }
                        acc += level_work[m];
                    }
                    next.push((lo, best.1));
                    next.push((best.1, hi));
                    split_any = true;
                } else {
                    next.push((lo, hi));
                }
            }
            groups = next;
            if !split_any {
                break;
            }
            depth += 1;
        }
        let groups: Vec<RaceGroup> = groups
            .into_iter()
            .map(|(lo, hi)| RaceGroup { lo, hi, work: level_work[lo..hi].iter().sum() })
            .collect();

        // Parity phases + least-loaded rank assignment. Rank loads
        // carry across phases so the *overall* apply stays balanced
        // even when one parity holds most of the work. Groups still
        // over the target after splitting (< 4 levels left) are
        // chunked across ranks at the target granularity.
        let phases = if groups.len() >= 2 { 2 } else { groups.len() };
        let mut assign = vec![vec![Vec::new(); p]; phases];
        let mut phase_work = vec![vec![0usize; p]; phases];
        let mut loads = vec![0usize; p];
        let mut max_unit_work = 0usize;
        let argmin = |loads: &[usize]| {
            loads.iter().enumerate().min_by_key(|&(_, &w)| w).map(|(i, _)| i).unwrap_or(0)
        };
        for (gi, grp) in groups.iter().enumerate() {
            let ph = gi % 2;
            if grp.work > balance_target && p > 1 {
                let mut unit: Vec<u32> = Vec::new();
                let mut uw = 0usize;
                for lv in &levels[grp.lo..grp.hi] {
                    for &r in lv {
                        unit.push(r);
                        uw += work[r as usize];
                        if uw >= balance_target {
                            let rank = argmin(&loads);
                            loads[rank] += uw;
                            phase_work[ph][rank] += uw;
                            max_unit_work = max_unit_work.max(uw);
                            assign[ph][rank].append(&mut unit);
                            uw = 0;
                        }
                    }
                }
                if !unit.is_empty() {
                    let rank = argmin(&loads);
                    loads[rank] += uw;
                    phase_work[ph][rank] += uw;
                    max_unit_work = max_unit_work.max(uw);
                    assign[ph][rank].append(&mut unit);
                }
            } else {
                let rank = argmin(&loads);
                loads[rank] += grp.work;
                phase_work[ph][rank] += grp.work;
                max_unit_work = max_unit_work.max(grp.work);
                for lv in &levels[grp.lo..grp.hi] {
                    assign[ph][rank].extend_from_slice(lv);
                }
            }
        }

        Self {
            levels,
            level_of,
            groups,
            depth,
            assign,
            phase_work,
            balance_target,
            max_row_work,
            max_unit_work,
        }
    }

    /// Parity phases per multiply (= barriers per apply in the
    /// threaded executors). At most 2.
    pub fn phases(&self) -> usize {
        self.assign.len()
    }

    /// Rows of group `gi`, in level order.
    pub fn group_rows(&self, gi: usize) -> Vec<u32> {
        let grp = &self.groups[gi];
        self.levels[grp.lo..grp.hi].concat()
    }

    /// Per-phase row-work balance: `max_rank_work * p / phase_total`
    /// (>= 1.0; 1.0 is perfect).
    pub fn phase_balance(&self) -> Vec<f64> {
        self.phase_work
            .iter()
            .map(|pw| {
                let total: usize = pw.iter().sum();
                let max = pw.iter().copied().max().unwrap_or(0);
                if total == 0 {
                    1.0
                } else {
                    max as f64 * pw.len() as f64 / total as f64
                }
            })
            .collect()
    }

    /// Whole-apply balance: worst rank's total work across all phases
    /// over the ideal `total / p` share (>= 1.0). The planner's
    /// structural score scales the traffic proxy by this.
    pub fn overall_balance(&self) -> f64 {
        let p = self.phase_work.first().map_or(1, Vec::len);
        let mut loads = vec![0usize; p];
        for pw in &self.phase_work {
            for (r, &w) in pw.iter().enumerate() {
                loads[r] += w;
            }
        }
        let total: usize = loads.iter().sum();
        let max = loads.iter().copied().max().unwrap_or(0);
        if total == 0 {
            1.0
        } else {
            max as f64 * p as f64 / total as f64
        }
    }
}

/// Preplanned phased executor over a shared matrix.
#[derive(Debug)]
pub struct RacePlan {
    /// The matrix (shared with worker threads).
    pub s: Arc<Sss>,
    /// Rank count.
    pub p: usize,
    /// Level grouping + assignment.
    pub structure: RaceStructure,
}

impl RacePlan {
    /// Build the level structure and distribute over `p` ranks.
    /// Accepts an owned or already-shared matrix (no clone either way).
    pub fn new(s: impl Into<Arc<Sss>>, p: usize) -> Result<Self> {
        let s: Arc<Sss> = s.into();
        ensure!(p >= 1, "need at least one rank");
        let structure = RaceStructure::build(&s, p);
        Ok(Self { s, p, structure })
    }

    /// Parity phases per multiply.
    pub fn phases(&self) -> usize {
        self.structure.phases()
    }

    /// Barriers per apply in the threaded executors: one per phase,
    /// bounded by `2 * depth` (in fact by 2).
    pub fn barriers_per_apply(&self) -> usize {
        self.structure.phases()
    }

    /// Recursion depth of the grouping.
    pub fn depth(&self) -> usize {
        self.structure.depth
    }

    /// Stamp the level-coloring provenance on a stats object.
    fn note_structure(&self, stats: &mut Pars3Stats) {
        stats.race_phases = self.phases();
        stats.race_depth = self.structure.depth;
        stats.race_phase_balance = self.structure.phase_balance();
    }

    /// One rank's phased apply: process owned rows phase by phase, one
    /// barrier per phase. Writes go through the atomic window — across
    /// same-parity groups they are provably disjoint; inside a chunked
    /// oversized group they may collide and the window absorbs them.
    fn rank_apply(&self, win: &Window, x: &[f64], ctx: &mut RankCtx) -> RankReport {
        let t0 = std::time::Instant::now();
        let s = &*self.s;
        let sign = s.sym.sign();
        for phase in &self.structure.assign {
            for &i in &phase[ctx.rank] {
                let i = i as usize;
                let xi = x[i];
                let mut yi = s.dvalues[i] * xi;
                for (j, v) in s.row(i) {
                    let j = j as usize;
                    yi += v * x[j];
                    win.add(j, sign * v * xi);
                }
                win.add(i, yi);
            }
            ctx.barrier(); // parity-phase synchronization point
        }
        RankReport { msgs: 0, msg_values: 0, seconds: t0.elapsed().as_secs_f64() }
    }

    /// Fused batch variant of [`Self::rank_apply`] over a column-major
    /// `n × kw` window: each loaded `(j, v)` serves all `kw` columns.
    fn rank_apply_batch(&self, win: &Window, xd: &[f64], kw: usize, ctx: &mut RankCtx) -> RankReport {
        let t0 = std::time::Instant::now();
        let s = &*self.s;
        let n = s.n;
        let sign = s.sym.sign();
        let mut yi = vec![0.0f64; kw];
        for phase in &self.structure.assign {
            for &i in &phase[ctx.rank] {
                let i = i as usize;
                for c in 0..kw {
                    yi[c] = s.dvalues[i] * xd[c * n + i];
                }
                for (j, v) in s.row(i) {
                    let j = j as usize;
                    let sv = sign * v;
                    for c in 0..kw {
                        yi[c] += v * xd[c * n + j];
                        win.add(c * n + j, sv * xd[c * n + i]);
                    }
                }
                for c in 0..kw {
                    win.add(c * n + i, yi[c]);
                }
            }
            ctx.barrier(); // parity-phase synchronization point
        }
        RankReport { msgs: 0, msg_values: 0, seconds: t0.elapsed().as_secs_f64() }
    }

    /// One-shot threaded execution (spawn, one multiply, join). The
    /// repeated-multiply hot path is [`RaceThreaded`].
    pub fn execute_threaded(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.s.n);
        let window = Window::new(self.s.n);
        let win = &window;
        let reports = World::run(self.p, |mut ctx| self.rank_apply(win, x, &mut ctx));
        let mut stats = Pars3Stats::default();
        self.note_structure(&mut stats);
        for r in reports {
            stats.rank_seconds.push(r.seconds);
        }
        (window.to_vec(), stats)
    }

    /// Rank-sequential emulation (deterministic, any `p`).
    pub fn execute_emulated(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        let s = &*self.s;
        assert_eq!(x.len(), s.n);
        let sign = s.sym.sign();
        let mut y = vec![0.0f64; s.n];
        for phase in &self.structure.assign {
            for rows in phase {
                for &i in rows {
                    let i = i as usize;
                    let xi = x[i];
                    let mut yi = s.dvalues[i] * xi;
                    for (j, v) in s.row(i) {
                        let j = j as usize;
                        yi += v * x[j];
                        y[j] += sign * v * xi;
                    }
                    y[i] += yi;
                }
            }
        }
        let mut stats = Pars3Stats::default();
        self.note_structure(&mut stats);
        (y, stats)
    }

    /// Rank-sequential fused batch emulation: identical numerics to
    /// [`Self::execute_emulated`] column by column, one matrix
    /// traversal for the whole batch.
    pub fn execute_emulated_batch(&self, xs: &VecBatch, ys: &mut VecBatch) -> Pars3Stats {
        let s = &*self.s;
        let sign = s.sym.sign();
        let (n, kw) = (s.n, xs.k());
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), kw);
        let xd = xs.data();
        ys.fill_zero();
        let yd = ys.data_mut();
        let mut yi = vec![0.0f64; kw];
        for phase in &self.structure.assign {
            for rows in phase {
                for &i in rows {
                    let i = i as usize;
                    for c in 0..kw {
                        yi[c] = s.dvalues[i] * xd[c * n + i];
                    }
                    for (j, v) in s.row(i) {
                        let j = j as usize;
                        let sv = sign * v;
                        for c in 0..kw {
                            yi[c] += v * xd[c * n + j];
                            yd[c * n + j] += sv * xd[c * n + i];
                        }
                    }
                    for c in 0..kw {
                        yd[c * n + i] += yi[c];
                    }
                }
            }
        }
        let mut stats = Pars3Stats::default();
        self.note_structure(&mut stats);
        stats
    }
}

/// Persistent threaded executor: rank threads spawn **once** here and
/// are reused for every apply, mirroring
/// [`crate::kernel::pars3::Pars3Threaded`]. Input hand-off is
/// zero-copy through a double-buffered [`InputSlot`].
pub struct RaceThreaded {
    plan: Arc<RacePlan>,
    world: PersistentWorld,
    window: Arc<Window>,
    xslot: Arc<InputSlot>,
    /// `n × k` column-major accumulate window for the fused batch path.
    batch_window: Option<(usize, Arc<Window>)>,
}

impl RaceThreaded {
    /// Spawn the rank threads for this plan.
    pub fn new(plan: Arc<RacePlan>) -> Self {
        let world = PersistentWorld::new(plan.p);
        let window = Window::new(plan.s.n);
        Self { plan, world, window, xslot: InputSlot::new(), batch_window: None }
    }

    fn collect(&self, reports: Vec<RankReport>) -> Pars3Stats {
        let mut stats = Pars3Stats::default();
        self.plan.note_structure(&mut stats);
        for r in reports {
            stats.rank_seconds.push(r.seconds);
        }
        stats
    }

    /// `y = A x` into a caller buffer on the persistent rank threads.
    pub fn apply_into(&self, x: &[f64], y: &mut [f64]) -> Pars3Stats {
        assert_eq!(x.len(), self.plan.s.n);
        assert_eq!(y.len(), self.plan.s.n);
        // All ranks are idle between jobs, so the epoch reset is safe.
        self.window.reset();
        let epoch = self.xslot.publish(x);
        let plan = self.plan.clone();
        let win = self.window.clone();
        let slot = self.xslot.clone();
        let reports = self.world.run_job(move |ctx| {
            // SAFETY: run_job returns only after every rank reports
            // done, so the caller's `x` outlives all reads of `epoch`.
            let x = unsafe { slot.read(epoch) };
            plan.rank_apply(&win, x, ctx)
        });
        self.xslot.retire(epoch);
        self.window.read_into(y);
        self.collect(reports)
    }

    /// Size (or resize) the `n × k` batch window ahead of time.
    pub fn prepare_batch(&mut self, k: usize) -> Arc<Window> {
        match &self.batch_window {
            Some((bk, w)) if *bk == k => w.clone(),
            _ => {
                let w = Window::new(self.plan.s.n * k.max(1));
                self.batch_window = Some((k.max(1), w.clone()));
                w
            }
        }
    }

    /// Fused batch multiply on the persistent rank threads: one matrix
    /// traversal and the same 2-barrier phase schedule as `k = 1`.
    pub fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) -> Pars3Stats {
        let n = self.plan.s.n;
        let k = xs.k();
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), k);
        if k == 0 {
            return Pars3Stats::default();
        }
        let win = self.prepare_batch(k);
        win.reset();
        let epoch = self.xslot.publish(xs.data());
        let plan = self.plan.clone();
        let slot = self.xslot.clone();
        let wjob = win.clone();
        let reports = self.world.run_job(move |ctx| {
            // SAFETY: as in apply_into.
            let xd = unsafe { slot.read(epoch) };
            plan.rank_apply_batch(&wjob, xd, k, ctx)
        });
        self.xslot.retire(epoch);
        win.read_into(ys.data_mut());
        self.collect(reports)
    }

    /// False once a rank panic has poisoned the persistent world.
    pub fn healthy(&self) -> bool {
        !self.world.is_poisoned()
    }
}

/// [`crate::kernel::Spmv`] adapter at a fixed rank count (what the
/// registry hands to solvers, benches, and the service).
pub struct RaceKernel {
    plan: Arc<RacePlan>,
    exec: Option<RaceThreaded>,
    last_stats: Option<Pars3Stats>,
}

impl RaceKernel {
    /// Build the level-coloring plan over `p` ranks. `threaded = false`
    /// uses the deterministic rank-sequential emulation; `true` spawns
    /// a persistent rank world once, here.
    pub fn new(s: impl Into<Arc<Sss>>, p: usize, threaded: bool) -> Result<Self> {
        let plan = Arc::new(RacePlan::new(s, p)?);
        let exec = if threaded { Some(RaceThreaded::new(plan.clone())) } else { None };
        Ok(Self { plan, exec, last_stats: None })
    }

    /// The underlying phased plan.
    pub fn plan(&self) -> &RacePlan {
        &self.plan
    }

    /// Stats of the most recent apply (phases, recursion depth,
    /// per-phase balance, roofline).
    pub fn last_stats(&self) -> Option<&Pars3Stats> {
        self.last_stats.as_ref()
    }
}

impl crate::kernel::Spmv for RaceKernel {
    fn n(&self) -> usize {
        self.plan.s.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        let t0 = std::time::Instant::now();
        let mut stats = match &self.exec {
            Some(exec) => exec.apply_into(x, y),
            None => {
                let (out, stats) = self.plan.execute_emulated(x);
                y.copy_from_slice(&out);
                stats
            }
        };
        stats.roofline =
            Some(Roofline::from_seconds(t0.elapsed().as_secs_f64(), self.flops(), self.bytes()));
        self.last_stats = Some(stats);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        let t0 = std::time::Instant::now();
        let mut stats = match &mut self.exec {
            Some(exec) => exec.apply_batch(xs, ys),
            None => self.plan.execute_emulated_batch(xs, ys),
        };
        let k = xs.k() as u64;
        stats.roofline = Some(Roofline::from_seconds(
            t0.elapsed().as_secs_f64(),
            self.flops() * k,
            self.bytes(),
        ));
        self.last_stats = Some(stats);
    }

    fn prepare_hint(&mut self, k: usize) {
        if let Some(exec) = &mut self.exec {
            exec.prepare_batch(k);
        }
    }

    fn healthy(&self) -> bool {
        self.exec.as_ref().is_none_or(RaceThreaded::healthy)
    }

    fn flops(&self) -> u64 {
        self.plan.s.spmv_flops()
    }

    fn bytes(&self) -> u64 {
        self.plan.s.spmv_bytes()
    }

    fn name(&self) -> &'static str {
        "race"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::kernel::Spmv;
    use crate::sparse::{convert, gen, skew, Symmetry};
    use crate::util::SmallRng;

    fn banded(n: usize, seed: u64) -> Sss {
        let coo = gen::small_test_matrix(n, seed, 1.5);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    fn small_world_sss(n: usize, seed: u64) -> Sss {
        let mut rng = SmallRng::seed_from_u64(seed);
        let edges = gen::small_world(n, 3, 0.4, &mut rng);
        let coo = skew::coo_from_pattern(n, &edges, 1.5, &mut rng);
        convert::coo_to_sss(&coo, Symmetry::Skew).unwrap()
    }

    #[test]
    fn emulated_matches_serial_on_banded_and_small_world() {
        for (s, label) in
            [(banded(120, 1), "banded"), (small_world_sss(150, 2), "sw")]
        {
            let n = s.n;
            let x: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 * 0.25 - 2.0).collect();
            let mut want = vec![0.0; n];
            sss_spmv(&s, &x, &mut want);
            for p in [1, 3, 8] {
                let plan = RacePlan::new(s.clone(), p).unwrap();
                let (got, _) = plan.execute_emulated(&x);
                for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < 1e-10, "{label} p={p} row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn persistent_threaded_stable_across_repeated_applies() {
        let s = small_world_sss(140, 3);
        let mut k = RaceKernel::new(s.clone(), 4, true).unwrap();
        let mut got = vec![0.0; 140];
        for round in 0..3u64 {
            let x: Vec<f64> =
                (0..140).map(|i| ((i as u64 * 13 + round * 7) % 23) as f64 * 0.5 - 5.0).collect();
            let mut want = vec![0.0; 140];
            sss_spmv(&s, &x, &mut want);
            k.apply(&x, &mut got);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "round {round} row {r}: {a} vs {b}");
            }
        }
        assert!(k.healthy());
    }

    #[test]
    fn batch_executors_match_columnwise_apply() {
        let s = small_world_sss(90, 4);
        let xs = VecBatch::from_fn(90, 3, |i, c| ((i + c * 13) % 9) as f64 * 0.5 - 2.0);
        for threaded in [false, true] {
            let mut k = RaceKernel::new(s.clone(), 3, threaded).unwrap();
            let mut ys = VecBatch::zeros(90, 3);
            k.apply_batch(&xs, &mut ys);
            for c in 0..3 {
                let mut want = vec![0.0; 90];
                k.apply(xs.col(c), &mut want);
                for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-10,
                        "threaded={threaded} col {c} row {r}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Same-parity groups must have pairwise-disjoint write sets — the
    /// conflict-freedom claim the 2-barrier schedule rests on.
    #[test]
    fn same_parity_groups_are_conflict_free() {
        for s in [banded(130, 5), small_world_sss(170, 6)] {
            let st = RaceStructure::build(&s, 4);
            for parity in 0..2usize {
                let mut owner: Vec<Option<usize>> = vec![None; s.n];
                for (gi, _) in st.groups.iter().enumerate().filter(|&(gi, _)| gi % 2 == parity) {
                    for &i in &st.group_rows(gi) {
                        let i = i as usize;
                        let mut claim = |v: usize| match owner[v] {
                            Some(o) if o != gi => {
                                panic!("groups {o} and {gi} (parity {parity}) both write {v}")
                            }
                            _ => owner[v] = Some(gi),
                        };
                        claim(i);
                        for (j, _) in s.row(i) {
                            claim(j as usize);
                        }
                    }
                }
            }
        }
    }

    /// Barriers per apply (= phases) stay within 2 × recursion depth,
    /// and phases never exceed 2 at all.
    #[test]
    fn barriers_bounded_by_twice_recursion_depth() {
        for (n, seed) in [(60usize, 7u64), (150, 8), (300, 9)] {
            let s = small_world_sss(n, seed);
            let plan = RacePlan::new(s, 8).unwrap();
            assert!(plan.depth() >= 1);
            assert!(plan.phases() <= 2);
            assert!(
                plan.barriers_per_apply() <= 2 * plan.depth(),
                "barriers {} vs depth {}",
                plan.barriers_per_apply(),
                plan.depth()
            );
        }
    }

    /// The recursion + chunking never hands a rank a contiguous unit
    /// larger than the per-thread balance target plus one row.
    #[test]
    fn recursion_respects_balance_target() {
        for s in [banded(200, 10), small_world_sss(240, 11)] {
            for p in [2, 4, 8] {
                let st = RaceStructure::build(&s, p);
                assert!(
                    st.max_unit_work <= st.balance_target + st.max_row_work,
                    "p={p}: unit {} target {} max_row {}",
                    st.max_unit_work,
                    st.balance_target,
                    st.max_row_work
                );
                // every row appears exactly once across the assignment
                let total: usize =
                    st.assign.iter().flat_map(|ph| ph.iter().map(Vec::len)).sum();
                assert_eq!(total, s.n);
            }
        }
    }

    /// On the small-world family RACE's 2 phases beat the greedy
    /// distance-2 coloring's color count — the headline win.
    #[test]
    fn fewer_phases_than_greedy_colors_on_small_world() {
        let s = small_world_sss(200, 12);
        let plan = RacePlan::new(s.clone(), 8).unwrap();
        let colors = crate::graph::coloring::color_rows(&s).num_colors;
        assert!(
            plan.phases() < colors,
            "race phases {} vs greedy colors {colors}",
            plan.phases()
        );
    }

    #[test]
    fn stats_carry_structure_and_roofline() {
        let s = small_world_sss(110, 13);
        let mut k = RaceKernel::new(s, 4, false).unwrap();
        let x: Vec<f64> = (0..110).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut y = vec![0.0; 110];
        k.apply(&x, &mut y);
        let stats = k.last_stats().unwrap();
        assert!(stats.race_phases >= 1 && stats.race_phases <= 2);
        assert!(stats.race_depth >= 1);
        assert_eq!(stats.race_phase_balance.len(), stats.race_phases);
        assert!(stats.race_phase_balance.iter().all(|&b| b >= 1.0));
        assert!(stats.roofline.is_some());
        assert_eq!(k.name(), "race");
    }

    #[test]
    fn handles_disconnected_components_and_tiny_matrices() {
        // disconnected: two rings with no cross edges
        let mut rng = SmallRng::seed_from_u64(14);
        let mut edges = gen::small_world(40, 2, 0.0, &mut rng);
        edges.extend(gen::small_world(30, 2, 0.0, &mut rng).iter().map(|&(a, b)| (a + 40, b + 40)));
        let coo = skew::coo_from_pattern(70, &edges, 1.2, &mut rng);
        let s = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let x: Vec<f64> = (0..70).map(|i| (i as f64 * 0.11).cos()).collect();
        let mut want = vec![0.0; 70];
        sss_spmv(&s, &x, &mut want);
        let plan = RacePlan::new(s, 3).unwrap();
        let (got, _) = plan.execute_emulated(&x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
        // n = 1
        let one = banded(1, 15);
        let plan = RacePlan::new(one, 1).unwrap();
        let (y1, _) = plan.execute_emulated(&[2.0]);
        assert_eq!(y1.len(), 1);
    }
}
