//! SIMD lane dispatch and L2 cache tiling for the band hot paths.
//!
//! Two independent mechanisms, composed by the DIA / band kernels:
//!
//! * **Lanes** — the dense-diagonal passes are `y[i] += c * v[i] *
//!   x[i]` strips. [`Lanes`] runs them as fixed-width
//!   ([`LANE_WIDTH`]) accumulator chunks the compiler autovectorizes,
//!   behind a `target_feature`-dispatched function pointer selected
//!   once per process: AVX2+FMA on x86-64 when the CPU has them, the
//!   portable chunked body otherwise (on aarch64 NEON is baseline, so
//!   the portable body already vectorizes). The chosen
//!   [`LaneVariant`] is recorded at kernel build and surfaces in
//!   `Pars3Stats`.
//! * **Tiles** — [`TilePlan`] splits a band traversal into row tiles
//!   sized so the `x`/`y` windows of one tile (tile rows + one
//!   bandwidth of halo, `k` columns wide) fit a configurable L2
//!   budget (`Config::l2_kib`). Diagonals then iterate *inside* each
//!   tile, so the forward and mirrored passes reuse vector windows
//!   that are still resident instead of streaming `x`/`y` once per
//!   diagonal — the RACE recipe (Alappat et al., 1907.06487) applied
//!   to the symmetric band.

use std::sync::OnceLock;

/// Accumulator strip width the lane kernels unroll to. Eight f64 lanes
/// = two AVX2 vectors or four NEON vectors per chunk — wide enough to
/// keep the FMA pipes busy, narrow enough that the scalar tail is
/// cheap on short diagonals.
pub const LANE_WIDTH: usize = 8;

/// Default L2 working-set budget per tile, KiB ([`TilePlan::new`]).
/// 256 KiB ≈ half a typical per-core L2: the tile's `x`/`y` windows
/// stay resident with room left for the diagonal values streaming
/// through.
pub const DEFAULT_L2_KIB: usize = 256;

/// Tiles never shrink below this many rows (when the matrix has them):
/// below ~64 rows the per-tile loop overhead beats any residency win.
const MIN_TILE_ROWS: usize = 64;

/// Which lane implementation [`Lanes::get`] dispatched to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LaneVariant {
    /// Chunked portable body (autovectorized by the compiler for the
    /// build target's baseline features).
    Portable,
    /// x86-64 with runtime-detected AVX2 + FMA.
    Avx2Fma,
    /// aarch64: NEON is baseline, the portable body compiles to NEON.
    Neon,
}

impl LaneVariant {
    /// Stable label recorded in `Pars3Stats` and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            LaneVariant::Portable => "portable",
            LaneVariant::Avx2Fma => "avx2+fma",
            LaneVariant::Neon => "neon",
        }
    }

    /// Runtime feature detection for the current CPU.
    pub fn detect() -> Self {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                LaneVariant::Avx2Fma
            } else {
                LaneVariant::Portable
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            LaneVariant::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            LaneVariant::Portable
        }
    }
}

/// `y[i] += c * vals[i] * x[i]` over equal-length strips, in
/// [`LANE_WIDTH`]-wide chunks with a scalar tail. `#[inline(always)]`
/// so each `target_feature` wrapper specializes its own copy with the
/// wrapper's enabled features.
#[inline(always)]
fn strip_axpy_body(y: &mut [f64], vals: &[f64], x: &[f64], c: f64) {
    let m = y.len().min(vals.len()).min(x.len());
    let head = m - m % LANE_WIDTH;
    let (yh, yt) = y[..m].split_at_mut(head);
    let (vh, vt) = vals[..m].split_at(head);
    let (xh, xt) = x[..m].split_at(head);
    for ((yc, vc), xc) in yh
        .chunks_exact_mut(LANE_WIDTH)
        .zip(vh.chunks_exact(LANE_WIDTH))
        .zip(xh.chunks_exact(LANE_WIDTH))
    {
        for l in 0..LANE_WIDTH {
            yc[l] += c * vc[l] * xc[l];
        }
    }
    for ((yi, vi), xi) in yt.iter_mut().zip(vt).zip(xt) {
        *yi += c * *vi * *xi;
    }
}

/// Uniform pointer type for the dispatched variants. The pointees are
/// memory-safe for any inputs; `unsafe` only carries the
/// `target_feature` calling requirement, discharged by
/// [`LaneVariant::detect`] before a pointer is ever installed.
type AxpyFn = unsafe fn(&mut [f64], &[f64], &[f64], f64);

unsafe fn strip_axpy_portable(y: &mut [f64], vals: &[f64], x: &[f64], c: f64) {
    strip_axpy_body(y, vals, x, c)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn strip_axpy_avx2(y: &mut [f64], vals: &[f64], x: &[f64], c: f64) {
    strip_axpy_body(y, vals, x, c)
}

/// The process-wide lane dispatch: a [`LaneVariant`] tag plus the
/// function pointer it selected. Kernels capture a copy at build time
/// (the tag is what `Pars3Stats` records as `lane_variant`).
#[derive(Clone, Copy)]
pub struct Lanes {
    /// Which implementation the pointer targets.
    pub variant: LaneVariant,
    axpy: AxpyFn,
}

impl std::fmt::Debug for Lanes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Lanes").field("variant", &self.variant).finish()
    }
}

impl PartialEq for Lanes {
    fn eq(&self, other: &Self) -> bool {
        self.variant == other.variant
    }
}

static LANES: OnceLock<Lanes> = OnceLock::new();

impl Lanes {
    /// The detected-once dispatch for this process.
    pub fn get() -> Lanes {
        *LANES.get_or_init(|| {
            let variant = LaneVariant::detect();
            let axpy: AxpyFn = match variant {
                #[cfg(target_arch = "x86_64")]
                LaneVariant::Avx2Fma => strip_axpy_avx2,
                _ => strip_axpy_portable,
            };
            Lanes { variant, axpy }
        })
    }

    /// `y[i] += c * vals[i] * x[i]` over the common prefix of the three
    /// slices, through the dispatched lane kernel.
    #[inline]
    pub fn axpy(&self, y: &mut [f64], vals: &[f64], x: &[f64], c: f64) {
        // Safety: the pointer was selected by `detect()`, so the
        // target features it was compiled with are present on this CPU;
        // the body itself is safe for any slice lengths.
        unsafe { (self.axpy)(y, vals, x, c) }
    }
}

/// Row tiling of a band traversal against an L2 working-set budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Rows per tile (the last tile of a range may be shorter).
    pub tile_rows: usize,
    /// Budget the plan was sized for (KiB), kept for reports.
    pub l2_kib: usize,
}

impl TilePlan {
    /// Size tiles so one tile's vector working set fits `l2_kib`: a
    /// tile of `t` rows touches ~`t + bw` entries of `x` and of `y`
    /// (the mirrored pass reaches one bandwidth ahead), each `k`
    /// columns of 8-byte f64 — so `t` solves
    /// `2 * 8 * k * (t + bw) <= l2_kib * 1024`, clamped to
    /// `[MIN_TILE_ROWS, n]`. A budget at or above the whole matrix
    /// degenerates to a single tile, i.e. the untiled traversal.
    pub fn new(n: usize, bw: usize, k: usize, l2_kib: usize) -> Self {
        let n = n.max(1);
        let budget_rows = (l2_kib.max(1) * 1024) / (16 * k.max(1));
        let tile_rows = budget_rows.saturating_sub(bw).clamp(MIN_TILE_ROWS.min(n), n);
        TilePlan { tile_rows, l2_kib }
    }

    /// Contiguous `(t0, t1)` row ranges covering `[r0, r1)` in order.
    pub fn tiles(&self, r0: usize, r1: usize) -> impl Iterator<Item = (usize, usize)> {
        let step = self.tile_rows.max(1);
        (r0..r1).step_by(step).map(move |t0| (t0, (t0 + step).min(r1)))
    }

    /// Number of tiles covering `[r0, r1)`.
    pub fn num_tiles(&self, r0: usize, r1: usize) -> usize {
        (r1.saturating_sub(r0)).div_ceil(self.tile_rows.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_axpy(y: &mut [f64], vals: &[f64], x: &[f64], c: f64) {
        for ((yi, vi), xi) in y.iter_mut().zip(vals).zip(x) {
            *yi += c * *vi * *xi;
        }
    }

    #[test]
    fn lane_axpy_matches_scalar_for_all_strip_lengths() {
        let lanes = Lanes::get();
        // every length around the chunk boundary, including 0 and tails
        for m in 0..(3 * LANE_WIDTH + 2) {
            let vals: Vec<f64> = (0..m).map(|i| (i as f64 * 0.7).sin()).collect();
            let x: Vec<f64> = (0..m).map(|i| (i as f64 * 0.3).cos()).collect();
            let mut y: Vec<f64> = (0..m).map(|i| i as f64 * 0.1).collect();
            let mut want = y.clone();
            lanes.axpy(&mut y, &vals, &x, -1.5);
            scalar_axpy(&mut want, &vals, &x, -1.5);
            for (i, (a, b)) in y.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-15, "m={m} i={i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn lane_variant_is_detected_and_named() {
        let lanes = Lanes::get();
        assert!(!lanes.variant.name().is_empty());
        // detection is idempotent and the cached dispatch agrees
        assert_eq!(Lanes::get().variant, lanes.variant);
        assert_eq!(LaneVariant::detect(), lanes.variant);
    }

    #[test]
    fn tiles_partition_the_range_exactly() {
        // tiny budget -> many tiles; they must cover [r0, r1) exactly
        // once, in order, each no longer than tile_rows
        let plan = TilePlan::new(1000, 7, 1, 1);
        let mut expect = 137usize;
        let mut count = 0;
        for (t0, t1) in plan.tiles(137, 911) {
            assert_eq!(t0, expect, "tiles must be contiguous");
            assert!(t1 > t0 && t1 - t0 <= plan.tile_rows);
            expect = t1;
            count += 1;
        }
        assert_eq!(expect, 911, "tiles must reach the end of the range");
        assert_eq!(count, plan.num_tiles(137, 911));
        assert!(count > 1, "a 1 KiB budget must split 774 rows");
    }

    #[test]
    fn single_tile_degenerate_case() {
        // budget >= whole matrix -> exactly one tile == the full range
        let plan = TilePlan::new(500, 9, 1, 1 << 20);
        assert_eq!(plan.tile_rows, 500);
        let tiles: Vec<_> = plan.tiles(0, 500).collect();
        assert_eq!(tiles, vec![(0, 500)]);
        assert_eq!(plan.num_tiles(0, 500), 1);
        // empty range -> no tiles
        assert_eq!(plan.tiles(10, 10).count(), 0);
    }

    #[test]
    fn tile_rows_scale_down_with_batch_width_and_up_with_budget() {
        let k1 = TilePlan::new(100_000, 50, 1, DEFAULT_L2_KIB);
        let k8 = TilePlan::new(100_000, 50, 8, DEFAULT_L2_KIB);
        assert!(k8.tile_rows < k1.tile_rows, "wider batches need shorter tiles");
        let big = TilePlan::new(100_000, 50, 1, 4 * DEFAULT_L2_KIB);
        assert!(big.tile_rows > k1.tile_rows);
        // budget arithmetic: k=1, 256 KiB, bw=50 -> 16384 - 50 rows
        assert_eq!(k1.tile_rows, 256 * 1024 / 16 - 50);
    }

    #[test]
    fn tile_rows_never_drop_below_the_minimum() {
        let plan = TilePlan::new(10_000, 9_999, 8, 1);
        assert_eq!(plan.tile_rows, 64, "clamped to MIN_TILE_ROWS");
        // tiny matrices clamp to n instead
        let tiny = TilePlan::new(5, 2, 1, 1);
        assert_eq!(tiny.tile_rows, 5);
    }
}
