//! Load-balance ablation: equal-rows vs equal-NNZ distribution.
//!
//! Paper §3.1.2: "Alternatively, one might consider distributing equal
//! amount of non-zero elements to processes with unequal amount of
//! rows, however, its benefits may not be as trivial to derive." This
//! module makes that discussion quantitative: it builds both partitions,
//! measures per-rank work imbalance and conflict counts, and lets the
//! cost model compare makespans (`benches/splits.rs` ablation).

use crate::kernel::split3::Split3;

/// A contiguous row partition over `p` ranks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowPartition {
    /// `starts[r]..starts[r+1]` = rows of rank `r`; length `p + 1`.
    pub starts: Vec<usize>,
}

impl RowPartition {
    /// Equal-rows blocks (the paper's choice).
    pub fn by_rows(n: usize, p: usize) -> Self {
        let d = crate::kernel::conflict::BlockDist::new(n, p);
        let mut starts: Vec<usize> = (0..p).map(|r| d.range(r).0).collect();
        starts.push(n);
        Self { starts }
    }

    /// Equal-work blocks: greedy prefix cut at `total/p` work units per
    /// rank (rows stay contiguous). Work units come from
    /// [`Split3::row_work`]: stored middle + outer entries for a pure
    /// SSS split; with the hybrid DIA middle the cut instead counts
    /// dense-diagonal **slots** (explicit zeros stream too) plus the
    /// SSS remainder and outer entries, so the partition balances what
    /// the DIA kernel actually executes.
    pub fn by_nnz(split: &Split3, p: usize) -> Self {
        let n = split.n;
        let row_nnz = split.row_work();
        let total: usize = row_nnz.iter().sum();
        let target = (total as f64 / p as f64).max(1.0);
        let mut starts = Vec::with_capacity(p + 1);
        starts.push(0usize);
        let mut acc = 0usize;
        let mut next_cut = target;
        for (i, &c) in row_nnz.iter().enumerate() {
            acc += c;
            if acc as f64 >= next_cut && starts.len() < p {
                starts.push(i + 1);
                next_cut += target;
            }
        }
        while starts.len() < p {
            // degenerate: fewer cuts than ranks; pad with empty ranks
            starts.push(n);
        }
        starts.push(n);
        Self { starts }
    }

    /// Rank count.
    pub fn p(&self) -> usize {
        self.starts.len() - 1
    }

    /// Row range of `rank`.
    pub fn range(&self, rank: usize) -> (usize, usize) {
        (self.starts[rank], self.starts[rank + 1])
    }

    /// Owner of `row` (binary search).
    pub fn rank_of(&self, row: usize) -> usize {
        match self.starts.binary_search(&row) {
            Ok(k) => k.min(self.p() - 1),
            Err(k) => k - 1,
        }
    }
}

/// Per-partition balance statistics.
#[derive(Debug, Clone)]
pub struct BalanceStats {
    /// Stored entries per rank.
    pub nnz_per_rank: Vec<usize>,
    /// Rows per rank.
    pub rows_per_rank: Vec<usize>,
    /// Cross-boundary (conflicting) entries per rank.
    pub conflicts_per_rank: Vec<usize>,
    /// `max(nnz) / mean(nnz)` — 1.0 is perfect balance.
    pub nnz_imbalance: f64,
    /// Total conflicting entries.
    pub total_conflicts: usize,
}

/// Analyze a partition over a split matrix in Θ(NNZ).
pub fn analyze(split: &Split3, part: &RowPartition) -> BalanceStats {
    let p = part.p();
    let mut nnz = vec![0usize; p];
    let mut rows = vec![0usize; p];
    let mut conf = vec![0usize; p];
    for r in 0..p {
        let (a, b) = part.range(r);
        rows[r] = b - a;
        for i in a..b {
            for (j, _) in split.middle.row(i) {
                nnz[r] += 1;
                if (j as usize) < a {
                    conf[r] += 1;
                }
            }
        }
    }
    for e in &split.outer {
        let r = part.rank_of(e.row as usize);
        nnz[r] += 1;
        if (e.col as usize) < part.range(r).0 {
            conf[r] += 1;
        }
    }
    let total: usize = nnz.iter().sum();
    let mean = total as f64 / p as f64;
    let imb = nnz.iter().copied().max().unwrap_or(0) as f64 / mean.max(1e-9);
    BalanceStats {
        nnz_imbalance: imb,
        total_conflicts: conf.iter().sum(),
        nnz_per_rank: nnz,
        rows_per_rank: rows,
        conflicts_per_rank: conf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{convert, gen, Symmetry};

    fn split_fixture(n: usize, seed: u64) -> Split3 {
        let coo = gen::small_test_matrix(n, seed, 1.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let s = convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap();
        Split3::with_outer_bw(&s, 3).unwrap()
    }

    #[test]
    fn partitions_cover_rows() {
        let split = split_fixture(300, 1);
        for p in [1, 3, 8] {
            for part in [RowPartition::by_rows(300, p), RowPartition::by_nnz(&split, p)] {
                assert_eq!(part.p(), p);
                assert_eq!(part.starts[0], 0);
                assert_eq!(*part.starts.last().unwrap(), 300);
                for w in part.starts.windows(2) {
                    assert!(w[0] <= w[1]);
                }
                for row in [0usize, 1, 150, 299] {
                    let r = part.rank_of(row);
                    let (a, b) = part.range(r);
                    assert!(a <= row && row < b, "row {row} rank {r} range {a}..{b}");
                }
            }
        }
    }

    #[test]
    fn nnz_partition_is_better_balanced() {
        let split = split_fixture(400, 2);
        let p = 8;
        let by_rows = analyze(&split, &RowPartition::by_rows(400, p));
        let by_nnz = analyze(&split, &RowPartition::by_nnz(&split, p));
        let total: usize = by_rows.nnz_per_rank.iter().sum();
        assert_eq!(total, by_nnz.nnz_per_rank.iter().sum::<usize>());
        assert!(
            by_nnz.nnz_imbalance <= by_rows.nnz_imbalance + 1e-9,
            "nnz {} vs rows {}",
            by_nnz.nnz_imbalance,
            by_rows.nnz_imbalance
        );
    }

    #[test]
    fn nnz_cuts_count_dia_slots_and_remainder() {
        let split = split_fixture(300, 4);
        let mut split_dia = split.clone();
        split_dia.select_format(crate::kernel::FormatPolicy::Dia);
        let dia = split_dia.dia.as_ref().expect("forced DIA must build");
        // the cut's work total is slots + remainder + outer, not raw nnz
        let work: usize = split_dia.row_work().iter().sum();
        assert_eq!(work, dia.dense_slots() + dia.rest.nnz_lower() + split_dia.nnz_outer());
        assert!(work >= split_dia.nnz_middle() + split_dia.nnz_outer());
        // and the partition still covers all rows for both formats
        for sp in [&split, &split_dia] {
            let part = RowPartition::by_nnz(sp, 6);
            assert_eq!(part.p(), 6);
            assert_eq!(part.starts[0], 0);
            assert_eq!(*part.starts.last().unwrap(), 300);
        }
    }

    #[test]
    fn conflicts_counted_consistently() {
        // with p=1 there are never conflicts, with any partition
        let split = split_fixture(200, 3);
        for part in [RowPartition::by_rows(200, 1), RowPartition::by_nnz(&split, 1)] {
            assert_eq!(analyze(&split, &part).total_conflicts, 0);
        }
    }
}
