//! Conflict-free phased SSpMV baseline (Elafrou et al. [3]).
//!
//! The competing approach the paper measures against: color the row
//! conflict graph, then execute one color class ("phase") at a time —
//! within a phase all rows are independent, so ranks write `y` directly
//! with no atomics; a **barrier separates phases**. The synchronization
//! cost grows with the number of phases, and high-bandwidth matrices
//! color badly — exactly the weakness PARS3's preprocessing removes.

use crate::graph::coloring::{color_rows, RowColoring};
use crate::kernel::batch::VecBatch;
use crate::kernel::dia::FormatPolicy;
use crate::kernel::split3::Split3;
use crate::mpisim::{Window, World};
use crate::sparse::Sss;
use crate::Result;
use anyhow::ensure;
use std::sync::Arc;

/// Preplanned phased executor.
#[derive(Debug)]
pub struct ColoringPlan {
    /// The matrix (shared with worker threads).
    pub s: Arc<Sss>,
    /// The row coloring.
    pub coloring: RowColoring,
    /// Rank count.
    pub p: usize,
    /// `assign[color][rank]` = rows of that class owned by the rank
    /// (work-weighted: each class is partitioned by
    /// [`Split3::row_work`], heaviest rows placed first on the
    /// least-loaded rank, so a phase's barrier waits on the *work*
    /// stragglers, not the row-count ones).
    pub assign: Vec<Vec<Vec<u32>>>,
}

impl ColoringPlan {
    /// Color the matrix and distribute each class over `p` ranks by
    /// row work (LPT greedy: rows sorted heaviest-first, each placed on
    /// the currently least-loaded rank — per class, since every class
    /// ends at its own barrier). Accepts an owned or already-shared
    /// matrix (no clone either way).
    pub fn new(s: impl Into<Arc<Sss>>, p: usize) -> Result<Self> {
        let s: Arc<Sss> = s.into();
        ensure!(p >= 1, "need at least one rank");
        let coloring = color_rows(&s);
        // DIA-aware per-row work when the band splits cleanly;
        // otherwise the raw SSS row cost (diagonal + 2 updates/entry).
        let work: Vec<usize> = match Split3::with_outer_bw_format(&s, 3, FormatPolicy::Auto) {
            Ok(split) => split.row_work(),
            Err(_) => {
                (0..s.n).map(|i| 1 + 2 * (s.row_ptr[i + 1] - s.row_ptr[i])).collect()
            }
        };
        let mut assign = Vec::with_capacity(coloring.num_colors);
        for class in &coloring.classes {
            let mut rows = class.clone();
            rows.sort_by_key(|&r| std::cmp::Reverse(work[r as usize]));
            let mut per_rank = vec![Vec::new(); p];
            let mut loads = vec![0usize; p];
            for &row in &rows {
                let rank = loads
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &w)| w)
                    .map(|(i, _)| i)
                    .expect("p >= 1");
                per_rank[rank].push(row);
                loads[rank] += work[row as usize];
            }
            assign.push(per_rank);
        }
        Ok(Self { s, coloring, p, assign })
    }

    /// Number of phases (= colors = barriers per multiply).
    pub fn phases(&self) -> usize {
        self.coloring.num_colors
    }

    /// Threaded phased execution. Within a phase writes are direct (the
    /// coloring guarantees disjoint write sets); a barrier ends each
    /// phase. Uses the atomic window for writes so the executor stays
    /// safe even if a future coloring bug violated disjointness — the
    /// *algorithmic* structure (phases + barriers) is what we model.
    pub fn execute_threaded(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.s.n);
        let window = Window::new(self.s.n);
        let win = &window;
        World::run(self.p, move |ctx| {
            let s = &*self.s;
            let sign = s.sym.sign();
            for per_rank in &self.assign {
                for &i in &per_rank[ctx.rank] {
                    let i = i as usize;
                    let xi = x[i];
                    let mut yi = s.dvalues[i] * xi;
                    for k in s.row_ptr[i]..s.row_ptr[i + 1] {
                        let j = s.col_ind[k] as usize;
                        let v = s.vals[k];
                        yi += v * x[j];
                        win.add(j, sign * v * xi);
                    }
                    win.add(i, yi);
                }
                ctx.barrier(); // phase synchronization point
            }
        });
        window.to_vec()
    }

    /// Fused threaded phased batch execution: one matrix traversal per
    /// batch; each loaded `(j, v)` is reused across all `k` columns.
    /// The accumulate window is widened to `n × k` (column-major, same
    /// layout as [`VecBatch`]) so phases keep their disjoint-write
    /// guarantee per column.
    pub fn execute_threaded_batch(&self, xs: &VecBatch, ys: &mut VecBatch) {
        let (n, kw) = (self.s.n, xs.k());
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), kw);
        let window = Window::new(n * kw);
        let win = &window;
        let xd = xs.data();
        World::run(self.p, move |ctx| {
            let s = &*self.s;
            let sign = s.sym.sign();
            let mut yi = vec![0.0f64; kw];
            for per_rank in &self.assign {
                for &i in &per_rank[ctx.rank] {
                    let i = i as usize;
                    for c in 0..kw {
                        yi[c] = s.dvalues[i] * xd[c * n + i];
                    }
                    for k in s.row_ptr[i]..s.row_ptr[i + 1] {
                        let j = s.col_ind[k] as usize;
                        let v = s.vals[k];
                        let sv = sign * v;
                        for c in 0..kw {
                            yi[c] += v * xd[c * n + j];
                            win.add(c * n + j, sv * xd[c * n + i]);
                        }
                    }
                    for c in 0..kw {
                        win.add(c * n + i, yi[c]);
                    }
                }
                ctx.barrier(); // phase synchronization point
            }
        });
        window.read_into(ys.data_mut());
    }

    /// Rank-sequential emulation (deterministic, any `p`).
    pub fn execute_emulated(&self, x: &[f64]) -> Vec<f64> {
        let s = &*self.s;
        let sign = s.sym.sign();
        let mut y = vec![0.0f64; s.n];
        for per_rank in &self.assign {
            for rows in per_rank {
                for &i in rows {
                    let i = i as usize;
                    let xi = x[i];
                    let mut yi = s.dvalues[i] * xi;
                    for k in s.row_ptr[i]..s.row_ptr[i + 1] {
                        let j = s.col_ind[k] as usize;
                        let v = s.vals[k];
                        yi += v * x[j];
                        y[j] += sign * v * xi;
                    }
                    y[i] += yi;
                }
            }
        }
        y
    }

    /// Rank-sequential fused batch emulation (deterministic, any `p`):
    /// identical numerics to [`Self::execute_emulated`] column-by-column,
    /// with one matrix traversal for the whole batch.
    pub fn execute_emulated_batch(&self, xs: &VecBatch, ys: &mut VecBatch) {
        let s = &*self.s;
        let sign = s.sym.sign();
        let (n, kw) = (s.n, xs.k());
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), kw);
        let xd = xs.data();
        ys.fill_zero();
        let yd = ys.data_mut();
        let mut yi = vec![0.0f64; kw];
        for per_rank in &self.assign {
            for rows in per_rank {
                for &i in rows {
                    let i = i as usize;
                    for c in 0..kw {
                        yi[c] = s.dvalues[i] * xd[c * n + i];
                    }
                    for k in s.row_ptr[i]..s.row_ptr[i + 1] {
                        let j = s.col_ind[k] as usize;
                        let v = s.vals[k];
                        let sv = sign * v;
                        for c in 0..kw {
                            yi[c] += v * xd[c * n + j];
                            yd[c * n + j] += sv * xd[c * n + i];
                        }
                    }
                    for c in 0..kw {
                        yd[c * n + i] += yi[c];
                    }
                }
            }
        }
    }
}

/// [`crate::kernel::Spmv`] adapter over a [`ColoringPlan`] at a fixed
/// rank count (what the kernel registry hands to solvers and benches).
pub struct ColoringKernel {
    plan: ColoringPlan,
    threaded: bool,
}

impl ColoringKernel {
    /// Color `s` and distribute over `p` ranks. `threaded = false` uses
    /// the deterministic rank-sequential emulation.
    pub fn new(s: impl Into<Arc<Sss>>, p: usize, threaded: bool) -> Result<Self> {
        Ok(Self { plan: ColoringPlan::new(s, p)?, threaded })
    }

    /// The underlying phased plan.
    pub fn plan(&self) -> &ColoringPlan {
        &self.plan
    }
}

impl crate::kernel::Spmv for ColoringKernel {
    fn n(&self) -> usize {
        self.plan.s.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        let out = if self.threaded {
            self.plan.execute_threaded(x)
        } else {
            self.plan.execute_emulated(x)
        };
        y.copy_from_slice(&out);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        if self.threaded {
            self.plan.execute_threaded_batch(xs, ys);
        } else {
            self.plan.execute_emulated_batch(xs, ys);
        }
    }

    fn flops(&self) -> u64 {
        self.plan.s.spmv_flops()
    }

    fn bytes(&self) -> u64 {
        self.plan.s.spmv_bytes()
    }

    fn name(&self) -> &'static str {
        "coloring"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen, Symmetry};

    fn banded(n: usize, seed: u64) -> Sss {
        let coo = gen::small_test_matrix(n, seed, 1.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    #[test]
    fn emulated_matches_serial() {
        let s = banded(100, 1);
        let x: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 100];
        sss_spmv(&s, &x, &mut want);
        for p in [1, 3, 8] {
            let plan = ColoringPlan::new(s.clone(), p).unwrap();
            let got = plan.execute_emulated(&x);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-10, "p={p}");
            }
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let s = banded(90, 2);
        let x: Vec<f64> = (0..90).map(|i| i as f64 * 0.01 - 0.4).collect();
        let mut want = vec![0.0; 90];
        sss_spmv(&s, &x, &mut want);
        let plan = Arc::new(ColoringPlan::new(s, 4).unwrap());
        let got = plan.execute_threaded(&x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn spmv_adapter_matches_serial() {
        use crate::kernel::Spmv;
        let s = banded(80, 4);
        let x: Vec<f64> = (0..80).map(|i| (i as f64 * 0.23).sin()).collect();
        let mut want = vec![0.0; 80];
        sss_spmv(&s, &x, &mut want);
        let mut k = ColoringKernel::new(s, 3, false).unwrap();
        let mut got = vec![0.0; 80];
        k.apply(&x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
        assert_eq!(k.name(), "coloring");
        assert!(k.plan().phases() >= 1);
    }

    #[test]
    fn batch_executors_match_columnwise_apply() {
        use crate::kernel::Spmv;
        let s = banded(70, 5);
        let xs = VecBatch::from_fn(70, 3, |i, c| ((i + c * 13) % 9) as f64 * 0.5 - 2.0);
        for threaded in [false, true] {
            let mut k = ColoringKernel::new(s.clone(), 3, threaded).unwrap();
            let mut ys = VecBatch::zeros(70, 3);
            k.apply_batch(&xs, &mut ys);
            for c in 0..3 {
                let mut want = vec![0.0; 70];
                k.apply(xs.col(c), &mut want);
                for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-10,
                        "threaded={threaded} col {c} row {r}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Each color class must be split by row *work*, not row count:
    /// the LPT greedy guarantees the heaviest rank stays within one
    /// row of the ideal per-rank share, phase by phase.
    #[test]
    fn class_partition_balances_row_work() {
        let s = banded(160, 7);
        // the same metric ColoringPlan::new partitions by
        let work: Vec<usize> = match Split3::with_outer_bw_format(&s, 3, FormatPolicy::Auto) {
            Ok(split) => split.row_work(),
            Err(_) => {
                (0..s.n).map(|i| 1 + 2 * (s.row_ptr[i + 1] - s.row_ptr[i])).collect()
            }
        };
        for p in [2, 4, 8] {
            let plan = ColoringPlan::new(s.clone(), p).unwrap();
            for (color, per_rank) in plan.assign.iter().enumerate() {
                let loads: Vec<usize> = per_rank
                    .iter()
                    .map(|rows| rows.iter().map(|&r| work[r as usize]).sum())
                    .collect();
                let total: usize = loads.iter().sum();
                let max = loads.iter().copied().max().unwrap();
                let max_row = plan.assign[color]
                    .iter()
                    .flatten()
                    .map(|&r| work[r as usize])
                    .max()
                    .unwrap_or(0);
                assert!(
                    max <= total.div_ceil(p) + max_row,
                    "color {color} p={p}: max load {max}, ideal {}, max row {max_row}",
                    total.div_ceil(p)
                );
            }
        }
    }

    #[test]
    fn phase_count_matches_coloring() {
        let s = banded(70, 3);
        let plan = ColoringPlan::new(s.clone(), 4).unwrap();
        assert_eq!(plan.phases(), crate::graph::coloring::color_rows(&s).num_colors);
        // every row appears exactly once across assignment
        let total: usize = plan
            .assign
            .iter()
            .flat_map(|pr| pr.iter().map(Vec::len))
            .sum();
        assert_eq!(total, 70);
    }
}
