//! The 3-way band split (paper §3.1.2, Figs. 6-8).
//!
//! After RCM, the (lower) band of the matrix is split into:
//!
//! 1. **diagonal split** — the dense main diagonal (for shifted
//!    skew-symmetric systems this is the constant shift);
//! 2. **middle split** — entries with diagonal distance
//!    `1 ..= split_bw`: the bulk of the NNZ, sparse inside the band;
//! 3. **outer split** — entries with distance `> split_bw`: few,
//!    scattered near the band edge, mostly conflicting under block
//!    distribution; processed sequentially per rank (paper §3.1.2).
//!
//! `split_bw` is the user bandwidth parameter; the paper's default puts
//! the outermost `outer_bw = 3` diagonals in the outer split.

use crate::kernel::blocking::DEFAULT_L2_KIB;
use crate::kernel::dia::{DiaBand, FormatPolicy};
use crate::sparse::{Sss, Symmetry};
use crate::Result;
use anyhow::ensure;

/// One entry of the outer split (COO-style, row-major sorted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OuterEntry {
    /// Row index.
    pub row: u32,
    /// Column index (`< row`).
    pub col: u32,
    /// Value.
    pub val: f64,
}

/// The 3-way split of a banded SSS matrix.
#[derive(Debug, Clone)]
pub struct Split3 {
    /// Matrix dimension.
    pub n: usize,
    /// Mirror convention inherited from the source matrix.
    pub sym: Symmetry,
    /// Diagonal split.
    pub diag: Vec<f64>,
    /// Middle split (distance `1..=split_bw`), SSS-compressed. When no
    /// DIA view is active this is the complete middle; when [`Self::dia`]
    /// is `Some` it holds **only the remainder** (entries on non-dense
    /// diagonals — the dense-covered entries live in the DIA arrays and
    /// are *not* duplicated here). Readers that need the complete entry
    /// set use [`Self::for_each_middle_entry`] or [`Self::full_middle`].
    pub middle: Sss,
    /// Hybrid diagonal-major view of the middle split (dense diagonals
    /// + SSS remainder), present when a [`FormatPolicy`] selected it.
    /// Kernels that see `Some` run the unit-stride DIA loops instead of
    /// the `col_ind` gather over `middle`.
    pub dia: Option<DiaBand>,
    /// Outer split (distance `> split_bw`), row-major COO.
    pub outer: Vec<OuterEntry>,
    /// The split boundary (user bandwidth parameter).
    pub split_bw: usize,
    /// L2 tile budget (KiB) handed to the DIA view's blocked passes.
    pub l2_kib: usize,
    /// Total bandwidth of the source band matrix.
    pub total_bw: usize,
    /// Name of the reordering strategy that produced the band this
    /// split was built from (`None` when the caller split an
    /// unannotated matrix directly). Set by
    /// [`crate::coordinator::Coordinator::prepare`]; flows into
    /// [`crate::kernel::pars3::Pars3Stats`].
    pub reorder_strategy: Option<&'static str>,
    /// The planner's resolved `reorder=... format=... backend=...`
    /// label when this split came out of a planned `prepare` (`None`
    /// for direct registry/bench construction). Flows into
    /// [`crate::kernel::pars3::Pars3Stats`] like `reorder_strategy`.
    pub plan_triple: Option<String>,
}

impl Split3 {
    /// Split `s` at diagonal distance `split_bw` with the pure SSS
    /// middle split (the paper's layout).
    pub fn new(s: &Sss, split_bw: usize) -> Result<Self> {
        Self::with_format(s, split_bw, FormatPolicy::Sss)
    }

    /// Split `s` at diagonal distance `split_bw`, selecting the
    /// middle-split storage per `policy`.
    pub fn with_format(s: &Sss, split_bw: usize, policy: FormatPolicy) -> Result<Self> {
        Self::with_format_budget(s, split_bw, policy, DEFAULT_L2_KIB)
    }

    /// [`Self::with_format`] with an explicit L2 tile budget (KiB) for
    /// the DIA view's blocked passes.
    pub fn with_format_budget(
        s: &Sss,
        split_bw: usize,
        policy: FormatPolicy,
        l2_kib: usize,
    ) -> Result<Self> {
        ensure!(split_bw >= 1, "split_bw must be >= 1");
        let total_bw = s.bandwidth();
        let mut row_ptr = vec![0usize; s.n + 1];
        let mut col_ind = Vec::new();
        let mut vals = Vec::new();
        let mut outer = Vec::new();
        for i in 0..s.n {
            for (j, v) in s.row(i) {
                let d = i - j as usize;
                if d <= split_bw {
                    col_ind.push(j);
                    vals.push(v);
                } else {
                    outer.push(OuterEntry { row: i as u32, col: j, val: v });
                }
            }
            row_ptr[i + 1] = vals.len();
        }
        let middle = Sss {
            n: s.n,
            dvalues: vec![0.0; s.n], // diagonal lives in `diag`
            row_ptr,
            col_ind,
            vals,
            sym: s.sym,
        };
        let mut split = Self {
            n: s.n,
            sym: s.sym,
            diag: s.dvalues.clone(),
            middle,
            dia: None,
            outer,
            split_bw,
            l2_kib,
            total_bw,
            reorder_strategy: None,
            plan_triple: None,
        };
        split.select_format(policy);
        Ok(split)
    }

    /// Paper default: outer split = the outermost `outer_bw` diagonals of
    /// the actual band (`split_bw = total_bw - outer_bw`), pure SSS middle.
    pub fn with_outer_bw(s: &Sss, outer_bw: usize) -> Result<Self> {
        Self::with_outer_bw_format(s, outer_bw, FormatPolicy::Sss)
    }

    /// Like [`Self::with_outer_bw`] with a middle-split storage policy.
    pub fn with_outer_bw_format(s: &Sss, outer_bw: usize, policy: FormatPolicy) -> Result<Self> {
        Self::with_outer_bw_format_budget(s, outer_bw, policy, DEFAULT_L2_KIB)
    }

    /// [`Self::with_outer_bw_format`] with an explicit L2 tile budget.
    pub fn with_outer_bw_format_budget(
        s: &Sss,
        outer_bw: usize,
        policy: FormatPolicy,
        l2_kib: usize,
    ) -> Result<Self> {
        let total = s.bandwidth();
        let split_bw = total.saturating_sub(outer_bw).max(1);
        Self::with_format_budget(s, split_bw, policy, l2_kib)
    }

    /// (Re)select the middle-split storage: builds the DIA view when the
    /// policy (or its fill heuristic) picks it, clears it otherwise.
    /// With a DIA view active the stored SSS `middle` keeps **only the
    /// remainder** — dense-covered entries are not duplicated, halving
    /// middle memory versus dual storage. Re-selection first
    /// reconstructs the complete middle so no entry is ever lost.
    pub fn select_format(&mut self, policy: FormatPolicy) {
        let full = self.full_middle();
        self.dia = DiaBand::from_policy_budget(&full, policy, self.l2_kib);
        self.middle = match &self.dia {
            Some(dia) => dia.rest.clone(),
            None => full,
        };
    }

    /// Reconstruct the complete middle-split SSS: dense-covered entries
    /// (true nonzeros only) merged back with the stored remainder. With
    /// no DIA view active this is a clone of [`Self::middle`].
    pub fn full_middle(&self) -> Sss {
        let Some(dia) = &self.dia else {
            return self.middle.clone();
        };
        let mut row_ptr = vec![0usize; self.n + 1];
        let mut col_ind = Vec::with_capacity(self.nnz_middle());
        let mut vals = Vec::with_capacity(self.nnz_middle());
        let mut row: Vec<(u32, f64)> = Vec::new();
        for i in 0..self.n {
            row.clear();
            self.for_each_middle_entry(i, |j, v| row.push((j as u32, v)));
            row.sort_unstable_by_key(|&(j, _)| j);
            for &(j, v) in &row {
                col_ind.push(j);
                vals.push(v);
            }
            row_ptr[i + 1] = vals.len();
        }
        Sss {
            n: self.n,
            dvalues: vec![0.0; self.n],
            row_ptr,
            col_ind,
            vals,
            sym: self.sym,
        }
    }

    /// Visit every **true** middle-split nonzero of row `i` as
    /// `(col, val)`, independent of storage: dense-diagonal slots
    /// holding a nonzero plus the stored SSS rows (the remainder when a
    /// DIA view is active, the whole middle otherwise). Explicit-zero
    /// dense slots are skipped, so conflict/halo analysis built on this
    /// sees exactly the same entry set for both formats. Column order
    /// is not guaranteed.
    pub fn for_each_middle_entry(&self, i: usize, mut f: impl FnMut(usize, f64)) {
        if let Some(dia) = &self.dia {
            for dd in &dia.diags {
                if i >= dd.d {
                    let j = i - dd.d;
                    let v = dd.vals[j];
                    if v != 0.0 {
                        f(j, v);
                    }
                }
            }
        }
        for (j, v) in self.middle.row(i) {
            f(j as usize, v);
        }
    }

    /// Name of the active middle-split storage (for stats/reports).
    pub fn format_name(&self) -> &'static str {
        if self.dia.is_some() {
            "dia"
        } else {
            "sss"
        }
    }

    /// Per-row work units for load balancing. With the DIA view active a
    /// row pays for its dense-diagonal **slots** (explicit zeros
    /// included — they are streamed regardless) plus remainder and outer
    /// entries; pure SSS rows pay middle + outer entries.
    pub fn row_work(&self) -> Vec<usize> {
        let mut w = vec![0usize; self.n];
        match &self.dia {
            Some(dia) => {
                for dd in &dia.diags {
                    for cost in w.iter_mut().skip(dd.d) {
                        *cost += 1;
                    }
                }
                for (i, cost) in w.iter_mut().enumerate() {
                    *cost += dia.rest.row_ptr[i + 1] - dia.rest.row_ptr[i];
                }
            }
            None => {
                for (i, cost) in w.iter_mut().enumerate() {
                    *cost += self.middle.row_ptr[i + 1] - self.middle.row_ptr[i];
                }
            }
        }
        for e in &self.outer {
            w[e.row as usize] += 1;
        }
        w
    }

    /// NNZ partition invariant check: middle + outer == source lower
    /// NNZ. True nonzeros regardless of storage — with a DIA view this
    /// is dense nonzeros + remainder, not slots.
    pub fn nnz_middle(&self) -> usize {
        match &self.dia {
            Some(dia) => dia.nnz(),
            None => self.middle.nnz_lower(),
        }
    }

    /// Outer-split NNZ.
    pub fn nnz_outer(&self) -> usize {
        self.outer.len()
    }

    /// Serial SpMV over the three splits. With the pure SSS middle this
    /// agrees *exactly* with [`crate::kernel::serial_sss::sss_spmv`] on
    /// the unsplit matrix (same per-row accumulation order); the DIA
    /// view accumulates diagonal-major, so it agrees to rounding only.
    pub fn spmv_serial(&self, x: &[f64], y: &mut [f64]) {
        let sign = self.sym.sign();
        // diagonal split
        for i in 0..self.n {
            y[i] = self.diag[i] * x[i];
        }
        // middle split
        match &self.dia {
            Some(dia) => dia.apply_add(x, y),
            None => {
                for i in 0..self.n {
                    let xi = x[i];
                    let mut yi = 0.0;
                    for k in self.middle.row_ptr[i]..self.middle.row_ptr[i + 1] {
                        let j = self.middle.col_ind[k] as usize;
                        let v = self.middle.vals[k];
                        yi += v * x[j];
                        y[j] += sign * v * xi;
                    }
                    y[i] += yi;
                }
            }
        }
        // outer split (sequential tail, paper §3.1.2)
        for e in &self.outer {
            let (i, j) = (e.row as usize, e.col as usize);
            y[i] += e.val * x[j];
            y[j] += sign * e.val * x[i];
        }
    }

    /// Reassemble the original SSS matrix (for tests / invariants).
    pub fn unsplit(&self) -> Sss {
        let mut entries: Vec<(u32, u32, f64)> = Vec::with_capacity(self.nnz_middle() + self.nnz_outer());
        for i in 0..self.n {
            self.for_each_middle_entry(i, |j, v| entries.push((i as u32, j as u32, v)));
        }
        for e in &self.outer {
            entries.push((e.row, e.col, e.val));
        }
        entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut row_ptr = vec![0usize; self.n + 1];
        let mut col_ind = Vec::with_capacity(entries.len());
        let mut vals = Vec::with_capacity(entries.len());
        let mut r = 0usize;
        for (i, j, v) in entries {
            while r < i as usize {
                r += 1;
                row_ptr[r] = col_ind.len();
            }
            col_ind.push(j);
            vals.push(v);
        }
        while r < self.n {
            r += 1;
            row_ptr[r] = col_ind.len();
        }
        Sss {
            n: self.n,
            dvalues: self.diag.clone(),
            row_ptr,
            col_ind,
            vals,
            sym: self.sym,
        }
    }

    /// Per-split statistics for the Figs. 6-8 report: `(name, nnz,
    /// slots-in-region, density)` rows.
    pub fn density_stats(&self) -> Vec<(&'static str, usize, u64, f64)> {
        let n = self.n as u64;
        let diag_nnz = self.diag.iter().filter(|v| **v != 0.0).count();
        let area = |bw_lo: u64, bw_hi: u64| -> u64 {
            // slots with diagonal distance in (bw_lo, bw_hi]
            let f = |b: u64| -> u64 {
                if n > b {
                    b * (b + 1) / 2 + (n - b - 1) * b
                } else {
                    n * (n - 1) / 2
                }
            };
            f(bw_hi) - f(bw_lo)
        };
        let mid_area = area(0, self.split_bw as u64).max(1);
        let out_area = area(self.split_bw as u64, self.total_bw as u64).max(1);
        vec![
            ("diag", diag_nnz, n, diag_nnz as f64 / n as f64),
            (
                "middle",
                self.nnz_middle(),
                mid_area,
                self.nnz_middle() as f64 / mid_area as f64,
            ),
            (
                "outer",
                self.nnz_outer(),
                out_area,
                self.nnz_outer() as f64 / out_area as f64,
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen};

    fn band_fixture(n: usize, seed: u64) -> Sss {
        // RCM-reorder a random matrix so it is genuinely banded
        let coo = gen::small_test_matrix(n, seed, 2.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let p = coo.permute_symmetric(&perm);
        convert::coo_to_sss(&p, Symmetry::Skew).unwrap()
    }

    #[test]
    fn partition_is_exact() {
        let s = band_fixture(80, 1);
        let total = s.nnz_lower();
        for split_bw in [1, 3, 8, 1000] {
            let sp = Split3::new(&s, split_bw).unwrap();
            assert_eq!(sp.nnz_middle() + sp.nnz_outer(), total, "split_bw={split_bw}");
        }
    }

    #[test]
    fn unsplit_roundtrips() {
        let s = band_fixture(60, 2);
        let sp = Split3::new(&s, 4).unwrap();
        assert_eq!(sp.unsplit(), s);
    }

    #[test]
    fn spmv_matches_unsplit_kernel() {
        let s = band_fixture(90, 3);
        let x: Vec<f64> = (0..90).map(|i| ((i * 29) % 13) as f64 - 6.0).collect();
        let mut want = vec![0.0; 90];
        sss_spmv(&s, &x, &mut want);
        for split_bw in [1, 2, 5, 20] {
            let sp = Split3::new(&s, split_bw).unwrap();
            let mut got = vec![0.0; 90];
            sp.spmv_serial(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12, "split_bw={split_bw}");
            }
        }
    }

    #[test]
    fn with_outer_bw_puts_fringe_outside() {
        let s = band_fixture(80, 4);
        let bw = s.bandwidth();
        let sp = Split3::with_outer_bw(&s, 3).unwrap();
        assert_eq!(sp.split_bw, bw - 3);
        for e in &sp.outer {
            assert!((e.row - e.col) as usize > bw - 3);
        }
    }

    #[test]
    fn middle_is_majority_outer_is_small() {
        // paper's observation: middle carries the bulk, outer is tiny
        let s = band_fixture(200, 5);
        let sp = Split3::with_outer_bw(&s, 3).unwrap();
        assert!(sp.nnz_middle() > sp.nnz_outer());
    }

    #[test]
    fn density_stats_sum_to_total() {
        let s = band_fixture(100, 6);
        let sp = Split3::new(&s, 5).unwrap();
        let stats = sp.density_stats();
        let total: usize = stats.iter().map(|(_, nnz, _, _)| *nnz).sum();
        let diag_nnz = sp.diag.iter().filter(|v| **v != 0.0).count();
        assert_eq!(total, s.nnz_lower() + diag_nnz);
    }

    #[test]
    fn rejects_zero_split_bw() {
        let s = band_fixture(30, 7);
        assert!(Split3::new(&s, 0).is_err());
    }

    #[test]
    fn dia_format_spmv_matches_sss_format() {
        let s = band_fixture(90, 8);
        let x: Vec<f64> = (0..90).map(|i| ((i * 13) % 11) as f64 * 0.5 - 2.0).collect();
        for split_bw in [2, 5, 20] {
            let sp_sss = Split3::new(&s, split_bw).unwrap();
            assert_eq!(sp_sss.format_name(), "sss");
            let sp_dia =
                Split3::with_format(&s, split_bw, crate::kernel::FormatPolicy::Dia).unwrap();
            assert_eq!(sp_dia.format_name(), "dia");
            let dia = sp_dia.dia.as_ref().unwrap();
            // the DIA view partitions exactly the middle entries
            assert_eq!(dia.nnz(), sp_dia.nnz_middle());
            let mut want = vec![0.0; 90];
            sp_sss.spmv_serial(&x, &mut want);
            let mut got = vec![0.0; 90];
            sp_dia.spmv_serial(&x, &mut got);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "split_bw={split_bw} row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dia_middle_stores_only_the_remainder() {
        let s = band_fixture(150, 10);
        let total = s.nnz_lower();
        for policy in [FormatPolicy::Auto, FormatPolicy::Dia] {
            let sp = Split3::with_outer_bw_format(&s, 3, policy).unwrap();
            if let Some(dia) = &sp.dia {
                // the stored SSS middle is exactly the DIA remainder —
                // dense-covered entries are not duplicated
                assert_eq!(sp.middle.nnz_lower(), dia.rest.nnz_lower());
                assert_eq!(sp.middle.row_ptr, dia.rest.row_ptr);
                // the partition invariant holds on true nonzeros
                assert_eq!(sp.nnz_middle(), dia.dense_nnz + sp.middle.nnz_lower());
                assert_eq!(sp.nnz_middle() + sp.nnz_outer(), total, "{policy}");
            }
        }
        // forced DIA drops every entry from the stored middle
        let sp = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        assert_eq!(sp.middle.nnz_lower(), 0);
        assert_eq!(sp.nnz_middle() + sp.nnz_outer(), total);
    }

    #[test]
    fn select_format_is_reentrant_and_unsplit_roundtrips() {
        let s = band_fixture(100, 11);
        let mut sp = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        assert_eq!(sp.unsplit(), s, "unsplit must merge dense + remainder");
        // flip back to SSS: the full middle must be reconstructed
        sp.select_format(FormatPolicy::Sss);
        assert!(sp.dia.is_none());
        assert_eq!(sp.unsplit(), s);
        // and forward again — re-selection must never lose entries
        sp.select_format(FormatPolicy::Dia);
        assert!(sp.dia.is_some());
        assert_eq!(sp.unsplit(), s);
        // full_middle agrees with a never-DIA split's middle
        let plain = Split3::with_outer_bw(&s, 3).unwrap();
        assert_eq!(sp.full_middle(), plain.middle);
    }

    #[test]
    fn for_each_middle_entry_sees_the_same_set_for_both_formats() {
        let s = band_fixture(130, 12);
        let collect = |sp: &Split3| {
            let mut es: Vec<(usize, usize, f64)> = Vec::new();
            for i in 0..sp.n {
                sp.for_each_middle_entry(i, |j, v| es.push((i, j, v)));
            }
            es.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            es
        };
        let sss = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Sss).unwrap();
        let dia = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        assert_eq!(collect(&sss), collect(&dia));
        assert_eq!(collect(&sss).len(), sss.nnz_middle());
    }

    #[test]
    fn row_work_counts_dia_slots_and_remainder() {
        let s = band_fixture(120, 9);
        let sp = Split3::with_outer_bw(&s, 3).unwrap();
        // pure SSS: work == actual entries
        assert_eq!(
            sp.row_work().iter().sum::<usize>(),
            sp.nnz_middle() + sp.nnz_outer()
        );
        let mut sp_dia = sp.clone();
        sp_dia.select_format(crate::kernel::FormatPolicy::Dia);
        let dia = sp_dia.dia.as_ref().unwrap();
        // DIA: dense slots (zeros included) + remainder + outer
        assert_eq!(
            sp_dia.row_work().iter().sum::<usize>(),
            dia.dense_slots() + dia.rest.nnz_lower() + sp_dia.nnz_outer()
        );
        assert!(dia.dense_slots() >= dia.dense_nnz);
    }
}
