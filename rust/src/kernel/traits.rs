//! Common kernel interface so solvers and benches swap kernels freely.

/// A repeated-multiply kernel `y = A x` (the iterative-solver hot path).
pub trait Spmv {
    /// Matrix dimension.
    fn n(&self) -> usize;

    /// Compute `y = A x`. `x.len() == y.len() == n()`.
    fn apply(&mut self, x: &[f64], y: &mut [f64]);

    /// Floating-point ops per `apply` (for roofline/throughput reports).
    fn flops(&self) -> u64;

    /// Bytes of matrix data touched per `apply` (memory-bound roofline).
    fn bytes(&self) -> u64;

    /// Human-readable kernel name for reports.
    fn name(&self) -> &'static str;
}
