//! Common kernel interface so solvers and benches swap kernels freely.

use crate::kernel::batch::VecBatch;

/// A repeated-multiply kernel `y = A x` (the iterative-solver hot path).
///
/// `Send` is a supertrait so built kernels (`Box<dyn Spmv>`) can be
/// cached inside the service worker thread and handed across threads;
/// every kernel in the crate is a value type over `Arc`s, channels and
/// atomics, so the bound costs nothing.
pub trait Spmv: Send {
    /// Matrix dimension.
    fn n(&self) -> usize;

    /// Compute `y = A x`. `x.len() == y.len() == n()`.
    fn apply(&mut self, x: &[f64], y: &mut [f64]);

    /// Compute `ys = A xs` for an `n × k` column-major batch (the
    /// multi-RHS / block-Krylov hot path). Kernels with a native fused
    /// implementation traverse the matrix **once** per batch, reusing
    /// each loaded `(j, a_ij)` across all `k` columns; this default
    /// falls back to `k` independent [`Spmv::apply`] calls and is
    /// numerically the reference the fused paths are tested against.
    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        assert_eq!(xs.n(), self.n(), "batch row count != kernel n");
        assert_eq!(xs.n(), ys.n());
        assert_eq!(xs.k(), ys.k(), "input/output batch widths differ");
        for c in 0..xs.k() {
            self.apply(xs.col(c), ys.col_mut(c));
        }
    }

    /// Hint the batch width of upcoming [`Spmv::apply_batch`] calls so
    /// plans can size scratch (windows, halo buffers) once instead of
    /// on the first batched multiply. Optional; the default is a no-op
    /// and kernels must still handle unhinted widths.
    fn prepare_hint(&mut self, _k: usize) {}

    /// False when the kernel can no longer serve applies (e.g. a
    /// threaded executor whose rank world was poisoned by a panic).
    /// Caches consult this to evict and rebuild instead of handing a
    /// wedged kernel back to every later request. Default: healthy.
    fn healthy(&self) -> bool {
        true
    }

    /// Floating-point ops per `apply` (for roofline/throughput reports).
    fn flops(&self) -> u64;

    /// Bytes of matrix data touched per `apply` (memory-bound roofline).
    fn bytes(&self) -> u64;

    /// Human-readable kernel name for reports.
    fn name(&self) -> &'static str;
}
