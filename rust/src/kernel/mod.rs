//! SpMV kernels: the serial baseline (paper Alg. 1), the 3-way band
//! split, conflict pre-identification, the parallel PARS3 kernel (the
//! paper's contribution), and the graph-coloring phased baseline
//! (Elafrou et al. [3]) it is compared against.

pub mod balance;
pub mod batch;
pub mod blocking;
pub mod coloring_spmv;
pub mod conflict;
pub mod csr_spmv;
pub mod dgbmv;
pub mod dia;
pub mod pars3;
pub mod race;
pub mod registry;
pub mod serial_sss;
pub mod split3;
pub mod traits;

pub use batch::VecBatch;
pub use blocking::{LaneVariant, Lanes, TilePlan, DEFAULT_L2_KIB, LANE_WIDTH};
pub use conflict::{BlockDist, ConflictMap};
pub use dia::FormatPolicy;
pub use pars3::Pars3Plan;
pub use registry::{KernelConfig, KERNEL_NAMES};
pub use split3::Split3;
pub use traits::Spmv;
