//! LAPACK-style banded matrix-vector multiply (`dgbmv` analogue).
//!
//! The paper's §2 discusses BLAS `dgbmv`: after RCM, the band can be
//! compressed into LAPACK banded storage — a dense `(2β+1) × n` array
//! with `ab[β + i - j][j] = A[i][j]` — trading **wasted storage on
//! explicit zeros inside the band** for perfectly regular access. This
//! module implements that baseline so the trade-off is measurable
//! (`benches/serial_baseline.rs`): for dense bands it wins on locality,
//! for the sparse post-RCM middle split it loses on wasted traffic —
//! which is exactly why PARS3 splits the band instead.
//!
//! A [`FormatPolicy`] can additionally promote this kernel to the
//! **hybrid** layout ([`crate::kernel::dia::DiaBand`]): only lower
//! sub-diagonals that clear the fill heuristic are stored densely (the
//! skew/symmetric mirror is applied by sign on the fly, halving the
//! classic both-triangle storage), and the scattered remainder rides an
//! SSS gather loop instead of wasting dense slots.

use crate::kernel::batch::VecBatch;
use crate::kernel::blocking::{Lanes, TilePlan, DEFAULT_L2_KIB};
use crate::kernel::dia::{DiaBand, FormatPolicy};
use crate::kernel::traits::Spmv;
use crate::sparse::{Sss, Symmetry};
use crate::Result;
use anyhow::ensure;

/// Hybrid-mode storage: main diagonal + diagonal-major lower band.
#[derive(Debug, Clone)]
struct HybridBand {
    diag: Vec<f64>,
    dia: DiaBand,
}

/// LAPACK-style banded matrix: classic dense both-triangle band, or the
/// hybrid diagonal-major layout when a [`FormatPolicy`] selects it.
#[derive(Debug, Clone)]
pub struct BandedDgbmv {
    /// Matrix dimension.
    pub n: usize,
    /// Half-bandwidth.
    pub beta: usize,
    /// Column-major LAPACK band storage: `ab[d * n + j] = A[j + d - beta][j]`
    /// for `d in 0..=2*beta` (rows `beta` above to `beta` below).
    /// Empty in hybrid mode.
    pub ab: Vec<f64>,
    /// Hybrid diagonal-major mode (`None` = classic dense band).
    hybrid: Option<HybridBand>,
    /// L2 tile budget (KiB) the classic band traversal blocks against
    /// (the hybrid mode's [`DiaBand`] carries its own copy).
    pub l2_kib: usize,
    /// Lane dispatch captured at build.
    lanes: Lanes,
}

impl BandedDgbmv {
    /// Build the classic dense band from an SSS matrix (expands the
    /// implied triangle; errors if the matrix is empty).
    pub fn from_sss(s: &Sss) -> Result<Self> {
        Self::from_sss_budget(s, DEFAULT_L2_KIB)
    }

    /// [`Self::from_sss`] with an explicit L2 tile budget (KiB).
    pub fn from_sss_budget(s: &Sss, l2_kib: usize) -> Result<Self> {
        let beta = s.bandwidth();
        ensure!(s.n > 0, "empty matrix");
        let sign = s.sym.sign();
        let width = 2 * beta + 1;
        let mut ab = vec![0.0f64; width * s.n];
        for i in 0..s.n {
            // diagonal at band row beta
            ab[beta * s.n + i] = s.dvalues[i];
            for (j, v) in s.row(i) {
                let j = j as usize;
                // lower entry A[i][j] at band row beta + i - j, column j
                ab[(beta + i - j) * s.n + j] = v;
                // mirrored upper entry A[j][i] at band row beta + j - i, column i
                ab[(beta + j - i) * s.n + i] = sign * v;
            }
        }
        Ok(Self { n: s.n, beta, ab, hybrid: None, l2_kib, lanes: Lanes::get() })
    }

    /// Build per the storage policy: the hybrid diagonal-major layout
    /// when the policy (or its fill heuristic) selects dense diagonals,
    /// the classic dense band otherwise.
    pub fn from_sss_format(s: &Sss, policy: FormatPolicy) -> Result<Self> {
        Self::from_sss_format_budget(s, policy, DEFAULT_L2_KIB)
    }

    /// [`Self::from_sss_format`] with an explicit L2 tile budget (KiB).
    pub fn from_sss_format_budget(s: &Sss, policy: FormatPolicy, l2_kib: usize) -> Result<Self> {
        ensure!(s.n > 0, "empty matrix");
        match DiaBand::from_policy_budget(s, policy, l2_kib) {
            Some(dia) => Ok(Self {
                n: s.n,
                beta: s.bandwidth(),
                ab: Vec::new(),
                hybrid: Some(HybridBand { diag: s.dvalues.clone(), dia }),
                l2_kib,
                lanes: Lanes::get(),
            }),
            None => Self::from_sss_budget(s, l2_kib),
        }
    }

    /// True when the hybrid diagonal-major layout is active.
    pub fn is_hybrid(&self) -> bool {
        self.hybrid.is_some()
    }

    /// Per-tile clamp of band row `d`'s column range: `i = j + off`
    /// must land in the row tile `[t0, t1)` and in `[0, n)`. Returns
    /// `(off, j_lo, j_hi)`.
    fn tile_range(&self, d: usize, t0: usize, t1: usize) -> (isize, usize, usize) {
        let (n, beta) = (self.n, self.beta);
        let off = d as isize - beta as isize;
        let j_lo = (t0 as isize - off).max(0) as usize;
        let j_hi_diag = if off > 0 { n - off as usize } else { n };
        let j_hi = ((t1 as isize - off).max(0) as usize).min(j_hi_diag);
        (off, j_lo, j_hi)
    }

    /// `y = A x`. The classic band touches every slot, zeros included
    /// (the dgbmv trade-off), but runs row tiles outer × band rows
    /// inner — one tile's x/y windows stay L2-resident across all
    /// `2β+1` diagonals — with each diagonal's tile segment as one
    /// unit-stride lane strip. Hybrid mode runs the blocked DIA passes
    /// plus the SSS remainder.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        if let Some(h) = &self.hybrid {
            for (yi, (&d, &xi)) in y.iter_mut().zip(h.diag.iter().zip(x)) {
                *yi = d * xi;
            }
            h.dia.apply_add(x, y);
            return;
        }
        let (n, beta) = (self.n, self.beta);
        y.iter_mut().for_each(|v| *v = 0.0);
        let plan = TilePlan::new(n, 2 * beta, 1, self.l2_kib);
        for (t0, t1) in plan.tiles(0, n) {
            for d in 0..=2 * beta {
                // band row d holds A[i][j] with i - j = d - beta
                let (off, j_lo, j_hi) = self.tile_range(d, t0, t1);
                if j_lo >= j_hi {
                    continue;
                }
                let row = &self.ab[d * n..(d + 1) * n];
                let i0 = (j_lo as isize + off) as usize;
                let m = j_hi - j_lo;
                self.lanes.axpy(&mut y[i0..i0 + m], &row[j_lo..j_hi], &x[j_lo..j_hi], 1.0);
            }
        }
    }

    /// Fused batch band multiply (a `dgbmv`-to-`dgbmm` promotion),
    /// tiled like [`Self::spmv`]: within a tile each band row runs one
    /// lane strip per batch column, so a band slot is re-read from a
    /// still-resident tile line rather than streamed `k` times.
    pub fn spmv_batch(&self, xs: &VecBatch, ys: &mut VecBatch) {
        let (n, beta, kw) = (self.n, self.beta, xs.k());
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), kw);
        if let Some(h) = &self.hybrid {
            {
                let xd = xs.data();
                let yd = ys.data_mut();
                for c in 0..kw {
                    for i in 0..n {
                        yd[c * n + i] = h.diag[i] * xd[c * n + i];
                    }
                }
            }
            h.dia.apply_add_batch(xs, ys);
            return;
        }
        let xd = xs.data();
        let yd = ys.data_mut();
        yd.iter_mut().for_each(|v| *v = 0.0);
        let plan = TilePlan::new(n, 2 * beta, kw, self.l2_kib);
        for (t0, t1) in plan.tiles(0, n) {
            for d in 0..=2 * beta {
                let (off, j_lo, j_hi) = self.tile_range(d, t0, t1);
                if j_lo >= j_hi {
                    continue;
                }
                let row = &self.ab[d * n..(d + 1) * n];
                let i0 = (j_lo as isize + off) as usize;
                let m = j_hi - j_lo;
                for c in 0..kw {
                    let xcol = &xd[c * n..(c + 1) * n];
                    let ycol = &mut yd[c * n..(c + 1) * n];
                    self.lanes.axpy(&mut ycol[i0..i0 + m], &row[j_lo..j_hi], &xcol[j_lo..j_hi], 1.0);
                }
            }
        }
    }

    /// Fraction of stored band slots that are explicit zeros (the wasted
    /// storage §2 points out). Hybrid mode only pays for the selected
    /// dense diagonals, so its waste is bounded by their fill.
    pub fn waste_ratio(&self) -> f64 {
        if let Some(h) = &self.hybrid {
            let stored = h.dia.dense_slots() + h.dia.rest.nnz_lower();
            if stored == 0 {
                return 0.0;
            }
            return (h.dia.dense_slots() - h.dia.dense_nnz) as f64 / stored as f64;
        }
        if self.ab.is_empty() {
            return 0.0;
        }
        let zeros = self.ab.iter().filter(|v| **v == 0.0).count();
        zeros as f64 / self.ab.len() as f64
    }
}

impl Spmv for BandedDgbmv {
    fn n(&self) -> usize {
        self.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        Self::spmv(self, x, y);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        Self::spmv_batch(self, xs, ys);
    }

    fn flops(&self) -> u64 {
        match &self.hybrid {
            // each stored slot/entry drives both the forward and the
            // mirrored multiply-accumulate
            Some(h) => (self.n + 4 * (h.dia.dense_slots() + h.dia.rest.nnz_lower())) as u64,
            None => (2 * (2 * self.beta + 1) * self.n) as u64,
        }
    }

    fn bytes(&self) -> u64 {
        match &self.hybrid {
            Some(h) => (self.n * 8) as u64 + h.dia.bytes(),
            None => ((2 * self.beta + 1) * self.n * 8) as u64,
        }
    }

    fn name(&self) -> &'static str {
        "dgbmv"
    }
}

/// Convenience check used by tests/benches.
pub fn is_skew(s: &Sss) -> bool {
    s.sym == Symmetry::Skew
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen};

    fn banded(n: usize, seed: u64) -> Sss {
        let mut rng = crate::util::SmallRng::seed_from_u64(seed);
        let edges = gen::random_banded_pattern(n, 3, 0.5, &mut rng);
        let coo = crate::sparse::skew::coo_from_pattern(n, &edges, 1.5, &mut rng);
        convert::coo_to_sss(&coo, Symmetry::Skew).unwrap()
    }

    #[test]
    fn matches_serial_sss() {
        let s = banded(200, 1);
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.17).sin()).collect();
        let mut want = vec![0.0; 200];
        sss_spmv(&s, &x, &mut want);
        let mut got = vec![0.0; 200];
        b.spmv(&x, &mut got);
        for (a, c) in got.iter().zip(&want) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_batch_matches_columnwise() {
        let s = banded(120, 3);
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let xs = VecBatch::from_fn(120, 3, |i, c| ((i * 7 + c) % 11) as f64 * 0.2 - 1.0);
        let mut ys = VecBatch::zeros(120, 3);
        b.spmv_batch(&xs, &mut ys);
        for c in 0..3 {
            let mut want = vec![0.0; 120];
            b.spmv(xs.col(c), &mut want);
            assert_eq!(ys.col(c), &want[..], "column {c}");
        }
    }

    #[test]
    fn symmetric_variant_matches() {
        let mut coo = crate::sparse::Coo::new(50);
        for i in 0..50u32 {
            coo.push(i, i, 2.0);
        }
        for i in 1..50u32 {
            coo.push(i, i - 1, 0.5);
            coo.push(i - 1, i, 0.5);
        }
        let s = convert::coo_to_sss(&coo, Symmetry::Symmetric).unwrap();
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let mut want = vec![0.0; 50];
        sss_spmv(&s, &x, &mut want);
        let mut got = vec![0.0; 50];
        b.spmv(&x, &mut got);
        for (a, c) in got.iter().zip(&want) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn hybrid_mode_matches_classic_dense_band() {
        let s = banded(150, 5);
        let classic = BandedDgbmv::from_sss(&s).unwrap();
        let hybrid = BandedDgbmv::from_sss_format(&s, FormatPolicy::Dia).unwrap();
        assert!(hybrid.is_hybrid());
        assert!(!classic.is_hybrid());
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.23).sin()).collect();
        let (mut a, mut b) = (vec![0.0; 150], vec![0.0; 150]);
        classic.spmv(&x, &mut a);
        hybrid.spmv(&x, &mut b);
        for (r, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!((u - v).abs() < 1e-10, "row {r}: {u} vs {v}");
        }
        // batch path too
        let xs = VecBatch::from_fn(150, 3, |i, c| ((i * 3 + c * 5) % 7) as f64 * 0.5 - 1.5);
        let mut ya = VecBatch::zeros(150, 3);
        let mut yb = VecBatch::zeros(150, 3);
        classic.spmv_batch(&xs, &mut ya);
        hybrid.spmv_batch(&xs, &mut yb);
        for c in 0..3 {
            for (r, (u, v)) in ya.col(c).iter().zip(yb.col(c)).enumerate() {
                assert!((u - v).abs() < 1e-10, "col {c} row {r}");
            }
        }
        // hybrid stores strictly less than the full both-triangle band
        assert!(hybrid.bytes() < classic.bytes());
        assert!(hybrid.waste_ratio() <= classic.waste_ratio() + 1e-12);
    }

    #[test]
    fn sss_policy_and_unqualified_auto_fall_back_to_classic() {
        let s = banded(100, 6);
        assert!(!BandedDgbmv::from_sss_format(&s, FormatPolicy::Sss).unwrap().is_hybrid());
        // a scattered band where no diagonal clears the Auto threshold
        let mut coo = crate::sparse::Coo::new(60);
        for i in 0..60u32 {
            coo.push(i, i, 2.0);
        }
        for (i, j) in [(20u32, 2u32), (40, 21), (59, 37)] {
            coo.push(i, j, 1.0);
            coo.push(j, i, -1.0);
        }
        let scattered = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        assert!(!BandedDgbmv::from_sss_format(&scattered, FormatPolicy::Auto)
            .unwrap()
            .is_hybrid());
    }

    #[test]
    fn waste_grows_with_sparse_bands() {
        // a sparse wide band wastes most slots; a tridiagonal wastes few
        let sparse = banded(300, 2);
        let b = BandedDgbmv::from_sss(&sparse).unwrap();
        assert!(b.waste_ratio() > 0.2, "waste {}", b.waste_ratio());
        let mut coo = crate::sparse::Coo::new(30);
        for i in 0..30u32 {
            coo.push(i, i, 1.0);
        }
        for i in 1..30u32 {
            coo.push(i, i - 1, 1.0);
            coo.push(i - 1, i, -1.0);
        }
        let tri = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let bt = BandedDgbmv::from_sss(&tri).unwrap();
        assert!(bt.waste_ratio() < b.waste_ratio());
    }
}
