//! LAPACK-style banded matrix-vector multiply (`dgbmv` analogue).
//!
//! The paper's §2 discusses BLAS `dgbmv`: after RCM, the band can be
//! compressed into LAPACK banded storage — a dense `(2β+1) × n` array
//! with `ab[β + i - j][j] = A[i][j]` — trading **wasted storage on
//! explicit zeros inside the band** for perfectly regular access. This
//! module implements that baseline so the trade-off is measurable
//! (`benches/serial_baseline.rs`): for dense bands it wins on locality,
//! for the sparse post-RCM middle split it loses on wasted traffic —
//! which is exactly why PARS3 splits the band instead.

use crate::kernel::batch::VecBatch;
use crate::kernel::traits::Spmv;
use crate::sparse::{Sss, Symmetry};
use crate::Result;
use anyhow::ensure;

/// Full (both-triangle) LAPACK-style banded matrix.
#[derive(Debug, Clone)]
pub struct BandedDgbmv {
    /// Matrix dimension.
    pub n: usize,
    /// Half-bandwidth.
    pub beta: usize,
    /// Column-major LAPACK band storage: `ab[d * n + j] = A[j + d - beta][j]`
    /// for `d in 0..=2*beta` (rows `beta` above to `beta` below).
    pub ab: Vec<f64>,
}

impl BandedDgbmv {
    /// Build from an SSS matrix (expands the implied triangle; errors if
    /// the band would be empty).
    pub fn from_sss(s: &Sss) -> Result<Self> {
        let beta = s.bandwidth();
        ensure!(s.n > 0, "empty matrix");
        let sign = s.sym.sign();
        let width = 2 * beta + 1;
        let mut ab = vec![0.0f64; width * s.n];
        for i in 0..s.n {
            // diagonal at band row beta
            ab[beta * s.n + i] = s.dvalues[i];
            for (j, v) in s.row(i) {
                let j = j as usize;
                // lower entry A[i][j] at band row beta + i - j, column j
                ab[(beta + i - j) * s.n + j] = v;
                // mirrored upper entry A[j][i] at band row beta + j - i, column i
                ab[(beta + j - i) * s.n + i] = sign * v;
            }
        }
        Ok(Self { n: s.n, beta, ab })
    }

    /// `y = A x` over the dense band (touches every band slot, zeros
    /// included — the dgbmv trade-off).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let (n, beta) = (self.n, self.beta);
        y.iter_mut().for_each(|v| *v = 0.0);
        for d in 0..=2 * beta {
            // band row d holds A[i][j] with i - j = d - beta
            let off = d as isize - beta as isize;
            let row = &self.ab[d * n..(d + 1) * n];
            // i = j + off must be in [0, n)
            let j_lo = (-off).max(0) as usize;
            let j_hi = if off > 0 { n - off as usize } else { n };
            for j in j_lo..j_hi {
                let i = (j as isize + off) as usize;
                y[i] += row[j] * x[j];
            }
        }
    }

    /// Fused batch band multiply: each band slot is loaded once and
    /// reused across all `k` columns (a `dgbmv`-to-`dgbmm` promotion).
    pub fn spmv_batch(&self, xs: &VecBatch, ys: &mut VecBatch) {
        let (n, beta, kw) = (self.n, self.beta, xs.k());
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), kw);
        let xd = xs.data();
        let yd = ys.data_mut();
        yd.iter_mut().for_each(|v| *v = 0.0);
        for d in 0..=2 * beta {
            let off = d as isize - beta as isize;
            let row = &self.ab[d * n..(d + 1) * n];
            let j_lo = (-off).max(0) as usize;
            let j_hi = if off > 0 { n - off as usize } else { n };
            for j in j_lo..j_hi {
                let i = (j as isize + off) as usize;
                let v = row[j];
                for c in 0..kw {
                    yd[c * n + i] += v * xd[c * n + j];
                }
            }
        }
    }

    /// Fraction of stored band slots that are explicit zeros (the wasted
    /// storage §2 points out).
    pub fn waste_ratio(&self) -> f64 {
        if self.ab.is_empty() {
            return 0.0;
        }
        let zeros = self.ab.iter().filter(|v| **v == 0.0).count();
        zeros as f64 / self.ab.len() as f64
    }
}

impl Spmv for BandedDgbmv {
    fn n(&self) -> usize {
        self.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        Self::spmv(self, x, y);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        Self::spmv_batch(self, xs, ys);
    }

    fn flops(&self) -> u64 {
        (2 * (2 * self.beta + 1) * self.n) as u64
    }

    fn bytes(&self) -> u64 {
        ((2 * self.beta + 1) * self.n * 8) as u64
    }

    fn name(&self) -> &'static str {
        "dgbmv"
    }
}

/// Convenience check used by tests/benches.
pub fn is_skew(s: &Sss) -> bool {
    s.sym == Symmetry::Skew
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen};

    fn banded(n: usize, seed: u64) -> Sss {
        let mut rng = crate::util::SmallRng::seed_from_u64(seed);
        let edges = gen::random_banded_pattern(n, 3, 0.5, &mut rng);
        let coo = crate::sparse::skew::coo_from_pattern(n, &edges, 1.5, &mut rng);
        convert::coo_to_sss(&coo, Symmetry::Skew).unwrap()
    }

    #[test]
    fn matches_serial_sss() {
        let s = banded(200, 1);
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.17).sin()).collect();
        let mut want = vec![0.0; 200];
        sss_spmv(&s, &x, &mut want);
        let mut got = vec![0.0; 200];
        b.spmv(&x, &mut got);
        for (a, c) in got.iter().zip(&want) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_batch_matches_columnwise() {
        let s = banded(120, 3);
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let xs = VecBatch::from_fn(120, 3, |i, c| ((i * 7 + c) % 11) as f64 * 0.2 - 1.0);
        let mut ys = VecBatch::zeros(120, 3);
        b.spmv_batch(&xs, &mut ys);
        for c in 0..3 {
            let mut want = vec![0.0; 120];
            b.spmv(xs.col(c), &mut want);
            assert_eq!(ys.col(c), &want[..], "column {c}");
        }
    }

    #[test]
    fn symmetric_variant_matches() {
        let mut coo = crate::sparse::Coo::new(50);
        for i in 0..50u32 {
            coo.push(i, i, 2.0);
        }
        for i in 1..50u32 {
            coo.push(i, i - 1, 0.5);
            coo.push(i - 1, i, 0.5);
        }
        let s = convert::coo_to_sss(&coo, Symmetry::Symmetric).unwrap();
        let b = BandedDgbmv::from_sss(&s).unwrap();
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let mut want = vec![0.0; 50];
        sss_spmv(&s, &x, &mut want);
        let mut got = vec![0.0; 50];
        b.spmv(&x, &mut got);
        for (a, c) in got.iter().zip(&want) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn waste_grows_with_sparse_bands() {
        // a sparse wide band wastes most slots; a tridiagonal wastes few
        let sparse = banded(300, 2);
        let b = BandedDgbmv::from_sss(&sparse).unwrap();
        assert!(b.waste_ratio() > 0.2, "waste {}", b.waste_ratio());
        let mut coo = crate::sparse::Coo::new(30);
        for i in 0..30u32 {
            coo.push(i, i, 1.0);
        }
        for i in 1..30u32 {
            coo.push(i, i - 1, 1.0);
            coo.push(i - 1, i, -1.0);
        }
        let tri = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let bt = BandedDgbmv::from_sss(&tri).unwrap();
        assert!(bt.waste_ratio() < b.waste_ratio());
    }
}
