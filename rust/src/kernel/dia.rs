//! Hybrid diagonal-major (DIA) storage for the band interior.
//!
//! Post-RCM, the diagonals inside `split_bw` are mostly *filled* — the
//! whole point of the reordering is that the nonzeros collapse onto a
//! narrow band. The pure SSS middle split still walks them through
//! `col_ind` indirection: one index load + one gather per stored entry.
//! This module stores the **dense** diagonals (fill ratio above a
//! threshold) as contiguous per-diagonal value arrays instead, so the
//! hot inner loop becomes two unit-stride, FMA-vectorizable passes per
//! diagonal with **zero per-entry index loads**:
//!
//! ```text
//! forward : y[j + d] +=        v[j] * x[j]        (j = 0 .. n-d)
//! mirrored: y[j]     += sign * v[j] * x[j + d]
//! ```
//!
//! Sparse diagonals stay in an SSS remainder (`rest`) and ride the
//! existing gather loop — the format is a *hybrid*: dense where the
//! band is dense, compressed where it is not. Selection is per matrix
//! via [`FormatPolicy`] (the `Auto` fill-ratio heuristic, or forced).
//!
//! Not to be confused with [`crate::sparse::DiaBand`], the fully dense
//! f32 interchange layout for the PJRT/Pallas path: that one stores
//! *every* sub-diagonal slot unconditionally; this one is an adaptive
//! f64 execution format for the native kernels.

use crate::kernel::batch::VecBatch;
use crate::kernel::blocking::{Lanes, TilePlan, DEFAULT_L2_KIB};
use crate::sparse::{Sss, Symmetry};

/// Fill ratio above which [`FormatPolicy::Auto`] stores a diagonal
/// densely. Below it, explicit-zero slots would cost more bandwidth
/// than the `col_ind` loads they replace.
pub const DEFAULT_FILL_THRESHOLD: f64 = 0.5;

/// Which middle-split storage the registry / coordinator should build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FormatPolicy {
    /// Per-matrix fill-ratio heuristic: diagonals filled above
    /// [`DEFAULT_FILL_THRESHOLD`] go dense; if none qualify the matrix
    /// stays pure SSS.
    #[default]
    Auto,
    /// Force the hybrid DIA storage (every nonempty diagonal dense).
    Dia,
    /// Force the pure SSS middle split (the paper's layout).
    Sss,
}

impl FormatPolicy {
    /// Dense-diagonal fill threshold this policy applies
    /// (`None` = never store a diagonal densely).
    pub fn threshold(self) -> Option<f64> {
        match self {
            FormatPolicy::Auto => Some(DEFAULT_FILL_THRESHOLD),
            FormatPolicy::Dia => Some(0.0),
            FormatPolicy::Sss => None,
        }
    }
}

impl std::fmt::Display for FormatPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FormatPolicy::Auto => "auto",
            FormatPolicy::Dia => "dia",
            FormatPolicy::Sss => "sss",
        })
    }
}

impl std::str::FromStr for FormatPolicy {
    type Err = anyhow::Error;

    fn from_str(t: &str) -> std::result::Result<Self, Self::Err> {
        Ok(match t {
            "auto" => FormatPolicy::Auto,
            "dia" => FormatPolicy::Dia,
            "sss" => FormatPolicy::Sss,
            other => anyhow::bail!("unknown format '{other}' (expected auto|dia|sss)"),
        })
    }
}

/// One densely stored sub-diagonal: `vals[j] = A[j + d][j]`, length
/// `n - d`, explicit zeros where the band has holes.
#[derive(Debug, Clone)]
pub struct DenseDiag {
    /// Diagonal distance (`row - col`), always `>= 1`.
    pub d: usize,
    /// Contiguous values, indexed by **column**.
    pub vals: Vec<f64>,
}

/// Hybrid diagonal-major storage of a strictly-lower-triangle matrix
/// (a [`Sss`] whose diagonal is handled elsewhere): dense per-diagonal
/// arrays for well-filled diagonals plus an SSS remainder for the rest.
#[derive(Debug, Clone)]
pub struct DiaBand {
    /// Matrix dimension.
    pub n: usize,
    /// Mirror convention (sign of the implied upper triangle).
    pub sym: Symmetry,
    /// Dense diagonals, ascending by distance.
    pub diags: Vec<DenseDiag>,
    /// Sparse remainder (entries on non-dense diagonals), SSS-compressed
    /// with a zero diagonal.
    pub rest: Sss,
    /// True nonzeros carried by the dense diagonals.
    pub dense_nnz: usize,
    /// The fill threshold the selection used (for reports).
    pub threshold: f64,
    /// L2 working-set budget (KiB) the apply passes tile against.
    pub l2_kib: usize,
    /// Lane dispatch captured at build time ([`Lanes::get`]); its
    /// variant is what `Pars3Stats` reports.
    pub lanes: Lanes,
}

impl DiaBand {
    /// Build per the policy: `None` means "stay SSS" (either the policy
    /// forces it or no diagonal clears the `Auto` threshold).
    pub fn from_policy(lower: &Sss, policy: FormatPolicy) -> Option<Self> {
        Self::from_policy_budget(lower, policy, DEFAULT_L2_KIB)
    }

    /// [`Self::from_policy`] with an explicit L2 tile budget (KiB).
    pub fn from_policy_budget(lower: &Sss, policy: FormatPolicy, l2_kib: usize) -> Option<Self> {
        policy.threshold().and_then(|t| Self::build_budget(lower, t, l2_kib))
    }

    /// Build with an explicit fill threshold; `None` if no nonempty
    /// diagonal has `nnz / (n - d) >= threshold`.
    pub fn build(lower: &Sss, threshold: f64) -> Option<Self> {
        Self::build_budget(lower, threshold, DEFAULT_L2_KIB)
    }

    /// [`Self::build`] with an explicit L2 tile budget (KiB).
    pub fn build_budget(lower: &Sss, threshold: f64, l2_kib: usize) -> Option<Self> {
        let n = lower.n;
        let bw = lower.bandwidth();
        if bw == 0 {
            return None;
        }
        // fill count per diagonal distance
        let mut count = vec![0usize; bw + 1];
        for i in 0..n {
            for (j, _) in lower.row(i) {
                count[i - j as usize] += 1;
            }
        }
        // pos[d] = index into `diags` for dense distances
        let mut pos = vec![usize::MAX; bw + 1];
        let mut diags = Vec::new();
        for d in 1..=bw {
            if count[d] > 0 && count[d] as f64 >= threshold * (n - d) as f64 {
                pos[d] = diags.len();
                diags.push(DenseDiag { d, vals: vec![0.0; n - d] });
            }
        }
        if diags.is_empty() {
            return None;
        }
        // scatter entries: dense diagonals get slots, the rest stays SSS
        let mut row_ptr = vec![0usize; n + 1];
        let mut col_ind = Vec::new();
        let mut vals = Vec::new();
        let mut dense_nnz = 0usize;
        for i in 0..n {
            for (j, v) in lower.row(i) {
                let d = i - j as usize;
                if pos[d] != usize::MAX {
                    diags[pos[d]].vals[j as usize] = v;
                    dense_nnz += 1;
                } else {
                    col_ind.push(j);
                    vals.push(v);
                }
            }
            row_ptr[i + 1] = vals.len();
        }
        let rest = Sss {
            n,
            dvalues: vec![0.0; n],
            row_ptr,
            col_ind,
            vals,
            sym: lower.sym,
        };
        Some(Self {
            n,
            sym: lower.sym,
            diags,
            rest,
            dense_nnz,
            threshold,
            l2_kib,
            lanes: Lanes::get(),
        })
    }

    /// Widest dense-diagonal distance — how far the mirrored pass
    /// reaches ahead of a tile (its halo).
    pub fn max_d(&self) -> usize {
        self.diags.last().map(|dd| dd.d).unwrap_or(0)
    }

    /// Row tiling of the dense passes for a `k`-wide batch against the
    /// configured budget.
    pub fn tile_plan(&self, k: usize) -> TilePlan {
        TilePlan::new(self.n, self.max_d(), k, self.l2_kib)
    }

    /// Total dense slots (including explicit zeros).
    pub fn dense_slots(&self) -> usize {
        self.diags.iter().map(|dd| dd.vals.len()).sum()
    }

    /// Fraction of dense slots holding a true nonzero.
    pub fn fill_ratio(&self) -> f64 {
        let slots = self.dense_slots();
        if slots == 0 {
            0.0
        } else {
            self.dense_nnz as f64 / slots as f64
        }
    }

    /// Stored entries (dense nonzeros + remainder).
    pub fn nnz(&self) -> usize {
        self.dense_nnz + self.rest.nnz_lower()
    }

    /// Matrix bytes touched per apply: dense slots (values only — no
    /// index arrays, the point of the layout) + remainder SSS traffic.
    pub fn bytes(&self) -> u64 {
        (self.dense_slots() * 8 + self.rest.nnz_lower() * 12 + (self.n + 1) * 8) as u64
    }

    /// Add this matrix's contribution (both triangles via the sign
    /// mirror) into `y`: two unit-stride passes per dense diagonal, the
    /// SSS gather loop for the remainder. `y` is **accumulated**, not
    /// overwritten. This is exactly [`Self::apply_window`] over the
    /// full row range with an empty halo.
    pub fn apply_add(&self, x: &[f64], y: &mut [f64]) {
        self.apply_window(0, self.n, 0, x, y);
    }

    /// Batch variant of [`Self::apply_add`] over column-major `n × k`
    /// batches. Each SSS **remainder** entry is loaded once and reused
    /// across all `k` columns; dense diagonals instead run their two
    /// unit-stride passes once **per column** — the column-major layout
    /// makes per-column passes contiguous, while fusing across columns
    /// would turn every access into a stride-`n` gather. (The
    /// interleaved rank-window variant [`Self::apply_window_batch`]
    /// does reuse each dense slot across all `k` columns.)
    pub fn apply_add_batch(&self, xs: &VecBatch, ys: &mut VecBatch) {
        let n = self.n;
        let k = xs.k();
        debug_assert_eq!(xs.n(), n);
        debug_assert_eq!(ys.n(), n);
        debug_assert_eq!(ys.k(), k);
        let sign = self.sym.sign();
        let xd = xs.data();
        let yd = ys.data_mut();
        // Row tiles outer, diagonals inner: the k columns' x/y tile
        // windows stay L2-resident across every diagonal's forward +
        // mirrored pass instead of streaming n rows once per diagonal.
        for (t0, t1) in self.tile_plan(k).tiles(0, n) {
            for dd in &self.diags {
                let d = dd.d;
                let lo_i = t0.max(d);
                if lo_i >= t1 {
                    continue;
                }
                let j0 = lo_i - d;
                let m = t1 - lo_i;
                let vals = &dd.vals[j0..j0 + m];
                for c in 0..k {
                    let xcol = &xd[c * n..(c + 1) * n];
                    let ycol = &mut yd[c * n..(c + 1) * n];
                    self.lanes.axpy(&mut ycol[j0 + d..j0 + d + m], vals, &xcol[j0..j0 + m], 1.0);
                    self.lanes.axpy(&mut ycol[j0..j0 + m], vals, &xcol[j0 + d..j0 + d + m], sign);
                }
            }
        }
        for i in 0..n {
            let lo = self.rest.row_ptr[i];
            let hi = self.rest.row_ptr[i + 1];
            for (&j, &v) in self.rest.col_ind[lo..hi].iter().zip(&self.rest.vals[lo..hi]) {
                let j = j as usize;
                let sv = sign * v;
                for c in 0..k {
                    let base = c * n;
                    yd[base + i] += v * xd[base + j];
                    yd[base + j] += sv * xd[base + i];
                }
            }
        }
    }

    /// Rank-window variant for the PARS3 middle split: add the
    /// contribution of rows `r0..r1` (forward **and** mirrored writes)
    /// into the window `yw` covering `[base, r1)`, reading `xw` over the
    /// same range. Mirror writes below `r0` land in the window's halo
    /// prefix, exactly like the SSS path. Dense-diagonal slots whose
    /// column falls below `base` are skipped — by construction of
    /// `halo_lo` those slots are explicit zeros, so the clamp drops only
    /// no-op work, never a contribution.
    pub fn apply_window(&self, r0: usize, r1: usize, base: usize, xw: &[f64], yw: &mut [f64]) {
        debug_assert_eq!(xw.len(), r1 - base);
        debug_assert_eq!(yw.len(), r1 - base);
        let sign = self.sym.sign();
        // Row tiles outer, diagonals inner: one tile's x/y windows stay
        // L2-resident across the forward + mirrored pass of every dense
        // diagonal. Each tile clamps its own halo: the per-diagonal
        // `lo_i` below works identically whether the lower bound comes
        // from the rank window (`r0`) or a tile boundary (`t0`).
        for (t0, t1) in self.tile_plan(1).tiles(r0, r1) {
            for dd in &self.diags {
                let d = dd.d;
                let lo_i = t0.max(base + d); // first row with col >= base
                if lo_i >= t1 {
                    continue;
                }
                let j0 = lo_i - d; // absolute column start (>= base)
                let m = t1 - lo_i;
                let vals = &dd.vals[j0..j0 + m];
                let w = j0 - base; // window offset of the column start
                // forward: y[i] += v * x[i - d]
                self.lanes.axpy(&mut yw[w + d..w + d + m], vals, &xw[w..w + m], 1.0);
                // mirrored: y[i - d] += sign * v * x[i]
                self.lanes.axpy(&mut yw[w..w + m], vals, &xw[w + d..w + d + m], sign);
            }
            // sparse remainder: same gather loop as the SSS middle
            // split, over the still-resident tile rows
            for i in t0..t1 {
                let xi = xw[i - base];
                let sxi = sign * xi;
                let mut yi = 0.0;
                let lo = self.rest.row_ptr[i];
                let hi = self.rest.row_ptr[i + 1];
                for (&j, &v) in self.rest.col_ind[lo..hi].iter().zip(&self.rest.vals[lo..hi]) {
                    let j = j as usize;
                    yi += v * xw[j - base];
                    yw[j - base] += v * sxi;
                }
                yw[i - base] += yi;
            }
        }
    }

    /// Fused batch rank-window variant: `xw`/`yw` are **interleaved**
    /// `k`-wide windows over `[base, r1)` (element `(row, c)` at
    /// `(row - base) * k + c`), matching the PARS3 batch layout.
    pub fn apply_window_batch(
        &self,
        r0: usize,
        r1: usize,
        base: usize,
        k: usize,
        xw: &[f64],
        yw: &mut [f64],
    ) {
        debug_assert_eq!(xw.len(), (r1 - base) * k);
        debug_assert_eq!(yw.len(), (r1 - base) * k);
        let sign = self.sym.sign();
        // Tiled like apply_window; the interleaved layout keeps the
        // inner per-slot column loop contiguous (k-wide, compiler-
        // vectorized), so tiling is the only blocking applied here.
        for (t0, t1) in self.tile_plan(k).tiles(r0, r1) {
            for dd in &self.diags {
                let d = dd.d;
                let lo_i = t0.max(base + d);
                if lo_i >= t1 {
                    continue;
                }
                let j0 = lo_i - d;
                let m = t1 - lo_i;
                let vals = &dd.vals[j0..j0 + m];
                let w = j0 - base;
                for (t, &v) in vals.iter().enumerate() {
                    let oj = (w + t) * k;
                    let oi = (w + t + d) * k;
                    let sv = sign * v;
                    for c in 0..k {
                        yw[oi + c] += v * xw[oj + c];
                        yw[oj + c] += sv * xw[oi + c];
                    }
                }
            }
            for i in t0..t1 {
                let oi = (i - base) * k;
                let lo = self.rest.row_ptr[i];
                let hi = self.rest.row_ptr[i + 1];
                for (&j, &v) in self.rest.col_ind[lo..hi].iter().zip(&self.rest.vals[lo..hi]) {
                    let oj = (j as usize - base) * k;
                    let sv = sign * v;
                    for c in 0..k {
                        yw[oi + c] += v * xw[oj + c];
                        yw[oj + c] += sv * xw[oi + c];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen, Coo};

    fn banded(n: usize, seed: u64, alpha: f64) -> Sss {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    /// Off-diagonal (mirror-expanded) reference: `sss_spmv` with the
    /// diagonal zeroed out.
    fn offdiag_ref(s: &Sss, x: &[f64]) -> Vec<f64> {
        let mut z = s.clone();
        z.dvalues = vec![0.0; s.n];
        let mut y = vec![0.0; s.n];
        sss_spmv(&z, x, &mut y);
        y
    }

    #[test]
    fn forced_dia_covers_every_entry_and_matches_sss() {
        let s = banded(120, 1, 1.5);
        let dia = DiaBand::from_policy(&s, FormatPolicy::Dia).unwrap();
        // threshold 0: every nonempty diagonal goes dense, no remainder
        assert_eq!(dia.dense_nnz, s.nnz_lower());
        assert_eq!(dia.rest.nnz_lower(), 0);
        assert_eq!(dia.nnz(), s.nnz_lower());
        let x: Vec<f64> = (0..120).map(|i| ((i * 31) % 17) as f64 * 0.25 - 2.0).collect();
        let want = offdiag_ref(&s, &x);
        let mut got = vec![0.0; 120];
        dia.apply_add(&x, &mut got);
        for (r, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-10, "row {r}: {a} vs {b}");
        }
    }

    #[test]
    fn partial_threshold_splits_between_dense_and_rest() {
        let s = banded(150, 2, 1.0);
        if let Some(dia) = DiaBand::build(&s, 0.3) {
            assert_eq!(dia.dense_nnz + dia.rest.nnz_lower(), s.nnz_lower());
            let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.17).cos()).collect();
            let want = offdiag_ref(&s, &x);
            let mut got = vec![0.0; 150];
            dia.apply_add(&x, &mut got);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "row {r}");
            }
        }
    }

    #[test]
    fn heuristic_picks_sss_for_scattered_and_dia_for_dense_bands() {
        let n = 64u32;
        // dense: a completely filled first sub-diagonal
        let mut c = Coo::new(n as usize);
        for i in 0..n {
            c.push(i, i, 2.0);
        }
        for i in 1..n {
            c.push(i, i - 1, 1.0);
            c.push(i - 1, i, -1.0);
        }
        let dense = convert::coo_to_sss(&c, Symmetry::Skew).unwrap();
        let picked = DiaBand::from_policy(&dense, FormatPolicy::Auto).unwrap();
        assert_eq!(picked.diags.len(), 1);
        assert_eq!(picked.diags[0].d, 1);
        assert!((picked.fill_ratio() - 1.0).abs() < 1e-12);
        assert_eq!(picked.rest.nnz_lower(), 0);
        // scattered: one entry per wide diagonal — every fill ratio tiny
        let mut c2 = Coo::new(n as usize);
        for i in 0..n {
            c2.push(i, i, 2.0);
        }
        for (i, j) in [(20u32, 3u32), (41, 22), (63, 40)] {
            c2.push(i, j, 1.0);
            c2.push(j, i, -1.0);
        }
        let scattered = convert::coo_to_sss(&c2, Symmetry::Skew).unwrap();
        assert!(DiaBand::from_policy(&scattered, FormatPolicy::Auto).is_none());
        // policy Sss never builds
        assert!(DiaBand::from_policy(&dense, FormatPolicy::Sss).is_none());
    }

    #[test]
    fn window_partition_sums_to_full_apply() {
        let s = banded(100, 3, 1.0);
        let dia = DiaBand::from_policy(&s, FormatPolicy::Dia).unwrap();
        let bw = s.bandwidth();
        let x: Vec<f64> = (0..100).map(|i| ((i * 7) % 13) as f64 * 0.5 - 3.0).collect();
        let mut want = vec![0.0; 100];
        dia.apply_add(&x, &mut want);
        let mut got = vec![0.0; 100];
        for (r0, r1) in [(0usize, 34usize), (34, 67), (67, 100)] {
            let base = r0.saturating_sub(bw);
            let xw = &x[base..r1];
            let mut yw = vec![0.0; r1 - base];
            dia.apply_window(r0, r1, base, xw, &mut yw);
            for (t, v) in yw.iter().enumerate() {
                got[base + t] += v;
            }
        }
        for (r, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-10, "row {r}: {a} vs {b}");
        }
    }

    #[test]
    fn batch_apply_matches_columnwise() {
        let s = banded(90, 4, 1.5);
        let dia = DiaBand::from_policy(&s, FormatPolicy::Dia).unwrap();
        let k = 4;
        let xs = VecBatch::from_fn(90, k, |i, c| ((i * 5 + c * 11) % 9) as f64 * 0.4 - 1.5);
        let mut ys = VecBatch::zeros(90, k);
        dia.apply_add_batch(&xs, &mut ys);
        for c in 0..k {
            let mut want = vec![0.0; 90];
            dia.apply_add(xs.col(c), &mut want);
            for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "col {c} row {r}");
            }
        }
    }

    #[test]
    fn window_batch_matches_scalar_window() {
        let s = banded(80, 5, 1.0);
        let dia = DiaBand::from_policy(&s, FormatPolicy::Dia).unwrap();
        let bw = s.bandwidth();
        let (r0, r1) = (30usize, 60usize);
        let base = r0.saturating_sub(bw);
        let k = 3;
        let w = r1 - base;
        // interleaved k-wide input window
        let mut xw = vec![0.0f64; w * k];
        for t in 0..w {
            for c in 0..k {
                xw[t * k + c] = ((t * 3 + c * 7) % 11) as f64 * 0.3 - 1.0;
            }
        }
        let mut yw = vec![0.0f64; w * k];
        dia.apply_window_batch(r0, r1, base, k, &xw, &mut yw);
        for c in 0..k {
            let xc: Vec<f64> = (0..w).map(|t| xw[t * k + c]).collect();
            let mut want = vec![0.0f64; w];
            dia.apply_window(r0, r1, base, &xc, &mut want);
            for t in 0..w {
                assert!((yw[t * k + c] - want[t]).abs() < 1e-10, "col {c} slot {t}");
            }
        }
    }

    #[test]
    fn tiny_tile_budget_matches_untiled_apply() {
        let s = banded(200, 6, 1.2);
        let x: Vec<f64> = (0..200).map(|i| ((i * 13) % 23) as f64 * 0.2 - 2.0).collect();
        let untiled = DiaBand::build_budget(&s, 0.0, 1 << 20).unwrap();
        assert_eq!(untiled.tile_plan(1).num_tiles(0, 200), 1, "huge budget = single tile");
        let mut want = vec![0.0; 200];
        untiled.apply_add(&x, &mut want);
        let tiled = DiaBand::build_budget(&s, 0.0, 1).unwrap();
        assert!(tiled.tile_plan(1).num_tiles(0, 200) > 1, "1 KiB budget must split 200 rows");
        let mut got = vec![0.0; 200];
        tiled.apply_add(&x, &mut got);
        for (r, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-12, "row {r}: {a} vs {b}");
        }
        // batch path under the same tiny budget
        let k = 3;
        let xs = VecBatch::from_fn(200, k, |i, c| ((i * 5 + c * 11) % 9) as f64 * 0.4 - 1.5);
        let mut ys = VecBatch::zeros(200, k);
        tiled.apply_add_batch(&xs, &mut ys);
        for c in 0..k {
            let mut want = vec![0.0; 200];
            untiled.apply_add(xs.col(c), &mut want);
            for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12, "col {c} row {r}");
            }
        }
    }

    #[test]
    fn tile_boundaries_clamp_the_window_halo_correctly() {
        // A rank window with a halo prefix (base < r0), cut into tiny
        // tiles: every tile boundary must clamp its per-diagonal start
        // exactly like the window's own lower bound does, and mirrored
        // writes crossing a boundary must still land.
        let s = banded(160, 7, 1.0);
        let bw = s.bandwidth();
        let (r0, r1) = (70usize, 150usize);
        let base = r0.saturating_sub(bw);
        let xw: Vec<f64> = (0..r1 - base).map(|t| ((t * 11) % 19) as f64 * 0.3 - 1.4).collect();
        let untiled = DiaBand::build_budget(&s, 0.0, 1 << 20).unwrap();
        let mut want = vec![0.0; r1 - base];
        untiled.apply_window(r0, r1, base, &xw, &mut want);
        let tiled = DiaBand::build_budget(&s, 0.0, 1).unwrap();
        assert!(tiled.tile_plan(1).num_tiles(r0, r1) > 1);
        let mut got = vec![0.0; r1 - base];
        tiled.apply_window(r0, r1, base, &xw, &mut got);
        for (t, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-12, "slot {t}: {a} vs {b}");
        }
        // lane dispatch was captured at build and is nameable
        assert!(!tiled.lanes.variant.name().is_empty());
    }

    #[test]
    fn policy_parsing_roundtrips() {
        for p in [FormatPolicy::Auto, FormatPolicy::Dia, FormatPolicy::Sss] {
            assert_eq!(p.to_string().parse::<FormatPolicy>().unwrap(), p);
        }
        assert!("nope".parse::<FormatPolicy>().is_err());
        assert_eq!(FormatPolicy::default(), FormatPolicy::Auto);
    }
}
