//! Serial SSS SpMV — the paper's Algorithm 1 (Fig. 3), adapted to
//! skew-symmetry. This is the baseline every speedup in Figure 9 is
//! measured against.
//!
//! For each stored lower entry `(i, j, v)` a *single read* drives two
//! multiply-accumulates ("unrolling SSS data", Θ(NNZ)):
//!
//! ```text
//! y[i] += v * x[j]          // direct
//! y[j] += sign * v * x[i]   // mirrored (sign = -1 for skew)
//! ```

use crate::kernel::batch::VecBatch;
use crate::kernel::blocking::DEFAULT_L2_KIB;
use crate::kernel::dia::{DiaBand, FormatPolicy};
use crate::kernel::traits::Spmv;
use crate::sparse::Sss;
use std::sync::Arc;

/// Gather-side unroll width of the compressed-row loop: four
/// independent partial sums break the serial dependence on the row
/// accumulator so the forward gathers pipeline (the mirrored scatter
/// stays per-entry — columns within a row are distinct, so its order is
/// free). The scalar and batch kernels chunk identically and reduce the
/// partials with the same tree, preserving their bit-for-bit agreement.
pub const GATHER_LANES: usize = 4;

/// Compute `y = A x` for an SSS matrix (Alg. 1). `y` is overwritten.
pub fn sss_spmv(s: &Sss, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), s.n);
    assert_eq!(y.len(), s.n);
    let sign = s.sym.sign();
    for i in 0..s.n {
        // line 2 of Alg. 1: diagonal contribution
        let xi = x[i];
        let sxi = sign * xi;
        // lines 3-7: unroll the compressed row, updating both pairs.
        // Zipped slice iteration lets LLVM drop the per-element bounds
        // checks on col_ind/vals (§Perf); the x[j]/y[j] gathers are
        // inherent to SpMV.
        let lo = s.row_ptr[i];
        let hi = s.row_ptr[i + 1];
        let cols = &s.col_ind[lo..hi];
        let vals = &s.vals[lo..hi];
        let head = cols.len() - cols.len() % GATHER_LANES;
        let mut acc = [0.0f64; GATHER_LANES];
        for (jc, vc) in cols[..head]
            .chunks_exact(GATHER_LANES)
            .zip(vals[..head].chunks_exact(GATHER_LANES))
        {
            for l in 0..GATHER_LANES {
                let j = jc[l] as usize;
                acc[l] += vc[l] * x[j];
                y[j] += vc[l] * sxi;
            }
        }
        for (l, (&j, &v)) in cols[head..].iter().zip(&vals[head..]).enumerate() {
            let j = j as usize;
            acc[l] += v * x[j];
            y[j] += v * sxi;
        }
        // y[i] accumulated last: all mirrored writes into y[i] come from
        // rows > i (col < row in SSS), which have not run yet.
        y[i] = s.dvalues[i] * xi + ((acc[0] + acc[1]) + (acc[2] + acc[3]));
    }
}

/// Fused batch Alg. 1: `ys = A xs` for an `n × k` column-major batch.
/// One traversal of the SSS data serves all `k` columns — each stored
/// `(j, v)` pair is loaded once and drives `2k` multiply-accumulates.
/// Column-for-column the operation sequence is identical to
/// [`sss_spmv`], so results match the unbatched kernel bit-for-bit.
pub fn sss_spmv_batch(s: &Sss, xs: &VecBatch, ys: &mut VecBatch) {
    assert_eq!(xs.n(), s.n);
    assert_eq!(ys.n(), s.n);
    assert_eq!(xs.k(), ys.k());
    let (n, k) = (s.n, xs.k());
    let sign = s.sym.sign();
    let xd = xs.data();
    let yd = ys.data_mut();
    // acc[l * k + c]: lane-l partial sum for batch column c — the same
    // four-lane chunking as the scalar kernel, replicated per column so
    // the reduction tree (and thus the rounding) matches it exactly.
    let mut acc = vec![0.0f64; GATHER_LANES * k];
    for i in 0..n {
        acc.iter_mut().for_each(|a| *a = 0.0);
        let lo = s.row_ptr[i];
        let hi = s.row_ptr[i + 1];
        let cols = &s.col_ind[lo..hi];
        let vals = &s.vals[lo..hi];
        let head = cols.len() - cols.len() % GATHER_LANES;
        for (jc, vc) in cols[..head]
            .chunks_exact(GATHER_LANES)
            .zip(vals[..head].chunks_exact(GATHER_LANES))
        {
            for l in 0..GATHER_LANES {
                let j = jc[l] as usize;
                let v = vc[l];
                let sv = sign * v;
                let al = l * k;
                for c in 0..k {
                    let base = c * n;
                    acc[al + c] += v * xd[base + j];
                    yd[base + j] += sv * xd[base + i];
                }
            }
        }
        for (l, (&j, &v)) in cols[head..].iter().zip(&vals[head..]).enumerate() {
            let j = j as usize;
            let sv = sign * v;
            let al = l * k;
            for c in 0..k {
                let base = c * n;
                acc[al + c] += v * xd[base + j];
                yd[base + j] += sv * xd[base + i];
            }
        }
        // same overwrite-last discipline as the scalar kernel: mirror
        // writes into row i only come from rows > i, which run later
        let d = s.dvalues[i];
        for c in 0..k {
            yd[c * n + i] = d * xd[c * n + i]
                + ((acc[c] + acc[k + c]) + (acc[2 * k + c] + acc[3 * k + c]));
        }
    }
}

/// Serial SSS kernel implementing [`Spmv`]. Holds the matrix behind an
/// [`Arc`] so registry construction shares one `Sss` across kernels.
///
/// With a [`FormatPolicy`] selecting DIA (see
/// [`crate::kernel::dia::DiaBand`]), the strictly-lower triangle is
/// additionally held in hybrid diagonal-major form and `apply` runs two
/// unit-stride passes per dense diagonal instead of the Alg. 1 gather —
/// same math, diagonal-major accumulation order (rounding-level
/// differences only).
pub struct SerialSss {
    /// The matrix.
    pub s: Arc<Sss>,
    /// Hybrid diagonal-major view of the lower triangle (`None` = the
    /// paper's pure row-wise Alg. 1).
    dia: Option<DiaBand>,
}

impl SerialSss {
    /// Wrap an SSS matrix (owned or already-shared); pure Alg. 1 layout.
    pub fn new(s: impl Into<Arc<Sss>>) -> Self {
        Self::with_format(s, FormatPolicy::Sss)
    }

    /// Wrap with a middle-storage policy (`Auto` builds the DIA view
    /// only when the fill-ratio heuristic finds dense diagonals).
    pub fn with_format(s: impl Into<Arc<Sss>>, policy: FormatPolicy) -> Self {
        Self::with_format_budget(s, policy, DEFAULT_L2_KIB)
    }

    /// [`Self::with_format`] with an explicit L2 tile budget (KiB) for
    /// the DIA view's blocked passes.
    pub fn with_format_budget(
        s: impl Into<Arc<Sss>>,
        policy: FormatPolicy,
        l2_kib: usize,
    ) -> Self {
        let s: Arc<Sss> = s.into();
        let dia = DiaBand::from_policy_budget(&s, policy, l2_kib);
        Self { s, dia }
    }

    /// Name of the active lower-triangle storage (for reports/tests).
    pub fn format_name(&self) -> &'static str {
        if self.dia.is_some() {
            "dia"
        } else {
            "sss"
        }
    }
}

impl Spmv for SerialSss {
    fn n(&self) -> usize {
        self.s.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        match &self.dia {
            None => sss_spmv(&self.s, x, y),
            Some(dia) => {
                for (yi, (&d, &xi)) in y.iter_mut().zip(self.s.dvalues.iter().zip(x)) {
                    *yi = d * xi;
                }
                dia.apply_add(x, y);
            }
        }
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        match &self.dia {
            None => sss_spmv_batch(&self.s, xs, ys),
            Some(dia) => {
                let (n, k) = (self.s.n, xs.k());
                assert_eq!(xs.n(), n);
                assert_eq!(ys.n(), n);
                assert_eq!(ys.k(), k);
                let xd = xs.data();
                let yd = ys.data_mut();
                for c in 0..k {
                    for i in 0..n {
                        yd[c * n + i] = self.s.dvalues[i] * xd[c * n + i];
                    }
                }
                dia.apply_add_batch(xs, ys);
            }
        }
    }

    fn flops(&self) -> u64 {
        match &self.dia {
            // dense slots (explicit zeros included) are streamed and
            // multiplied like any entry: 4 flops per slot + remainder
            Some(dia) => (self.s.n + 4 * (dia.dense_slots() + dia.rest.nnz_lower())) as u64,
            None => self.s.spmv_flops(),
        }
    }

    fn bytes(&self) -> u64 {
        match &self.dia {
            // dvalues once + dense slots (no index arrays) + remainder
            Some(dia) => (self.s.n * 8) as u64 + dia.bytes(),
            None => self.s.spmv_bytes(),
        }
    }

    fn name(&self) -> &'static str {
        "serial_sss"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{convert, gen, Symmetry};

    #[test]
    fn matches_coo_reference() {
        let coo = gen::small_test_matrix(64, 42, 2.0);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let x: Vec<f64> = (0..64).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let mut want = vec![0.0; 64];
        coo.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; 64];
        sss_spmv(&sss, &x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn symmetric_variant_matches() {
        // build a symmetric matrix via pattern with +v mirrors
        let mut coo = crate::sparse::Coo::new(6);
        for i in 0..6 {
            coo.push(i, i, 1.0 + i as f64);
        }
        for (i, j, v) in [(2u32, 0u32, 3.0), (4, 1, -2.0), (5, 4, 0.5)] {
            coo.push(i, j, v);
            coo.push(j, i, v);
        }
        let sss = convert::coo_to_sss(&coo, Symmetry::Symmetric).unwrap();
        let x: Vec<f64> = (0..6).map(|i| i as f64 * 0.3 - 1.0).collect();
        let mut want = vec![0.0; 6];
        coo.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; 6];
        sss_spmv(&sss, &x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn skew_invariant_x_dot_sx_is_zero() {
        // pure skew part: (x, Sx) = 0; with alpha shift, (x, Ax) = alpha*||x||^2
        let coo = gen::small_test_matrix(50, 7, 3.0);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.717).sin()).collect();
        let mut y = vec![0.0; 50];
        sss_spmv(&sss, &x, &mut y);
        let xay: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let xx: f64 = x.iter().map(|a| a * a).sum();
        assert!((xay - 3.0 * xx).abs() < 1e-9 * xx.max(1.0));
    }

    #[test]
    fn fused_batch_is_bit_identical_to_columnwise_apply() {
        let coo = gen::small_test_matrix(96, 17, 1.5);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let k = 5;
        let xs = VecBatch::from_fn(96, k, |i, c| ((i * 3 + c * 11) % 13) as f64 * 0.4 - 2.0);
        let mut ys = VecBatch::zeros(96, k);
        sss_spmv_batch(&sss, &xs, &mut ys);
        for c in 0..k {
            let mut want = vec![0.0; 96];
            sss_spmv(&sss, xs.col(c), &mut want);
            assert_eq!(ys.col(c), &want[..], "column {c}");
        }
    }

    #[test]
    fn dia_format_matches_pure_sss_kernel() {
        let coo = gen::small_test_matrix(110, 23, 2.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let sss = std::sync::Arc::new(
            convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap(),
        );
        let mut plain = SerialSss::new(sss.clone());
        let mut hybrid = SerialSss::with_format(sss.clone(), FormatPolicy::Dia);
        assert_eq!(plain.format_name(), "sss");
        assert_eq!(hybrid.format_name(), "dia");
        let x: Vec<f64> = (0..110).map(|i| ((i * 29) % 13) as f64 * 0.4 - 2.0).collect();
        let (mut a, mut b) = (vec![0.0; 110], vec![0.0; 110]);
        plain.apply(&x, &mut a);
        hybrid.apply(&x, &mut b);
        for (r, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!((u - v).abs() < 1e-10, "row {r}: {u} vs {v}");
        }
        // fused batch path agrees column-for-column too
        let k = 3;
        let xs = VecBatch::from_fn(110, k, |i, c| ((i + c * 7) % 11) as f64 * 0.3 - 1.5);
        let mut ya = VecBatch::zeros(110, k);
        let mut yb = VecBatch::zeros(110, k);
        plain.apply_batch(&xs, &mut ya);
        hybrid.apply_batch(&xs, &mut yb);
        for c in 0..k {
            for (r, (u, v)) in ya.col(c).iter().zip(yb.col(c)).enumerate() {
                assert!((u - v).abs() < 1e-10, "col {c} row {r}");
            }
        }
    }

    #[test]
    fn spmv_trait_counters() {
        let coo = gen::small_test_matrix(32, 9, 1.0);
        let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let nnz = sss.nnz_lower();
        let k = SerialSss::new(sss);
        assert_eq!(k.n(), 32);
        assert_eq!(k.flops(), (32 + 4 * nnz) as u64);
        assert_eq!(k.name(), "serial_sss");
    }
}
