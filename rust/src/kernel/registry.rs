//! Unified kernel registry: construct any [`Spmv`] kernel **by name**.
//!
//! Every kernel in the crate — the serial SSS baseline (paper Alg. 1),
//! plain CSR, the LAPACK-style dense band (`dgbmv`), the graph-coloring
//! phased baseline (Elafrou et al. [3]), the RACE-style recursive
//! level-coloring kernel, and PARS3 itself — implements
//! the same [`Spmv`] trait; this module is the single construction
//! point. Solvers, the coordinator, and the benches all go through it,
//! so adding a kernel (or comparing an existing pair) never requires
//! touching call sites: the set of kernels *is* [`KERNEL_NAMES`].
//! Which kernel to build is decided upstream by
//! `coordinator::planner` (the backend axis of the plan triple); the
//! registry stays the only construction path, and CI greps for direct
//! constructor calls that would bypass it.
//!
//! All kernels built from one source matrix operate in the same (RCM)
//! ordering, so for any input vector they produce identical outputs —
//! the property the cross-kernel benches and tests rely on.

use crate::coordinator::error::Pars3Error;
use crate::graph::reorder::{self, ReorderPolicy, ReorderReport};
use crate::graph::Adjacency;
use crate::kernel::coloring_spmv::ColoringKernel;
use crate::kernel::csr_spmv::CsrSpmv;
use crate::kernel::dgbmv::BandedDgbmv;
use crate::kernel::blocking::DEFAULT_L2_KIB;
use crate::kernel::dia::FormatPolicy;
use crate::kernel::pars3::Pars3Kernel;
use crate::kernel::race::RaceKernel;
use crate::kernel::serial_sss::SerialSss;
use crate::kernel::split3::Split3;
use crate::kernel::traits::Spmv;
use crate::sparse::{convert, Coo, Sss, Symmetry};
use crate::util::pool::PrepPool;
use std::sync::Arc;
use std::time::Instant;

/// Names of every registered kernel, in bench display order.
pub const KERNEL_NAMES: &[&str] =
    &["serial_sss", "csr", "dgbmv", "coloring", "race", "pars3"];

/// Construction parameters shared by all kernels (parallel kernels use
/// `threads`/`threaded`; `pars3` additionally uses `outer_bw`; the
/// band-interior kernels — `serial_sss`, `dgbmv`, `pars3` — honor
/// `format`; `reorder` only matters to the from-COO entry point
/// [`build`], which preprocesses).
#[derive(Debug, Clone)]
pub struct KernelConfig {
    /// Rank count for the parallel kernels (clamped to the matrix size).
    pub threads: usize,
    /// Outer-split bandwidth for `pars3` (paper default 3).
    pub outer_bw: usize,
    /// Real threads (`true`) or the deterministic emulated executors.
    pub threaded: bool,
    /// Band-interior storage: hybrid diagonal-major (DIA) vs pure SSS,
    /// with `Auto` deciding per matrix by fill ratio.
    pub format: FormatPolicy,
    /// Reordering strategy for the from-COO preprocessing path.
    pub reorder: ReorderPolicy,
    /// `Auto`'s decline gate (fractional bandwidth improvement a
    /// reordering must clear over natural; see
    /// [`crate::graph::reorder::Auto`]).
    pub reorder_min_gain: f64,
    /// Cache budget (KiB) for the tile-blocked band traversals; sizes
    /// the row tiles so the touched x/y stretch stays resident across
    /// the forward and mirrored passes (see [`crate::kernel::blocking`]).
    pub l2_kib: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        Self {
            threads: 8,
            outer_bw: 3,
            threaded: false,
            format: FormatPolicy::Auto,
            reorder: ReorderPolicy::Auto,
            reorder_min_gain: 0.0,
            l2_kib: DEFAULT_L2_KIB,
        }
    }
}

impl KernelConfig {
    /// Config for `p` ranks with everything else at defaults.
    pub fn with_threads(threads: usize) -> Self {
        Self { threads, ..Self::default() }
    }
}

/// Shared preprocessing for every entry point that starts from a full
/// COO matrix (this module's [`build`] and
/// [`crate::coordinator::Coordinator::prepare`]): run the selected
/// [`ReorderPolicy`] strategy per connected component (the default
/// `Auto` measures the candidates and keeps the natural order when no
/// reordering clears `min_gain` — paper §4.1's pattern-recognition
/// note, generalized per Asudeh et al.), then convert to SSS. Returns
/// the chosen permutation (`perm[old] = new`), the reordered matrix,
/// and the instrumented [`ReorderReport`].
pub fn reorder_to_sss(
    coo: &Coo,
    strategy: ReorderPolicy,
    min_gain: f64,
) -> Result<(Vec<u32>, Sss, ReorderReport), Pars3Error> {
    reorder_to_sss_with(coo, strategy, min_gain, &PrepPool::serial())
}

/// [`reorder_to_sss`] on a prepare pool: the strategy's BFS/CM passes,
/// the symmetric permutation, and the SSS assembly all run across the
/// pool's workers, producing bit-identical artifacts for every width.
/// The permutation + conversion time is stamped into the report as
/// `timings.build_ms`.
pub fn reorder_to_sss_with(
    coo: &Coo,
    strategy: ReorderPolicy,
    min_gain: f64,
    pool: &PrepPool,
) -> Result<(Vec<u32>, Sss, ReorderReport), Pars3Error> {
    let g = Adjacency::from_coo(coo);
    let (perm, mut report) = reorder::reorder_with_report_with(&g, strategy, min_gain, pool);
    let t0 = Instant::now();
    let sss =
        convert::coo_to_sss_with(&coo.permute_symmetric_with(&perm, pool), Symmetry::Skew, pool)
            .map_err(|e| {
                Pars3Error::InvalidMatrix(format!("matrix is not (shifted) skew-symmetric: {e:#}"))
            })?;
    report.timings.build_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok((perm, sss, report))
}

/// Build a kernel by name from a full (both-triangle) shifted
/// skew-symmetric COO matrix (preprocessing via [`reorder_to_sss`]
/// with `cfg.reorder`). The returned kernel operates in the reordered
/// space — consistent across every kernel name for the same input
/// matrix and strategy.
pub fn build(name: &str, coo: &Coo, cfg: &KernelConfig) -> Result<Box<dyn Spmv>, Pars3Error> {
    let (_, sss, _) = reorder_to_sss(coo, cfg.reorder, cfg.reorder_min_gain)?;
    build_from_sss(name, sss, cfg)
}

/// Build a kernel by name from an already-ordered SSS matrix (the entry
/// point for the coordinator and benches, which preprocess once and
/// construct many kernels from the same [`Sss`]).
///
/// Accepts an owned `Sss` or an `Arc<Sss>`; either way the matrix is
/// **shared, not cloned** — kernels that keep the SSS form alive
/// (`serial_sss`, `coloring`) hold the same allocation, and kernels
/// that convert (`csr`, `dgbmv`, `pars3`) borrow it during
/// construction. Many-kernels-per-matrix construction is O(1) in
/// matrix copies.
pub fn build_from_sss(
    name: &str,
    sss: impl Into<Arc<Sss>>,
    cfg: &KernelConfig,
) -> Result<Box<dyn Spmv>, Pars3Error> {
    let sss: Arc<Sss> = sss.into();
    let p = cfg.threads.clamp(1, sss.n.max(1));
    Ok(match name {
        "serial_sss" => Box::new(SerialSss::with_format_budget(sss, cfg.format, cfg.l2_kib)),
        "csr" => Box::new(CsrSpmv::new(convert::sss_to_csr(&sss))),
        "dgbmv" => Box::new(BandedDgbmv::from_sss_format_budget(&sss, cfg.format, cfg.l2_kib)?),
        "coloring" => Box::new(ColoringKernel::new(sss, p, cfg.threaded)?),
        "race" => Box::new(RaceKernel::new(sss, p, cfg.threaded)?),
        "pars3" => {
            let split = Split3::with_outer_bw_format_budget(
                &sss,
                cfg.outer_bw,
                cfg.format,
                cfg.l2_kib,
            )?;
            return build_from_split(split, cfg);
        }
        other => return Err(Pars3Error::UnknownKernel { name: other.to_string() }),
    })
}

/// Build the `pars3` kernel from an existing 3-way split, reusing
/// preprocessing a caller already did (e.g.
/// [`crate::coordinator::Prepared::split`]) instead of recomputing it.
/// Accepts owned or `Arc`-shared splits; never clones the split data.
/// The split's middle storage (DIA vs SSS) is whatever the caller
/// selected at split construction — `cfg.format` is not re-applied
/// (an `Arc`-shared split cannot be mutated).
pub fn build_from_split(
    split: impl Into<Arc<Split3>>,
    cfg: &KernelConfig,
) -> Result<Box<dyn Spmv>, Pars3Error> {
    let split: Arc<Split3> = split.into();
    let p = cfg.threads.clamp(1, split.n.max(1));
    Ok(Box::new(Pars3Kernel::new(split, p, cfg.threaded)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rcm;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::solver::cg::cg_solve;
    use crate::solver::mrs::{mrs_solve, MrsOptions};
    use crate::sparse::gen;

    fn fixture(n: usize, seed: u64, alpha: f64) -> (Coo, Sss) {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let g = Adjacency::from_coo(&coo);
        let perm = rcm(&g);
        let sss =
            convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap();
        (coo, sss)
    }

    #[test]
    fn reorder_to_sss_honors_every_strategy() {
        let coo = gen::small_test_matrix(120, 9, 2.0);
        for policy in [
            ReorderPolicy::Natural,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Auto,
        ] {
            let (perm, sss, report) = reorder_to_sss(&coo, policy, 0.0).unwrap();
            assert_eq!(report.requested, policy);
            assert_eq!(perm.len(), 120);
            // the reordered matrix's bandwidth is what the report says
            assert_eq!(sss.bandwidth(), report.bw_after, "{policy}");
            if policy == ReorderPolicy::Natural {
                assert_eq!(perm, (0..120).collect::<Vec<u32>>());
            }
        }
    }

    #[test]
    fn every_registered_kernel_agrees_with_serial() {
        let (_, sss) = fixture(120, 1, 2.0);
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.19).cos()).collect();
        let mut want = vec![0.0; 120];
        sss_spmv(&sss, &x, &mut want);
        for &name in KERNEL_NAMES {
            let mut k =
                build_from_sss(name, sss.clone(), &KernelConfig::with_threads(4)).unwrap();
            assert_eq!(k.n(), 120, "{name}");
            assert_eq!(k.name(), name);
            assert!(k.flops() > 0 && k.bytes() > 0, "{name}");
            let mut got = vec![0.0; 120];
            k.apply(&x, &mut got);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-9, "{name} row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn arc_shared_matrix_is_shared_not_cloned() {
        let (_, sss) = fixture(80, 6, 1.0);
        let sss = Arc::new(sss);
        let k = build_from_sss("serial_sss", sss.clone(), &KernelConfig::default()).unwrap();
        // the kernel holds the same allocation, not a deep copy
        assert_eq!(Arc::strong_count(&sss), 2);
        drop(k);
        assert_eq!(Arc::strong_count(&sss), 1);
    }

    #[test]
    fn every_registered_kernel_batch_matches_columnwise_apply() {
        use crate::kernel::batch::VecBatch;
        let (_, sss) = fixture(100, 7, 2.0);
        let sss = Arc::new(sss);
        let kw = 4;
        let xs = VecBatch::from_fn(100, kw, |i, c| ((i * 13 + c * 7) % 11) as f64 * 0.3 - 1.5);
        for &name in KERNEL_NAMES {
            let mut k =
                build_from_sss(name, sss.clone(), &KernelConfig::with_threads(4)).unwrap();
            k.prepare_hint(kw);
            let mut ys = VecBatch::zeros(100, kw);
            k.apply_batch(&xs, &mut ys);
            for c in 0..kw {
                let mut want = vec![0.0; 100];
                k.apply(xs.col(c), &mut want);
                for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < 1e-9, "{name} col {c} row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn build_from_coo_reorders_consistently() {
        let (coo, _) = fixture(150, 2, 1.5);
        let cfg = KernelConfig::with_threads(3);
        let x: Vec<f64> = (0..150).map(|i| ((i * 11) % 7) as f64 - 3.0).collect();
        let mut y_serial = vec![0.0; 150];
        build("serial_sss", &coo, &cfg).unwrap().apply(&x, &mut y_serial);
        let mut y_pars3 = vec![0.0; 150];
        build("pars3", &coo, &cfg).unwrap().apply(&x, &mut y_pars3);
        for (a, b) in y_serial.iter().zip(&y_pars3) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn every_registered_kernel_agrees_across_format_policies() {
        let (_, sss) = fixture(130, 8, 1.5);
        let sss = Arc::new(sss);
        let x: Vec<f64> = (0..130).map(|i| ((i * 17) % 19) as f64 * 0.3 - 2.5).collect();
        for &name in KERNEL_NAMES {
            let mut outs = Vec::new();
            for format in [FormatPolicy::Sss, FormatPolicy::Dia, FormatPolicy::Auto] {
                let cfg = KernelConfig { threads: 4, format, ..KernelConfig::default() };
                let mut k = build_from_sss(name, sss.clone(), &cfg).unwrap();
                let mut y = vec![0.0; 130];
                k.apply(&x, &mut y);
                outs.push(y);
            }
            for y in &outs[1..] {
                for (r, (a, b)) in y.iter().zip(&outs[0]).enumerate() {
                    assert!((a - b).abs() < 1e-9, "{name} row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn every_registered_kernel_agrees_across_tile_budgets() {
        // a 1 KiB budget forces many tiny tiles; a huge one forces a
        // single tile — both must match the default plan exactly
        let (_, sss) = fixture(140, 11, 2.0);
        let sss = Arc::new(sss);
        let x: Vec<f64> = (0..140).map(|i| ((i * 23) % 13) as f64 * 0.4 - 2.0).collect();
        for &name in KERNEL_NAMES {
            let mut outs = Vec::new();
            for l2_kib in [1, DEFAULT_L2_KIB, 1 << 20] {
                let cfg = KernelConfig { threads: 4, l2_kib, ..KernelConfig::default() };
                let mut k = build_from_sss(name, sss.clone(), &cfg).unwrap();
                let mut y = vec![0.0; 140];
                k.apply(&x, &mut y);
                outs.push(y);
            }
            for y in &outs[1..] {
                for (r, (a, b)) in y.iter().zip(&outs[0]).enumerate() {
                    assert!((a - b).abs() < 1e-12, "{name} row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn unknown_name_is_rejected_with_inventory() {
        let (_, sss) = fixture(30, 3, 1.0);
        let err = build_from_sss("nope", sss, &KernelConfig::default()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nope") && msg.contains("pars3"), "{msg}");
    }

    #[test]
    fn thread_count_is_clamped_to_matrix_size() {
        let (_, sss) = fixture(20, 4, 1.0);
        // 64 ranks on a 20-row matrix must not error
        let mut k =
            build_from_sss("pars3", sss.clone(), &KernelConfig::with_threads(64)).unwrap();
        let x = vec![1.0; 20];
        let mut y = vec![0.0; 20];
        k.apply(&x, &mut y);
        let mut want = vec![0.0; 20];
        sss_spmv(&sss, &x, &mut want);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn mrs_solver_runs_through_registry_kernels() {
        let (_, sss) = fixture(100, 5, 3.0);
        let b: Vec<f64> = (0..100).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let opts = MrsOptions { alpha: 3.0, max_iters: 400, tol: 1e-8 };
        let mut reference: Option<Vec<f64>> = None;
        for &name in KERNEL_NAMES {
            let mut k =
                build_from_sss(name, sss.clone(), &KernelConfig::with_threads(4)).unwrap();
            let res = mrs_solve(&mut *k, &b, &opts);
            assert!(res.converged, "{name}: {} iters", res.iters);
            match &reference {
                None => reference = Some(res.x),
                Some(want) => {
                    for (a, c) in res.x.iter().zip(want) {
                        assert!((a - c).abs() < 1e-6, "{name}");
                    }
                }
            }
        }
    }

    #[test]
    fn cg_solver_runs_through_registry_kernel() {
        // SPD symmetric tridiagonal system through the registry's
        // serial kernel (the symmetric variant of the SSS path)
        let n = 80;
        let mut c = Coo::new(n);
        for i in 0..n as u32 {
            c.push(i, i, 4.0);
        }
        for i in 1..n as u32 {
            c.push(i, i - 1, -1.0);
            c.push(i - 1, i, -1.0);
        }
        let sss = convert::coo_to_sss(&c, Symmetry::Symmetric).unwrap();
        let mut k =
            build_from_sss("serial_sss", sss, &KernelConfig::default()).unwrap();
        let b = vec![1.0; n];
        let res = cg_solve(&mut *k, &b, 500, 1e-10);
        assert!(res.converged);
    }
}
