//! Plain CSR SpMV — the non-symmetric sanity baseline.
//!
//! Stores *both* triangles explicitly (twice the matrix traffic of SSS),
//! which is exactly the memory-bandwidth saving the paper's SSS kernels
//! exploit. Used to sanity-check results and to put the SSS kernels'
//! throughput in context (§Perf).

use crate::kernel::batch::VecBatch;
use crate::kernel::traits::Spmv;
use crate::sparse::Csr;

/// `y = A x` for a general CSR matrix.
pub fn csr_spmv(a: &Csr, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    for i in 0..a.n {
        let mut acc = 0.0;
        for k in a.row_ptr[i]..a.row_ptr[i + 1] {
            acc += a.vals[k] * x[a.col_ind[k] as usize];
        }
        y[i] = acc;
    }
}

/// Fused batch CSR: one traversal of the matrix serves all `k`
/// columns; each loaded `(j, v)` drives `k` multiply-accumulates.
pub fn csr_spmv_batch(a: &Csr, xs: &VecBatch, ys: &mut VecBatch) {
    assert_eq!(xs.n(), a.n);
    assert_eq!(ys.n(), a.n);
    assert_eq!(xs.k(), ys.k());
    let (n, kw) = (a.n, xs.k());
    let xd = xs.data();
    let yd = ys.data_mut();
    let mut acc = vec![0.0f64; kw];
    for i in 0..n {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for k in a.row_ptr[i]..a.row_ptr[i + 1] {
            let j = a.col_ind[k] as usize;
            let v = a.vals[k];
            for c in 0..kw {
                acc[c] += v * xd[c * n + j];
            }
        }
        for c in 0..kw {
            yd[c * n + i] = acc[c];
        }
    }
}

/// Owned CSR kernel implementing [`Spmv`].
pub struct CsrSpmv {
    /// The matrix.
    pub a: Csr,
}

impl CsrSpmv {
    /// Wrap a CSR matrix.
    pub fn new(a: Csr) -> Self {
        Self { a }
    }
}

impl Spmv for CsrSpmv {
    fn n(&self) -> usize {
        self.a.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        csr_spmv(&self.a, x, y);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        csr_spmv_batch(&self.a, xs, ys);
    }

    fn flops(&self) -> u64 {
        2 * self.a.nnz() as u64
    }

    fn bytes(&self) -> u64 {
        (self.a.nnz() * (8 + 4) + (self.a.n + 1) * 8) as u64
    }

    fn name(&self) -> &'static str {
        "csr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{convert, gen};

    #[test]
    fn matches_coo_reference() {
        let coo = gen::small_test_matrix(48, 3, 1.5);
        let csr = convert::coo_to_csr(&coo);
        let x: Vec<f64> = (0..48).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 48];
        coo.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; 48];
        csr_spmv(&csr, &x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_batch_matches_columnwise() {
        let coo = gen::small_test_matrix(60, 8, 1.0);
        let csr = convert::coo_to_csr(&coo);
        let xs = VecBatch::from_fn(60, 4, |i, c| (i as f64 * 0.3 + c as f64).sin());
        let mut ys = VecBatch::zeros(60, 4);
        csr_spmv_batch(&csr, &xs, &mut ys);
        for c in 0..4 {
            let mut want = vec![0.0; 60];
            csr_spmv(&csr, xs.col(c), &mut want);
            assert_eq!(ys.col(c), &want[..], "column {c}");
        }
    }

    #[test]
    fn agrees_with_serial_sss() {
        let coo = gen::small_test_matrix(64, 5, 2.0);
        let csr = convert::coo_to_csr(&coo);
        let sss = convert::coo_to_sss(&coo, crate::sparse::Symmetry::Skew).unwrap();
        let x: Vec<f64> = (0..64).map(|i| ((i * 13) % 7) as f64).collect();
        let mut y0 = vec![0.0; 64];
        let mut y1 = vec![0.0; 64];
        csr_spmv(&csr, &x, &mut y0);
        crate::kernel::serial_sss::sss_spmv(&sss, &x, &mut y1);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
