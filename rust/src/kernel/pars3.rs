//! PARS3 — Parallel 3-Way Banded Skew-SSpMV (the paper's contribution,
//! §3.1.2).
//!
//! Pipeline per multiply, given the preprocessing done once in
//! [`Pars3Plan::new`]:
//!
//! 1. **stage 1** — block row distribution of `x` (each rank owns a
//!    contiguous slice, mirroring the output distribution);
//! 2. **stage 2** — `x`-halo exchange: each rank needs the columns
//!    `[halo_lo, r0)` to its left; the band structure makes these come
//!    from the immediate neighbour(s). Messages follow the paper's
//!    deadlock-avoiding order (posted from the last rank toward root);
//! 3. **middle split compute** — each rank unrolls its local SSS slice;
//!    mirror writes that stay local go straight into the output block,
//!    mirror writes that cross a block boundary are *pre-identified*
//!    (see [`crate::kernel::conflict`]) and batched into a per-rank
//!    scratch slice;
//! 4. **one-sided accumulate** — the scratch slice is pushed into the
//!    shared output window (`MPI_Accumulate` substitute), overlappable
//!    with the outer tail;
//! 5. **outer split** — the few fringe entries are processed
//!    sequentially per rank (paper's choice: avoids fine-grained
//!    irregular communication);
//! 6. **epoch fence** — barrier; the window now holds `y = A x`.

use crate::kernel::conflict::BlockDist;
use crate::kernel::split3::Split3;
use crate::mpisim::{PersistentWorld, RankCtx, RankReport, Window, World};
use crate::Result;
use anyhow::ensure;
use std::sync::Arc;

/// Tag for halo messages.
const TAG_HALO: u32 = 1;

/// Per-rank precomputed plan.
#[derive(Debug, Clone)]
pub struct RankPlan {
    /// Rank id.
    pub rank: usize,
    /// Owned row range `[r0, r1)`.
    pub r0: usize,
    /// End of owned row range.
    pub r1: usize,
    /// Leftmost column referenced by any local entry (`<= r0`).
    pub halo_lo: usize,
    /// Halo sends: `(dest, lo, hi)` sub-ranges of *this* rank's block.
    pub sends: Vec<(usize, usize, usize)>,
    /// Halo receives: `(src, lo, hi)` sub-ranges arriving from the left.
    pub recvs: Vec<(usize, usize, usize)>,
    /// Middle-split entries with off-rank mirrors (conflict count).
    pub conflicting_nnz: usize,
    /// Local middle-split entries.
    pub middle_nnz: usize,
    /// Local outer-split entries (sequential tail).
    pub outer_nnz: usize,
}

/// Execution statistics (instrumentation for the cost replay + §Perf).
#[derive(Debug, Clone, Default)]
pub struct Pars3Stats {
    /// Messages sent per rank.
    pub msgs: Vec<usize>,
    /// Payload f64 count per rank.
    pub msg_values: Vec<usize>,
    /// Wallclock seconds per rank (threaded mode only).
    pub rank_seconds: Vec<f64>,
}

/// The preprocessed parallel kernel.
#[derive(Debug, Clone)]
pub struct Pars3Plan {
    /// The 3-way split (RCM-ordered band).
    pub split: Arc<Split3>,
    /// Block row distribution.
    pub dist: BlockDist,
    /// Per-rank plans.
    pub ranks: Vec<RankPlan>,
    /// Outer entries grouped by owning rank (row-major within a rank).
    outer_by_rank: Vec<Vec<usize>>,
}

impl Pars3Plan {
    /// Preprocess: Θ(NNZ) conflict/halo discovery for `p` ranks.
    pub fn new(split: Split3, p: usize) -> Result<Self> {
        ensure!(p >= 1, "need at least one rank");
        ensure!(split.n >= p, "more ranks than rows ({} < {p})", split.n);
        let split = Arc::new(split);
        let dist = BlockDist::new(split.n, p);
        let mut ranks: Vec<RankPlan> = (0..p)
            .map(|r| {
                let (r0, r1) = dist.range(r);
                RankPlan {
                    rank: r,
                    r0,
                    r1,
                    halo_lo: r0,
                    sends: Vec::new(),
                    recvs: Vec::new(),
                    conflicting_nnz: 0,
                    middle_nnz: 0,
                    outer_nnz: 0,
                }
            })
            .collect();

        // Θ(NNZ) discovery pass (paper: "we first iterate over SSS data
        // ... to mark the conflicting process IDs").
        for r in 0..p {
            let (r0, r1) = dist.range(r);
            let rp = &mut ranks[r];
            for i in r0..r1 {
                for (j, _) in split.middle.row(i) {
                    let j = j as usize;
                    rp.middle_nnz += 1;
                    if j < r0 {
                        rp.conflicting_nnz += 1;
                        rp.halo_lo = rp.halo_lo.min(j);
                    }
                }
            }
        }
        let mut outer_by_rank = vec![Vec::new(); p];
        for (k, e) in split.outer.iter().enumerate() {
            let r = dist.rank_of(e.row as usize);
            ranks[r].outer_nnz += 1;
            let j = e.col as usize;
            if j < ranks[r].r0 {
                ranks[r].conflicting_nnz += 1;
                ranks[r].halo_lo = ranks[r].halo_lo.min(j);
            }
            outer_by_rank[r].push(k);
        }

        // Build halo send/recv schedules: rank r needs [halo_lo, r0).
        for r in 0..p {
            let (lo, hi) = (ranks[r].halo_lo, ranks[r].r0);
            if lo >= hi {
                continue;
            }
            let mut src = dist.rank_of(lo);
            while src < r {
                let (s0, s1) = dist.range(src);
                let a = lo.max(s0);
                let b = hi.min(s1);
                if a < b {
                    ranks[r].recvs.push((src, a, b));
                }
                src += 1;
            }
            let recvs = ranks[r].recvs.clone();
            for (src, a, b) in recvs {
                ranks[src].sends.push((r, a, b));
            }
        }
        // Paper order: halo messages posted from the last rank toward
        // root — sort each rank's sends by descending destination.
        for rp in &mut ranks {
            rp.sends.sort_by(|a, b| b.0.cmp(&a.0));
            rp.recvs.sort_by(|a, b| b.0.cmp(&a.0));
        }

        Ok(Self { split, dist, ranks, outer_by_rank })
    }

    /// Rank-local compute shared by both executors. Adds this rank's
    /// contributions into `yw`, a window covering `[halo_lo, r1)`:
    /// `yw[..r0-halo_lo]` receives the cross-boundary (conflicting)
    /// mirror contributions destined for one-sided accumulation, and
    /// `yw[r0-halo_lo..]` is the rank's own output block. `xw` is the
    /// matching contiguous `x` window over `[halo_lo, r1)` (§Perf:
    /// branch-free indexing instead of a halo/local discriminating
    /// closure on every access).
    fn rank_compute(&self, rp: &RankPlan, xw: &[f64], yw: &mut [f64]) {
        let split = &*self.split;
        let sign = split.sym.sign();
        let (r0, r1, base) = (rp.r0, rp.r1, rp.halo_lo);
        debug_assert_eq!(xw.len(), r1 - base);
        debug_assert_eq!(yw.len(), r1 - base);
        // diagonal split
        for i in r0..r1 {
            yw[i - base] = split.diag[i] * xw[i - base];
        }
        // middle split
        for i in r0..r1 {
            let xi = xw[i - base];
            let sxi = sign * xi;
            let mut yi = 0.0;
            let lo = split.middle.row_ptr[i];
            let hi = split.middle.row_ptr[i + 1];
            for (&j, &v) in split.middle.col_ind[lo..hi].iter().zip(&split.middle.vals[lo..hi]) {
                let j = j as usize;
                yi += v * xw[j - base];
                yw[j - base] += v * sxi; // safe or conflicting mirror
            }
            yw[i - base] += yi;
        }
        // outer split: sequential tail
        for &k in &self.outer_by_rank[rp.rank] {
            let e = &split.outer[k];
            let (i, j) = (e.row as usize, e.col as usize);
            yw[i - base] += e.val * xw[j - base];
            yw[j - base] += sign * e.val * xw[i - base];
        }
    }

    /// One rank's full apply: halo exchange + compute + one-sided
    /// accumulate + epoch fence. Shared by the one-shot threaded
    /// executor and the persistent [`Pars3Threaded`] executor.
    fn rank_apply(&self, win: &Window, x: &[f64], ctx: &mut RankCtx) -> RankReport {
        let t0 = std::time::Instant::now();
        let (m0, v0) = (ctx.sent_msgs, ctx.sent_values);
        let rp = &self.ranks[ctx.rank];
        // stage 1: block distribution — rank owns x[r0..r1]
        let x_block = &x[rp.r0..rp.r1];
        // stage 2: halo exchange, paper's last-to-root order
        for &(dest, a, b) in &rp.sends {
            ctx.send(dest, TAG_HALO, x[a..b].to_vec());
        }
        // contiguous x window [halo_lo, r1): halo then local block
        let mut xw = vec![0.0f64; rp.r1 - rp.halo_lo];
        xw[rp.r0 - rp.halo_lo..].copy_from_slice(x_block);
        for &(src, a, b) in &rp.recvs {
            let data = ctx.recv(src, TAG_HALO);
            debug_assert_eq!(data.len(), b - a);
            xw[a - rp.halo_lo..b - rp.halo_lo].copy_from_slice(&data);
        }
        // compute into the matching y window
        let mut yw = vec![0.0f64; rp.r1 - rp.halo_lo];
        self.rank_compute(rp, &xw, &mut yw);
        // one-sided epoch: one batched accumulate covers both the
        // cross-boundary mirrors and the rank's own block
        win.accumulate(rp.halo_lo, &yw);
        ctx.barrier(); // epoch fence
        RankReport {
            msgs: ctx.sent_msgs - m0,
            msg_values: ctx.sent_values - v0,
            seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// One-shot threaded execution: spawns rank threads, runs one
    /// multiply, joins. Returns `(y, stats)`. For the repeated-multiply
    /// hot path use [`Pars3Threaded`] (or [`Pars3Kernel`] with
    /// `threaded = true`), which reuses its rank threads.
    pub fn execute_threaded(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.split.n);
        let window = Window::new(self.split.n);
        let win = &window;
        let results =
            World::run(self.dist.p, |mut ctx| self.rank_apply(win, x, &mut ctx));
        let mut stats = Pars3Stats::default();
        for r in results {
            stats.msgs.push(r.msgs);
            stats.msg_values.push(r.msg_values);
            stats.rank_seconds.push(r.seconds);
        }
        (window.to_vec(), stats)
    }

    /// Rank-sequential emulation: identical numerics and message
    /// accounting without spawning threads. Used for large simulated `p`
    /// (the cost replay) and for deterministic tests.
    pub fn execute_emulated(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.split.n);
        let mut y = vec![0.0f64; self.split.n];
        let mut stats = Pars3Stats::default();
        let mut yw = Vec::new();
        for rp in &self.ranks {
            // zero-copy x window; reused y window buffer (§Perf:
            // allocation-free after the first rank)
            let xw = &x[rp.halo_lo..rp.r1];
            yw.clear();
            yw.resize(rp.r1 - rp.halo_lo, 0.0);
            self.rank_compute(rp, xw, &mut yw);
            for (k, v) in yw.iter().enumerate() {
                y[rp.halo_lo + k] += v;
            }
            stats.msgs.push(rp.sends.len());
            stats.msg_values.push(rp.sends.iter().map(|&(_, a, b)| b - a).sum());
            stats.rank_seconds.push(0.0);
        }
        (y, stats)
    }
}

/// Persistent threaded executor: rank threads are spawned **once** here
/// (over a [`PersistentWorld`]) and reused for every [`Self::apply`] —
/// the iterative-solver hot path pays thread-spawn cost zero times per
/// multiply. The one-sided window persists too and is reset (while all
/// ranks are idle) at the start of each epoch.
pub struct Pars3Threaded {
    plan: Arc<Pars3Plan>,
    world: PersistentWorld,
    window: Arc<Window>,
}

impl Pars3Threaded {
    /// Spawn the rank threads for this plan's distribution.
    pub fn new(plan: Arc<Pars3Plan>) -> Self {
        let world = PersistentWorld::new(plan.dist.p);
        let window = Window::new(plan.split.n);
        Self { plan, world, window }
    }

    /// `y = A x` on the persistent rank threads. Returns `(y, stats)`.
    pub fn apply(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.plan.split.n);
        // All ranks are idle between jobs, so the epoch reset is safe;
        // the job channel send/recv pair orders it before rank writes.
        self.window.reset();
        let x = Arc::new(x.to_vec());
        let plan = self.plan.clone();
        let win = self.window.clone();
        let reports = self.world.run_job(move |ctx| plan.rank_apply(&win, &x, ctx));
        let mut stats = Pars3Stats::default();
        for r in reports {
            stats.msgs.push(r.msgs);
            stats.msg_values.push(r.msg_values);
            stats.rank_seconds.push(r.seconds);
        }
        (self.window.to_vec(), stats)
    }
}

/// [`crate::kernel::Spmv`] adapter at a fixed rank count (the
/// solver-facing interface). `threaded = true` builds a
/// [`Pars3Threaded`] once at construction, so repeated `apply` calls
/// reuse the same rank threads.
pub struct Pars3Kernel {
    plan: Arc<Pars3Plan>,
    exec: Option<Pars3Threaded>,
}

impl Pars3Kernel {
    /// Build from a split at `p` ranks. `threaded = false` uses the
    /// emulated executor (deterministic; preferable on a 1-core box).
    pub fn new(split: Split3, p: usize, threaded: bool) -> Result<Self> {
        let plan = Arc::new(Pars3Plan::new(split, p)?);
        let exec = if threaded { Some(Pars3Threaded::new(plan.clone())) } else { None };
        Ok(Self { plan, exec })
    }

    /// The underlying plan.
    pub fn plan(&self) -> &Pars3Plan {
        &self.plan
    }
}

impl crate::kernel::Spmv for Pars3Kernel {
    fn n(&self) -> usize {
        self.plan.split.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        let (out, _) = match &self.exec {
            Some(exec) => exec.apply(x),
            None => self.plan.execute_emulated(x),
        };
        y.copy_from_slice(&out);
    }

    fn flops(&self) -> u64 {
        let s = &self.plan.split;
        (s.n + 4 * (s.nnz_middle() + s.nnz_outer())) as u64
    }

    fn bytes(&self) -> u64 {
        let s = &self.plan.split;
        (s.n * 8 + (s.nnz_middle() + s.nnz_outer()) * 12) as u64
    }

    fn name(&self) -> &'static str {
        "pars3"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen, Symmetry};

    fn banded(n: usize, seed: u64, alpha: f64) -> crate::sparse::Sss {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    fn check_matches_serial(n: usize, seed: u64, p: usize, threaded: bool) {
        let s = banded(n, seed, 1.5);
        let x: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 * 0.25 - 2.0).collect();
        let mut want = vec![0.0; n];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, p).unwrap());
        let (got, stats) = if threaded {
            plan.execute_threaded(&x)
        } else {
            plan.execute_emulated(&x)
        };
        assert_eq!(stats.msgs.len(), p);
        for (k, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-10, "row {k}: {a} vs {b} (n={n} p={p})");
        }
    }

    #[test]
    fn emulated_matches_serial_various_p() {
        for p in [1, 2, 3, 4, 7, 16] {
            check_matches_serial(120, 1, p, false);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        for p in [1, 2, 4, 8] {
            check_matches_serial(150, 2, p, true);
        }
    }

    #[test]
    fn big_p_edge_cases() {
        check_matches_serial(64, 3, 64, false); // one row per rank
        check_matches_serial(65, 4, 64, false); // uneven blocks
    }

    #[test]
    fn threaded_and_emulated_agree() {
        let s = banded(200, 5, 2.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 6).unwrap());
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.7).cos()).collect();
        let (a, _) = plan.execute_threaded(&x);
        let (b, _) = plan.execute_emulated(&x);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_more_ranks_than_rows() {
        let s = banded(10, 6, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        assert!(Pars3Plan::new(split, 11).is_err());
    }

    #[test]
    fn halo_is_neighbor_only_for_narrow_bands() {
        let s = banded(600, 7, 1.0);
        let bw = s.bandwidth();
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 4;
        let plan = Pars3Plan::new(split, p).unwrap();
        let block = 150;
        if bw < block {
            for rp in &plan.ranks {
                for &(src, _, _) in &rp.recvs {
                    assert_eq!(src + 1, rp.rank, "recv from non-neighbor");
                }
            }
        }
    }

    #[test]
    fn sends_are_posted_in_paper_order() {
        let s = banded(300, 8, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Pars3Plan::new(split, 8).unwrap();
        for rp in &plan.ranks {
            for w in rp.sends.windows(2) {
                assert!(w[0].0 >= w[1].0, "sends not descending by dest");
            }
        }
    }

    #[test]
    fn persistent_threaded_kernel_stable_across_repeated_applies() {
        use crate::kernel::Spmv;
        let s = banded(160, 10, 1.5);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        // threaded = true: rank threads spawn once, here.
        let mut k = Pars3Kernel::new(split, 4, true).unwrap();
        let mut got = vec![0.0; 160];
        // >= 3 consecutive multiplies through the same executor must
        // stay bit-stable vs the serial kernel (window reset + halo
        // matching must not leak state between epochs).
        for round in 0..4u64 {
            let x: Vec<f64> =
                (0..160).map(|i| ((i as u64 * 13 + round * 7) % 23) as f64 * 0.5 - 5.0).collect();
            let mut want = vec![0.0; 160];
            sss_spmv(&s, &x, &mut want);
            k.apply(&x, &mut got);
            for (c, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "round {round} row {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn persistent_executor_stats_are_per_apply_deltas() {
        let s = banded(120, 11, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 3).unwrap());
        let exec = Pars3Threaded::new(plan.clone());
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.3).sin()).collect();
        let (_, s1) = exec.apply(&x);
        let (_, s2) = exec.apply(&x);
        // counters must not accumulate across applies
        assert_eq!(s1.msgs, s2.msgs);
        assert_eq!(s1.msg_values, s2.msg_values);
        // and match the plan's send schedule exactly
        for (r, rp) in plan.ranks.iter().enumerate() {
            assert_eq!(s2.msgs[r], rp.sends.len());
        }
    }

    #[test]
    fn spmv_adapter_works() {
        use crate::kernel::Spmv;
        let s = banded(80, 9, 1.0);
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.1).collect();
        let mut want = vec![0.0; 80];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let mut k = Pars3Kernel::new(split, 4, false).unwrap();
        let mut got = vec![0.0; 80];
        k.apply(&x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
        assert_eq!(k.name(), "pars3");
    }
}
