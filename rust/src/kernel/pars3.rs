//! PARS3 — Parallel 3-Way Banded Skew-SSpMV (the paper's contribution,
//! §3.1.2).
//!
//! Pipeline per multiply, given the preprocessing done once in
//! [`Pars3Plan::new`]:
//!
//! 1. **stage 1** — block row distribution of `x` (each rank owns a
//!    contiguous slice, mirroring the output distribution);
//! 2. **stage 2** — `x`-halo exchange: each rank needs the columns
//!    `[halo_lo, r0)` to its left; the band structure makes these come
//!    from the immediate neighbour(s). Messages follow the paper's
//!    deadlock-avoiding order (posted from the last rank toward root);
//! 3. **middle split compute** — each rank unrolls its local SSS slice;
//!    mirror writes that stay local go straight into the output block,
//!    mirror writes that cross a block boundary are *pre-identified*
//!    (see [`crate::kernel::conflict`]) and batched into a per-rank
//!    scratch slice;
//! 4. **one-sided accumulate** — the scratch slice is pushed into the
//!    shared output window (`MPI_Accumulate` substitute), overlappable
//!    with the outer tail;
//! 5. **outer split** — the few fringe entries are processed
//!    sequentially per rank (paper's choice: avoids fine-grained
//!    irregular communication);
//! 6. **epoch fence** — barrier; the window now holds `y = A x`.

use crate::kernel::batch::VecBatch;
use crate::kernel::blocking::Lanes;
use crate::kernel::conflict::BlockDist;
use crate::kernel::serial_sss::GATHER_LANES;
use crate::kernel::split3::Split3;
use crate::mpisim::{InputSlot, PersistentWorld, RankCtx, RankReport, Window, World};
use crate::perf::Roofline;
use crate::Result;
use anyhow::ensure;
use std::sync::Arc;

/// Tag for halo messages.
const TAG_HALO: u32 = 1;

/// Per-rank precomputed plan.
#[derive(Debug, Clone)]
pub struct RankPlan {
    /// Rank id.
    pub rank: usize,
    /// Owned row range `[r0, r1)`.
    pub r0: usize,
    /// End of owned row range.
    pub r1: usize,
    /// Leftmost column referenced by any local entry (`<= r0`).
    pub halo_lo: usize,
    /// Halo sends: `(dest, lo, hi)` sub-ranges of *this* rank's block.
    pub sends: Vec<(usize, usize, usize)>,
    /// Halo receives: `(src, lo, hi)` sub-ranges arriving from the left.
    pub recvs: Vec<(usize, usize, usize)>,
    /// Middle-split entries with off-rank mirrors (conflict count).
    pub conflicting_nnz: usize,
    /// Local middle-split entries.
    pub middle_nnz: usize,
    /// Local outer-split entries (sequential tail).
    pub outer_nnz: usize,
}

/// Execution statistics (instrumentation for the cost replay + §Perf).
#[derive(Debug, Clone, Default)]
pub struct Pars3Stats {
    /// Messages sent per rank.
    pub msgs: Vec<usize>,
    /// Payload f64 count per rank.
    pub msg_values: Vec<usize>,
    /// Wallclock seconds per rank (threaded mode only).
    pub rank_seconds: Vec<f64>,
    /// Dense diagonals in the middle split's hybrid DIA storage
    /// (0 = pure SSS middle — the fill-ratio heuristic's record).
    pub dia_diagonals: usize,
    /// Middle-split nnz served by the dense diagonals (the remainder
    /// rides the SSS gather loop).
    pub dia_nnz: usize,
    /// Reordering strategy that produced the band this plan's split
    /// came from (`None` when the split was built from an unannotated
    /// matrix — e.g. directly in a test or bench).
    pub reorder_strategy: Option<&'static str>,
    /// The planner's resolved `reorder=... format=... backend=...`
    /// triple for the preparation this split came from (`None` for
    /// unplanned/direct construction).
    pub plan_triple: Option<String>,
    /// Bandwidth of the (reordered) band the split was built from.
    pub reordered_bw: usize,
    /// Lane implementation the band passes dispatched to
    /// ([`crate::kernel::blocking::LaneVariant::name`]; `""` before the
    /// first apply stamps it).
    pub lane_variant: &'static str,
    /// Measured roofline point of the most recent apply through
    /// [`Pars3Kernel`] (`None` for plan-level executions that did not
    /// go through the kernel adapter).
    pub roofline: Option<Roofline>,
    /// Parity phases the `race` backend executed per apply (0 for
    /// every other kernel; at most 2 — see [`crate::kernel::race`]).
    pub race_phases: usize,
    /// Recursion depth of the `race` level grouping (0 for every other
    /// kernel).
    pub race_depth: usize,
    /// Per-phase row-work balance of the `race` schedule
    /// (`max_rank_work * p / phase_total`, 1.0 = perfect; empty for
    /// every other kernel).
    pub race_phase_balance: Vec<f64>,
}

/// The preprocessed parallel kernel.
#[derive(Debug, Clone)]
pub struct Pars3Plan {
    /// The 3-way split (RCM-ordered band).
    pub split: Arc<Split3>,
    /// Block row distribution.
    pub dist: BlockDist,
    /// Per-rank plans.
    pub ranks: Vec<RankPlan>,
    /// Outer entries grouped by owning rank (row-major within a rank).
    outer_by_rank: Vec<Vec<usize>>,
}

impl Pars3Plan {
    /// Preprocess: Θ(NNZ) conflict/halo discovery for `p` ranks.
    /// Accepts an owned or already-shared split (no clone either way),
    /// so many plans over one matrix share one `Split3`.
    pub fn new(split: impl Into<Arc<Split3>>, p: usize) -> Result<Self> {
        let split: Arc<Split3> = split.into();
        ensure!(p >= 1, "need at least one rank");
        ensure!(split.n >= p, "more ranks than rows ({} < {p})", split.n);
        let dist = BlockDist::new(split.n, p);
        let mut ranks: Vec<RankPlan> = (0..p)
            .map(|r| {
                let (r0, r1) = dist.range(r);
                RankPlan {
                    rank: r,
                    r0,
                    r1,
                    halo_lo: r0,
                    sends: Vec::new(),
                    recvs: Vec::new(),
                    conflicting_nnz: 0,
                    middle_nnz: 0,
                    outer_nnz: 0,
                }
            })
            .collect();

        // Θ(NNZ) discovery pass (paper: "we first iterate over SSS data
        // ... to mark the conflicting process IDs"). Iterates TRUE
        // middle nonzeros regardless of storage — with a DIA view
        // active the stored SSS middle holds only the remainder, and
        // explicit-zero dense slots must not widen the halo (so the
        // SSS and DIA splits of one matrix get identical schedules).
        for r in 0..p {
            let (r0, r1) = dist.range(r);
            let rp = &mut ranks[r];
            for i in r0..r1 {
                split.for_each_middle_entry(i, |j, _| {
                    rp.middle_nnz += 1;
                    if j < r0 {
                        rp.conflicting_nnz += 1;
                        rp.halo_lo = rp.halo_lo.min(j);
                    }
                });
            }
        }
        let mut outer_by_rank = vec![Vec::new(); p];
        for (k, e) in split.outer.iter().enumerate() {
            let r = dist.rank_of(e.row as usize);
            ranks[r].outer_nnz += 1;
            let j = e.col as usize;
            if j < ranks[r].r0 {
                ranks[r].conflicting_nnz += 1;
                ranks[r].halo_lo = ranks[r].halo_lo.min(j);
            }
            outer_by_rank[r].push(k);
        }

        // Build halo send/recv schedules: rank r needs [halo_lo, r0).
        for r in 0..p {
            let (lo, hi) = (ranks[r].halo_lo, ranks[r].r0);
            if lo >= hi {
                continue;
            }
            let mut src = dist.rank_of(lo);
            while src < r {
                let (s0, s1) = dist.range(src);
                let a = lo.max(s0);
                let b = hi.min(s1);
                if a < b {
                    ranks[r].recvs.push((src, a, b));
                }
                src += 1;
            }
            let recvs = ranks[r].recvs.clone();
            for (src, a, b) in recvs {
                ranks[src].sends.push((r, a, b));
            }
        }
        // Paper order: halo messages posted from the last rank toward
        // root — sort each rank's sends by descending destination.
        for rp in &mut ranks {
            rp.sends.sort_by(|a, b| b.0.cmp(&a.0));
            rp.recvs.sort_by(|a, b| b.0.cmp(&a.0));
        }

        Ok(Self { split, dist, ranks, outer_by_rank })
    }

    /// Record the preprocessing provenance on a stats object: the
    /// middle-split storage choice (the fill-ratio heuristic's outcome)
    /// and the reordering the band came from.
    fn note_format(&self, stats: &mut Pars3Stats) {
        stats.reorder_strategy = self.split.reorder_strategy;
        stats.plan_triple = self.split.plan_triple.clone();
        stats.reordered_bw = self.split.total_bw;
        stats.lane_variant = Lanes::get().variant.name();
        if let Some(dia) = &self.split.dia {
            stats.dia_diagonals = dia.diags.len();
            stats.dia_nnz = dia.dense_nnz;
        }
    }

    /// Rank-local compute shared by both executors. Adds this rank's
    /// contributions into `yw`, a window covering `[halo_lo, r1)`:
    /// `yw[..r0-halo_lo]` receives the cross-boundary (conflicting)
    /// mirror contributions destined for one-sided accumulation, and
    /// `yw[r0-halo_lo..]` is the rank's own output block. `xw` is the
    /// matching contiguous `x` window over `[halo_lo, r1)` (§Perf:
    /// branch-free indexing instead of a halo/local discriminating
    /// closure on every access).
    fn rank_compute(&self, rp: &RankPlan, xw: &[f64], yw: &mut [f64]) {
        let split = &*self.split;
        let sign = split.sym.sign();
        let (r0, r1, base) = (rp.r0, rp.r1, rp.halo_lo);
        debug_assert_eq!(xw.len(), r1 - base);
        debug_assert_eq!(yw.len(), r1 - base);
        // diagonal split
        for i in r0..r1 {
            yw[i - base] = split.diag[i] * xw[i - base];
        }
        // middle split: blocked unit-stride DIA passes when the hybrid
        // view is selected; otherwise the col_ind gather loop, chunked
        // into GATHER_LANES independent partial sums like Alg. 1
        match &split.dia {
            Some(dia) => dia.apply_window(r0, r1, base, xw, yw),
            None => {
                for i in r0..r1 {
                    let xi = xw[i - base];
                    let sxi = sign * xi;
                    let lo = split.middle.row_ptr[i];
                    let hi = split.middle.row_ptr[i + 1];
                    let cols = &split.middle.col_ind[lo..hi];
                    let vals = &split.middle.vals[lo..hi];
                    let head = cols.len() - cols.len() % GATHER_LANES;
                    let mut acc = [0.0f64; GATHER_LANES];
                    for (jc, vc) in cols[..head]
                        .chunks_exact(GATHER_LANES)
                        .zip(vals[..head].chunks_exact(GATHER_LANES))
                    {
                        for l in 0..GATHER_LANES {
                            let j = jc[l] as usize - base;
                            acc[l] += vc[l] * xw[j];
                            yw[j] += vc[l] * sxi; // safe or conflicting mirror
                        }
                    }
                    for (l, (&j, &v)) in cols[head..].iter().zip(&vals[head..]).enumerate() {
                        let j = j as usize - base;
                        acc[l] += v * xw[j];
                        yw[j] += v * sxi;
                    }
                    yw[i - base] += (acc[0] + acc[1]) + (acc[2] + acc[3]);
                }
            }
        }
        // outer split: sequential tail
        for &k in &self.outer_by_rank[rp.rank] {
            let e = &split.outer[k];
            let (i, j) = (e.row as usize, e.col as usize);
            yw[i - base] += e.val * xw[j - base];
            yw[j - base] += sign * e.val * xw[i - base];
        }
    }

    /// Fused batch variant of [`Self::rank_compute`]: `xw`/`yw` are
    /// **interleaved** `k`-wide windows over `[halo_lo, r1)` — element
    /// `(row_idx, c)` lives at `row_idx * k + c` — so each loaded
    /// `(j, a_ij)` drives `2k` contiguous multiply-accumulates. One
    /// traversal of the rank's matrix slice serves the whole batch.
    fn rank_compute_batch(&self, rp: &RankPlan, k: usize, xw: &[f64], yw: &mut [f64]) {
        let split = &*self.split;
        let sign = split.sym.sign();
        let (r0, r1, base) = (rp.r0, rp.r1, rp.halo_lo);
        debug_assert_eq!(xw.len(), (r1 - base) * k);
        debug_assert_eq!(yw.len(), (r1 - base) * k);
        // diagonal split
        for i in r0..r1 {
            let d = split.diag[i];
            let o = (i - base) * k;
            for c in 0..k {
                yw[o + c] = d * xw[o + c];
            }
        }
        // middle split — each (j, v) loaded once for all k columns;
        // DIA dense diagonals additionally skip the col_ind loads
        match &split.dia {
            Some(dia) => dia.apply_window_batch(r0, r1, base, k, xw, yw),
            None => {
                for i in r0..r1 {
                    let oi = (i - base) * k;
                    let lo = split.middle.row_ptr[i];
                    let hi = split.middle.row_ptr[i + 1];
                    for (&j, &v) in
                        split.middle.col_ind[lo..hi].iter().zip(&split.middle.vals[lo..hi])
                    {
                        let oj = (j as usize - base) * k;
                        let sv = sign * v;
                        for c in 0..k {
                            yw[oi + c] += v * xw[oj + c];
                            yw[oj + c] += sv * xw[oi + c]; // safe or conflicting mirror
                        }
                    }
                }
            }
        }
        // outer split: sequential tail
        for &e_idx in &self.outer_by_rank[rp.rank] {
            let e = &split.outer[e_idx];
            let oi = (e.row as usize - base) * k;
            let oj = (e.col as usize - base) * k;
            let sv = sign * e.val;
            for c in 0..k {
                yw[oi + c] += e.val * xw[oj + c];
                yw[oj + c] += sv * xw[oi + c];
            }
        }
    }

    /// One rank's full apply: halo exchange + compute + one-sided
    /// accumulate + epoch fence. Shared by the one-shot threaded
    /// executor and the persistent [`Pars3Threaded`] executor.
    fn rank_apply(&self, win: &Window, x: &[f64], ctx: &mut RankCtx) -> RankReport {
        let t0 = std::time::Instant::now();
        let (m0, v0) = (ctx.sent_msgs, ctx.sent_values);
        let rp = &self.ranks[ctx.rank];
        // stage 1: block distribution — rank owns x[r0..r1]
        let x_block = &x[rp.r0..rp.r1];
        // stage 2: halo exchange, paper's last-to-root order
        for &(dest, a, b) in &rp.sends {
            ctx.send(dest, TAG_HALO, x[a..b].to_vec());
        }
        // contiguous x window [halo_lo, r1): halo then local block
        let mut xw = vec![0.0f64; rp.r1 - rp.halo_lo];
        xw[rp.r0 - rp.halo_lo..].copy_from_slice(x_block);
        for &(src, a, b) in &rp.recvs {
            let data = ctx.recv(src, TAG_HALO);
            debug_assert_eq!(data.len(), b - a);
            xw[a - rp.halo_lo..b - rp.halo_lo].copy_from_slice(&data);
        }
        // compute into the matching y window
        let mut yw = vec![0.0f64; rp.r1 - rp.halo_lo];
        self.rank_compute(rp, &xw, &mut yw);
        // one-sided epoch: one batched accumulate covers both the
        // cross-boundary mirrors and the rank's own block
        win.accumulate(rp.halo_lo, &yw);
        ctx.barrier(); // epoch fence
        RankReport {
            msgs: ctx.sent_msgs - m0,
            msg_values: ctx.sent_values - v0,
            seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// One rank's fused batch apply over a **column-major** `n × k`
    /// output window. `xd` is the column-major batch input
    /// (`xd[c * n + i]`). Exactly the same message schedule as the
    /// scalar [`Self::rank_apply`] — one halo message per neighbour
    /// range per batch, payload scaled by `k` — so an iterative block
    /// solver pays one halo exchange round per batch, not per vector.
    fn rank_apply_batch(&self, win: &Window, xd: &[f64], k: usize, ctx: &mut RankCtx) -> RankReport {
        let t0 = std::time::Instant::now();
        let (m0, v0) = (ctx.sent_msgs, ctx.sent_values);
        let rp = &self.ranks[ctx.rank];
        let n = self.split.n;
        let (r0, r1, base) = (rp.r0, rp.r1, rp.halo_lo);
        let w = r1 - base;
        // stage 1: gather this rank's own block into the interleaved
        // window (transpose from column-major to k-wide rows)
        let mut xw = vec![0.0f64; w * k];
        for i in r0..r1 {
            let o = (i - base) * k;
            for c in 0..k {
                xw[o + c] = xd[c * n + i];
            }
        }
        // stage 2: halo exchange, paper's last-to-root order — ONE
        // k-wide message per neighbour range (same count as k = 1)
        for &(dest, a, b) in &rp.sends {
            ctx.send(dest, TAG_HALO, xw[(a - base) * k..(b - base) * k].to_vec());
        }
        for &(src, a, b) in &rp.recvs {
            let data = ctx.recv(src, TAG_HALO);
            debug_assert_eq!(data.len(), (b - a) * k);
            xw[(a - base) * k..(b - base) * k].copy_from_slice(&data);
        }
        // fused compute: one matrix traversal for the whole batch
        let mut yw = vec![0.0f64; w * k];
        self.rank_compute_batch(rp, k, &xw, &mut yw);
        // one-sided epoch: scatter the interleaved window into the
        // column-major n×k accumulation window
        for idx in 0..w {
            for c in 0..k {
                win.add(c * n + base + idx, yw[idx * k + c]);
            }
        }
        ctx.barrier(); // epoch fence
        RankReport {
            msgs: ctx.sent_msgs - m0,
            msg_values: ctx.sent_values - v0,
            seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// Rank-sequential fused batch emulation: identical numerics to the
    /// threaded batch path and the same message accounting (`msgs` as
    /// at `k = 1`, payload scaled by `k`) without spawning threads.
    pub fn execute_emulated_batch(&self, xs: &VecBatch, ys: &mut VecBatch) -> Pars3Stats {
        let n = self.split.n;
        let k = xs.k();
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), k);
        let xd = xs.data();
        ys.fill_zero();
        let yd = ys.data_mut();
        let mut stats = Pars3Stats::default();
        self.note_format(&mut stats);
        let (mut xw, mut yw) = (Vec::new(), Vec::new());
        for rp in &self.ranks {
            let (base, r1) = (rp.halo_lo, rp.r1);
            let w = r1 - base;
            // gather the full [halo_lo, r1) window (emulation sees all
            // of x, so the "halo" is a direct gather, not a message)
            xw.clear();
            xw.resize(w * k, 0.0);
            for i in base..r1 {
                let o = (i - base) * k;
                for c in 0..k {
                    xw[o + c] = xd[c * n + i];
                }
            }
            yw.clear();
            yw.resize(w * k, 0.0);
            self.rank_compute_batch(rp, k, &xw, &mut yw);
            for idx in 0..w {
                for c in 0..k {
                    yd[c * n + base + idx] += yw[idx * k + c];
                }
            }
            stats.msgs.push(rp.sends.len());
            stats.msg_values.push(rp.sends.iter().map(|&(_, a, b)| (b - a) * k).sum());
            stats.rank_seconds.push(0.0);
        }
        stats
    }

    /// One-shot threaded execution: spawns rank threads, runs one
    /// multiply, joins. Returns `(y, stats)`. For the repeated-multiply
    /// hot path use [`Pars3Threaded`] (or [`Pars3Kernel`] with
    /// `threaded = true`), which reuses its rank threads.
    pub fn execute_threaded(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.split.n);
        let window = Window::new(self.split.n);
        let win = &window;
        let results =
            World::run(self.dist.p, |mut ctx| self.rank_apply(win, x, &mut ctx));
        let mut stats = Pars3Stats::default();
        self.note_format(&mut stats);
        for r in results {
            stats.msgs.push(r.msgs);
            stats.msg_values.push(r.msg_values);
            stats.rank_seconds.push(r.seconds);
        }
        (window.to_vec(), stats)
    }

    /// Rank-sequential emulation: identical numerics and message
    /// accounting without spawning threads. Used for large simulated `p`
    /// (the cost replay) and for deterministic tests.
    pub fn execute_emulated(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        assert_eq!(x.len(), self.split.n);
        let mut y = vec![0.0f64; self.split.n];
        let mut stats = Pars3Stats::default();
        self.note_format(&mut stats);
        let mut yw = Vec::new();
        for rp in &self.ranks {
            // zero-copy x window; reused y window buffer (§Perf:
            // allocation-free after the first rank)
            let xw = &x[rp.halo_lo..rp.r1];
            yw.clear();
            yw.resize(rp.r1 - rp.halo_lo, 0.0);
            self.rank_compute(rp, xw, &mut yw);
            for (k, v) in yw.iter().enumerate() {
                y[rp.halo_lo + k] += v;
            }
            stats.msgs.push(rp.sends.len());
            stats.msg_values.push(rp.sends.iter().map(|&(_, a, b)| b - a).sum());
            stats.rank_seconds.push(0.0);
        }
        (y, stats)
    }
}

/// Persistent threaded executor: rank threads are spawned **once** here
/// (over a [`PersistentWorld`]) and reused for every [`Self::apply`] —
/// the iterative-solver hot path pays thread-spawn cost zero times per
/// multiply. The one-sided window persists too and is reset (while all
/// ranks are idle) at the start of each epoch.
///
/// Input hand-off is **zero-copy**: the caller's `x` (or batch) is
/// published into a double-buffered [`InputSlot`] and rank threads read
/// it in place — no per-apply `Arc<Vec<f64>>` clone. The borrow is
/// sound because [`PersistentWorld::run_job`] blocks until every rank
/// reports done, so the slice outlives all reads of its epoch.
pub struct Pars3Threaded {
    plan: Arc<Pars3Plan>,
    world: PersistentWorld,
    window: Arc<Window>,
    xslot: Arc<InputSlot>,
    /// `n × k` column-major accumulate window for the fused batch path,
    /// sized once per batch width (see [`Self::prepare_batch`]).
    batch_window: Option<(usize, Arc<Window>)>,
}

impl Pars3Threaded {
    /// Spawn the rank threads for this plan's distribution.
    pub fn new(plan: Arc<Pars3Plan>) -> Self {
        let world = PersistentWorld::new(plan.dist.p);
        let window = Window::new(plan.split.n);
        Self { plan, world, window, xslot: InputSlot::new(), batch_window: None }
    }

    fn collect(&self, reports: Vec<RankReport>) -> Pars3Stats {
        let mut stats = Pars3Stats::default();
        self.plan.note_format(&mut stats);
        for r in reports {
            stats.msgs.push(r.msgs);
            stats.msg_values.push(r.msg_values);
            stats.rank_seconds.push(r.seconds);
        }
        stats
    }

    /// `y = A x` into a caller buffer on the persistent rank threads.
    /// Allocation-free on the executor side: ranks read `x` through the
    /// input slot and `y` is filled straight from the window.
    pub fn apply_into(&self, x: &[f64], y: &mut [f64]) -> Pars3Stats {
        assert_eq!(x.len(), self.plan.split.n);
        assert_eq!(y.len(), self.plan.split.n);
        // All ranks are idle between jobs, so the epoch reset is safe;
        // the job channel send/recv pair orders it before rank writes.
        self.window.reset();
        let epoch = self.xslot.publish(x);
        let plan = self.plan.clone();
        let win = self.window.clone();
        let slot = self.xslot.clone();
        let reports = self.world.run_job(move |ctx| {
            // SAFETY: run_job returns only after every rank reports
            // done, so the caller's `x` outlives all reads of `epoch`.
            let x = unsafe { slot.read(epoch) };
            plan.rank_apply(&win, x, ctx)
        });
        self.xslot.retire(epoch);
        self.window.read_into(y);
        self.collect(reports)
    }

    /// `y = A x` on the persistent rank threads. Returns `(y, stats)`.
    pub fn apply(&self, x: &[f64]) -> (Vec<f64>, Pars3Stats) {
        let mut y = vec![0.0f64; self.plan.split.n];
        let stats = self.apply_into(x, &mut y);
        (y, stats)
    }

    /// False once a rank panic has poisoned the persistent world: any
    /// further job submission fails loudly instead of hanging peers at
    /// the barrier (the poisoned-epoch guard).
    pub fn healthy(&self) -> bool {
        !self.world.is_poisoned()
    }

    /// Size (or resize) the `n × k` batch window ahead of time so the
    /// first batched multiply pays no allocation.
    pub fn prepare_batch(&mut self, k: usize) -> Arc<Window> {
        match &self.batch_window {
            Some((bk, w)) if *bk == k => w.clone(),
            _ => {
                let w = Window::new(self.plan.split.n * k.max(1));
                self.batch_window = Some((k.max(1), w.clone()));
                w
            }
        }
    }

    /// Fused batch multiply `ys = A xs` on the persistent rank threads:
    /// one matrix traversal and **one halo exchange round** per batch
    /// (message count identical to a single apply; payload scaled by
    /// `k`). The caller's column-major batch is read in place through
    /// the input slot — no clone.
    pub fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) -> Pars3Stats {
        let n = self.plan.split.n;
        let k = xs.k();
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        assert_eq!(ys.k(), k);
        if k == 0 {
            return Pars3Stats::default();
        }
        let win = self.prepare_batch(k);
        win.reset();
        let epoch = self.xslot.publish(xs.data());
        let plan = self.plan.clone();
        let slot = self.xslot.clone();
        let wjob = win.clone();
        let reports = self.world.run_job(move |ctx| {
            // SAFETY: as in apply_into — run_job blocks until every
            // rank reports, so the batch outlives all epoch reads.
            let xd = unsafe { slot.read(epoch) };
            plan.rank_apply_batch(&wjob, xd, k, ctx)
        });
        self.xslot.retire(epoch);
        win.read_into(ys.data_mut());
        self.collect(reports)
    }
}

/// [`crate::kernel::Spmv`] adapter at a fixed rank count (the
/// solver-facing interface). `threaded = true` builds a
/// [`Pars3Threaded`] once at construction, so repeated `apply` calls
/// reuse the same rank threads.
pub struct Pars3Kernel {
    plan: Arc<Pars3Plan>,
    exec: Option<Pars3Threaded>,
    last_stats: Option<Pars3Stats>,
}

impl Pars3Kernel {
    /// Build from a split at `p` ranks. `threaded = false` uses the
    /// emulated executor (deterministic; preferable on a 1-core box).
    pub fn new(split: impl Into<Arc<Split3>>, p: usize, threaded: bool) -> Result<Self> {
        let plan = Arc::new(Pars3Plan::new(split, p)?);
        let exec = if threaded { Some(Pars3Threaded::new(plan.clone())) } else { None };
        Ok(Self { plan, exec, last_stats: None })
    }

    /// The underlying plan.
    pub fn plan(&self) -> &Pars3Plan {
        &self.plan
    }

    /// Execution statistics of the most recent `apply`/`apply_batch`
    /// (message counts per rank; the batch-fusion acceptance tests
    /// assert on these).
    pub fn last_stats(&self) -> Option<&Pars3Stats> {
        self.last_stats.as_ref()
    }
}

impl crate::kernel::Spmv for Pars3Kernel {
    fn n(&self) -> usize {
        self.plan.split.n
    }

    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        let t0 = std::time::Instant::now();
        let mut stats = match &self.exec {
            Some(exec) => exec.apply_into(x, y),
            None => {
                let (out, stats) = self.plan.execute_emulated(x);
                y.copy_from_slice(&out);
                stats
            }
        };
        stats.roofline =
            Some(Roofline::from_seconds(t0.elapsed().as_secs_f64(), self.flops(), self.bytes()));
        self.last_stats = Some(stats);
    }

    fn apply_batch(&mut self, xs: &VecBatch, ys: &mut VecBatch) {
        let t0 = std::time::Instant::now();
        let mut stats = match &mut self.exec {
            Some(exec) => exec.apply_batch(xs, ys),
            None => self.plan.execute_emulated_batch(xs, ys),
        };
        // the batch does k vectors' flops over one matrix traversal
        let k = xs.k() as u64;
        stats.roofline = Some(Roofline::from_seconds(
            t0.elapsed().as_secs_f64(),
            self.flops() * k,
            self.bytes(),
        ));
        self.last_stats = Some(stats);
    }

    fn prepare_hint(&mut self, k: usize) {
        if let Some(exec) = &mut self.exec {
            exec.prepare_batch(k);
        }
    }

    fn healthy(&self) -> bool {
        self.exec.as_ref().is_none_or(Pars3Threaded::healthy)
    }

    fn flops(&self) -> u64 {
        let s = &self.plan.split;
        let middle = match &s.dia {
            // dense slots are streamed and multiplied, zeros included
            Some(dia) => dia.dense_slots() + dia.rest.nnz_lower(),
            None => s.nnz_middle(),
        };
        (s.n + 4 * (middle + s.nnz_outer())) as u64
    }

    fn bytes(&self) -> u64 {
        let s = &self.plan.split;
        match &s.dia {
            Some(dia) => (s.n * 8 + s.nnz_outer() * 12) as u64 + dia.bytes(),
            None => (s.n * 8 + (s.nnz_middle() + s.nnz_outer()) * 12) as u64,
        }
    }

    fn name(&self) -> &'static str {
        "pars3"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::serial_sss::sss_spmv;
    use crate::sparse::{convert, gen, Symmetry};

    fn banded(n: usize, seed: u64, alpha: f64) -> crate::sparse::Sss {
        let coo = gen::small_test_matrix(n, seed, alpha);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    fn check_matches_serial(n: usize, seed: u64, p: usize, threaded: bool) {
        let s = banded(n, seed, 1.5);
        let x: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 * 0.25 - 2.0).collect();
        let mut want = vec![0.0; n];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, p).unwrap());
        let (got, stats) = if threaded {
            plan.execute_threaded(&x)
        } else {
            plan.execute_emulated(&x)
        };
        assert_eq!(stats.msgs.len(), p);
        for (k, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-10, "row {k}: {a} vs {b} (n={n} p={p})");
        }
    }

    #[test]
    fn emulated_matches_serial_various_p() {
        for p in [1, 2, 3, 4, 7, 16] {
            check_matches_serial(120, 1, p, false);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        for p in [1, 2, 4, 8] {
            check_matches_serial(150, 2, p, true);
        }
    }

    #[test]
    fn big_p_edge_cases() {
        check_matches_serial(64, 3, 64, false); // one row per rank
        check_matches_serial(65, 4, 64, false); // uneven blocks
    }

    #[test]
    fn threaded_and_emulated_agree() {
        let s = banded(200, 5, 2.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 6).unwrap());
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.7).cos()).collect();
        let (a, _) = plan.execute_threaded(&x);
        let (b, _) = plan.execute_emulated(&x);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_more_ranks_than_rows() {
        let s = banded(10, 6, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        assert!(Pars3Plan::new(split, 11).is_err());
    }

    #[test]
    fn halo_is_neighbor_only_for_narrow_bands() {
        let s = banded(600, 7, 1.0);
        let bw = s.bandwidth();
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 4;
        let plan = Pars3Plan::new(split, p).unwrap();
        let block = 150;
        if bw < block {
            for rp in &plan.ranks {
                for &(src, _, _) in &rp.recvs {
                    assert_eq!(src + 1, rp.rank, "recv from non-neighbor");
                }
            }
        }
    }

    #[test]
    fn sends_are_posted_in_paper_order() {
        let s = banded(300, 8, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Pars3Plan::new(split, 8).unwrap();
        for rp in &plan.ranks {
            for w in rp.sends.windows(2) {
                assert!(w[0].0 >= w[1].0, "sends not descending by dest");
            }
        }
    }

    #[test]
    fn persistent_threaded_kernel_stable_across_repeated_applies() {
        use crate::kernel::Spmv;
        let s = banded(160, 10, 1.5);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        // threaded = true: rank threads spawn once, here.
        let mut k = Pars3Kernel::new(split, 4, true).unwrap();
        let mut got = vec![0.0; 160];
        // >= 3 consecutive multiplies through the same executor must
        // stay bit-stable vs the serial kernel (window reset + halo
        // matching must not leak state between epochs).
        for round in 0..4u64 {
            let x: Vec<f64> =
                (0..160).map(|i| ((i as u64 * 13 + round * 7) % 23) as f64 * 0.5 - 5.0).collect();
            let mut want = vec![0.0; 160];
            sss_spmv(&s, &x, &mut want);
            k.apply(&x, &mut got);
            for (c, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "round {round} row {c}: {a} vs {b}");
            }
        }
        // a live executor reports healthy (the kernel cache's evict probe)
        assert!(k.healthy());
    }

    #[test]
    fn persistent_executor_stats_are_per_apply_deltas() {
        let s = banded(120, 11, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 3).unwrap());
        let exec = Pars3Threaded::new(plan.clone());
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.3).sin()).collect();
        let (_, s1) = exec.apply(&x);
        let (_, s2) = exec.apply(&x);
        // counters must not accumulate across applies
        assert_eq!(s1.msgs, s2.msgs);
        assert_eq!(s1.msg_values, s2.msg_values);
        // and match the plan's send schedule exactly
        for (r, rp) in plan.ranks.iter().enumerate() {
            assert_eq!(s2.msgs[r], rp.sends.len());
        }
    }

    #[test]
    fn emulated_batch_matches_columnwise_apply() {
        let s = banded(140, 12, 1.5);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Pars3Plan::new(split, 5).unwrap();
        let k = 4;
        let xs = VecBatch::from_fn(140, k, |i, c| ((i * 7 + c * 31) % 19) as f64 * 0.3 - 2.5);
        let mut ys = VecBatch::zeros(140, k);
        plan.execute_emulated_batch(&xs, &mut ys);
        for c in 0..k {
            let (want, _) = plan.execute_emulated(xs.col(c));
            for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-9, "col {c} row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn threaded_batch_matches_emulated_batch() {
        let s = banded(160, 13, 2.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 4).unwrap());
        let mut exec = Pars3Threaded::new(plan.clone());
        let k = 3;
        let xs = VecBatch::from_fn(160, k, |i, c| (i as f64 * 0.17 + c as f64).cos());
        let mut got = VecBatch::zeros(160, k);
        exec.apply_batch(&xs, &mut got);
        let mut want = VecBatch::zeros(160, k);
        plan.execute_emulated_batch(&xs, &mut want);
        for c in 0..k {
            for (r, (a, b)) in got.col(c).iter().zip(want.col(c)).enumerate() {
                assert!((a - b).abs() < 1e-10, "col {c} row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_fuses_halo_exchange_one_round_per_batch() {
        // acceptance: msgs for a k=8 batch == msgs for k=1, payload ×8,
        // on BOTH executors — the batch traverses the matrix once and
        // exchanges halos once, not once per vector.
        let s = banded(200, 14, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 6).unwrap());
        let k = 8;
        let xs = VecBatch::from_fn(200, k, |i, c| ((i + c * 17) % 23) as f64 * 0.25 - 2.0);
        let x1 = xs.col(0).to_vec();

        // emulated executor
        let (_, s_one) = plan.execute_emulated(&x1);
        let mut ys = VecBatch::zeros(200, k);
        let s_batch = plan.execute_emulated_batch(&xs, &mut ys);
        assert_eq!(s_batch.msgs, s_one.msgs, "emulated: batch must not add messages");
        for (r, (&bv, &ov)) in s_batch.msg_values.iter().zip(&s_one.msg_values).enumerate() {
            assert_eq!(bv, ov * k, "emulated rank {r}: payload must scale by k");
        }

        // persistent threaded executor
        let mut exec = Pars3Threaded::new(plan.clone());
        let (_, t_one) = exec.apply(&x1);
        let mut yt = VecBatch::zeros(200, k);
        let t_batch = exec.apply_batch(&xs, &mut yt);
        assert_eq!(t_batch.msgs, t_one.msgs, "threaded: batch must not add messages");
        for (r, (&bv, &ov)) in t_batch.msg_values.iter().zip(&t_one.msg_values).enumerate() {
            assert_eq!(bv, ov * k, "threaded rank {r}: payload must scale by k");
        }
    }

    #[test]
    fn threaded_apply_reads_x_in_place_zero_copy() {
        // regression for the old per-apply `Arc<Vec<f64>>` clone: the
        // executor must read the caller's buffer through the input
        // slot, and repeated applies through the same executor must
        // stay correct while the caller rewrites that same buffer.
        use crate::kernel::Spmv;
        let s = banded(100, 15, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let mut k = Pars3Kernel::new(split, 3, true).unwrap();
        let mut x = vec![0.0f64; 100];
        let mut got = vec![0.0f64; 100];
        for round in 0..3u64 {
            for (i, v) in x.iter_mut().enumerate() {
                *v = ((i as u64 * 5 + round * 11) % 17) as f64 * 0.5 - 3.0;
            }
            let mut want = vec![0.0; 100];
            sss_spmv(&s, &x, &mut want);
            k.apply(&x, &mut got);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "round {round} row {r}");
            }
        }
        assert!(k.last_stats().is_some());
    }

    #[test]
    fn persistent_executor_survives_interleaved_batch_widths() {
        // k=1 applies and k=4/k=2 batches interleaved through ONE
        // executor: the double-buffered slot and the resizable batch
        // window must not leak state between epochs of different widths
        let s = banded(110, 17, 1.5);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 4).unwrap());
        let mut exec = Pars3Threaded::new(plan.clone());
        for (round, &k) in [1usize, 4, 2, 4, 1].iter().enumerate() {
            let xs = VecBatch::from_fn(110, k, |i, c| {
                ((i * 3 + c * 13 + round * 7) % 19) as f64 * 0.4 - 3.0
            });
            let mut got = VecBatch::zeros(110, k);
            exec.apply_batch(&xs, &mut got);
            for c in 0..k {
                let mut want = vec![0.0; 110];
                sss_spmv(&s, xs.col(c), &mut want);
                for (r, (a, b)) in got.col(c).iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-10,
                        "round {round} k={k} col {c} row {r}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prepare_hint_presizes_the_batch_window() {
        let s = banded(90, 16, 1.0);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, 3).unwrap());
        let mut exec = Pars3Threaded::new(plan);
        let w1 = exec.prepare_batch(4);
        let w2 = exec.prepare_batch(4);
        assert!(Arc::ptr_eq(&w1, &w2), "same width must reuse the window");
        assert_eq!(w1.len(), 90 * 4);
        let w3 = exec.prepare_batch(2);
        assert_eq!(w3.len(), 90 * 2);
    }

    #[test]
    fn dia_middle_split_matches_sss_on_both_executors_and_is_recorded() {
        use crate::kernel::FormatPolicy;
        let s = banded(170, 21, 1.5);
        let x: Vec<f64> = (0..170).map(|i| ((i * 19) % 23) as f64 * 0.3 - 2.5).collect();
        let split_sss = Split3::with_outer_bw(&s, 3).unwrap();
        let split_dia = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        assert!(split_dia.dia.is_some(), "forced DIA must build");
        for p in [1, 3, 6] {
            let plan_s = Pars3Plan::new(split_sss.clone(), p).unwrap();
            let plan_d = Arc::new(Pars3Plan::new(split_dia.clone(), p).unwrap());
            let (want, stats_s) = plan_s.execute_emulated(&x);
            let (got, stats_d) = plan_d.execute_emulated(&x);
            // heuristic outcome is recorded on the stats
            assert_eq!(stats_s.dia_diagonals, 0);
            assert!(stats_d.dia_diagonals > 0);
            assert_eq!(stats_d.dia_nnz, split_dia.dia.as_ref().unwrap().dense_nnz);
            // identical message schedule (format changes compute, not
            // communication), same numerics to rounding
            assert_eq!(stats_s.msgs, stats_d.msgs);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "p={p} row {r}: {a} vs {b}");
            }
            // threaded executor over the DIA split
            let (got_t, _) = plan_d.execute_threaded(&x);
            for (r, (a, b)) in got_t.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "threaded p={p} row {r}");
            }
        }
    }

    #[test]
    fn dia_batch_matches_sss_batch() {
        use crate::kernel::FormatPolicy;
        let s = banded(140, 22, 1.0);
        let k = 5;
        let xs = VecBatch::from_fn(140, k, |i, c| ((i * 7 + c * 13) % 17) as f64 * 0.25 - 2.0);
        let split_sss = Split3::with_outer_bw(&s, 3).unwrap();
        let split_dia = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        let plan_s = Pars3Plan::new(split_sss, 4).unwrap();
        let plan_d = Arc::new(Pars3Plan::new(split_dia, 4).unwrap());
        let mut want = VecBatch::zeros(140, k);
        plan_s.execute_emulated_batch(&xs, &mut want);
        let mut got = VecBatch::zeros(140, k);
        let st = plan_d.execute_emulated_batch(&xs, &mut got);
        assert!(st.dia_diagonals > 0);
        for c in 0..k {
            for (r, (a, b)) in got.col(c).iter().zip(want.col(c)).enumerate() {
                assert!((a - b).abs() < 1e-10, "col {c} row {r}");
            }
        }
        // persistent threaded batch path over the DIA split
        let mut exec = Pars3Threaded::new(plan_d);
        let mut got_t = VecBatch::zeros(140, k);
        let st_t = exec.apply_batch(&xs, &mut got_t);
        assert_eq!(st_t.dia_diagonals, st.dia_diagonals);
        for c in 0..k {
            for (r, (a, b)) in got_t.col(c).iter().zip(want.col(c)).enumerate() {
                assert!((a - b).abs() < 1e-10, "threaded col {c} row {r}");
            }
        }
    }

    #[test]
    fn spmv_adapter_works() {
        use crate::kernel::Spmv;
        let s = banded(80, 9, 1.0);
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.1).collect();
        let mut want = vec![0.0; 80];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let mut k = Pars3Kernel::new(split, 4, false).unwrap();
        let mut got = vec![0.0; 80];
        k.apply(&x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
        assert_eq!(k.name(), "pars3");
        // stats carry the lane dispatch and a measured roofline point
        let st = k.last_stats().unwrap();
        assert!(!st.lane_variant.is_empty(), "lane variant must be stamped");
        let r = st.roofline.expect("kernel apply must stamp a roofline");
        assert!(r.peak_gbytes > 0.0 && r.gbytes > 0.0);
        assert!((r.achieved_fraction - r.gbytes / r.peak_gbytes).abs() < 1e-12);
    }

    #[test]
    fn tiny_tile_budget_matches_default_through_rank_windows() {
        use crate::kernel::FormatPolicy;
        let s = banded(180, 23, 1.5);
        let x: Vec<f64> = (0..180).map(|i| ((i * 17) % 29) as f64 * 0.2 - 2.3).collect();
        let split_def = Split3::with_outer_bw_format(&s, 3, FormatPolicy::Dia).unwrap();
        let split_tiny =
            Split3::with_outer_bw_format_budget(&s, 3, FormatPolicy::Dia, 1).unwrap();
        for p in [1, 4] {
            let (want, _) = Pars3Plan::new(split_def.clone(), p).unwrap().execute_emulated(&x);
            let (got, _) = Pars3Plan::new(split_tiny.clone(), p).unwrap().execute_emulated(&x);
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12, "p={p} row {r}: {a} vs {b}");
            }
        }
    }
}
