//! Block row distribution and conflict pre-identification (paper Fig. 2).
//!
//! Under block row distribution, processing a stored lower entry
//! `(i, j)` on `rank(i)` also updates `y[j]` (the mirrored write). The
//! entry is **safe** (yellow squares in Fig. 2) when `rank(j) ==
//! rank(i)`; it is **conflicting** (purple) when the mirror lands in
//! another rank's output block. The key PARS3 idea: because the matrix
//! is banded, conflicts are confined to block boundaries, and a single
//! Θ(NNZ) preprocessing pass can enumerate them exactly — no runtime
//! synchronization or speculative rollback needed.

use crate::kernel::split3::Split3;
use crate::sparse::Sss;

/// Block (contiguous) row distribution over `p` ranks.
///
/// The first `n % p` ranks get `ceil(n/p)` rows, the rest `floor(n/p)` —
/// the paper's "equal amount of rows" scatter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockDist {
    /// Matrix dimension.
    pub n: usize,
    /// Rank count.
    pub p: usize,
}

impl BlockDist {
    /// Create a distribution; `p >= 1`.
    pub fn new(n: usize, p: usize) -> Self {
        assert!(p >= 1, "need at least one rank");
        Self { n, p }
    }

    /// Row range `[start, end)` owned by `rank`.
    pub fn range(&self, rank: usize) -> (usize, usize) {
        let base = self.n / self.p;
        let extra = self.n % self.p;
        let start = rank * base + rank.min(extra);
        let len = base + usize::from(rank < extra);
        (start, (start + len).min(self.n))
    }

    /// Owner rank of `row`.
    pub fn rank_of(&self, row: usize) -> usize {
        debug_assert!(row < self.n);
        let base = self.n / self.p;
        let extra = self.n % self.p;
        let cut = extra * (base + 1);
        if row < cut {
            row / (base + 1)
        } else if base > 0 {
            extra + (row - cut) / base
        } else {
            // n < p: ranks beyond n own nothing
            row
        }
    }

    /// Rows owned by `rank`.
    pub fn rows_of(&self, rank: usize) -> usize {
        let (a, b) = self.range(rank);
        b - a
    }
}

/// Per-rank conflict statistics from the preprocessing pass.
#[derive(Debug, Clone, Default)]
pub struct RankConflicts {
    /// Stored middle-split entries whose rows this rank owns.
    pub local_nnz: usize,
    /// Of those, entries whose mirror write stays local (safe, yellow).
    pub safe_nnz: usize,
    /// Entries whose mirror write targets another rank (purple).
    pub conflicting_nnz: usize,
    /// Distinct remote ranks this rank's mirrors write into.
    pub target_ranks: Vec<usize>,
    /// Columns needed from other ranks for the direct products
    /// (`x`-halo): per source rank, count of referenced columns.
    pub halo_cols_by_src: Vec<(usize, usize)>,
    /// Outer-split entries owned by this rank.
    pub outer_nnz: usize,
    /// Of the outer entries, how many conflict.
    pub outer_conflicting: usize,
}

/// Whole-matrix conflict map for a given rank count.
#[derive(Debug, Clone)]
pub struct ConflictMap {
    /// The distribution analyzed.
    pub dist: BlockDist,
    /// Per-rank statistics.
    pub per_rank: Vec<RankConflicts>,
}

impl ConflictMap {
    /// Analyze a split matrix under `p` ranks in one Θ(NNZ) pass.
    pub fn analyze(split: &Split3, p: usize) -> Self {
        let dist = BlockDist::new(split.n, p);
        let mut per_rank = vec![RankConflicts::default(); p];
        let mut halo: Vec<std::collections::BTreeMap<usize, usize>> =
            vec![Default::default(); p];

        // True middle nonzeros regardless of storage: with a DIA view
        // the stored SSS middle is remainder-only, and explicit-zero
        // dense slots must not count as conflicts.
        for i in 0..split.n {
            let r = dist.rank_of(i);
            let rc = &mut per_rank[r];
            let h = &mut halo[r];
            split.for_each_middle_entry(i, |j, _| {
                let jr = dist.rank_of(j);
                rc.local_nnz += 1;
                if jr == r {
                    rc.safe_nnz += 1;
                } else {
                    rc.conflicting_nnz += 1;
                    if !rc.target_ranks.contains(&jr) {
                        rc.target_ranks.push(jr);
                    }
                    *h.entry(jr).or_insert(0) += 1;
                }
            });
        }
        for e in &split.outer {
            let r = dist.rank_of(e.row as usize);
            let jr = dist.rank_of(e.col as usize);
            per_rank[r].outer_nnz += 1;
            if jr != r {
                per_rank[r].outer_conflicting += 1;
            }
        }
        for (r, h) in halo.into_iter().enumerate() {
            per_rank[r].halo_cols_by_src = h.into_iter().collect();
            per_rank[r].target_ranks.sort_unstable();
        }
        Self { dist, per_rank }
    }

    /// Analyze an unsplit SSS matrix (middle = everything).
    pub fn analyze_sss(s: &Sss, p: usize) -> Self {
        let split = Split3::new(s, s.bandwidth().max(1)).expect("split");
        Self::analyze(&split, p)
    }

    /// Total conflicting entries across ranks (the Fig. 2 / [3] "data
    /// races" count: grows with `p`).
    pub fn total_conflicts(&self) -> usize {
        self.per_rank.iter().map(|r| r.conflicting_nnz + r.outer_conflicting).sum()
    }

    /// Total safe entries (middle + outer whose mirrors stay local).
    pub fn total_safe(&self) -> usize {
        self.per_rank
            .iter()
            .map(|r| r.safe_nnz + (r.outer_nnz - r.outer_conflicting))
            .sum()
    }

    /// Rank 0 never conflicts (paper §3: its mirrors stay local because
    /// band columns `j < i` of the first block are owned by rank 0).
    pub fn rank0_conflicts(&self) -> usize {
        self.per_rank[0].conflicting_nnz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{convert, gen, Symmetry};

    fn banded_split(n: usize, seed: u64, split_bw: usize) -> Split3 {
        let coo = gen::small_test_matrix(n, seed, 1.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        let sss = convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap();
        Split3::new(&sss, split_bw).unwrap()
    }

    #[test]
    fn block_dist_partitions_rows() {
        for (n, p) in [(10, 3), (7, 7), (100, 8), (5, 8), (64, 1)] {
            let d = BlockDist::new(n, p);
            let mut covered = 0;
            for r in 0..p {
                let (a, b) = d.range(r);
                covered += b - a;
                for row in a..b {
                    assert_eq!(d.rank_of(row), r, "n={n} p={p} row={row}");
                }
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn conflicts_partition_local_nnz() {
        let split = banded_split(120, 1, 6);
        for p in [1, 2, 4, 8] {
            let cm = ConflictMap::analyze(&split, p);
            let total: usize = cm.per_rank.iter().map(|r| r.local_nnz).sum();
            assert_eq!(total, split.nnz_middle());
            assert_eq!(cm.total_safe() + cm.total_conflicts(),
                       split.nnz_middle() + split.nnz_outer());
        }
    }

    #[test]
    fn single_rank_has_no_conflicts() {
        let split = banded_split(80, 2, 4);
        let cm = ConflictMap::analyze(&split, 1);
        assert_eq!(cm.total_conflicts(), 0);
    }

    #[test]
    fn conflicts_grow_with_ranks() {
        // the paper/[3] observation: more processes => more data races
        let split = banded_split(200, 3, 8);
        let c2 = ConflictMap::analyze(&split, 2).total_conflicts();
        let c8 = ConflictMap::analyze(&split, 8).total_conflicts();
        let c32 = ConflictMap::analyze(&split, 32).total_conflicts();
        assert!(c2 <= c8 && c8 <= c32, "c2={c2} c8={c8} c32={c32}");
    }

    #[test]
    fn rank0_never_conflicts() {
        let split = banded_split(150, 4, 6);
        for p in [2, 4, 8] {
            let cm = ConflictMap::analyze(&split, p);
            assert_eq!(cm.rank0_conflicts(), 0, "p={p}");
        }
    }

    #[test]
    fn banded_matrix_conflicts_only_with_neighbors() {
        // with bandwidth << block size, every conflict targets rank-1
        let split = banded_split(400, 5, 4);
        let bw = split.total_bw;
        let cm = ConflictMap::analyze(&split, 4);
        let block = 100;
        if bw < block {
            for (r, rc) in cm.per_rank.iter().enumerate() {
                for &t in &rc.target_ranks {
                    assert_eq!(t, r - 1, "rank {r} targets {t}");
                }
            }
        }
    }

    #[test]
    fn dia_and_sss_splits_get_identical_conflict_maps() {
        // the analysis must see true nonzeros only, so the remainder-
        // only DIA storage and the pure SSS middle agree entry-for-entry
        let split_sss = banded_split(180, 7, 6);
        let mut split_dia = split_sss.clone();
        split_dia.select_format(crate::kernel::FormatPolicy::Dia);
        assert!(split_dia.dia.is_some());
        for p in [1, 3, 8] {
            let a = ConflictMap::analyze(&split_sss, p);
            let b = ConflictMap::analyze(&split_dia, p);
            for (ra, rb) in a.per_rank.iter().zip(&b.per_rank) {
                assert_eq!(ra.local_nnz, rb.local_nnz, "p={p}");
                assert_eq!(ra.safe_nnz, rb.safe_nnz, "p={p}");
                assert_eq!(ra.conflicting_nnz, rb.conflicting_nnz, "p={p}");
                assert_eq!(ra.target_ranks, rb.target_ranks, "p={p}");
                assert_eq!(ra.halo_cols_by_src, rb.halo_cols_by_src, "p={p}");
            }
        }
    }

    #[test]
    fn halo_counts_match_conflicts() {
        let split = banded_split(160, 6, 5);
        let cm = ConflictMap::analyze(&split, 8);
        for rc in &cm.per_rank {
            let halo_total: usize = rc.halo_cols_by_src.iter().map(|(_, c)| c).sum();
            assert_eq!(halo_total, rc.conflicting_nnz);
        }
    }
}
