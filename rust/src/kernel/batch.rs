//! Column-major multi-vector batches for the fused `apply_batch` path.
//!
//! A [`VecBatch`] is an `n × k` block of `k` input (or output) vectors
//! stored column-major in one contiguous allocation — the layout
//! block-Krylov and multi-RHS solvers already hold their vectors in, so
//! handing a batch to a kernel is pointer-passing, not repacking. The
//! fused kernels traverse the matrix **once** per batch and reuse each
//! loaded `(j, a_ij)` entry across all `k` columns, which is where the
//! batch win comes from: matrix traffic is amortized `k`-fold while
//! vector traffic stays linear.

/// A dense `n × k` column-major multi-vector (k vectors of length n).
#[derive(Debug, Clone, PartialEq)]
pub struct VecBatch {
    n: usize,
    k: usize,
    data: Vec<f64>,
}

impl VecBatch {
    /// Zero-initialized `n × k` batch.
    pub fn zeros(n: usize, k: usize) -> Self {
        Self { n, k, data: vec![0.0; n * k] }
    }

    /// Build from `k` columns, each of length `n`. Panics on ragged input.
    pub fn from_columns(cols: &[Vec<f64>]) -> Self {
        let k = cols.len();
        let n = cols.first().map(Vec::len).unwrap_or(0);
        let mut data = Vec::with_capacity(n * k);
        for c in cols {
            assert_eq!(c.len(), n, "ragged batch columns");
            data.extend_from_slice(c);
        }
        Self { n, k, data }
    }

    /// Build column `c` element `i` as `f(i, c)`.
    pub fn from_fn(n: usize, k: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut b = Self::zeros(n, k);
        for c in 0..k {
            for i in 0..n {
                b.data[c * n + i] = f(i, c);
            }
        }
        b
    }

    /// Vector length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of columns (batch width).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Column `c` as a slice.
    pub fn col(&self, c: usize) -> &[f64] {
        &self.data[c * self.n..(c + 1) * self.n]
    }

    /// Column `c` as a mutable slice.
    pub fn col_mut(&mut self, c: usize) -> &mut [f64] {
        &mut self.data[c * self.n..(c + 1) * self.n]
    }

    /// The whole column-major backing storage (`n * k` values).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing storage.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Element `(i, c)`.
    #[inline]
    pub fn get(&self, i: usize, c: usize) -> f64 {
        self.data[c * self.n + i]
    }

    /// Set element `(i, c)`.
    #[inline]
    pub fn set(&mut self, i: usize, c: usize, v: f64) {
        self.data[c * self.n + i] = v;
    }

    /// Iterate columns.
    pub fn columns(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.n.max(1)).take(self.k)
    }

    /// Zero every element (reuse a batch as an output buffer).
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_major_layout_roundtrips() {
        let b = VecBatch::from_fn(3, 2, |i, c| (c * 10 + i) as f64);
        assert_eq!(b.n(), 3);
        assert_eq!(b.k(), 2);
        assert_eq!(b.col(0), &[0.0, 1.0, 2.0]);
        assert_eq!(b.col(1), &[10.0, 11.0, 12.0]);
        assert_eq!(b.data(), &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        assert_eq!(b.get(2, 1), 12.0);
    }

    #[test]
    fn from_columns_matches_from_fn() {
        let a = VecBatch::from_columns(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let f = VecBatch::from_fn(2, 2, |i, c| (c * 2 + i + 1) as f64);
        assert_eq!(a, f);
    }

    #[test]
    fn col_mut_and_fill_zero() {
        let mut b = VecBatch::zeros(2, 2);
        b.col_mut(1)[0] = 7.0;
        assert_eq!(b.get(0, 1), 7.0);
        b.fill_zero();
        assert!(b.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn columns_iterator_yields_k_slices() {
        let b = VecBatch::from_fn(4, 3, |i, c| (i + c) as f64);
        let cols: Vec<&[f64]> = b.columns().collect();
        assert_eq!(cols.len(), 3);
        assert_eq!(cols[2], b.col(2));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_columns_rejected() {
        VecBatch::from_columns(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
