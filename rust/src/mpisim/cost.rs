//! α-β-γ cost model + makespan replay (Figure 9's scaling estimator).
//!
//! This box has one physical core; the paper's platform has 64 (4×16-core
//! Opteron, NUMA, MPI over 8 sockets). We therefore *measure* the
//! computation rate (γ: seconds per processed lower-NNZ, per-row
//! overhead) on real serial runs, *model* communication with the
//! standard α (latency) + β (per byte) machine parameters, and replay
//! the exact per-rank work and message counts produced by the
//! instrumented executors. The paper's speedup curves are a function of
//! exactly these quantities, so the shape (who scales, where it
//! saturates) is preserved even though absolute times differ
//! (DESIGN.md §2; EXPERIMENTS.md compares shapes).

use crate::graph::coloring::RowColoring;
use crate::kernel::conflict::ConflictMap;
use crate::kernel::split3::Split3;
use crate::kernel::serial_sss::sss_spmv;
use crate::sparse::Sss;

/// Machine parameters for the makespan replay.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Seconds per processed lower-triangle nonzero (2 FMA + the mirror
    /// scatter) — measured by [`CostModel::calibrate`].
    pub t_nnz: f64,
    /// Per-row loop overhead in seconds (row_ptr read, diagonal FMA).
    pub t_row: f64,
    /// Message startup latency (seconds). Default: intra-node MPI ~1 µs.
    pub alpha: f64,
    /// Per-byte transfer cost (seconds). Default: ~10 GB/s effective.
    pub beta: f64,
    /// Barrier cost per participating-rank doubling (α_bar · ⌈log2 p⌉).
    pub barrier_alpha: f64,
    /// Fraction of one-sided accumulate cost hidden behind computation
    /// (MPI_Accumulate is non-blocking; the paper overlaps it).
    pub accum_overlap: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            t_nnz: 2.0e-9,
            t_row: 1.5e-9,
            alpha: 1.0e-6,
            beta: 1.0e-10,
            barrier_alpha: 8.0e-7,
            accum_overlap: 0.7,
        }
    }
}

impl CostModel {
    /// Platform profile approximating the paper's testbed: 4 × 16-core
    /// AMD Opteron (Bulldozer-era), MPI over 8 NUMA sockets. Per-core
    /// compute is ~3-4× slower than this box (lower clocks, shared FPUs,
    /// DDR3), which makes communication *relatively* cheaper — the
    /// regime in which the paper reports its 19× headline.
    pub fn opteron() -> Self {
        Self {
            t_nnz: 4.5e-9,
            t_row: 3.0e-9,
            alpha: 1.2e-6,
            beta: 1.6e-10, // ~6 GB/s effective cross-socket
            barrier_alpha: 8.0e-7,
            accum_overlap: 0.7,
        }
    }

    /// Measure `t_nnz` / `t_row` from real serial SSS SpMV runs on this
    /// machine. Two matrices with different nnz/row ratios give a 2x2
    /// system; we solve it (clamped to positive).
    pub fn calibrate(s: &Sss, reps: usize) -> Self {
        let mut model = Self::default();
        let time_of = |m: &Sss| -> f64 {
            let x: Vec<f64> = (0..m.n).map(|i| (i as f64 * 0.37).sin()).collect();
            let mut y = vec![0.0; m.n];
            // warmup
            sss_spmv(m, &x, &mut y);
            let t0 = std::time::Instant::now();
            for _ in 0..reps.max(1) {
                sss_spmv(m, &x, &mut y);
            }
            std::hint::black_box(&y);
            t0.elapsed().as_secs_f64() / reps.max(1) as f64
        };
        let t = time_of(s);
        // attribute 15% to per-row overhead, the rest to nnz processing
        let nnz = s.nnz_lower().max(1);
        model.t_row = 0.15 * t / s.n as f64;
        model.t_nnz = 0.85 * t / nnz as f64;
        model
    }

    /// Serial (Alg. 1) time for a matrix with `n` rows and `nnz` stored
    /// lower entries.
    pub fn serial_time(&self, n: usize, nnz: usize) -> f64 {
        self.t_row * n as f64 + self.t_nnz * nnz as f64
    }

    /// Barrier cost at `p` ranks.
    pub fn barrier_time(&self, p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            self.barrier_alpha * (p as f64).log2().ceil()
        }
    }

    /// PARS3 makespan for a conflict map (which embeds the distribution)
    /// and its split. Mirrors `Pars3Plan`'s phase structure:
    /// halo exchange → middle compute (+ overlapped accumulate) → outer
    /// sequential tail → epoch fence.
    pub fn pars3_makespan(&self, cm: &ConflictMap, split: &Split3) -> f64 {
        let p = cm.dist.p;
        if p == 1 {
            return self.serial_time(split.n, split.nnz_middle() + split.nnz_outer());
        }
        let mut worst: f64 = 0.0;
        for (r, rc) in cm.per_rank.iter().enumerate() {
            let rows = cm.dist.rows_of(r);
            // halo receive: one message per source rank (batched columns)
            let t_halo: f64 = rc
                .halo_cols_by_src
                .iter()
                .map(|&(_, cols)| self.alpha + self.beta * 8.0 * cols as f64)
                .sum();
            let t_mid = self.t_row * rows as f64 + self.t_nnz * rc.local_nnz as f64;
            // one accumulate message per target rank + payload, partly hidden
            let accum_msgs = rc.target_ranks.len() as f64;
            let t_accum = (1.0 - self.accum_overlap)
                * (accum_msgs * self.alpha + self.beta * 8.0 * rc.conflicting_nnz as f64);
            // outer split: sequential per-rank tail (paper §3.1.2)
            let t_outer = self.t_nnz * rc.outer_nnz as f64;
            worst = worst.max(t_halo + t_mid + t_accum + t_outer);
        }
        worst + self.barrier_time(p)
    }

    /// Phased graph-coloring baseline makespan ([3]): per color class,
    /// rows are distributed round-robin; every phase ends in a barrier.
    pub fn coloring_makespan(&self, s: &Sss, coloring: &RowColoring, p: usize) -> f64 {
        if p == 1 {
            return self.serial_time(s.n, s.nnz_lower());
        }
        let mut total = 0.0;
        for class in &coloring.classes {
            // per-rank nnz share of this phase (round-robin by position)
            let mut share = vec![0usize; p];
            let mut rows = vec![0usize; p];
            for (pos, &i) in class.iter().enumerate() {
                let r = pos % p;
                share[r] += s.row_ptr[i as usize + 1] - s.row_ptr[i as usize];
                rows[r] += 1;
            }
            let worst = (0..p)
                .map(|r| self.t_row * rows[r] as f64 + self.t_nnz * share[r] as f64)
                .fold(0.0f64, f64::max);
            total += worst + self.barrier_time(p);
        }
        total
    }

    /// Speedup of a makespan vs the serial baseline for the same matrix.
    pub fn speedup(&self, serial: f64, parallel: f64) -> f64 {
        serial / parallel.max(1e-30)
    }

    /// Amdahl bound for a serial fraction `s` at `p` ranks (§1 analysis).
    pub fn amdahl(serial_fraction: f64, p: usize) -> f64 {
        1.0 / (serial_fraction + (1.0 - serial_fraction) / p as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coloring::color_rows;
    use crate::sparse::{convert, gen, Symmetry};

    fn banded(n: usize, seed: u64) -> Sss {
        let coo = gen::small_test_matrix(n, seed, 1.0);
        let g = crate::graph::Adjacency::from_coo(&coo);
        let perm = crate::graph::rcm(&g);
        convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
    }

    #[test]
    fn calibration_gives_positive_rates() {
        let s = banded(400, 1);
        let m = CostModel::calibrate(&s, 3);
        assert!(m.t_nnz > 0.0 && m.t_row > 0.0);
        assert!(m.t_nnz < 1e-5, "implausible t_nnz {}", m.t_nnz);
    }

    #[test]
    fn pars3_speedup_grows_then_saturates() {
        let s = banded(2000, 2);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let m = CostModel::default();
        let serial = m.serial_time(s.n, s.nnz_lower());
        let sp = |p: usize| {
            let cm = ConflictMap::analyze(&split, p);
            m.speedup(serial, m.pars3_makespan(&cm, &split))
        };
        let s2 = sp(2);
        let s8 = sp(8);
        assert!(s2 > 1.2, "s2={s2}");
        assert!(s8 > s2, "s8={s8} s2={s2}");
        // never superlinear in this model
        assert!(sp(64) <= 64.0);
    }

    #[test]
    fn coloring_pays_per_phase_barriers() {
        let s = banded(1200, 3);
        let coloring = color_rows(&s);
        let m = CostModel::default();
        let serial = m.serial_time(s.n, s.nnz_lower());
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 32;
        let cm = ConflictMap::analyze(&split, p);
        let t_pars3 = m.pars3_makespan(&cm, &split);
        let t_color = m.coloring_makespan(&s, &coloring, p);
        // the paper's claim: PARS3 beats the phased baseline at scale
        assert!(
            t_pars3 < t_color,
            "pars3 {t_pars3} vs coloring {t_color} (serial {serial})"
        );
    }

    #[test]
    fn single_rank_equals_serial() {
        let s = banded(500, 4);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let m = CostModel::default();
        let cm = ConflictMap::analyze(&split, 1);
        assert!(
            (m.pars3_makespan(&cm, &split) - m.serial_time(s.n, s.nnz_lower())).abs() < 1e-15
        );
    }

    #[test]
    fn amdahl_bound() {
        assert!((CostModel::amdahl(0.0, 8) - 8.0).abs() < 1e-12);
        assert!(CostModel::amdahl(0.1, 1000) < 10.0);
    }
}
