//! One-sided accumulation window (`MPI_Accumulate` substitute).
//!
//! The paper pushes symmetric-pair partial results (`mul2`, eqs. (2)-(6))
//! into remote ranks' output slices with `MPI_Accumulate` — a
//! non-blocking RMA `+=` that overlaps with computation and needs no
//! receive posted by the target. The shared-memory equivalent is a
//! lock-free atomic f64 add (CAS loop on the u64 bit pattern); the epoch
//! fence maps to a barrier.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared accumulation window over an f64 vector.
#[derive(Debug)]
pub struct Window {
    cells: Vec<AtomicU64>,
}

impl Window {
    /// Zero-initialized window of length `n`.
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { cells: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect() })
    }

    /// Window length.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Atomic `window[idx] += v` (lock-free CAS loop).
    #[inline]
    pub fn add(&self, idx: usize, v: f64) {
        if v == 0.0 {
            return;
        }
        let cell = &self.cells[idx];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Accumulate a contiguous slice starting at `offset`
    /// (one "MPI_Accumulate" call; batched for message efficiency).
    pub fn accumulate(&self, offset: usize, vals: &[f64]) {
        for (k, &v) in vals.iter().enumerate() {
            self.add(offset + k, v);
        }
    }

    /// Read one element (only meaningful after an epoch fence).
    pub fn get(&self, idx: usize) -> f64 {
        f64::from_bits(self.cells[idx].load(Ordering::Acquire))
    }

    /// Snapshot the whole window (after a fence).
    pub fn to_vec(&self) -> Vec<f64> {
        self.cells.iter().map(|c| f64::from_bits(c.load(Ordering::Acquire))).collect()
    }

    /// Snapshot into a caller-provided buffer (allocation-free read for
    /// the repeated-multiply hot path). `out.len()` must equal `len()`.
    pub fn read_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cells.len());
        for (o, c) in out.iter_mut().zip(&self.cells) {
            *o = f64::from_bits(c.load(Ordering::Acquire));
        }
    }

    /// Reset all cells to zero (next epoch).
    pub fn reset(&self) {
        for c in &self.cells {
            c.store(0f64.to_bits(), Ordering::Release);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::World;

    #[test]
    fn concurrent_adds_are_lossless() {
        let w = Window::new(8);
        let w2 = w.clone();
        World::run(4, move |ctx| {
            for k in 0..1000 {
                w2.add(k % 8, 1.0 + ctx.rank as f64 * 0.0);
            }
            ctx.barrier();
        });
        let total: f64 = w.to_vec().iter().sum();
        assert_eq!(total, 4000.0);
    }

    #[test]
    fn accumulate_slice() {
        let w = Window::new(6);
        w.accumulate(2, &[1.0, 2.0, 3.0]);
        w.accumulate(3, &[10.0]);
        assert_eq!(w.to_vec(), vec![0.0, 0.0, 1.0, 12.0, 3.0, 0.0]);
    }

    #[test]
    fn reset_zeroes() {
        let w = Window::new(3);
        w.add(1, 5.0);
        w.reset();
        assert_eq!(w.to_vec(), vec![0.0; 3]);
    }

    #[test]
    fn read_into_matches_to_vec() {
        let w = Window::new(4);
        w.accumulate(1, &[2.0, 3.0]);
        let mut out = vec![f64::NAN; 4];
        w.read_into(&mut out);
        assert_eq!(out, w.to_vec());
    }

    #[test]
    fn zero_add_is_noop_fastpath() {
        let w = Window::new(2);
        w.add(0, 0.0);
        assert_eq!(w.get(0), 0.0);
    }
}
