//! Rank world over threads + channels (MPI point-to-point substitute).
//!
//! Each rank runs on its own OS thread with a `RankCtx` handle providing
//! tagged `send`/`recv` with (source, tag) matching semantics and a
//! world barrier — enough to express the paper's communication schedule
//! (ordered halo chain + accumulate epochs). Channels are unbounded, so
//! the paper's deadlock concern with blocking sends does not bite here;
//! the *ordering* of the chain is still preserved for fidelity of the
//! instrumentation.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A reusable rank barrier that can be **poisoned**: when a rank
/// panics, its executor poisons the barrier so peers parked at the
/// epoch fence wake up and panic too, letting the original panic
/// propagate instead of deadlocking the world (plain
/// `std::sync::Barrier` would park them forever).
struct PoisonBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    count: usize,
    generation: u64,
    poisoned: bool,
}

impl PoisonBarrier {
    fn new(n: usize) -> Self {
        Self {
            n,
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) {
        let mut s = self.state.lock().unwrap();
        if s.poisoned {
            panic!("rank barrier poisoned by a peer panic");
        }
        let gen = s.generation;
        s.count += 1;
        if s.count == self.n {
            s.count = 0;
            s.generation += 1;
            self.cv.notify_all();
        } else {
            while s.generation == gen && !s.poisoned {
                s = self.cv.wait(s).unwrap();
            }
            if s.poisoned {
                panic!("rank barrier poisoned by a peer panic");
            }
        }
    }

    fn poison(&self) {
        let mut s = self.state.lock().unwrap();
        s.poisoned = true;
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned
    }
}

/// A tagged message between ranks.
#[derive(Debug)]
struct Msg {
    src: usize,
    tag: u32,
    data: Vec<f64>,
}

/// Per-rank communication handle.
pub struct RankCtx {
    /// This rank's id.
    pub rank: usize,
    /// World size.
    pub p: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    pending: HashMap<(usize, u32), VecDeque<Vec<f64>>>,
    barrier: Arc<PoisonBarrier>,
    /// Messages sent (count, payload f64s) — instrumentation.
    pub sent_msgs: usize,
    /// Total payload values sent.
    pub sent_values: usize,
}

impl RankCtx {
    /// Send `data` to `dest` with `tag` (non-blocking, buffered).
    pub fn send(&mut self, dest: usize, tag: u32, data: Vec<f64>) {
        self.sent_msgs += 1;
        self.sent_values += data.len();
        self.senders[dest]
            .send(Msg { src: self.rank, tag, data })
            .expect("rank channel closed");
    }

    /// Blocking receive matching `(src, tag)`; out-of-order arrivals are
    /// queued (MPI matching semantics). Panics if the world is poisoned
    /// by a peer panic while waiting (the sender may never send).
    pub fn recv(&mut self, src: usize, tag: u32) -> Vec<f64> {
        if let Some(q) = self.pending.get_mut(&(src, tag)) {
            if let Some(d) = q.pop_front() {
                return d;
            }
        }
        loop {
            let m = match self.receiver.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(m) => m,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if self.barrier.is_poisoned() {
                        panic!("rank world poisoned by a peer panic while rank {} waited for ({src}, {tag})", self.rank);
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    panic!("rank channel closed")
                }
            };
            if m.src == src && m.tag == tag {
                return m.data;
            }
            self.pending.entry((m.src, m.tag)).or_default().push_back(m.data);
        }
    }

    /// World barrier. Panics if a peer rank panicked (poisoned epoch).
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// The rank world: runs `f` on `p` rank threads (spawn-per-call; see
/// [`PersistentWorld`] for the reusable-thread executor).
pub struct World;

impl World {
    /// Run `f(rank_ctx)` on `p` ranks; returns per-rank results in rank
    /// order. Panics in any rank propagate. Scoped threads: `f` may
    /// borrow from the caller's stack (no `Arc`/`'static` plumbing).
    pub fn run<R, F>(p: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(RankCtx) -> R + Send + Sync,
    {
        assert!(p >= 1);
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let barrier = Arc::new(PoisonBarrier::new(p));
        let f = &f;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(p);
            for (rank, receiver) in receivers.into_iter().enumerate() {
                let ctx = RankCtx {
                    rank,
                    p,
                    senders: senders.clone(),
                    receiver,
                    pending: HashMap::new(),
                    barrier: barrier.clone(),
                    sent_msgs: 0,
                    sent_values: 0,
                };
                let b = barrier.clone();
                handles.push(s.spawn(move || {
                    // poison the barrier on panic so peers parked at a
                    // fence wake and die too — otherwise scope's
                    // implicit join would deadlock before propagating
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ctx))) {
                        Ok(r) => r,
                        Err(payload) => {
                            b.poison();
                            std::panic::resume_unwind(payload);
                        }
                    }
                }));
            }
            drop(senders);
            handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
        })
    }
}

/// A double-buffered **zero-copy input slot**: the executor publishes a
/// borrowed slice for one epoch and persistent rank threads read it in
/// place — no per-apply clone of the input vector, no `Arc<Vec<f64>>`
/// allocation on the repeated-multiply hot path.
///
/// Protocol (enforced by [`PersistentWorld::run_job`]'s structure, not
/// by this type):
///
/// 1. the caller `publish`es `x`, getting an epoch token;
/// 2. the job fan-out hands the token to every rank, which `read`s the
///    slice for the duration of the job;
/// 3. `run_job` returns only after every rank has reported done, so
///    the borrow ends before the caller regains control;
/// 4. the caller `retire`s the epoch (a late read then fails loudly on
///    a null pointer instead of dereferencing a dangling one).
///
/// Two cells, indexed by epoch parity, make the hand-off double
/// buffered: publishing epoch `e+1` never overwrites the cell a
/// straggling reader of epoch `e` might still be looking at.
pub struct InputSlot {
    slots: [SlotCell; 2],
    epoch: AtomicU64,
}

struct SlotCell {
    ptr: AtomicPtr<f64>,
    len: AtomicUsize,
    /// Epoch this cell was last published for — `read` verifies it so a
    /// protocol-violating read after a same-parity republish fails
    /// loudly instead of silently aliasing the wrong buffer.
    epoch: AtomicU64,
}

impl SlotCell {
    fn empty() -> Self {
        Self {
            ptr: AtomicPtr::new(std::ptr::null_mut()),
            len: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
        }
    }
}

impl InputSlot {
    /// A slot with no published epoch.
    pub fn new() -> Arc<Self> {
        Arc::new(Self { slots: [SlotCell::empty(), SlotCell::empty()], epoch: AtomicU64::new(0) })
    }

    /// Publish `x` for the next epoch and return its token.
    ///
    /// Caller contract: `x` must stay alive and unmodified until every
    /// reader of this epoch is done (see the type-level protocol).
    pub fn publish(&self, x: &[f64]) -> u64 {
        let e = self.epoch.load(Ordering::Relaxed).wrapping_add(1);
        let cell = &self.slots[(e % 2) as usize];
        cell.len.store(x.len(), Ordering::Release);
        cell.ptr.store(x.as_ptr() as *mut f64, Ordering::Release);
        cell.epoch.store(e, Ordering::Release);
        self.epoch.store(e, Ordering::Release);
        e
    }

    /// Read the slice published for `epoch`. Panics if the epoch was
    /// retired, or if its cell has since been republished for a newer
    /// epoch (a stale read must fail loudly, never alias the wrong
    /// buffer).
    ///
    /// # Safety
    /// The publisher must guarantee the slice published for `epoch`
    /// outlives this borrow — [`PersistentWorld::run_job`] blocking
    /// until all ranks report provides exactly that guarantee.
    pub unsafe fn read(&self, epoch: u64) -> &[f64] {
        let cell = &self.slots[(epoch % 2) as usize];
        let cell_epoch = cell.epoch.load(Ordering::Acquire);
        assert_eq!(
            cell_epoch, epoch,
            "InputSlot::read of a stale epoch: cell holds {cell_epoch}, caller asked for {epoch}"
        );
        let ptr = cell.ptr.load(Ordering::Acquire);
        assert!(!ptr.is_null(), "InputSlot::read of a retired or never-published epoch");
        let len = cell.len.load(Ordering::Acquire);
        std::slice::from_raw_parts(ptr, len)
    }

    /// Retire `epoch`: null the cell so a protocol-violating late read
    /// panics instead of touching freed memory.
    pub fn retire(&self, epoch: u64) {
        let cell = &self.slots[(epoch % 2) as usize];
        cell.ptr.store(std::ptr::null_mut(), Ordering::Release);
        cell.len.store(0, Ordering::Release);
    }
}

/// Per-job instrumentation report from a rank body (deltas, not
/// cumulative totals — [`RankCtx`] counters persist across jobs).
#[derive(Debug, Clone, Copy, Default)]
pub struct RankReport {
    /// Messages sent during the job.
    pub msgs: usize,
    /// Payload f64 count sent during the job.
    pub msg_values: usize,
    /// Wallclock seconds spent in the job.
    pub seconds: f64,
}

type Job = Arc<dyn Fn(&mut RankCtx) -> RankReport + Send + Sync>;

/// Per-rank job outcome on the internal done channel.
enum Done {
    Ok(RankReport),
    Panicked,
}

/// A rank world with **persistent** threads: ranks are spawned once at
/// construction and reused for every [`PersistentWorld::run_job`] call.
/// This is the executor behind [`crate::kernel::pars3::Pars3Kernel`]'s
/// threaded mode — the iterative-solver hot path pays thread-spawn cost
/// zero times per multiply. Rank state (channels, pending-message
/// queues, the world barrier) also persists, so jobs keep full
/// tagged send/recv semantics across calls.
///
/// A rank panicking inside a job poisons the world: `run_job` drains
/// every rank's report (the poison wakes parked peers, so all of them
/// exit the job body) and then panics with the first panicking rank's
/// id. The world stays poisoned afterwards — any later `run_job` fails
/// fast at submission ([`Self::is_poisoned`]) instead of leaving peers
/// blocked on the shared barrier waiting for the dead rank, and Drop
/// can always join cleanly because no rank is ever left inside a job.
pub struct PersistentWorld {
    p: usize,
    job_txs: Vec<Sender<Job>>,
    done_rx: Receiver<(usize, Done)>,
    handles: Vec<JoinHandle<()>>,
    poisoned: std::cell::Cell<bool>,
}

impl PersistentWorld {
    /// Spawn `p` rank threads, idle until the first job.
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        let mut msg_txs = Vec::with_capacity(p);
        let mut msg_rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            msg_txs.push(tx);
            msg_rxs.push(rx);
        }
        let barrier = Arc::new(PoisonBarrier::new(p));
        let (done_tx, done_rx) = channel();
        let mut job_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for (rank, receiver) in msg_rxs.into_iter().enumerate() {
            let (job_tx, job_rx) = channel::<Job>();
            job_txs.push(job_tx);
            let mut ctx = RankCtx {
                rank,
                p,
                senders: msg_txs.clone(),
                receiver,
                pending: HashMap::new(),
                barrier: barrier.clone(),
                sent_msgs: 0,
                sent_values: 0,
            };
            let b = barrier.clone();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || (*job)(&mut ctx),
                    ));
                    let (outcome, dead) = match result {
                        Ok(report) => (Done::Ok(report), false),
                        Err(_) => {
                            // wake peers parked at the epoch fence
                            b.poison();
                            (Done::Panicked, true)
                        }
                    };
                    if done.send((ctx.rank, outcome)).is_err() || dead {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);
        Self { p, job_txs, done_rx, handles, poisoned: std::cell::Cell::new(false) }
    }

    /// World size.
    pub fn p(&self) -> usize {
        self.p
    }

    /// True once a rank panic has poisoned the world. A poisoned world
    /// rejects further jobs loudly ([`Self::run_job`] panics up front)
    /// instead of letting surviving ranks block on the shared barrier
    /// waiting for a dead peer — the poisoned-**epoch** detection: the
    /// panic is caught in the epoch it happened, and every later epoch
    /// fails fast at submission.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.get()
    }

    /// Run one job on every rank; blocks until all ranks report —
    /// **including on the panic path**. A rank panicking poisons the
    /// world, but `run_job` still drains all `p` reports before
    /// re-panicking: the poisoned barrier/recv wake every surviving
    /// rank, so each one is guaranteed to exit the job body and report.
    /// This all-ranks-done fence is what makes borrowed-input hand-offs
    /// ([`InputSlot`]) sound even when a job panics — no rank can still
    /// be reading the caller's buffer once `run_job` unwinds.
    /// Returns reports in rank order.
    pub fn run_job<F>(&self, f: F) -> Vec<RankReport>
    where
        F: Fn(&mut RankCtx) -> RankReport + Send + Sync + 'static,
    {
        assert!(!self.poisoned.get(), "PersistentWorld poisoned by an earlier rank panic");
        let job: Job = Arc::new(f);
        for tx in &self.job_txs {
            tx.send(job.clone()).expect("rank thread died");
        }
        let mut out = vec![RankReport::default(); self.p];
        let mut panicked: Option<usize> = None;
        for _ in 0..self.p {
            let (rank, outcome) = self.done_rx.recv().expect("rank thread died");
            match outcome {
                Done::Ok(report) => out[rank] = report,
                Done::Panicked => {
                    // keep draining: peers woken by the poison will
                    // report too, so every rank leaves its job body
                    // before we unwind (and Drop can later join all)
                    self.poisoned.set(true);
                    panicked.get_or_insert(rank);
                }
            }
        }
        if let Some(rank) = panicked {
            panic!("rank {rank} panicked during a PersistentWorld job");
        }
        out
    }
}

impl Drop for PersistentWorld {
    fn drop(&mut self) {
        // Closing the job channels makes every rank's recv() fail,
        // ending its loop; then join for a clean shutdown. This is safe
        // after a rank panic too: `run_job` drains ALL rank reports
        // before setting the poison, so by the time a poisoned world is
        // dropped every rank has left its job body — the panicked rank
        // broke out of its loop, and the survivors are parked on the
        // (now closed) job channel, not the barrier.
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = World::run(4, |mut ctx| {
            let next = (ctx.rank + 1) % ctx.p;
            let prev = (ctx.rank + ctx.p - 1) % ctx.p;
            ctx.send(next, 7, vec![ctx.rank as f64]);
            let got = ctx.recv(prev, 7);
            got[0]
        });
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 1, vec![1.0]);
                ctx.send(1, 2, vec![2.0]);
                0.0
            } else {
                // receive in the opposite order of sending
                let b = ctx.recv(0, 2);
                let a = ctx.recv(0, 1);
                a[0] * 10.0 + b[0]
            }
        });
        assert_eq!(results[1], 12.0);
    }

    #[test]
    fn paper_chain_order_no_deadlock() {
        // last rank sends to P-1, ..., rank 1 sends to 0 (paper §3.1.2)
        let p = 6;
        let results = World::run(p, |mut ctx| {
            if ctx.rank + 1 < ctx.p {
                let d = ctx.recv(ctx.rank + 1, 3);
                if ctx.rank > 0 {
                    ctx.send(ctx.rank - 1, 3, vec![d[0] + 1.0]);
                }
                d[0]
            } else {
                ctx.send(ctx.rank - 1, 3, vec![0.0]);
                -1.0
            }
        });
        assert_eq!(results[0], (p - 2) as f64);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        let results = World::run(4, |ctx| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            COUNT.load(Ordering::SeqCst)
        });
        assert!(results.iter().all(|&c| c == 4));
    }

    #[test]
    #[should_panic(expected = "rank panicked")]
    fn rank_panic_propagates_instead_of_hanging() {
        // rank 2 panics; ranks 0/1 are parked at the barrier and must
        // be woken by the poison so the scope can join and propagate.
        World::run(3, |ctx| {
            if ctx.rank == 2 {
                panic!("boom");
            }
            ctx.barrier();
        });
    }

    #[test]
    #[should_panic(expected = "rank panicked")]
    fn rank_panic_wakes_peer_blocked_in_recv() {
        // rank 1 dies before sending; rank 0's recv must observe the
        // poison instead of blocking forever.
        World::run(2, |mut ctx| {
            if ctx.rank == 1 {
                panic!("boom");
            }
            let _ = ctx.recv(1, 9);
        });
    }

    #[test]
    fn persistent_world_reuses_threads_across_jobs() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let w = PersistentWorld::new(3);
        let ids = Arc::new(Mutex::new(HashSet::new()));
        for _ in 0..5 {
            let ids2 = ids.clone();
            let reports = w.run_job(move |ctx| {
                ids2.lock().unwrap().insert(std::thread::current().id());
                ctx.barrier();
                RankReport::default()
            });
            assert_eq!(reports.len(), 3);
        }
        // ThreadIds are never reused within a process: 5 jobs over 3
        // persistent threads must observe exactly 3 distinct ids. A
        // spawn-per-job executor would observe 15.
        assert_eq!(ids.lock().unwrap().len(), 3);
    }

    #[test]
    fn persistent_world_messages_match_within_each_job() {
        let w = PersistentWorld::new(2);
        for round in 0..3usize {
            let reports = w.run_job(move |ctx| {
                let mut r = RankReport::default();
                if ctx.rank == 0 {
                    let m0 = ctx.sent_msgs;
                    ctx.send(1, 4, vec![round as f64]);
                    r.msgs = ctx.sent_msgs - m0;
                } else {
                    let d = ctx.recv(0, 4);
                    assert_eq!(d, vec![round as f64]);
                }
                ctx.barrier();
                r
            });
            assert_eq!(reports[0].msgs, 1);
            assert_eq!(reports[1].msgs, 0);
        }
    }

    #[test]
    #[should_panic(expected = "panicked during a PersistentWorld job")]
    fn persistent_world_rank_panic_surfaces_instead_of_hanging() {
        let w = PersistentWorld::new(2);
        // rank 1 panics before the (never reached) barrier; rank 0
        // returns immediately. run_job must panic with the rank id,
        // not block forever on the missing report.
        w.run_job(|ctx| {
            if ctx.rank == 1 {
                panic!("boom");
            }
            RankReport::default()
        });
    }

    #[test]
    fn persistent_world_rank_panic_waits_for_all_ranks_before_unwinding() {
        use std::sync::atomic::{AtomicBool, Ordering};
        // the soundness fence behind InputSlot: even when a rank
        // panics, run_job must not unwind (freeing the caller's
        // published buffer) until every sibling rank has left the job
        // body. The slow rank sets SLOW_DONE as its last job action;
        // it must be set by the time the panic reaches the caller.
        static SLOW_DONE: AtomicBool = AtomicBool::new(false);
        SLOW_DONE.store(false, Ordering::SeqCst);
        let w = PersistentWorld::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.run_job(|ctx| {
                if ctx.rank == 0 {
                    panic!("boom");
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
                SLOW_DONE.store(true, Ordering::SeqCst);
                RankReport::default()
            });
        }));
        assert!(result.is_err(), "the rank panic must surface");
        assert!(
            SLOW_DONE.load(Ordering::SeqCst),
            "run_job unwound before the slow rank finished its job body"
        );
    }

    #[test]
    fn persistent_world_poisoned_epoch_fails_next_job_loudly() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        // epoch 1: rank 0 panics mid-job; the caller catches it.
        let w = PersistentWorld::new(3);
        assert!(!w.is_poisoned());
        let first = catch_unwind(AssertUnwindSafe(|| {
            w.run_job(|ctx| {
                if ctx.rank == 0 {
                    panic!("boom");
                }
                RankReport::default()
            });
        }));
        assert!(first.is_err());
        assert!(w.is_poisoned(), "the rank panic must poison the world");
        // epoch 2: submission must fail fast with a clear message, not
        // hand the job to surviving ranks that would then block on the
        // barrier waiting for the dead rank.
        let second = catch_unwind(AssertUnwindSafe(|| {
            w.run_job(|_| RankReport::default());
        }));
        let payload = second.expect_err("second job must be rejected");
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| payload.downcast_ref::<&str>().copied())
            .unwrap_or("");
        assert!(msg.contains("poisoned"), "unexpected panic message: {msg}");
        // dropping the poisoned world must not hang (all ranks have
        // left their job bodies) — implicit in the test returning.
    }

    #[test]
    fn input_slot_read_aliases_the_published_slice() {
        let slot = InputSlot::new();
        let data = vec![1.0, 2.0, 3.0];
        let e = slot.publish(&data);
        let got = unsafe { slot.read(e) };
        assert_eq!(got.as_ptr(), data.as_ptr(), "read must be zero-copy");
        assert_eq!(got, &data[..]);
        slot.retire(e);
    }

    #[test]
    #[should_panic(expected = "retired")]
    fn input_slot_late_read_fails_loudly() {
        let slot = InputSlot::new();
        let data = vec![1.0];
        let e = slot.publish(&data);
        slot.retire(e);
        let _ = unsafe { slot.read(e) };
    }

    #[test]
    fn input_slot_double_buffer_keeps_previous_epoch_readable() {
        let slot = InputSlot::new();
        let a = vec![1.0; 4];
        let b = vec![2.0; 8];
        let ea = slot.publish(&a);
        let eb = slot.publish(&b);
        // parity-indexed cells: publishing b must not clobber a's cell
        assert_eq!(unsafe { slot.read(ea) }.as_ptr(), a.as_ptr());
        assert_eq!(unsafe { slot.read(eb) }.len(), 8);
        slot.retire(ea);
        slot.retire(eb);
    }

    #[test]
    #[should_panic(expected = "stale epoch")]
    fn input_slot_same_parity_republish_invalidates_old_epoch() {
        let slot = InputSlot::new();
        let (a, b, c) = (vec![1.0], vec![2.0], vec![3.0]);
        let ea = slot.publish(&a);
        let _eb = slot.publish(&b);
        let _ec = slot.publish(&c); // same parity as ea: overwrites its cell
        let _ = unsafe { slot.read(ea) };
    }

    #[test]
    fn persistent_world_slot_survives_interleaved_epoch_sizes() {
        // the double-buffered slot must stay coherent when the published
        // slice length changes every epoch (interleaved batch widths)
        let w = PersistentWorld::new(3);
        let slot = InputSlot::new();
        for &len in &[4usize, 12, 8, 4, 12, 1] {
            let x: Vec<f64> = (0..len).map(|i| i as f64 + len as f64).collect();
            let expect_sum: f64 = x.iter().sum();
            let e = slot.publish(&x);
            let s2 = slot.clone();
            let reports = w.run_job(move |ctx| {
                // SAFETY: run_job blocks until all ranks report, so `x`
                // outlives every read of this epoch.
                let got = unsafe { s2.read(e) };
                assert_eq!(got.len(), len);
                let sum: f64 = got.iter().sum();
                assert!((sum - expect_sum).abs() < 1e-12);
                ctx.barrier();
                RankReport::default()
            });
            slot.retire(e);
            assert_eq!(reports.len(), 3);
        }
    }

    #[test]
    fn instrumentation_counts() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 0, vec![0.0; 10]);
                ctx.send(1, 1, vec![0.0; 5]);
                (ctx.sent_msgs, ctx.sent_values)
            } else {
                ctx.recv(0, 0);
                ctx.recv(0, 1);
                (0, 0)
            }
        });
        assert_eq!(results[0], (2, 15));
    }
}
