//! Rank world over threads + channels (MPI point-to-point substitute).
//!
//! Each rank runs on its own OS thread with a `RankCtx` handle providing
//! tagged `send`/`recv` with (source, tag) matching semantics and a
//! world barrier — enough to express the paper's communication schedule
//! (ordered halo chain + accumulate epochs). Channels are unbounded, so
//! the paper's deadlock concern with blocking sends does not bite here;
//! the *ordering* of the chain is still preserved for fidelity of the
//! instrumentation.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// A tagged message between ranks.
#[derive(Debug)]
struct Msg {
    src: usize,
    tag: u32,
    data: Vec<f64>,
}

/// Per-rank communication handle.
pub struct RankCtx {
    /// This rank's id.
    pub rank: usize,
    /// World size.
    pub p: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    pending: HashMap<(usize, u32), VecDeque<Vec<f64>>>,
    barrier: Arc<Barrier>,
    /// Messages sent (count, payload f64s) — instrumentation.
    pub sent_msgs: usize,
    /// Total payload values sent.
    pub sent_values: usize,
}

impl RankCtx {
    /// Send `data` to `dest` with `tag` (non-blocking, buffered).
    pub fn send(&mut self, dest: usize, tag: u32, data: Vec<f64>) {
        self.sent_msgs += 1;
        self.sent_values += data.len();
        self.senders[dest]
            .send(Msg { src: self.rank, tag, data })
            .expect("rank channel closed");
    }

    /// Blocking receive matching `(src, tag)`; out-of-order arrivals are
    /// queued (MPI matching semantics).
    pub fn recv(&mut self, src: usize, tag: u32) -> Vec<f64> {
        if let Some(q) = self.pending.get_mut(&(src, tag)) {
            if let Some(d) = q.pop_front() {
                return d;
            }
        }
        loop {
            let m = self.receiver.recv().expect("rank channel closed");
            if m.src == src && m.tag == tag {
                return m.data;
            }
            self.pending.entry((m.src, m.tag)).or_default().push_back(m.data);
        }
    }

    /// World barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// The rank world: spawns `p` threads and runs `f` on each.
pub struct World;

impl World {
    /// Run `f(rank_ctx)` on `p` ranks; returns per-rank results in rank
    /// order. Panics in any rank propagate.
    pub fn run<R, F>(p: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(RankCtx) -> R + Send + Sync + 'static,
    {
        assert!(p >= 1);
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let barrier = Arc::new(Barrier::new(p));
        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(p);
        for (rank, receiver) in receivers.into_iter().enumerate() {
            let ctx = RankCtx {
                rank,
                p,
                senders: senders.clone(),
                receiver,
                pending: HashMap::new(),
                barrier: barrier.clone(),
                sent_msgs: 0,
                sent_values: 0,
            };
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(ctx)));
        }
        drop(senders);
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = World::run(4, |mut ctx| {
            let next = (ctx.rank + 1) % ctx.p;
            let prev = (ctx.rank + ctx.p - 1) % ctx.p;
            ctx.send(next, 7, vec![ctx.rank as f64]);
            let got = ctx.recv(prev, 7);
            got[0]
        });
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 1, vec![1.0]);
                ctx.send(1, 2, vec![2.0]);
                0.0
            } else {
                // receive in the opposite order of sending
                let b = ctx.recv(0, 2);
                let a = ctx.recv(0, 1);
                a[0] * 10.0 + b[0]
            }
        });
        assert_eq!(results[1], 12.0);
    }

    #[test]
    fn paper_chain_order_no_deadlock() {
        // last rank sends to P-1, ..., rank 1 sends to 0 (paper §3.1.2)
        let p = 6;
        let results = World::run(p, |mut ctx| {
            if ctx.rank + 1 < ctx.p {
                let d = ctx.recv(ctx.rank + 1, 3);
                if ctx.rank > 0 {
                    ctx.send(ctx.rank - 1, 3, vec![d[0] + 1.0]);
                }
                d[0]
            } else {
                ctx.send(ctx.rank - 1, 3, vec![0.0]);
                -1.0
            }
        });
        assert_eq!(results[0], (p - 2) as f64);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        let results = World::run(4, |ctx| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            COUNT.load(Ordering::SeqCst)
        });
        assert!(results.iter().all(|&c| c == 4));
    }

    #[test]
    fn instrumentation_counts() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 0, vec![0.0; 10]);
                ctx.send(1, 1, vec![0.0; 5]);
                (ctx.sent_msgs, ctx.sent_values)
            } else {
                ctx.recv(0, 0);
                ctx.recv(0, 1);
                (0, 0)
            }
        });
        assert_eq!(results[0], (2, 15));
    }
}
