//! Rank world over threads + channels (MPI point-to-point substitute).
//!
//! Each rank runs on its own OS thread with a `RankCtx` handle providing
//! tagged `send`/`recv` with (source, tag) matching semantics and a
//! world barrier — enough to express the paper's communication schedule
//! (ordered halo chain + accumulate epochs). Channels are unbounded, so
//! the paper's deadlock concern with blocking sends does not bite here;
//! the *ordering* of the chain is still preserved for fidelity of the
//! instrumentation.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A reusable rank barrier that can be **poisoned**: when a rank
/// panics, its executor poisons the barrier so peers parked at the
/// epoch fence wake up and panic too, letting the original panic
/// propagate instead of deadlocking the world (plain
/// `std::sync::Barrier` would park them forever).
struct PoisonBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    count: usize,
    generation: u64,
    poisoned: bool,
}

impl PoisonBarrier {
    fn new(n: usize) -> Self {
        Self {
            n,
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) {
        let mut s = self.state.lock().unwrap();
        if s.poisoned {
            panic!("rank barrier poisoned by a peer panic");
        }
        let gen = s.generation;
        s.count += 1;
        if s.count == self.n {
            s.count = 0;
            s.generation += 1;
            self.cv.notify_all();
        } else {
            while s.generation == gen && !s.poisoned {
                s = self.cv.wait(s).unwrap();
            }
            if s.poisoned {
                panic!("rank barrier poisoned by a peer panic");
            }
        }
    }

    fn poison(&self) {
        let mut s = self.state.lock().unwrap();
        s.poisoned = true;
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned
    }
}

/// A tagged message between ranks.
#[derive(Debug)]
struct Msg {
    src: usize,
    tag: u32,
    data: Vec<f64>,
}

/// Per-rank communication handle.
pub struct RankCtx {
    /// This rank's id.
    pub rank: usize,
    /// World size.
    pub p: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    pending: HashMap<(usize, u32), VecDeque<Vec<f64>>>,
    barrier: Arc<PoisonBarrier>,
    /// Messages sent (count, payload f64s) — instrumentation.
    pub sent_msgs: usize,
    /// Total payload values sent.
    pub sent_values: usize,
}

impl RankCtx {
    /// Send `data` to `dest` with `tag` (non-blocking, buffered).
    pub fn send(&mut self, dest: usize, tag: u32, data: Vec<f64>) {
        self.sent_msgs += 1;
        self.sent_values += data.len();
        self.senders[dest]
            .send(Msg { src: self.rank, tag, data })
            .expect("rank channel closed");
    }

    /// Blocking receive matching `(src, tag)`; out-of-order arrivals are
    /// queued (MPI matching semantics). Panics if the world is poisoned
    /// by a peer panic while waiting (the sender may never send).
    pub fn recv(&mut self, src: usize, tag: u32) -> Vec<f64> {
        if let Some(q) = self.pending.get_mut(&(src, tag)) {
            if let Some(d) = q.pop_front() {
                return d;
            }
        }
        loop {
            let m = match self.receiver.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(m) => m,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if self.barrier.is_poisoned() {
                        panic!("rank world poisoned by a peer panic while rank {} waited for ({src}, {tag})", self.rank);
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    panic!("rank channel closed")
                }
            };
            if m.src == src && m.tag == tag {
                return m.data;
            }
            self.pending.entry((m.src, m.tag)).or_default().push_back(m.data);
        }
    }

    /// World barrier. Panics if a peer rank panicked (poisoned epoch).
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// The rank world: runs `f` on `p` rank threads (spawn-per-call; see
/// [`PersistentWorld`] for the reusable-thread executor).
pub struct World;

impl World {
    /// Run `f(rank_ctx)` on `p` ranks; returns per-rank results in rank
    /// order. Panics in any rank propagate. Scoped threads: `f` may
    /// borrow from the caller's stack (no `Arc`/`'static` plumbing).
    pub fn run<R, F>(p: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(RankCtx) -> R + Send + Sync,
    {
        assert!(p >= 1);
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let barrier = Arc::new(PoisonBarrier::new(p));
        let f = &f;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(p);
            for (rank, receiver) in receivers.into_iter().enumerate() {
                let ctx = RankCtx {
                    rank,
                    p,
                    senders: senders.clone(),
                    receiver,
                    pending: HashMap::new(),
                    barrier: barrier.clone(),
                    sent_msgs: 0,
                    sent_values: 0,
                };
                let b = barrier.clone();
                handles.push(s.spawn(move || {
                    // poison the barrier on panic so peers parked at a
                    // fence wake and die too — otherwise scope's
                    // implicit join would deadlock before propagating
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ctx))) {
                        Ok(r) => r,
                        Err(payload) => {
                            b.poison();
                            std::panic::resume_unwind(payload);
                        }
                    }
                }));
            }
            drop(senders);
            handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
        })
    }
}

/// Per-job instrumentation report from a rank body (deltas, not
/// cumulative totals — [`RankCtx`] counters persist across jobs).
#[derive(Debug, Clone, Copy, Default)]
pub struct RankReport {
    /// Messages sent during the job.
    pub msgs: usize,
    /// Payload f64 count sent during the job.
    pub msg_values: usize,
    /// Wallclock seconds spent in the job.
    pub seconds: f64,
}

type Job = Arc<dyn Fn(&mut RankCtx) -> RankReport + Send + Sync>;

/// Per-rank job outcome on the internal done channel.
enum Done {
    Ok(RankReport),
    Panicked,
}

/// A rank world with **persistent** threads: ranks are spawned once at
/// construction and reused for every [`PersistentWorld::run_job`] call.
/// This is the executor behind [`crate::kernel::pars3::Pars3Kernel`]'s
/// threaded mode — the iterative-solver hot path pays thread-spawn cost
/// zero times per multiply. Rank state (channels, pending-message
/// queues, the world barrier) also persists, so jobs keep full
/// tagged send/recv semantics across calls.
///
/// A rank panicking inside a job poisons the world: `run_job` panics
/// with the rank id (instead of deadlocking on the missing report),
/// and drop skips joining — sibling ranks may be parked at the shared
/// barrier and are deliberately leaked rather than hung on.
pub struct PersistentWorld {
    p: usize,
    job_txs: Vec<Sender<Job>>,
    done_rx: Receiver<(usize, Done)>,
    handles: Vec<JoinHandle<()>>,
    poisoned: std::cell::Cell<bool>,
}

impl PersistentWorld {
    /// Spawn `p` rank threads, idle until the first job.
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        let mut msg_txs = Vec::with_capacity(p);
        let mut msg_rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = channel();
            msg_txs.push(tx);
            msg_rxs.push(rx);
        }
        let barrier = Arc::new(PoisonBarrier::new(p));
        let (done_tx, done_rx) = channel();
        let mut job_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for (rank, receiver) in msg_rxs.into_iter().enumerate() {
            let (job_tx, job_rx) = channel::<Job>();
            job_txs.push(job_tx);
            let mut ctx = RankCtx {
                rank,
                p,
                senders: msg_txs.clone(),
                receiver,
                pending: HashMap::new(),
                barrier: barrier.clone(),
                sent_msgs: 0,
                sent_values: 0,
            };
            let b = barrier.clone();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || (*job)(&mut ctx),
                    ));
                    let (outcome, dead) = match result {
                        Ok(report) => (Done::Ok(report), false),
                        Err(_) => {
                            // wake peers parked at the epoch fence
                            b.poison();
                            (Done::Panicked, true)
                        }
                    };
                    if done.send((ctx.rank, outcome)).is_err() || dead {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);
        Self { p, job_txs, done_rx, handles, poisoned: std::cell::Cell::new(false) }
    }

    /// World size.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Run one job on every rank; blocks until all ranks report.
    /// Returns reports in rank order. Panics (poisoning the world) if
    /// any rank panics inside the job.
    pub fn run_job<F>(&self, f: F) -> Vec<RankReport>
    where
        F: Fn(&mut RankCtx) -> RankReport + Send + Sync + 'static,
    {
        assert!(!self.poisoned.get(), "PersistentWorld poisoned by an earlier rank panic");
        let job: Job = Arc::new(f);
        for tx in &self.job_txs {
            tx.send(job.clone()).expect("rank thread died");
        }
        let mut out = vec![RankReport::default(); self.p];
        for _ in 0..self.p {
            let (rank, outcome) = self.done_rx.recv().expect("rank thread died");
            match outcome {
                Done::Ok(report) => out[rank] = report,
                Done::Panicked => {
                    // surviving ranks may be parked at the barrier;
                    // poison so drop leaks instead of hanging on join
                    self.poisoned.set(true);
                    panic!("rank {rank} panicked during a PersistentWorld job");
                }
            }
        }
        out
    }
}

impl Drop for PersistentWorld {
    fn drop(&mut self) {
        // Closing the job channels makes every rank's recv() fail,
        // ending its loop; then join for a clean shutdown. After a
        // rank panic, peers can be blocked at the shared barrier —
        // skip the join and leak them rather than hang.
        self.job_txs.clear();
        if self.poisoned.get() {
            return;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = World::run(4, |mut ctx| {
            let next = (ctx.rank + 1) % ctx.p;
            let prev = (ctx.rank + ctx.p - 1) % ctx.p;
            ctx.send(next, 7, vec![ctx.rank as f64]);
            let got = ctx.recv(prev, 7);
            got[0]
        });
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 1, vec![1.0]);
                ctx.send(1, 2, vec![2.0]);
                0.0
            } else {
                // receive in the opposite order of sending
                let b = ctx.recv(0, 2);
                let a = ctx.recv(0, 1);
                a[0] * 10.0 + b[0]
            }
        });
        assert_eq!(results[1], 12.0);
    }

    #[test]
    fn paper_chain_order_no_deadlock() {
        // last rank sends to P-1, ..., rank 1 sends to 0 (paper §3.1.2)
        let p = 6;
        let results = World::run(p, |mut ctx| {
            if ctx.rank + 1 < ctx.p {
                let d = ctx.recv(ctx.rank + 1, 3);
                if ctx.rank > 0 {
                    ctx.send(ctx.rank - 1, 3, vec![d[0] + 1.0]);
                }
                d[0]
            } else {
                ctx.send(ctx.rank - 1, 3, vec![0.0]);
                -1.0
            }
        });
        assert_eq!(results[0], (p - 2) as f64);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        let results = World::run(4, |ctx| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            COUNT.load(Ordering::SeqCst)
        });
        assert!(results.iter().all(|&c| c == 4));
    }

    #[test]
    #[should_panic(expected = "rank panicked")]
    fn rank_panic_propagates_instead_of_hanging() {
        // rank 2 panics; ranks 0/1 are parked at the barrier and must
        // be woken by the poison so the scope can join and propagate.
        World::run(3, |ctx| {
            if ctx.rank == 2 {
                panic!("boom");
            }
            ctx.barrier();
        });
    }

    #[test]
    #[should_panic(expected = "rank panicked")]
    fn rank_panic_wakes_peer_blocked_in_recv() {
        // rank 1 dies before sending; rank 0's recv must observe the
        // poison instead of blocking forever.
        World::run(2, |mut ctx| {
            if ctx.rank == 1 {
                panic!("boom");
            }
            let _ = ctx.recv(1, 9);
        });
    }

    #[test]
    fn persistent_world_reuses_threads_across_jobs() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let w = PersistentWorld::new(3);
        let ids = Arc::new(Mutex::new(HashSet::new()));
        for _ in 0..5 {
            let ids2 = ids.clone();
            let reports = w.run_job(move |ctx| {
                ids2.lock().unwrap().insert(std::thread::current().id());
                ctx.barrier();
                RankReport::default()
            });
            assert_eq!(reports.len(), 3);
        }
        // ThreadIds are never reused within a process: 5 jobs over 3
        // persistent threads must observe exactly 3 distinct ids. A
        // spawn-per-job executor would observe 15.
        assert_eq!(ids.lock().unwrap().len(), 3);
    }

    #[test]
    fn persistent_world_messages_match_within_each_job() {
        let w = PersistentWorld::new(2);
        for round in 0..3usize {
            let reports = w.run_job(move |ctx| {
                let mut r = RankReport::default();
                if ctx.rank == 0 {
                    let m0 = ctx.sent_msgs;
                    ctx.send(1, 4, vec![round as f64]);
                    r.msgs = ctx.sent_msgs - m0;
                } else {
                    let d = ctx.recv(0, 4);
                    assert_eq!(d, vec![round as f64]);
                }
                ctx.barrier();
                r
            });
            assert_eq!(reports[0].msgs, 1);
            assert_eq!(reports[1].msgs, 0);
        }
    }

    #[test]
    #[should_panic(expected = "panicked during a PersistentWorld job")]
    fn persistent_world_rank_panic_surfaces_instead_of_hanging() {
        let w = PersistentWorld::new(2);
        // rank 1 panics before the (never reached) barrier; rank 0
        // returns immediately. run_job must panic with the rank id,
        // not block forever on the missing report.
        w.run_job(|ctx| {
            if ctx.rank == 1 {
                panic!("boom");
            }
            RankReport::default()
        });
    }

    #[test]
    fn instrumentation_counts() {
        let results = World::run(2, |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 0, vec![0.0; 10]);
                ctx.send(1, 1, vec![0.0; 5]);
                (ctx.sent_msgs, ctx.sent_values)
            } else {
                ctx.recv(0, 0);
                ctx.recv(0, 1);
                (0, 0)
            }
        });
        assert_eq!(results[0], (2, 15));
    }
}
