//! Simulated-MPI runtime (DESIGN.md §2 substitution for the paper's
//! 64-core Opteron cluster + MPI).
//!
//! * [`comm`] — a rank world over OS threads and channels: tagged
//!   send/recv with (source, tag) matching, barriers, and the
//!   double-buffered zero-copy [`InputSlot`] used by the persistent
//!   executors to hand borrowed input vectors to rank threads.
//! * [`window`] — one-sided accumulation windows (`MPI_Accumulate`
//!   substitute): lock-free atomic f64 `+=` into a shared output vector,
//!   flushed by an epoch fence.
//! * [`cost`] — an α-β-γ communication/computation cost model replaying
//!   instrumented per-rank work to estimate makespans for rank counts
//!   this box cannot physically run (Figure 9's P = 1..64).

pub mod comm;
pub mod cost;
pub mod window;

pub use comm::{InputSlot, PersistentWorld, RankCtx, RankReport, World};
pub use cost::CostModel;
pub use window::Window;
