//! Scoped prepare thread pool (§Parallel prepare).
//!
//! [`PrepPool`] is the shared parallelism handle for the *prepare*
//! pipeline — BFS level expansion, per-level RCM child sorting,
//! permutation application, SSS construction, and the planner's timed
//! probes. It is deliberately a **width**, not a set of persistent
//! threads: every parallel region runs on `std::thread::scope` workers
//! spawned for that region, so closures may borrow freely from the
//! caller's stack (graph, dist array, frontier) with no `Arc`/`'static`
//! plumbing and no cross-region state. Persistent rank threads remain
//! the apply path's business ([`crate::mpisim::PersistentWorld`]);
//! prepare regions are long enough (milliseconds on matrices where
//! parallelism matters at all) that scoped spawn cost is noise.
//!
//! Determinism contract: [`PrepPool::map_chunks`] splits `0..n` into
//! **contiguous, ordered** chunks and returns the per-chunk results in
//! chunk order, whatever the interleaving of the workers. Callers that
//! merge those results in order — the BFS frontier merge, the RCM
//! per-level child merge, the slab concatenation in `sparse::convert`
//! — therefore produce output that is bit-for-bit independent of
//! scheduling and of the thread count.

use std::ops::Range;

/// Work-size floor below which a parallel region is not worth a spawn;
/// callers pass domain-specific floors, this is the shared default.
pub const MIN_PAR_WORK: usize = 256;

/// A prepare-parallelism handle: a clamped thread width plus the scoped
/// fan-out primitives the prepare stages share.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrepPool {
    threads: usize,
}

impl PrepPool {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// The single-threaded pool: every `map_*` call runs inline.
    pub fn serial() -> Self {
        Self { threads: 1 }
    }

    /// A pool as wide as the machine (`available_parallelism`), the
    /// `--prepare-threads` default.
    pub fn default_parallel() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `0..n` into at most [`Self::threads`] contiguous chunks of
    /// at least `min_chunk` items each, run `f(chunk_index, range)` on a
    /// scoped worker per chunk, and return the results **in chunk
    /// order**. Degenerates to one inline call (no spawn) when the work
    /// is too small or the pool is serial; a panic in any worker
    /// propagates to the caller via the scope join.
    pub fn map_chunks<T, F>(&self, n: usize, min_chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, Range<usize>) -> T + Sync,
    {
        let min_chunk = min_chunk.max(1);
        let chunks = self.threads.min((n + min_chunk - 1) / min_chunk).max(1);
        if chunks == 1 {
            return vec![f(0, 0..n)];
        }
        let per = (n + chunks - 1) / chunks;
        let mut out: Vec<Option<T>> = (0..chunks).map(|_| None).collect();
        std::thread::scope(|s| {
            for (idx, slot) in out.iter_mut().enumerate() {
                let range = (idx * per).min(n)..((idx + 1) * per).min(n);
                let f = &f;
                s.spawn(move || {
                    *slot = Some(f(idx, range));
                });
            }
        });
        out.into_iter().map(|r| r.expect("scoped pool worker completed")).collect()
    }

    /// Run `f(i)` for every `i in 0..n` (one logical task per item,
    /// batched onto the workers) and return the results in item order.
    /// This is the fan-out behind the planner's concurrent probes.
    pub fn map_items<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.map_chunks(n, 1, |_, r| r.map(&f).collect::<Vec<T>>())
            .into_iter()
            .flatten()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = PrepPool::serial();
        assert_eq!(pool.threads(), 1);
        let got = pool.map_chunks(10, 1, |idx, r| (idx, r));
        assert_eq!(got, vec![(0, 0..10)]);
    }

    #[test]
    fn zero_width_clamps_to_one() {
        assert_eq!(PrepPool::new(0).threads(), 1);
    }

    #[test]
    fn chunks_cover_the_range_in_order() {
        let pool = PrepPool::new(4);
        for n in [0usize, 1, 3, 4, 5, 17, 1000] {
            let chunks = pool.map_chunks(n, 1, |_, r| r);
            let mut expect = 0;
            for r in &chunks {
                assert_eq!(r.start, expect, "n={n}");
                expect = r.end;
            }
            assert_eq!(expect, n, "chunks must cover 0..{n}");
            assert!(chunks.len() <= 4);
        }
    }

    #[test]
    fn min_chunk_limits_the_split() {
        let pool = PrepPool::new(8);
        // 100 items at min_chunk 64 -> at most 2 chunks
        let chunks = pool.map_chunks(100, 64, |_, r| r);
        assert!(chunks.len() <= 2, "got {} chunks", chunks.len());
        // below the floor -> inline
        assert_eq!(pool.map_chunks(63, 64, |_, r| r), vec![0..63]);
    }

    #[test]
    fn map_items_preserves_item_order() {
        let pool = PrepPool::new(3);
        let got = pool.map_items(20, |i| i * i);
        let want: Vec<usize> = (0..20).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn results_are_deterministic_across_widths() {
        // the determinism contract callers rely on: ordered chunk merge
        // gives the same concatenation for every thread count
        let serial: Vec<usize> = PrepPool::serial()
            .map_chunks(500, 16, |_, r| r.map(|i| i * 3).collect::<Vec<_>>())
            .into_iter()
            .flatten()
            .collect();
        for t in [2usize, 4, 7] {
            let par: Vec<usize> = PrepPool::new(t)
                .map_chunks(500, 16, |_, r| r.map(|i| i * 3).collect::<Vec<_>>())
                .into_iter()
                .flatten()
                .collect();
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        PrepPool::new(2).map_chunks(600, 1, |idx, _| {
            if idx == 1 {
                panic!("worker boom");
            }
            0
        });
    }
}
