//! Deterministic PRNG (xoshiro256++ seeded by splitmix64).
//!
//! Stands in for the `rand` crate (unavailable offline). Quality is more
//! than sufficient for synthetic matrix generation and randomized
//! property tests; determinism per seed is what the reproduction needs.

/// Small fast deterministic RNG.
#[derive(Debug, Clone)]
pub struct SmallRng {
    s: [u64; 4],
}

impl SmallRng {
    /// Seed via splitmix64 expansion (any seed, including 0, is fine).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, hi)`; `hi > 0`.
    #[inline]
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard-normal-ish sample (sum of 12 uniforms, CLT; mean 0 var 1).
    #[inline]
    pub fn gen_normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.gen_f64();
        }
        s - 6.0
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n` in `perm[old] = new` convention.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = SmallRng::seed_from_u64(42);
        let mut b = SmallRng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SmallRng::seed_from_u64(1);
        let mut b = SmallRng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SmallRng::seed_from_u64(7);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = SmallRng::seed_from_u64(9);
        for _ in 0..1000 {
            let x = r.gen_range_usize(3, 10);
            assert!((3..10).contains(&x));
        }
    }

    #[test]
    fn mean_of_uniform_near_half() {
        let mut r = SmallRng::seed_from_u64(11);
        let m: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = SmallRng::seed_from_u64(13);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = SmallRng::seed_from_u64(17);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
