//! Minimal benchmark harness (`criterion` substitute, offline
//! environment). Benches are `harness = false` binaries that use this
//! to get warmup + repeated timing + criterion-style output, and write
//! **two** reports under `target/bench_reports/`: a human-readable
//! `<group>.md` and a machine-readable `<group>.json` (via
//! [`crate::util::json`]) so per-PR speedup trajectories can be
//! tracked by tooling instead of by eyeballing markdown diffs.
//!
//! Runs measured with [`Bencher::bench_rated`] additionally carry the
//! work they performed (`flops`/`bytes` per call, from the kernel's own
//! accounting) and are reported as [`Roofline`] points — GF/s, GB/s,
//! and the fraction of the measured STREAM-triad bandwidth achieved —
//! in both report files. All throughput math goes through
//! [`crate::perf`]; benches never divide by time themselves.

use crate::perf::{self, membench, time_fn, Roofline, Timing};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

/// One recorded measurement: rated runs remember their per-call work
/// so the report stage can derive rates and roofline points.
struct Measurement {
    name: String,
    t: Timing,
    /// `(flops, bytes)` per call for rated runs.
    work: Option<(u64, u64)>,
}

/// A named group of measurements, rendered like criterion output.
pub struct Bencher {
    group: String,
    lines: Vec<String>,
    measurements: Vec<Measurement>,
    report: String,
}

impl Bencher {
    /// Start a bench group (one per bench binary).
    pub fn new(group: &str) -> Self {
        println!("\nBenchmarking group: {group}");
        Self {
            group: group.to_string(),
            lines: Vec::new(),
            measurements: Vec::new(),
            report: String::new(),
        }
    }

    /// Time `f` with warmup and `reps` measured runs.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, reps: usize, f: F) -> Timing {
        let t = time_fn(warmup, reps, f);
        let line = format!(
            "{}/{name:<40} time: [min {} median {} mean {}]",
            self.group,
            fmt_t(t.min),
            fmt_t(t.median),
            fmt_t(t.mean)
        );
        println!("{line}");
        self.lines.push(line);
        self.measurements.push(Measurement { name: name.to_string(), t, work: None });
        t
    }

    /// Time `f` like [`Self::bench`] and rate it against the machine's
    /// memory roofline: `flops`/`bytes` are the work one call performs
    /// (the kernel's own `flops()`/`bytes()` accounting). Records both
    /// the min- and median-based rates; the returned [`Roofline`] point
    /// is min-based (best observed = least noise).
    pub fn bench_rated<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        reps: usize,
        flops: u64,
        bytes: u64,
        f: F,
    ) -> (Timing, Roofline) {
        let t = time_fn(warmup, reps, f);
        let tp = perf::throughput(t, flops, bytes);
        let roof = Roofline::from_seconds(t.min, flops, bytes);
        let line = format!(
            "{}/{name:<40} time: [min {} median {}]  \
             rate: [median {:.3} GF/s]  {}",
            self.group,
            fmt_t(t.min),
            fmt_t(t.median),
            tp.gflops_median,
            roof.summary()
        );
        println!("{line}");
        self.lines.push(line);
        self.measurements.push(Measurement {
            name: name.to_string(),
            t,
            work: Some((flops, bytes)),
        });
        (t, roof)
    }

    /// Attach a pre-rendered markdown section to the report file.
    pub fn section(&mut self, md: &str) {
        println!("{md}");
        self.report.push_str(md);
        self.report.push('\n');
    }

    /// The machine-readable report document (what `finish` writes to
    /// `<group>.json`): `{group, runs: [{name, min_s, median_s, mean_s,
    /// reps, ...}]}`. Rated runs add `gflops`/`gbytes` (min-based),
    /// `gflops_median`/`gbytes_median`, `achieved_fraction` and
    /// `arithmetic_intensity`; the document then also carries the
    /// shared `peak_gbytes` triad figure they were rated against.
    pub fn to_json(&self) -> Json {
        let mut any_rated = false;
        let runs: Vec<Json> = self
            .measurements
            .iter()
            .map(|mm| {
                let t = mm.t;
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(mm.name.clone()));
                m.insert("min_s".to_string(), Json::Num(t.min));
                m.insert("median_s".to_string(), Json::Num(t.median));
                m.insert("mean_s".to_string(), Json::Num(t.mean));
                m.insert("reps".to_string(), Json::Num(t.reps as f64));
                if let Some((flops, bytes)) = mm.work {
                    any_rated = true;
                    let tp = perf::throughput(t, flops, bytes);
                    let roof = Roofline::from_seconds(t.min, flops, bytes);
                    m.insert("gflops".to_string(), Json::Num(tp.gflops));
                    m.insert("gbytes".to_string(), Json::Num(tp.gbytes));
                    m.insert("gflops_median".to_string(), Json::Num(tp.gflops_median));
                    m.insert("gbytes_median".to_string(), Json::Num(tp.gbytes_median));
                    m.insert(
                        "achieved_fraction".to_string(),
                        Json::Num(roof.achieved_fraction),
                    );
                    m.insert(
                        "arithmetic_intensity".to_string(),
                        Json::Num(roof.arithmetic_intensity),
                    );
                }
                Json::Obj(m)
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("group".to_string(), Json::Str(self.group.clone()));
        doc.insert("runs".to_string(), Json::Arr(runs));
        if any_rated {
            doc.insert("peak_gbytes".to_string(), Json::Num(membench::peak_gbytes()));
        }
        Json::Obj(doc)
    }

    /// Markdown roofline table covering every rated run (empty string
    /// when nothing was rated).
    fn roofline_md(&self) -> String {
        let rated: Vec<&Measurement> =
            self.measurements.iter().filter(|m| m.work.is_some()).collect();
        if rated.is_empty() {
            return String::new();
        }
        let mut md = String::from(
            "## roofline\n\n\
             | run | GF/s | GB/s | median GF/s | achieved | AI flop/B |\n\
             |-----|------|------|-------------|----------|-----------|\n",
        );
        for mm in rated {
            let (flops, bytes) = mm.work.expect("filtered on work");
            let tp = perf::throughput(mm.t, flops, bytes);
            let roof = Roofline::from_seconds(mm.t.min, flops, bytes);
            let _ = writeln!(
                md,
                "| {} | {:.3} | {:.3} | {:.3} | {:.1}% | {:.4} |",
                mm.name,
                roof.gflops,
                roof.gbytes,
                tp.gflops_median,
                100.0 * roof.achieved_fraction,
                roof.arithmetic_intensity
            );
        }
        let _ = writeln!(
            md,
            "\npeak bandwidth (STREAM triad, cached per process): {:.2} GB/s\n",
            membench::peak_gbytes()
        );
        md
    }

    /// Write `target/bench_reports/<group>.md` (timings + roofline
    /// table + sections) and `target/bench_reports/<group>.json`
    /// (machine-readable runs).
    pub fn finish(self) {
        let dir = PathBuf::from("target/bench_reports");
        let _ = std::fs::create_dir_all(&dir);
        let mut out = format!("# bench: {}\n\n```\n", self.group);
        for l in &self.lines {
            let _ = writeln!(out, "{l}");
        }
        out.push_str("```\n\n");
        out.push_str(&self.roofline_md());
        out.push_str(&self.report);
        let path = dir.join(format!("{}.md", self.group));
        if std::fs::write(&path, out).is_ok() {
            println!("\nreport written to {}", path.display());
        }
        let jpath = dir.join(format!("{}.json", self.group));
        if std::fs::write(&jpath, self.to_json().dump()).is_ok() {
            println!("json report written to {}", jpath.display());
        }
    }
}

fn fmt_t(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_formats() {
        let mut b = Bencher::new("selftest");
        let t = b.bench("noop", 1, 3, || { std::hint::black_box(1 + 1); });
        assert!(t.min >= 0.0);
        assert_eq!(fmt_t(0.5e-7), "50.0 ns");
        assert_eq!(fmt_t(2.0), "2.000 s");
    }

    #[test]
    fn json_report_is_parseable_and_complete() {
        let mut b = Bencher::new("selftest_json");
        b.bench("first/run", 0, 2, || { std::hint::black_box(3 * 7); });
        b.bench("second/run", 0, 2, || { std::hint::black_box(5 + 5); });
        let doc = b.to_json();
        let parsed = Json::parse(&doc.dump()).unwrap();
        assert_eq!(parsed.req("group").unwrap().as_str().unwrap(), "selftest_json");
        let runs = parsed.req("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].req("name").unwrap().as_str().unwrap(), "first/run");
        assert_eq!(runs[0].req("reps").unwrap().as_usize().unwrap(), 2);
        assert!(runs[1].req("min_s").unwrap().as_f64().unwrap() >= 0.0);
        // un-rated groups carry no roofline surface
        assert!(parsed.req("peak_gbytes").is_err());
    }

    #[test]
    fn rated_runs_carry_roofline_fields_in_json_and_md() {
        let mut b = Bencher::new("selftest_rated");
        let v: Vec<f64> = (0..4096).map(|i| i as f64).collect();
        let (t, roof) = b.bench_rated("axpy-ish", 1, 3, 2 * 4096, 8 * 4096, || {
            std::hint::black_box(v.iter().sum::<f64>());
        });
        assert!(t.min > 0.0);
        assert!(roof.gflops > 0.0 && roof.gbytes > 0.0 && roof.peak_gbytes > 0.0);
        let parsed = Json::parse(&b.to_json().dump()).unwrap();
        assert!(parsed.req("peak_gbytes").unwrap().as_f64().unwrap() > 0.0);
        let run = &parsed.req("runs").unwrap().as_arr().unwrap()[0];
        for field in [
            "gflops",
            "gbytes",
            "gflops_median",
            "gbytes_median",
            "achieved_fraction",
            "arithmetic_intensity",
        ] {
            assert!(run.req(field).unwrap().as_f64().unwrap() >= 0.0, "{field}");
        }
        // min-based rate can't be slower than the median-based one
        let min_rate = run.req("gflops").unwrap().as_f64().unwrap();
        let med_rate = run.req("gflops_median").unwrap().as_f64().unwrap();
        assert!(min_rate >= med_rate);
        let md = b.roofline_md();
        assert!(md.contains("## roofline") && md.contains("axpy-ish"));
        assert!(md.contains("STREAM triad"));
    }
}
