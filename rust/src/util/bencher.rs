//! Minimal benchmark harness (`criterion` substitute, offline
//! environment). Benches are `harness = false` binaries that use this
//! to get warmup + repeated timing + criterion-style output, and write
//! **two** reports under `target/bench_reports/`: a human-readable
//! `<group>.md` and a machine-readable `<group>.json` (via
//! [`crate::util::json`]) so per-PR speedup trajectories can be
//! tracked by tooling instead of by eyeballing markdown diffs.

use crate::perf::{time_fn, Timing};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

/// A named group of measurements, rendered like criterion output.
pub struct Bencher {
    group: String,
    lines: Vec<String>,
    measurements: Vec<(String, Timing)>,
    report: String,
}

impl Bencher {
    /// Start a bench group (one per bench binary).
    pub fn new(group: &str) -> Self {
        println!("\nBenchmarking group: {group}");
        Self {
            group: group.to_string(),
            lines: Vec::new(),
            measurements: Vec::new(),
            report: String::new(),
        }
    }

    /// Time `f` with warmup and `reps` measured runs.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, reps: usize, f: F) -> Timing {
        let t = time_fn(warmup, reps, f);
        let line = format!(
            "{}/{name:<40} time: [min {} median {} mean {}]",
            self.group,
            fmt_t(t.min),
            fmt_t(t.median),
            fmt_t(t.mean)
        );
        println!("{line}");
        self.lines.push(line);
        self.measurements.push((name.to_string(), t));
        t
    }

    /// Attach a pre-rendered markdown section to the report file.
    pub fn section(&mut self, md: &str) {
        println!("{md}");
        self.report.push_str(md);
        self.report.push('\n');
    }

    /// The machine-readable report document (what `finish` writes to
    /// `<group>.json`): `{group, runs: [{name, min_s, median_s,
    /// mean_s, reps}]}`.
    pub fn to_json(&self) -> Json {
        let runs: Vec<Json> = self
            .measurements
            .iter()
            .map(|(name, t)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.clone()));
                m.insert("min_s".to_string(), Json::Num(t.min));
                m.insert("median_s".to_string(), Json::Num(t.median));
                m.insert("mean_s".to_string(), Json::Num(t.mean));
                m.insert("reps".to_string(), Json::Num(t.reps as f64));
                Json::Obj(m)
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("group".to_string(), Json::Str(self.group.clone()));
        doc.insert("runs".to_string(), Json::Arr(runs));
        Json::Obj(doc)
    }

    /// Write `target/bench_reports/<group>.md` (timings + sections) and
    /// `target/bench_reports/<group>.json` (machine-readable runs).
    pub fn finish(self) {
        let dir = PathBuf::from("target/bench_reports");
        let _ = std::fs::create_dir_all(&dir);
        let mut out = format!("# bench: {}\n\n```\n", self.group);
        for l in &self.lines {
            let _ = writeln!(out, "{l}");
        }
        out.push_str("```\n\n");
        out.push_str(&self.report);
        let path = dir.join(format!("{}.md", self.group));
        if std::fs::write(&path, out).is_ok() {
            println!("\nreport written to {}", path.display());
        }
        let jpath = dir.join(format!("{}.json", self.group));
        if std::fs::write(&jpath, self.to_json().dump()).is_ok() {
            println!("json report written to {}", jpath.display());
        }
    }
}

fn fmt_t(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_formats() {
        let mut b = Bencher::new("selftest");
        let t = b.bench("noop", 1, 3, || { std::hint::black_box(1 + 1); });
        assert!(t.min >= 0.0);
        assert_eq!(fmt_t(0.5e-7), "50.0 ns");
        assert_eq!(fmt_t(2.0), "2.000 s");
    }

    #[test]
    fn json_report_is_parseable_and_complete() {
        let mut b = Bencher::new("selftest_json");
        b.bench("first/run", 0, 2, || { std::hint::black_box(3 * 7); });
        b.bench("second/run", 0, 2, || { std::hint::black_box(5 + 5); });
        let doc = b.to_json();
        let parsed = Json::parse(&doc.dump()).unwrap();
        assert_eq!(parsed.req("group").unwrap().as_str().unwrap(), "selftest_json");
        let runs = parsed.req("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].req("name").unwrap().as_str().unwrap(), "first/run");
        assert_eq!(runs[0].req("reps").unwrap().as_usize().unwrap(), 2);
        assert!(runs[1].req("min_s").unwrap().as_f64().unwrap() >= 0.0);
    }
}
