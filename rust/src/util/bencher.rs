//! Minimal benchmark harness (`criterion` substitute, offline
//! environment). Benches are `harness = false` binaries that use this
//! to get warmup + repeated timing + criterion-style output, and write
//! a markdown report under `target/bench_reports/`.

use crate::perf::{time_fn, Timing};
use std::fmt::Write as _;
use std::path::PathBuf;

/// A named group of measurements, rendered like criterion output.
pub struct Bencher {
    group: String,
    lines: Vec<String>,
    report: String,
}

impl Bencher {
    /// Start a bench group (one per bench binary).
    pub fn new(group: &str) -> Self {
        println!("\nBenchmarking group: {group}");
        Self { group: group.to_string(), lines: Vec::new(), report: String::new() }
    }

    /// Time `f` with warmup and `reps` measured runs.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, reps: usize, f: F) -> Timing {
        let t = time_fn(warmup, reps, f);
        let line = format!(
            "{}/{name:<40} time: [min {} median {} mean {}]",
            self.group,
            fmt_t(t.min),
            fmt_t(t.median),
            fmt_t(t.mean)
        );
        println!("{line}");
        self.lines.push(line);
        t
    }

    /// Attach a pre-rendered markdown section to the report file.
    pub fn section(&mut self, md: &str) {
        println!("{md}");
        self.report.push_str(md);
        self.report.push('\n');
    }

    /// Write `target/bench_reports/<group>.md` with timings + sections.
    pub fn finish(self) {
        let dir = PathBuf::from("target/bench_reports");
        let _ = std::fs::create_dir_all(&dir);
        let mut out = format!("# bench: {}\n\n```\n", self.group);
        for l in &self.lines {
            let _ = writeln!(out, "{l}");
        }
        out.push_str("```\n\n");
        out.push_str(&self.report);
        let path = dir.join(format!("{}.md", self.group));
        if std::fs::write(&path, out).is_ok() {
            println!("\nreport written to {}", path.display());
        }
    }
}

fn fmt_t(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_formats() {
        let mut b = Bencher::new("selftest");
        let t = b.bench("noop", 1, 3, || { std::hint::black_box(1 + 1); });
        assert!(t.min >= 0.0);
        assert_eq!(fmt_t(0.5e-7), "50.0 ns");
        assert_eq!(fmt_t(2.0), "2.000 s");
    }
}
