//! Small self-contained utilities standing in for crates unavailable in
//! this offline environment (DESIGN.md §2): a deterministic PRNG
//! (`rand` substitute), a minimal JSON parser/writer (`serde_json`
//! substitute), and a property-test driver (`proptest` substitute).

pub mod bencher;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::SmallRng;
