//! Small self-contained utilities standing in for crates unavailable in
//! this offline environment (DESIGN.md §2): a deterministic PRNG
//! (`rand` substitute), a minimal JSON parser/writer (`serde_json`
//! substitute), a property-test driver (`proptest` substitute), and the
//! scoped prepare thread pool ([`pool::PrepPool`]).

pub mod bencher;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

pub use pool::PrepPool;
pub use rng::SmallRng;
