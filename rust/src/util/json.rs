//! Minimal JSON parser **and writer** (`serde_json` substitute, offline
//! environment).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! the coordinator config; writes the machine-readable bench reports
//! ([`crate::util::bencher`]) and the wire encoding of plan/stats
//! reports ([`crate::net`]). Supports the full JSON value grammar
//! except exotic number formats; strings support the standard escapes.
//!
//! Non-finite floats extend strict JSON with the `NaN` / `Infinity` /
//! `-Infinity` literals (the Python-`json` convention): reports carry
//! measured ratios that can legitimately be non-finite (e.g. a speedup
//! over a zero-time baseline), and now that they cross process
//! boundaries the encoding must be total — `dump` then `parse` returns
//! the value, never `null` in its place.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// As str.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Serialize to compact JSON text. Round-trips through
    /// [`Json::parse`] for **every** value, including non-finite
    /// numbers (written as the `NaN`/`Infinity`/`-Infinity` literals,
    /// which strict JSON lacks but our parser — and Python's — accept).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        use std::fmt::Write as _;
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) if x.is_nan() => out.push_str("NaN"),
            Json::Num(x) if x.is_infinite() => {
                out.push_str(if *x > 0.0 { "Infinity" } else { "-Infinity" })
            }
            Json::Num(x) => {
                let _ = write!(out, "{x}");
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'N' => self.lit("NaN", Json::Num(f64::NAN)),
            b'I' => self.lit("Infinity", Json::Num(f64::INFINITY)),
            b'-' if self.b.get(self.i + 1) == Some(&b'I') => {
                self.lit("-Infinity", Json::Num(f64::NEG_INFINITY))
            }
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "spmv_n1024_b16", "n": 1024, "inputs": [{"shape": [16, 1024], "dtype": "float32"}]}]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.req("version").unwrap().as_usize().unwrap(), 1);
        let arts = j.req("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].req("name").unwrap().as_str().unwrap(), "spmv_n1024_b16");
        let shape = arts[0].req("inputs").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 1024);
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn dump_round_trips_through_parse() {
        let doc = r#"{"group": "bench", "runs": [{"name": "a/b", "min_s": 1.5e-6, "reps": 5}], "note": "line\nbreak \"quoted\"", "ok": true, "none": null}"#;
        let v = Json::parse(doc).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn non_finite_floats_round_trip() {
        assert_eq!(Json::Num(2.5).dump(), "2.5");
        assert_eq!(Json::Num(f64::NAN).dump(), "NaN");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "Infinity");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump(), "-Infinity");

        // NaN != NaN, so the round trip is asserted structurally
        match Json::parse("NaN").unwrap() {
            Json::Num(x) => assert!(x.is_nan()),
            other => panic!("expected number, got {other:?}"),
        }
        assert_eq!(Json::parse("Infinity").unwrap(), Json::Num(f64::INFINITY));
        assert_eq!(Json::parse("-Infinity").unwrap(), Json::Num(f64::NEG_INFINITY));

        // nested, through a full dump->parse cycle
        let v = Json::Arr(vec![
            Json::Num(f64::NEG_INFINITY),
            Json::Num(-1.5),
            Json::Num(f64::NAN),
            Json::Num(f64::INFINITY),
        ]);
        let parsed = Json::parse(&v.dump()).unwrap();
        let a = parsed.as_arr().unwrap();
        assert_eq!(a[0], Json::Num(f64::NEG_INFINITY));
        assert_eq!(a[1], Json::Num(-1.5));
        assert!(matches!(a[2], Json::Num(x) if x.is_nan()));
        assert_eq!(a[3], Json::Num(f64::INFINITY));

        // near-miss literals still fail loudly
        assert!(Json::parse("Nan").is_err());
        assert!(Json::parse("-Inf").is_err());
        assert!(Json::parse("Infinit").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
