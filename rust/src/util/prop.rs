//! Tiny property-test driver (`proptest` substitute, offline environment).
//!
//! Runs a property over many seeded random cases and reports the failing
//! seed so a failure reproduces deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath on this offline box)
//! use pars3::util::prop::for_all;
//! for_all("sum commutes", 64, |rng| {
//!     let a = rng.gen_f64();
//!     let b = rng.gen_f64();
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::SmallRng;

/// Run `body` for `cases` seeds (0..cases). Panics with the failing seed
/// embedded in the message on the first failure.
pub fn for_all<F: Fn(&mut SmallRng) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    body: F,
) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = SmallRng::seed_from_u64(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all("addition commutes", 16, |rng| {
            let a = rng.gen_f64();
            let b = rng.gen_f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn reports_failing_seed() {
        for_all("always fails", 4, |_| panic!("nope"));
    }
}
