//! # PARS3 — Parallel Sparse Skew-Symmetric SpMV with RCM Reordering
//!
//! Production-grade reproduction of *PARS3: Parallel Sparse
//! Skew-Symmetric Matrix-Vector Multiplication with Reverse
//! Cuthill-McKee Reordering* (Yıldırım & Manguoğlu, cs.DC 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution:
//!   RCM reordering, 3-way band splitting, conflict pre-identification,
//!   block distribution, simulated-MPI rank runtime with one-sided
//!   accumulation, plus every substrate the paper depends on (sparse
//!   formats, SPARSKIT-style conversions, graph algorithms, the
//!   graph-coloring baseline of Elafrou et al., iterative solvers).
//! * **L2/L1 (build-time Python)** — the MRS iteration + Pallas banded
//!   skew-symmetric SpMV kernel, AOT-lowered to HLO text in
//!   `artifacts/` and executed from Rust via PJRT (`runtime`).
//!
//! Start with [`coordinator::Coordinator`] for the high-level pipeline,
//! [`kernel::pars3`] for the parallel kernel itself, or [`net::Server`]
//! to put the sharded service on a TCP/Unix socket. See DESIGN.md
//! for the module inventory and EXPERIMENTS.md for reproduced results.

pub mod coordinator;
pub mod graph;
pub mod kernel;
pub mod mpisim;
pub mod net;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod solver;
pub mod sparse;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
