//! Measured memory-bandwidth bound: a STREAM-triad microbench
//! (`a[i] = b[i] + s*c[i]`, McCalpin) over a working set far past L2,
//! cached once per process.
//!
//! This is the roofline's denominator: every achieved-fraction figure
//! in bench reports, `Pars3Stats`, and plan evidence divides by the
//! number measured here. Set `PARS3_PEAK_GBS` to pin the bound (CI
//! smoke runs do, so achieved fractions are deterministic on shared
//! runners); otherwise the first caller pays one ~tens-of-ms
//! measurement and every later caller reads the cached value.

use std::sync::OnceLock;

/// Doubles per triad array: 2 Mi × 8 B × 3 arrays = 48 MiB working
/// set — far beyond any L2/L3 a build runner has, so the measurement
/// is memory bandwidth, not cache bandwidth.
pub const TRIAD_LEN: usize = 1 << 21;

/// Measured timed repetitions (after one warmup pass that also faults
/// the pages in).
pub const TRIAD_REPS: usize = 3;

static PEAK: OnceLock<f64> = OnceLock::new();

/// The process-wide machine bandwidth bound in GB/s. First call
/// measures (or reads `PARS3_PEAK_GBS`); later calls are free.
pub fn peak_gbytes() -> f64 {
    *PEAK.get_or_init(|| {
        if let Ok(v) = std::env::var("PARS3_PEAK_GBS") {
            if let Ok(g) = v.parse::<f64>() {
                if g > 0.0 {
                    return g;
                }
            }
        }
        measure_triad_gbytes(TRIAD_LEN, TRIAD_REPS)
    })
}

/// Run the triad over `len`-element arrays for `reps` timed passes and
/// return GB/s from the fastest pass. Exposed for tests; production
/// callers want the cached [`peak_gbytes`].
pub fn measure_triad_gbytes(len: usize, reps: usize) -> f64 {
    let len = len.max(1);
    let scalar = 3.0f64;
    let b = vec![1.0f64; len];
    let c = vec![2.0f64; len];
    let mut a = vec![0.0f64; len];
    let t = super::time_fn(1, reps.max(1), || {
        for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
            *ai = *bi + scalar * *ci;
        }
        std::hint::black_box(&a);
    });
    // the triad streams two loads + one store of f64 per element
    (24 * len) as f64 / t.min / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triad_measures_a_positive_bandwidth() {
        // tiny arrays so the test is instant; the rate is still > 0
        let g = measure_triad_gbytes(1 << 12, 2);
        assert!(g > 0.0 && g.is_finite());
    }

    #[test]
    fn peak_is_cached_and_stable() {
        let a = peak_gbytes();
        let b = peak_gbytes();
        assert!(a > 0.0);
        assert_eq!(a, b, "OnceLock must return the same bound every time");
    }
}
