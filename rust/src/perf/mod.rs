//! Timing, counters, Amdahl analysis, and roofline accounting
//! (§Perf instrumentation).
//!
//! The roofline surface ([`Roofline`], [`membench`]) is the crate's
//! single source of truth for throughput claims: every kernel-facing
//! rate (GF/s, GB/s, achieved fraction of machine bandwidth) is
//! computed here from the kernel's own `flops()`/`bytes()` accessors
//! and the measured STREAM-triad bound — CI greps for ad-hoc
//! throughput math outside this module.

pub mod membench;

use std::time::Instant;

/// Simple repeated-run timer: median + min over `reps` runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median seconds per run.
    pub median: f64,
    /// Minimum seconds per run (least-noise estimate).
    pub min: f64,
    /// Mean seconds per run.
    pub mean: f64,
    /// Runs measured.
    pub reps: usize,
}

/// Time `f` for `reps` runs after `warmup` unmeasured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing { median, min, mean, reps }
}

/// Throughput helpers for SpMV-style kernels. Both the min-based rate
/// (the least-noise "best case") and the median-based rate (the honest
/// steady-state figure on noisy shared runners) are reported; min alone
/// overstates what a production request stream will see.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    /// GFLOP/s from the minimum run time (peak estimate).
    pub gflops: f64,
    /// Effective matrix-data GB/s from the minimum run time.
    pub gbytes: f64,
    /// GFLOP/s from the median run time (steady-state estimate).
    pub gflops_median: f64,
    /// Effective matrix-data GB/s from the median run time.
    pub gbytes_median: f64,
}

/// Compute throughput from a timing and per-run op counts.
pub fn throughput(t: Timing, flops: u64, bytes: u64) -> Throughput {
    let rate = |secs: f64, count: u64| if secs > 0.0 { count as f64 / secs / 1e9 } else { 0.0 };
    Throughput {
        gflops: rate(t.min, flops),
        gbytes: rate(t.min, bytes),
        gflops_median: rate(t.median, flops),
        gbytes_median: rate(t.median, bytes),
    }
}

/// A measured operating point against the machine's memory roofline
/// (Williams et al.; RACE — Alappat et al. 1907.06487 — reads its
/// symmetric-kernel results the same way). Built from a kernel's
/// `flops()`/`bytes()` accessors and a measured run time; the peak is
/// the process-cached STREAM-triad bound from [`membench`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    /// Achieved GFLOP/s.
    pub gflops: f64,
    /// Achieved GB/s of kernel data traffic.
    pub gbytes: f64,
    /// Measured machine bandwidth bound (GB/s, STREAM triad).
    pub peak_gbytes: f64,
    /// `gbytes / peak_gbytes`: how close the kernel runs to the memory
    /// roof. Band SpMV is bandwidth-bound, so this — not GF/s — is the
    /// number that says whether optimization headroom remains.
    pub achieved_fraction: f64,
    /// `flops / bytes` (flop per byte): position on the roofline's
    /// x-axis, a static property of the kernel + matrix.
    pub arithmetic_intensity: f64,
}

impl Roofline {
    /// Roofline point from one measured duration and per-run op counts.
    pub fn from_seconds(secs: f64, flops: u64, bytes: u64) -> Self {
        let peak_gbytes = membench::peak_gbytes();
        let rate =
            |count: u64| if secs > 0.0 { count as f64 / secs / 1e9 } else { 0.0 };
        let gbytes = rate(bytes);
        Roofline {
            gflops: rate(flops),
            gbytes,
            peak_gbytes,
            achieved_fraction: if peak_gbytes > 0.0 { gbytes / peak_gbytes } else { 0.0 },
            arithmetic_intensity: if bytes > 0 { flops as f64 / bytes as f64 } else { 0.0 },
        }
    }

    /// One-line human-readable summary (shared by `describe`, the CLI
    /// report table, and the bench reports).
    pub fn summary(&self) -> String {
        format!(
            "{:.3} GF/s, {:.3} GB/s ({:.1}% of {:.2} GB/s triad), AI {:.4} flop/B",
            self.gflops,
            self.gbytes,
            self.achieved_fraction * 100.0,
            self.peak_gbytes,
            self.arithmetic_intensity
        )
    }

    /// JSON encoding for the wire / bench reports (degenerate
    /// measurements can carry non-finite rates, which
    /// [`crate::util::json`] round-trips as `NaN`/`Infinity` literals).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("gflops".to_string(), Json::Num(self.gflops));
        m.insert("gbytes".to_string(), Json::Num(self.gbytes));
        m.insert("peak_gbytes".to_string(), Json::Num(self.peak_gbytes));
        m.insert("achieved_fraction".to_string(), Json::Num(self.achieved_fraction));
        m.insert("arithmetic_intensity".to_string(), Json::Num(self.arithmetic_intensity));
        Json::Obj(m)
    }

    /// Inverse of [`Roofline::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(Roofline {
            gflops: j.req("gflops")?.as_f64()?,
            gbytes: j.req("gbytes")?.as_f64()?,
            peak_gbytes: j.req("peak_gbytes")?.as_f64()?,
            achieved_fraction: j.req("achieved_fraction")?.as_f64()?,
            arithmetic_intensity: j.req("arithmetic_intensity")?.as_f64()?,
        })
    }
}

/// Roofline point from a [`Timing`]'s minimum (least-noise) run.
pub fn roofline(t: Timing, flops: u64, bytes: u64) -> Roofline {
    Roofline::from_seconds(t.min, flops, bytes)
}

/// Serial fraction estimate from measured speedup at `p` (inverse
/// Amdahl): `s = (p/S - 1) / (p - 1)`. Speedups at or above `p`
/// (super-linear runs happen on cache effects) have no meaningful
/// serial fraction — the unguarded formula would silently return a
/// negative value — so they clamp to `0`.
pub fn serial_fraction(speedup: f64, p: usize) -> f64 {
    if p <= 1 || speedup >= p as f64 {
        return 0.0;
    }
    ((p as f64 / speedup) - 1.0) / (p as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let t = time_fn(1, 5, || {
            let mut s = 0.0f64;
            for i in 0..10_000 {
                s += (i as f64).sqrt();
            }
            std::hint::black_box(s);
        });
        assert!(t.min > 0.0 && t.median >= t.min && t.reps == 5);
    }

    #[test]
    fn throughput_math_reports_min_and_median_rates() {
        let t = Timing { median: 1.0, min: 0.5, mean: 1.0, reps: 2 };
        let th = throughput(t, 1_000_000_000, 2_000_000_000);
        assert!((th.gflops - 2.0).abs() < 1e-12);
        assert!((th.gbytes - 4.0).abs() < 1e-12);
        assert!((th.gflops_median - 1.0).abs() < 1e-12);
        assert!((th.gbytes_median - 2.0).abs() < 1e-12);
        // min-based rate can only be >= the median-based rate
        assert!(th.gflops >= th.gflops_median && th.gbytes >= th.gbytes_median);
    }

    #[test]
    fn roofline_point_is_consistent() {
        let r = Roofline::from_seconds(0.5, 1_000_000_000, 2_000_000_000);
        assert!((r.gflops - 2.0).abs() < 1e-12);
        assert!((r.gbytes - 4.0).abs() < 1e-12);
        assert!((r.arithmetic_intensity - 0.5).abs() < 1e-12);
        assert!(r.peak_gbytes > 0.0, "membench must report a positive bound");
        assert!((r.achieved_fraction - r.gbytes / r.peak_gbytes).abs() < 1e-12);
        assert!(r.summary().contains("GF/s") && r.summary().contains("AI"));
    }

    #[test]
    fn roofline_degenerate_inputs_do_not_divide_by_zero() {
        let r = Roofline::from_seconds(0.0, 10, 0);
        assert_eq!(r.gflops, 0.0);
        assert_eq!(r.gbytes, 0.0);
        assert_eq!(r.arithmetic_intensity, 0.0);
        assert_eq!(r.achieved_fraction, 0.0);
    }

    #[test]
    fn roofline_round_trips_through_json() {
        let r = Roofline::from_seconds(0.5, 1_000_000_000, 2_000_000_000);
        assert_eq!(Roofline::from_json(&r.to_json()).unwrap(), r);
        // a degenerate point survives the text form too
        let text =
            Roofline { achieved_fraction: f64::INFINITY, ..r }.to_json().dump();
        let back =
            Roofline::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.achieved_fraction, f64::INFINITY);
    }

    #[test]
    fn serial_fraction_inverse_of_amdahl() {
        let p = 16;
        let s = 0.05;
        let speedup = crate::mpisim::CostModel::amdahl(s, p);
        let est = serial_fraction(speedup, p);
        assert!((est - s).abs() < 1e-12);
    }

    #[test]
    fn serial_fraction_guards_superlinear_speedup() {
        // speedup > p used to return a silently negative fraction
        assert_eq!(serial_fraction(17.0, 16), 0.0);
        assert_eq!(serial_fraction(16.0, 16), 0.0);
        assert!(serial_fraction(15.9, 16) > 0.0);
        assert_eq!(serial_fraction(2.0, 1), 0.0);
    }
}
