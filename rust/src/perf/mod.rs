//! Timing, counters, and Amdahl analysis (§Perf instrumentation).

use std::time::Instant;

/// Simple repeated-run timer: median + min over `reps` runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median seconds per run.
    pub median: f64,
    /// Minimum seconds per run (least-noise estimate).
    pub min: f64,
    /// Mean seconds per run.
    pub mean: f64,
    /// Runs measured.
    pub reps: usize,
}

/// Time `f` for `reps` runs after `warmup` unmeasured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing { median, min, mean, reps }
}

/// Throughput helpers for SpMV-style kernels.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    /// GFLOP/s.
    pub gflops: f64,
    /// Effective matrix-data GB/s.
    pub gbytes: f64,
}

/// Compute throughput from a timing and per-run op counts.
pub fn throughput(t: Timing, flops: u64, bytes: u64) -> Throughput {
    Throughput {
        gflops: flops as f64 / t.min / 1e9,
        gbytes: bytes as f64 / t.min / 1e9,
    }
}

/// Serial fraction estimate from measured speedup at `p` (inverse
/// Amdahl): `s = (p/S - 1) / (p - 1)`.
pub fn serial_fraction(speedup: f64, p: usize) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    ((p as f64 / speedup) - 1.0) / (p as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let t = time_fn(1, 5, || {
            let mut s = 0.0f64;
            for i in 0..10_000 {
                s += (i as f64).sqrt();
            }
            std::hint::black_box(s);
        });
        assert!(t.min > 0.0 && t.median >= t.min && t.reps == 5);
    }

    #[test]
    fn throughput_math() {
        let t = Timing { median: 1.0, min: 0.5, mean: 1.0, reps: 1 };
        let th = throughput(t, 1_000_000_000, 2_000_000_000);
        assert!((th.gflops - 2.0).abs() < 1e-12);
        assert!((th.gbytes - 4.0).abs() < 1e-12);
    }

    #[test]
    fn serial_fraction_inverse_of_amdahl() {
        let p = 16;
        let s = 0.05;
        let speedup = crate::mpisim::CostModel::amdahl(s, p);
        let est = serial_fraction(speedup, p);
        assert!((est - s).abs() < 1e-12);
    }
}
