//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos with 64-bit instruction ids).

use crate::runtime::artifacts::{ArtifactSpec, Manifest};
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::HashMap;

/// A compiled, ready-to-execute artifact.
pub struct LoadedArtifact {
    /// The artifact signature.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with f32 input buffers (in manifest order); returns f32
    /// outputs (in tuple order).
    pub fn execute_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact '{}' expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (buf, ts)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            ensure!(
                buf.len() == ts.numel(),
                "input {k} of '{}': expected {} elements, got {}",
                self.spec.name,
                ts.numel(),
                buf.len()
            );
            let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact '{}' returned {} outputs, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Build an input literal for position `k` of this artifact's
    /// signature (validates shape). Use with [`Self::execute_literals`]
    /// to hoist invariant inputs (e.g. the band matrix) out of a solver
    /// loop — literal creation copies the host data, so doing it once
    /// per solve instead of once per call removes the dominant per-
    /// iteration transfer (§Perf).
    pub fn literal_for(&self, k: usize, buf: &[f32]) -> Result<xla::Literal> {
        let ts = &self.spec.inputs[k];
        ensure!(
            buf.len() == ts.numel(),
            "input {k} of '{}': expected {} elements, got {}",
            self.spec.name,
            ts.numel(),
            buf.len()
        );
        let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(buf).reshape(&dims)?)
    }

    /// Execute with pre-built literals (see [`Self::literal_for`]).
    pub fn execute_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact '{}' expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let result = self.exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// The PJRT CPU runtime with a compiled-artifact cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, LoadedArtifact>,
}

impl PjrtRuntime {
    /// Create a CPU client over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.by_name(name)?.clone();
            let path = self.manifest.path_of(&spec);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            self.cache.insert(name.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Load the smallest artifact of `kind` fitting `(n, beta)`.
    pub fn load_best(&mut self, kind: &str, n: usize, beta: usize) -> Result<&LoadedArtifact> {
        let name = self.manifest.best_fit(kind, n, beta)?.name.clone();
        self.load(&name)
    }

    /// Upload an f32 host slice to a device buffer with the given dims.
    pub fn to_device(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Read an f32 device buffer back to the host.
pub fn from_device(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
}
