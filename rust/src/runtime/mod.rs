//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the L2/L1
//! JAX+Pallas graph to HLO *text* once; this module compiles it on the
//! PJRT CPU client (`xla` crate) and executes with concrete buffers.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifacts::{ArtifactSpec, Manifest};
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedArtifact, PjrtRuntime};
