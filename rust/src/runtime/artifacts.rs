//! Artifact manifest: what `aot.py` exported, with shapes and kinds.

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::path::{Path, PathBuf};

/// One tensor's shape/dtype in the artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Dtype name (currently always `float32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Unique name, e.g. `spmv_n4096_b32`.
    pub name: String,
    /// Kind: `spmv`, `mrs_step`, or `mrs_solve`.
    pub kind: String,
    /// HLO text file (relative to the manifest directory).
    pub file: PathBuf,
    /// Matrix dimension the artifact was lowered for.
    pub n: usize,
    /// Band half-bandwidth.
    pub beta: usize,
    /// Row-tile size used by the Pallas kernel.
    pub tile: usize,
    /// Iterations fused into the artifact (mrs_chunk / mrs_solve kinds).
    pub iters: Option<usize>,
    /// Input signatures in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signatures in tuple order.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and HLO files) live in.
    pub dir: PathBuf,
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
}

fn tensor_list(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            let shape = t
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { shape, dtype: t.req("dtype")?.as_str()?.to_string() })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)?;
        ensure!(j.req("version")?.as_usize()? == 1, "unsupported manifest version");
        let artifacts = j
            .req("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a.req("name")?.as_str()?.to_string(),
                    kind: a.req("kind")?.as_str()?.to_string(),
                    file: PathBuf::from(a.req("file")?.as_str()?),
                    n: a.req("n")?.as_usize()?,
                    beta: a.req("beta")?.as_usize()?,
                    tile: a.req("tile")?.as_usize()?,
                    iters: a.get("iters").map(|v| v.as_usize()).transpose()?,
                    inputs: tensor_list(a.req("inputs")?)?,
                    outputs: tensor_list(a.req("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dir, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Smallest artifact of `kind` that fits a problem of size `n` with
    /// bandwidth `beta` (the coordinator zero-pads up to it).
    pub fn best_fit(&self, kind: &str, n: usize, beta: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.n >= n && a.beta >= beta)
            .min_by_key(|a| (a.n, a.beta))
            .ok_or_else(|| {
                anyhow!("no '{kind}' artifact fits n={n}, beta={beta}; re-export with larger configs")
            })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 6);
        let spmv = m.by_name("spmv_n1024_b16").unwrap();
        assert_eq!(spmv.kind, "spmv");
        assert_eq!(spmv.inputs[0].shape, vec![16, 1024]);
        assert_eq!(spmv.outputs[0].shape, vec![1024]);
        assert!(m.path_of(spmv).exists());
    }

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let a = m.best_fit("spmv", 900, 10).unwrap();
        assert_eq!((a.n, a.beta), (1024, 16));
        let b = m.best_fit("spmv", 1500, 10).unwrap();
        assert_eq!((b.n, b.beta), (4096, 32));
        assert!(m.best_fit("spmv", 1 << 20, 1).is_err());
    }
}
