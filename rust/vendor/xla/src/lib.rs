//! Offline API stub of the `xla` crate surface that `pars3::runtime::pjrt`
//! compiles against.
//!
//! The build environment has no network and no PJRT plugin, so this stub
//! keeps the `pjrt` feature *compilable* while failing honestly at
//! runtime: [`PjRtClient::cpu`] returns an error, so no downstream
//! method is ever reached on a real code path. When a real PJRT-backed
//! `xla` crate is available, point `rust/Cargo.toml` at it instead; the
//! signatures here mirror the subset `runtime/pjrt.rs` uses
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`).

use std::fmt;
use std::path::Path;

/// Stub error type (std-error so `?`/`.context()` interop works).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the XLA/PJRT runtime is not available in this offline build \
         (vendor/xla is an API stub; swap in a real xla crate to run artifacts)"
    )))
}

/// PJRT client handle. [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub build.
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; returns per-device output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side tensor literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_loudly() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not produce a client"),
        };
        assert!(err.to_string().contains("offline"));
    }

    #[test]
    fn literal_plumbing_typechecks() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_tuple().is_err());
    }
}
