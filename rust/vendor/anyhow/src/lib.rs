//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The pars3 build environment has no network and no crates.io registry
//! (DESIGN.md §2), so the subset of the `anyhow` API the codebase uses
//! is provided here as a vendored path dependency:
//!
//! * [`Error`] — a context-chain error type. `{e}` prints the outermost
//!   message, `{e:#}` the full chain joined by `": "` (matching anyhow's
//!   alternate formatting), `{e:?}` an anyhow-style "Caused by" report.
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std errors and [`Error`] itself) and on `Option`.
//!
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`
//! once registry access exists; no call sites need to change.

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost (most recently
/// attached) message; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The `?` conversion from any std error. `Error` itself deliberately
// does NOT implement `std::error::Error`, which keeps this blanket impl
// coherent with the identity `From<Error> for Error` (the same design
// choice the real anyhow makes).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `Result` with a defaulted boxed-free error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for std::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

mod ext {
    use super::Error;
    use std::fmt::Display;

    // Implemented for both std errors and `Error` so `.context(..)`
    // works on `Result<_, io::Error>` and on `anyhow::Result` alike.
    // Coherent because `Error: std::error::Error` never holds (above).
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T, E>: private::Sealed {
    /// Wrap the error with an outer message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-built outer message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::StdError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::StdError::ext_context(e, context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::StdError::ext_context(e, f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = anyhow!("outer {}", 7);
        assert_eq!(format!("{e}"), "outer 7");
        let e = e.context("ctx");
        assert_eq!(format!("{e}"), "ctx");
        assert_eq!(format!("{e:#}"), "ctx: outer 7");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_from_std_error() {
        fn f() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");

        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }
}
