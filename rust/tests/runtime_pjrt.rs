//! PJRT runtime integration: AOT artifacts vs native Rust numerics.
//!
//! This file only compiles with the `pjrt` feature (see the
//! `required-features` entry in `rust/Cargo.toml`), and every test
//! additionally skips (with a note) unless both the AOT artifacts
//! (`make artifacts`) and a working PJRT plugin are present — the
//! default offline build vendors an API stub whose client creation
//! fails, and that must read as "skipped", not "failed".

use pars3::coordinator::{Backend, Config, Coordinator};
use pars3::runtime::{Manifest, PjrtRuntime};
use pars3::solver::mrs::MrsOptions;
use pars3::sparse::{convert, gen, DiaBand, Symmetry};
use pars3::util::SmallRng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping PJRT test: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// Artifacts + a live PJRT client, or `None` (skip) with a note.
fn live_runtime() -> Option<(PathBuf, PjrtRuntime)> {
    let dir = artifacts_dir()?;
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping PJRT test: manifest unreadable: {e:#}");
            return None;
        }
    };
    match PjrtRuntime::new(manifest) {
        Ok(rt) => Some((dir, rt)),
        Err(e) => {
            eprintln!("skipping PJRT test: no PJRT plugin ({e:#})");
            None
        }
    }
}

fn banded_system(n: usize, beta_max: usize, alpha: f64, seed: u64) -> DiaBand {
    let mut rng = SmallRng::seed_from_u64(seed);
    let mut dia = DiaBand::zeros(n, beta_max, alpha);
    for d in 0..beta_max {
        for j in 0..n.saturating_sub(d + 1) {
            if rng.gen_f64() < 0.4 {
                dia.set(d, j, rng.gen_range_f64(-1.0, 1.0));
            }
        }
    }
    dia
}

#[test]
fn spmv_artifact_matches_rust_dia_reference() {
    let Some((_dir, mut rt)) = live_runtime() else { return };
    let dia = banded_system(1024, 16, 1.7, 1);
    let x: Vec<f64> = (0..1024).map(|i| (i as f64 * 0.031).sin()).collect();
    let mut want = vec![0.0; 1024];
    dia.spmv_ref(&x, &mut want);

    let art = rt.load("spmv_n1024_b16").unwrap();
    let lo = dia.to_f32_padded(16, 1024).unwrap();
    let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let out = art.execute_f32(&[&lo, &x32, &[1.7f32]]).unwrap();
    assert_eq!(out.len(), 1);
    for (k, (a, b)) in out[0].iter().zip(&want).enumerate() {
        assert!((*a as f64 - b).abs() < 1e-3, "row {k}: {a} vs {b}");
    }
}

/// Narrow-band fixture whose RCM bandwidth fits the artifact configs.
fn narrow_system(n: usize, alpha: f64, seed: u64) -> pars3::sparse::Coo {
    let mut rng = SmallRng::seed_from_u64(seed);
    let edges = gen::random_banded_pattern(n, 3, 0.4, &mut rng);
    pars3::sparse::skew::coo_from_pattern(n, &edges, alpha, &mut rng)
}

#[test]
fn padded_execution_matches_smaller_problem() {
    // a n=700 problem runs on the n=1024 artifact via zero padding
    let Some((dir, _rt)) = live_runtime() else { return };
    let coo = narrow_system(700, 2.0, 3);
    let mut coord = Coordinator::new(Config { artifacts_dir: dir, ..Config::default() });
    let prep = coord.prepare("pad", &coo).unwrap();
    assert!(prep.reordered_bw <= 16 || prep.n <= 4096, "fixture fits an artifact");
    let x: Vec<f64> = (0..700).map(|i| (i as f64 * 0.05).cos()).collect();
    let y_serial = coord.spmv(&prep, &x, Backend::Serial).unwrap();
    let y_pjrt = coord.spmv(&prep, &x, Backend::Pjrt).unwrap();
    assert_eq!(y_pjrt.len(), 700);
    for (k, (a, b)) in y_pjrt.iter().zip(&y_serial).enumerate() {
        assert!((a - b).abs() < 1e-3, "row {k}: {a} vs {b}");
    }
}

#[test]
fn mrs_step_artifact_consistent_with_native_iteration() {
    let Some((_dir, mut rt)) = live_runtime() else { return };
    let dia = banded_system(1024, 16, 2.0, 7);
    let b: Vec<f64> = (0..1024).map(|i| ((i % 17) as f64 - 8.0) * 0.1).collect();

    // one native f64 iteration
    let mut p = vec![0.0; 1024];
    dia.spmv_ref(&b, &mut p);
    let rr: f64 = b.iter().map(|v| v * v).sum();
    let pp: f64 = p.iter().map(|v| v * v).sum();
    let a = 2.0 * rr / pp;
    let x1: Vec<f64> = b.iter().map(|&r| a * r).collect();
    let r1: Vec<f64> = b.iter().zip(&p).map(|(r, p)| r - a * p).collect();

    // one artifact iteration
    let art = rt.load("mrs_step_n1024_b16").unwrap();
    let lo = dia.to_f32_padded(16, 1024).unwrap();
    let x32 = vec![0.0f32; 1024];
    let r32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
    let out = art.execute_f32(&[&lo, &x32, &r32, &[2.0f32]]).unwrap();
    assert_eq!(out.len(), 3);
    // rr reported by the artifact is ||r||^2 before the update
    assert!((out[2][0] as f64 - rr).abs() < 1e-2 * rr, "rr {} vs {rr}", out[2][0]);
    for (k, (g, w)) in out[0].iter().zip(&x1).enumerate() {
        assert!((*g as f64 - w).abs() < 1e-3, "x row {k}");
    }
    for (k, (g, w)) in out[1].iter().zip(&r1).enumerate() {
        assert!((*g as f64 - w).abs() < 1e-3, "r row {k}");
    }
}

#[test]
fn pjrt_solve_converges_and_matches_native() {
    let Some((dir, _rt)) = live_runtime() else { return };
    let coo = narrow_system(900, 3.0, 13);
    let mut coord = Coordinator::new(Config { artifacts_dir: dir, ..Config::default() });
    let prep = coord.prepare("slv", &coo).unwrap();
    let b: Vec<f64> = (0..900).map(|i| ((i * 3) % 11) as f64 * 0.1 - 0.5).collect();
    let opts = MrsOptions { alpha: 3.0, max_iters: 400, tol: 1e-6 };
    let r_native = coord.solve(&prep, &b, &opts, Backend::Serial).unwrap();
    let r_pjrt = coord.solve(&prep, &b, &opts, Backend::Pjrt).unwrap();
    assert!(r_native.converged && r_pjrt.converged);
    let err = r_native
        .x
        .iter()
        .zip(&r_pjrt.x)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(err < 1e-2, "f32 artifact path err {err}");
}

#[test]
fn manifest_best_fit_and_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.by_name("nope").is_err());
    let a = m.best_fit("mrs_step", 1024, 16).unwrap();
    assert_eq!(a.name, "mrs_step_n1024_b16");
    assert!(m.best_fit("spmv", 8193, 1).is_err());
    // whole-solve artifact exists too
    assert!(m.artifacts.iter().any(|a| a.kind == "mrs_solve"));
}

#[test]
fn dia_conversion_guards() {
    // non-constant diagonal must be rejected by the PJRT path
    let mut coo = gen::small_test_matrix(100, 5, 2.0);
    // perturb one diagonal entry
    for k in 0..coo.nnz() {
        if coo.rows[k] == coo.cols[k] {
            coo.vals[k] = 9.0;
            break;
        }
    }
    let sss = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
    assert!(DiaBand::from_sss(&sss, sss.bandwidth().max(1)).is_err());
}
