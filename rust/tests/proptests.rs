//! Property-based tests over coordinator/kernel invariants.
//!
//! Uses the in-crate randomized driver `util::prop::for_all` (the
//! offline registry has no proptest; failures reproduce by seed).

use pars3::coordinator::{Backend, Config, Coordinator};
use pars3::graph::coloring::{color_rows, verify_coloring};
use pars3::graph::{rcm, Adjacency};
use pars3::kernel::conflict::{BlockDist, ConflictMap};
use pars3::kernel::serial_sss::sss_spmv;
use pars3::kernel::Split3;
use pars3::mpisim::Window;
use pars3::sparse::{convert, gen, skew, Symmetry};
use pars3::util::prop::for_all;
use pars3::util::SmallRng;
use std::sync::Arc;

/// Random shifted skew-symmetric matrix + RCM-banded SSS form.
fn random_banded(rng: &mut SmallRng) -> pars3::sparse::Sss {
    let n = 20 + rng.gen_range_usize(0, 180);
    let per_row = 1 + rng.gen_range_usize(0, 6);
    let mut edges = gen::random_banded_pattern(n, per_row, 0.5, rng);
    gen::add_long_range(&mut edges, n, 0.1 * rng.gen_f64(), rng);
    let alpha = rng.gen_range_f64(0.5, 4.0);
    let coo = skew::coo_from_pattern(n, &edges, alpha, rng);
    let g = Adjacency::from_coo(&coo);
    let perm = rcm(&g);
    convert::coo_to_sss(&coo.permute_symmetric(&perm), Symmetry::Skew).unwrap()
}

#[test]
fn prop_rcm_is_always_a_permutation() {
    for_all("rcm permutation", 40, |rng| {
        let n = 5 + rng.gen_range_usize(0, 200);
        let edges = gen::random_banded_pattern(n, 1 + rng.gen_range_usize(0, 4), 0.4, rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        let perm = rcm(&g);
        let mut seen = vec![false; n];
        for &p in &perm {
            assert!(!seen[p as usize], "duplicate target {p}");
            seen[p as usize] = true;
        }
    });
}

/// Random disconnected lower-edge pattern: several disjoint banded
/// components plus trailing isolated vertices. Returns `(n, edges)`.
fn disconnected_pattern(rng: &mut SmallRng) -> (usize, Vec<(u32, u32)>) {
    let comps = 1 + rng.gen_range_usize(0, 5);
    let mut edges = Vec::new();
    let mut base = 0u32;
    for _ in 0..comps {
        let cn = 2 + rng.gen_range_usize(0, 40);
        let per_row = 1 + rng.gen_range_usize(0, 3);
        for (i, j) in gen::random_banded_pattern(cn, per_row, 0.5, rng) {
            edges.push((i + base, j + base));
        }
        base += cn as u32;
    }
    let isolated = rng.gen_range_usize(0, 4);
    (base as usize + isolated, edges)
}

#[test]
fn prop_rcm_is_total_permutation_on_disconnected_graphs() {
    // RCM must emit every vertex exactly once even when the graph has
    // many components and isolated vertices (each component gets its
    // own pseudo-peripheral start; isolated vertices are their own
    // components).
    for_all("rcm total on disconnected", 40, |rng| {
        let (n, edges) = disconnected_pattern(rng);
        let edges = gen::scramble(&edges, n, rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        let perm = rcm(&g);
        assert_eq!(perm.len(), n);
        let mut seen = vec![false; n];
        for &p in &perm {
            assert!(!seen[p as usize], "target {p} assigned twice");
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "permutation is not total");
    });
}

/// One random pattern from the three families the reordering benches
/// exercise: tight banded, scattered (long-range + scrambled), and
/// disconnected blocks. Returns `(n, edges)`.
fn pattern_families(rng: &mut SmallRng) -> (usize, Vec<(u32, u32)>) {
    match rng.gen_range_usize(0, 3) {
        0 => {
            let n = 20 + rng.gen_range_usize(0, 300);
            (n, gen::random_banded_pattern(n, 1 + rng.gen_range_usize(0, 5), 0.5, rng))
        }
        1 => {
            let n = 20 + rng.gen_range_usize(0, 300);
            let mut e = gen::random_banded_pattern(n, 2, 0.5, rng);
            gen::add_long_range(&mut e, n, 0.2 * rng.gen_f64(), rng);
            (n, gen::scramble(&e, n, rng))
        }
        _ => {
            let (n, e) = disconnected_pattern(rng);
            (n, gen::scramble(&e, n, rng))
        }
    }
}

#[test]
fn prop_parallel_bfs_and_rcm_match_serial_for_every_pool_width() {
    // the prepare pool is a pure speedup: for ANY pattern family and
    // ANY pool width, the BFS level structure and the RCM permutation
    // are bit-identical to the serial ones
    use pars3::graph::bfs::{level_structure, level_structure_with};
    use pars3::graph::rcm::rcm_with;
    use pars3::util::PrepPool;
    for_all("parallel bfs/rcm == serial", 20, |rng| {
        let (n, edges) = pattern_families(rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        let serial_perm = rcm(&g);
        let root = rng.gen_range_usize(0, n) as u32;
        let serial_ls = level_structure(&g, root);
        for t in [1usize, 2, 4] {
            let pool = PrepPool::new(t);
            assert_eq!(rcm_with(&g, &pool), serial_perm, "threads={t} n={n}");
            let ls = level_structure_with(&g, root, &pool);
            assert_eq!(ls.dist, serial_ls.dist, "threads={t} n={n} root={root}");
            assert_eq!(ls.levels, serial_ls.levels, "threads={t} n={n} root={root}");
        }
    });
}

#[test]
fn prop_reorder_report_is_deterministic_per_pool_width() {
    // same input + same pool width => the same permutation and the same
    // ReorderReport (wall-clock timings excepted — they are the only
    // nondeterministic fields, so they are zeroed before comparing)
    use pars3::graph::reorder::{reorder_with_report_with, ReorderPolicy};
    use pars3::util::PrepPool;
    for_all("reorder report deterministic", 10, |rng| {
        let (n, edges) = pattern_families(rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        for policy in [ReorderPolicy::Rcm, ReorderPolicy::Auto] {
            for t in [1usize, 4] {
                let pool = PrepPool::new(t);
                let (perm_a, mut rep_a) = reorder_with_report_with(&g, policy, 0.0, &pool);
                let (perm_b, mut rep_b) = reorder_with_report_with(&g, policy, 0.0, &pool);
                assert_eq!(perm_a, perm_b, "{policy} threads={t} n={n}");
                assert_eq!(rep_a.timings.threads, t, "{policy}");
                rep_a.timings = Default::default();
                rep_b.timings = Default::default();
                assert_eq!(rep_a, rep_b, "{policy} threads={t} n={n}");
            }
        }
    });
}

#[test]
fn prop_prepare_permutation_never_increases_bandwidth() {
    // The pipeline's reordering contract: `Coordinator::prepare` picks
    // RCM when it helps and falls back to the identity when the input
    // is already at least as tightly banded (raw RCM alone offers no
    // bandwidth guarantee) — so `Coo::permute_symmetric` with the
    // chosen permutation never increases the bandwidth, including on
    // disconnected matrices.
    for_all("prepare bandwidth guard", 25, |rng| {
        let (n, edges) = disconnected_pattern(rng);
        if n < 2 {
            return;
        }
        let edges = gen::scramble(&edges, n, rng);
        let alpha = rng.gen_range_f64(0.5, 3.0);
        let coo = skew::coo_from_pattern(n, &edges, alpha, rng);
        let coord = Coordinator::new(Config::default());
        let prep = coord.prepare("prop", &coo).unwrap();
        assert!(
            prep.reordered_bw <= prep.bw_before,
            "bandwidth grew: {} -> {}",
            prep.bw_before,
            prep.reordered_bw
        );
        // the permutation is total...
        let mut seen = vec![false; n];
        for &p in &prep.perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
        // ...and permute_symmetric under it reproduces exactly the
        // bandwidth the pipeline reports
        let permuted = coo.permute_symmetric(&prep.perm);
        assert_eq!(permuted.bandwidth(), prep.reordered_bw);
        assert!(permuted.bandwidth() <= coo.bandwidth());
    });
}

#[test]
fn prop_every_reorder_strategy_is_a_total_permutation() {
    // every strategy — including Auto's measured pick — must emit a
    // total permutation on arbitrary disconnected graphs, with the
    // per-component stats accounting for every vertex
    use pars3::graph::reorder::{reorder_with_report, ReorderPolicy};
    for_all("reorder strategies total on disconnected", 25, |rng| {
        let (n, edges) = disconnected_pattern(rng);
        let edges = gen::scramble(&edges, n, rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        for policy in [
            ReorderPolicy::Natural,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Auto,
        ] {
            let (perm, report) = reorder_with_report(&g, policy, 0.0);
            assert_eq!(perm.len(), n, "{policy}");
            let mut seen = vec![false; n];
            for &p in &perm {
                assert!(!seen[p as usize], "{policy}: target {p} assigned twice");
                seen[p as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{policy}: permutation is not total");
            assert_eq!(
                report.components.iter().map(|c| c.size).sum::<usize>(),
                n,
                "{policy}: component stats must cover every vertex"
            );
        }
    });
}

#[test]
fn prop_auto_never_increases_bandwidth_over_natural() {
    // the Asudeh-et-al. gate: whatever Auto picks, its bandwidth is
    // never worse than declining to reorder (natural is a candidate)
    use pars3::graph::rcm::bandwidth_under;
    use pars3::graph::reorder::{reorder_with_report, ReorderPolicy};
    for_all("auto bandwidth gate", 25, |rng| {
        let (n, edges) = disconnected_pattern(rng);
        let edges = gen::scramble(&edges, n, rng);
        let g = Adjacency::from_lower_edges(n, &edges);
        let id: Vec<u32> = (0..n as u32).collect();
        let min_gain = 0.2 * rng.gen_f64();
        let (perm, report) = reorder_with_report(&g, ReorderPolicy::Auto, min_gain);
        let nat_bw = bandwidth_under(&g, &id);
        assert!(
            bandwidth_under(&g, &perm) <= nat_bw,
            "auto picked a worse-than-natural ordering (min_gain {min_gain})"
        );
        assert_eq!(report.bw_after, bandwidth_under(&g, &perm));
        assert_eq!(report.bw_before, nat_bw);
    });
}

#[test]
fn prop_split3_partitions_nnz_exactly() {
    for_all("split3 partition", 40, |rng| {
        let s = random_banded(rng);
        let bw = s.bandwidth().max(1);
        let split_bw = 1 + rng.gen_range_usize(0, bw + 2);
        let sp = Split3::new(&s, split_bw).unwrap();
        assert_eq!(sp.nnz_middle() + sp.nnz_outer(), s.nnz_lower());
        assert_eq!(sp.unsplit(), s, "unsplit must roundtrip");
        // every middle entry within split_bw, every outer beyond
        for i in 0..sp.n {
            for (j, _) in sp.middle.row(i) {
                assert!(i - j as usize <= split_bw);
            }
        }
        for e in &sp.outer {
            assert!((e.row - e.col) as usize > split_bw);
        }
    });
}

#[test]
fn prop_pars3_matches_serial_for_any_rank_count() {
    for_all("pars3 == serial", 30, |rng| {
        let s = random_banded(rng);
        let n = s.n;
        let p = 1 + rng.gen_range_usize(0, n.min(24));
        let outer_bw = 1 + rng.gen_range_usize(0, 5);
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-2.0, 2.0)).collect();
        let mut want = vec![0.0; n];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, outer_bw).unwrap();
        let plan = pars3::kernel::pars3::Pars3Plan::new(split, p).unwrap();
        let (got, _) = plan.execute_emulated(&x);
        for (k, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-9, "row {k}: {a} vs {b} (n={n} p={p})");
        }
    });
}

#[test]
fn prop_conflict_map_is_consistent() {
    for_all("conflict accounting", 30, |rng| {
        let s = random_banded(rng);
        let p = 1 + rng.gen_range_usize(0, s.n.min(32));
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let cm = ConflictMap::analyze(&split, p);
        // safe + conflicting covers everything exactly once
        assert_eq!(
            cm.total_safe() + cm.total_conflicts(),
            split.nnz_middle() + split.nnz_outer()
        );
        // rank 0 never conflicts (paper §3)
        assert_eq!(cm.rank0_conflicts(), 0);
        // every conflict targets a strictly lower rank (lower triangle)
        for (r, rc) in cm.per_rank.iter().enumerate() {
            for &t in &rc.target_ranks {
                assert!(t < r, "rank {r} targets {t}");
            }
        }
    });
}

#[test]
fn prop_block_dist_covers_rows_exactly_once() {
    for_all("block distribution", 60, |rng| {
        let n = 1 + rng.gen_range_usize(0, 500);
        let p = 1 + rng.gen_range_usize(0, 80);
        let d = BlockDist::new(n, p);
        let mut owner = vec![usize::MAX; n];
        for r in 0..p {
            let (a, b) = d.range(r);
            for row in a..b {
                assert_eq!(owner[row], usize::MAX, "row {row} double-owned");
                owner[row] = r;
                assert_eq!(d.rank_of(row), r);
            }
        }
        assert!(owner.iter().all(|&o| o != usize::MAX));
    });
}

#[test]
fn prop_coloring_is_always_conflict_free() {
    for_all("coloring valid", 25, |rng| {
        let s = random_banded(rng);
        let c = color_rows(&s);
        assert!(verify_coloring(&s, &c));
        assert_eq!(c.classes.iter().map(Vec::len).sum::<usize>(), s.n);
    });
}

#[test]
fn prop_window_accumulate_is_linear() {
    for_all("window linearity", 20, |rng| {
        let n = 1 + rng.gen_range_usize(0, 64);
        let w = Window::new(n);
        let mut expect = vec![0.0f64; n];
        for _ in 0..200 {
            let i = rng.gen_range_usize(0, n);
            let v = rng.gen_range_f64(-1.0, 1.0);
            w.add(i, v);
            expect[i] += v;
        }
        for (a, b) in w.to_vec().iter().zip(&expect) {
            assert!((a - b).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_coordinator_spmv_backends_agree() {
    for_all("coordinator backends", 15, |rng| {
        let mut coord = Coordinator::new(Config::default());
        let n = 50 + rng.gen_range_usize(0, 150);
        let coo = gen::small_test_matrix(n, rng.next_u64(), 1.0 + rng.gen_f64());
        let prep = coord.prepare("prop", &coo).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let y0 = coord.spmv(&prep, &x, Backend::Serial).unwrap();
        let p = 1 + rng.gen_range_usize(0, 12);
        let y1 = coord.spmv(&prep, &x, Backend::Pars3 { p }).unwrap();
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_skew_invariant_preserved_by_pipeline() {
    // (x, Sx) = 0 must hold after reorder + split + parallel execution
    for_all("skew invariant", 20, |rng| {
        let s = random_banded(rng);
        let alpha = s.dvalues[0];
        let n = s.n;
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 1 + rng.gen_range_usize(0, 8);
        let plan = pars3::kernel::pars3::Pars3Plan::new(split, p).unwrap();
        let (y, _) = plan.execute_emulated(&x);
        let xay: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let xx: f64 = x.iter().map(|v| v * v).sum();
        assert!(
            (xay - alpha * xx).abs() < 1e-7 * (1.0 + xx),
            "xAy={xay} alpha*xx={}",
            alpha * xx
        );
    });
}

#[test]
fn prop_apply_batch_matches_columnwise_apply_for_every_kernel() {
    use pars3::kernel::registry::{build_from_sss, KernelConfig};
    use pars3::kernel::{Spmv, VecBatch, KERNEL_NAMES};
    for_all("apply_batch == k applies (all kernels)", 10, |rng| {
        let s = Arc::new(random_banded(rng));
        let n = s.n;
        let k = 1 + rng.gen_range_usize(0, 7);
        let xs = VecBatch::from_fn(n, k, |_, _| rng.gen_range_f64(-2.0, 2.0));
        let cfg = KernelConfig {
            threads: 1 + rng.gen_range_usize(0, 8),
            outer_bw: 1 + rng.gen_range_usize(0, 4),
            threaded: false,
            ..KernelConfig::default()
        };
        for &name in KERNEL_NAMES {
            let mut kern = build_from_sss(name, s.clone(), &cfg).unwrap();
            kern.prepare_hint(k);
            let mut ys = VecBatch::zeros(n, k);
            kern.apply_batch(&xs, &mut ys);
            for c in 0..k {
                let mut want = vec![0.0; n];
                kern.apply(xs.col(c), &mut want);
                for (r, (a, b)) in ys.col(c).iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "{name} col {c} row {r}: {a} vs {b} (n={n} k={k})"
                    );
                }
            }
        }
    });
}

/// Random banded *symmetric* matrix (positive mirror) in SSS form.
fn random_banded_symmetric(rng: &mut SmallRng) -> pars3::sparse::Sss {
    let n = 30 + rng.gen_range_usize(0, 120);
    let edges = gen::random_banded_pattern(n, 1 + rng.gen_range_usize(0, 4), 0.5, rng);
    let mut coo = pars3::sparse::Coo::new(n);
    for i in 0..n as u32 {
        coo.push(i, i, rng.gen_range_f64(1.0, 3.0));
    }
    for &(i, j) in &edges {
        let v = rng.gen_range_f64(-1.0, 1.0);
        coo.push(i, j, v);
        coo.push(j, i, v);
    }
    convert::coo_to_sss(&coo, Symmetry::Symmetric).unwrap()
}

#[test]
fn prop_race_matches_sss_for_every_mode() {
    // the RACE level-coloring schedule is a processing order, never a
    // different computation: for ANY skew or symmetric matrix, both
    // execution modes (emulated and persistent-threaded) and both
    // batch widths must reproduce the serial SSS kernel within 1e-12
    use pars3::kernel::race::RaceKernel;
    use pars3::kernel::{Spmv, VecBatch};
    for_all("race == serial for every mode", 6, |rng| {
        for skew in [true, false] {
            let s =
                Arc::new(if skew { random_banded(rng) } else { random_banded_symmetric(rng) });
            let n = s.n;
            let p = 1 + rng.gen_range_usize(0, 8);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-2.0, 2.0)).collect();
            let mut want = vec![0.0; n];
            sss_spmv(&s, &x, &mut want);
            let kw = 8usize;
            let xs = VecBatch::from_fn(n, kw, |_, _| rng.gen_range_f64(-2.0, 2.0));
            let mut want_b = VecBatch::zeros(n, kw);
            for c in 0..kw {
                let mut col = vec![0.0; n];
                sss_spmv(&s, xs.col(c), &mut col);
                want_b.col_mut(c).copy_from_slice(&col);
            }
            for threaded in [false, true] {
                let mut k = RaceKernel::new(s.clone(), p, threaded).unwrap();
                let mut y = vec![0.0; n];
                k.apply(&x, &mut y);
                for (r, (a, b)) in y.iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-12,
                        "skew={skew} threaded={threaded} p={p} row {r}: {a} vs {b} (n={n})"
                    );
                }
                k.prepare_hint(kw);
                let mut ys = VecBatch::zeros(n, kw);
                k.apply_batch(&xs, &mut ys);
                for c in 0..kw {
                    for (r, (a, b)) in ys.col(c).iter().zip(want_b.col(c)).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-12,
                            "skew={skew} threaded={threaded} col {c} row {r}: {a} vs {b} (n={n})"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_dia_format_matches_sss_for_every_kernel() {
    // the middle-split storage is an execution detail: for ANY banded
    // skew or symmetric matrix, every registered kernel must produce
    // the same result (within rounding) under FormatPolicy::Dia and
    // FormatPolicy::Sss, at k = 1 and at k > 1.
    use pars3::kernel::registry::{build_from_sss, KernelConfig};
    use pars3::kernel::{FormatPolicy, Spmv, VecBatch, KERNEL_NAMES};
    for_all("dia == sss for every kernel", 6, |rng| {
        for skew in [true, false] {
            let s =
                Arc::new(if skew { random_banded(rng) } else { random_banded_symmetric(rng) });
            let n = s.n;
            let kw = 2 + rng.gen_range_usize(0, 5);
            let threads = 1 + rng.gen_range_usize(0, 8);
            let outer_bw = 1 + rng.gen_range_usize(0, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-2.0, 2.0)).collect();
            let xs = VecBatch::from_fn(n, kw, |_, _| rng.gen_range_f64(-2.0, 2.0));
            for &name in KERNEL_NAMES {
                let mk = |format| KernelConfig {
                    threads,
                    outer_bw,
                    threaded: false,
                    format,
                    ..KernelConfig::default()
                };
                let mut k_sss = build_from_sss(name, s.clone(), &mk(FormatPolicy::Sss)).unwrap();
                let mut k_dia = build_from_sss(name, s.clone(), &mk(FormatPolicy::Dia)).unwrap();
                // k = 1
                let (mut ya, mut yb) = (vec![0.0; n], vec![0.0; n]);
                k_sss.apply(&x, &mut ya);
                k_dia.apply(&x, &mut yb);
                for (r, (a, b)) in ya.iter().zip(&yb).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "{name} skew={skew} row {r}: {a} vs {b} (n={n})"
                    );
                }
                // k > 1 (fused batch)
                k_sss.prepare_hint(kw);
                k_dia.prepare_hint(kw);
                let mut za = VecBatch::zeros(n, kw);
                let mut zb = VecBatch::zeros(n, kw);
                k_sss.apply_batch(&xs, &mut za);
                k_dia.apply_batch(&xs, &mut zb);
                for c in 0..kw {
                    for (r, (a, b)) in za.col(c).iter().zip(zb.col(c)).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-9,
                            "{name} skew={skew} col {c} row {r} (n={n} k={kw})"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_blocked_and_lane_variants_match_scalar_for_every_kernel() {
    // cache blocking and lane unrolling are execution details: for ANY
    // banded skew or symmetric matrix, every registered kernel must
    // reproduce the plain scalar reference (`sss_spmv`, column by
    // column) under a tiny tile budget (many tiles), the default one,
    // and a huge one (a single tile spanning the matrix), at k = 1 and
    // at k = 8.
    use pars3::kernel::registry::{build_from_sss, KernelConfig};
    use pars3::kernel::{Spmv, VecBatch, DEFAULT_L2_KIB, KERNEL_NAMES};
    for_all("blocked/lane == scalar for every kernel", 4, |rng| {
        for skew in [true, false] {
            let s =
                Arc::new(if skew { random_banded(rng) } else { random_banded_symmetric(rng) });
            let n = s.n;
            let kw = 8usize;
            let threads = 1 + rng.gen_range_usize(0, 8);
            let outer_bw = 1 + rng.gen_range_usize(0, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-2.0, 2.0)).collect();
            let xs = VecBatch::from_fn(n, kw, |_, _| rng.gen_range_f64(-2.0, 2.0));
            // scalar reference, per column
            let mut want1 = vec![0.0; n];
            sss_spmv(&s, &x, &mut want1);
            let mut want_b = VecBatch::zeros(n, kw);
            for c in 0..kw {
                let mut col = vec![0.0; n];
                sss_spmv(&s, xs.col(c), &mut col);
                want_b.col_mut(c).copy_from_slice(&col);
            }
            for l2_kib in [1usize, DEFAULT_L2_KIB, 1 << 20] {
                for &name in KERNEL_NAMES {
                    let cfg = KernelConfig {
                        threads,
                        outer_bw,
                        threaded: false,
                        l2_kib,
                        ..KernelConfig::default()
                    };
                    let mut k = build_from_sss(name, s.clone(), &cfg).unwrap();
                    let mut y = vec![0.0; n];
                    k.apply(&x, &mut y);
                    for (r, (a, b)) in y.iter().zip(&want1).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-12,
                            "{name} skew={skew} l2={l2_kib} row {r}: {a} vs {b} (n={n})"
                        );
                    }
                    k.prepare_hint(kw);
                    let mut ys = VecBatch::zeros(n, kw);
                    k.apply_batch(&xs, &mut ys);
                    for c in 0..kw {
                        for (r, (a, b)) in ys.col(c).iter().zip(want_b.col(c)).enumerate() {
                            assert!(
                                (a - b).abs() < 1e-12,
                                "{name} skew={skew} l2={l2_kib} col {c} row {r} (n={n})"
                            );
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_pars3_batch_modes_agree_and_fuse_halos() {
    use pars3::kernel::pars3::{Pars3Plan, Pars3Threaded};
    use pars3::kernel::VecBatch;
    for_all("pars3 batch: emulated == threaded, one halo round", 6, |rng| {
        let s = random_banded(rng);
        let n = s.n;
        let p = 1 + rng.gen_range_usize(0, n.min(6));
        let k = 1 + rng.gen_range_usize(0, 5);
        let xs = VecBatch::from_fn(n, k, |_, _| rng.gen_range_f64(-1.0, 1.0));
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let plan = Arc::new(Pars3Plan::new(split, p).unwrap());
        let mut want = VecBatch::zeros(n, k);
        let se = plan.execute_emulated_batch(&xs, &mut want);
        let mut exec = Pars3Threaded::new(plan.clone());
        let mut got = VecBatch::zeros(n, k);
        let st = exec.apply_batch(&xs, &mut got);
        // both modes: identical message accounting and numerics
        assert_eq!(se.msgs, st.msgs);
        assert_eq!(se.msg_values, st.msg_values);
        for c in 0..k {
            for (r, (a, b)) in got.col(c).iter().zip(want.col(c)).enumerate() {
                assert!((a - b).abs() < 1e-9, "col {c} row {r} (n={n} p={p} k={k})");
            }
        }
        // fusion invariant: a k-wide batch sends exactly as many halo
        // messages as a single apply, with payload scaled by k
        let (_, s1) = plan.execute_emulated(xs.col(0));
        assert_eq!(se.msgs, s1.msgs);
        for (bv, ov) in se.msg_values.iter().zip(&s1.msg_values) {
            assert_eq!(*bv, ov * k);
        }
    });
}

#[test]
fn prop_threaded_pars3_matches_emulated() {
    for_all("threaded == emulated", 8, |rng| {
        let s = random_banded(rng);
        let x: Vec<f64> = (0..s.n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 1 + rng.gen_range_usize(0, s.n.min(6));
        let plan = Arc::new(pars3::kernel::pars3::Pars3Plan::new(split, p).unwrap());
        let (a, _) = plan.execute_threaded(&x);
        let (b, _) = plan.execute_emulated(&x);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_symmetric_variant_works_through_pars3() {
    // paper §1: "our approach also naturally applies to parallel sparse
    // symmetric SpMVs" — same pipeline, sign = +1
    for_all("symmetric pars3 == serial", 15, |rng| {
        let n = 30 + rng.gen_range_usize(0, 120);
        let edges = gen::random_banded_pattern(n, 1 + rng.gen_range_usize(0, 4), 0.5, rng);
        let mut coo = pars3::sparse::Coo::new(n);
        for i in 0..n as u32 {
            coo.push(i, i, rng.gen_range_f64(1.0, 3.0));
        }
        for &(i, j) in &edges {
            let v = rng.gen_range_f64(-1.0, 1.0);
            coo.push(i, j, v);
            coo.push(j, i, v); // symmetric mirror
        }
        let s = convert::coo_to_sss(&coo, Symmetry::Symmetric).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let mut want = vec![0.0; n];
        sss_spmv(&s, &x, &mut want);
        let split = Split3::with_outer_bw(&s, 3).unwrap();
        let p = 1 + rng.gen_range_usize(0, n.min(12));
        let plan = pars3::kernel::pars3::Pars3Plan::new(split, p).unwrap();
        let (got, _) = plan.execute_emulated(&x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_planned_triple_matches_pinned_numerics() {
    // planning is pure selection, never a different computation: for ANY
    // matrix, read the (reorder, format, backend) triple an all-auto
    // plan chose, pin exactly that triple through the legacy per-axis
    // config path, and the two pipelines must agree to 1e-12 on spmv
    // and step-for-step on the solver
    use pars3::coordinator::PlanMode;
    use pars3::solver::MrsOptions;
    for_all("planned triple == pinned triple", 10, |rng| {
        let n = 40 + rng.gen_range_usize(0, 140);
        let alpha = 1.5 + rng.gen_f64();
        let coo = gen::small_test_matrix(n, rng.next_u64(), alpha);

        let mut auto_coord = Coordinator::new(Config::default());
        let auto_prep = auto_coord.prepare("prop", &coo).unwrap();

        let pinned_cfg = Config {
            plan: PlanMode::Pinned,
            reorder: auto_prep.choice.reorder,
            format: auto_prep.choice.format,
            ..Config::default()
        };
        let mut pinned_coord = Coordinator::new(pinned_cfg);
        let pinned_prep = pinned_coord.prepare("prop", &coo).unwrap();

        // same concrete reorder policy -> same permutation; same format
        // policy -> same middle-split storage
        assert_eq!(auto_prep.perm, pinned_prep.perm, "n={n}");
        assert_eq!(auto_prep.split.format_name(), pinned_prep.split.format_name(), "n={n}");
        // the pinned run reports every axis as pinned
        assert!(pinned_prep.plan.axes.iter().all(|ax| ax.pinned));

        let backend = auto_prep.choice.backend;
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let ya = auto_coord.spmv(&auto_prep, &x, backend).unwrap();
        let yp = pinned_coord.spmv(&pinned_prep, &x, backend).unwrap();
        for (r, (a, b)) in ya.iter().zip(&yp).enumerate() {
            assert!((a - b).abs() <= 1e-12, "spmv row {r}: {a} vs {b} (n={n})");
        }

        let opts = MrsOptions { alpha, max_iters: 200, tol: 1e-7 };
        let ra = auto_coord.solve(&auto_prep, &x, &opts, backend).unwrap();
        let rp = pinned_coord.solve(&pinned_prep, &x, &opts, backend).unwrap();
        assert_eq!(ra.iters, rp.iters, "n={n}");
        assert_eq!(ra.converged, rp.converged, "n={n}");
        for (r, (a, b)) in ra.x.iter().zip(&rp.x).enumerate() {
            assert!((a - b).abs() <= 1e-12, "solve row {r}: {a} vs {b} (n={n})");
        }
    });
}

#[test]
fn prop_client_matches_coordinator_for_every_registered_backend() {
    // the typed handle/ticket surface is a transport, not a different
    // engine: for ANY matrix and EVERY registry-backed Backend variant,
    // spmv and solve answers through a sharded `Service` + `Client`
    // must match a direct single-owner `Coordinator` on the same config
    use pars3::coordinator::Service;
    use pars3::solver::MrsOptions;
    for_all("client == coordinator", 6, |rng| {
        let n = 40 + rng.gen_range_usize(0, 120);
        let alpha = 1.5 + rng.gen_f64();
        let coo = gen::small_test_matrix(n, rng.next_u64(), alpha);
        let cfg = Config { shards: 1 + rng.gen_range_usize(0, 3), ..Config::default() };
        let p = 1 + rng.gen_range_usize(0, 8);
        let backends = [
            Backend::Serial,
            Backend::Csr,
            Backend::Dgbmv,
            Backend::Coloring { p },
            Backend::Race { p },
            Backend::Pars3 { p },
        ];

        let mut coord = Coordinator::new(cfg.clone());
        let prep = coord.prepare("prop", &coo).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        let opts = MrsOptions { alpha, max_iters: 200, tol: 1e-7 };

        let svc = Service::start(cfg);
        let client = svc.client();
        let h = client.prepare("prop", coo).wait().unwrap();
        // pipeline one spmv ticket per backend before collecting any
        let tickets: Vec<_> =
            backends.iter().map(|&b| client.spmv(&h, x.clone(), b)).collect();
        for (&backend, t) in backends.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let want = coord.spmv(&prep, &x, backend).unwrap();
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-10, "{backend:?} row {r}: {a} vs {b}");
            }
        }
        // one solve through a randomly chosen backend
        let backend = backends[rng.gen_range_usize(0, backends.len())];
        let got = client.solve(&h, x.clone(), opts.clone(), backend).wait().unwrap();
        let want = coord.solve(&prep, &x, &opts, backend).unwrap();
        assert_eq!(got.converged, want.converged, "{backend:?}");
        assert_eq!(got.iters, want.iters, "{backend:?}");
        for (a, b) in got.x.iter().zip(&want.x) {
            assert!((a - b).abs() < 1e-10, "{backend:?}");
        }
        svc.shutdown();
    });
}

#[test]
fn prop_frame_codec_round_trips_every_message() {
    // any sequence of wire messages survives encode -> concatenate ->
    // adversarial re-chunking (byte-at-a-time or random cuts) ->
    // decode, bit-for-bit. TCP guarantees no read boundaries; this is
    // the property that makes the framed codec safe over it.
    use pars3::coordinator::{CacheStats, Pars3Error, Service};
    use pars3::kernel::VecBatch;
    use pars3::net::frame::{write_frame, FrameDecoder};
    use pars3::net::proto::{Request, Response};
    use pars3::solver::mrs::{MrsOptions, MrsResult};

    // handles are only minted by a service (opaque fields); one real
    // handle serves every case — the codec just sees its four words
    let svc = Service::start(Config { shards: 1, ..Config::default() });
    let client = svc.client();
    let handle = client.prepare("prop", gen::small_test_matrix(30, 3, 2.0)).wait().unwrap();
    let info = client.describe(&handle).wait().unwrap();

    for_all("frame codec round trips", 48, |rng| {
        #[derive(Debug, PartialEq)]
        enum Msg {
            Req(Request),
            Resp(Response),
        }
        fn vecf(rng: &mut SmallRng, len: usize) -> Vec<f64> {
            (0..len).map(|_| rng.gen_range_f64(-1e3, 1e3)).collect()
        }

        let n = 5 + rng.gen_range_usize(0, 30);
        let coo = {
            let edges = gen::random_banded_pattern(n, 2, 0.5, rng);
            skew::coo_from_pattern(n, &edges, 1.5 + rng.gen_f64(), rng)
        };
        let p = 1 + rng.gen_range_usize(0, 8);
        let backend = [
            Backend::Serial,
            Backend::Csr,
            Backend::Dgbmv,
            Backend::Coloring { p },
            Backend::Race { p },
            Backend::Pars3 { p },
            Backend::Pjrt,
        ][rng.gen_range_usize(0, 7)];
        let opts = MrsOptions {
            alpha: 1.0 + rng.gen_f64(),
            max_iters: 1 + rng.gen_range_usize(0, 300),
            tol: 1e-8,
        };
        let k = 1 + rng.gen_range_usize(0, 4);
        let xs = VecBatch::from_fn(n, k, |i, c| ((i * 31 + c * 7) as f64).sin());
        let mrs = MrsResult {
            x: vecf(rng, n),
            r: vecf(rng, n),
            history: vecf(rng, 4),
            iters: rng.gen_range_usize(0, 300),
            converged: rng.gen_f64() < 0.5,
        };
        let err = match rng.gen_range_usize(0, 5) {
            0 => Pars3Error::ServiceStopped,
            1 => Pars3Error::DimensionMismatch { expected: n, got: n + 1 },
            2 => Pars3Error::Io("connection reset by peer".into()),
            3 => Pars3Error::StaleHandle { shard: 0, slot: 1, held: 1, current: 2 },
            _ => Pars3Error::Protocol("torn frame".into()),
        };
        let shard_sel =
            if rng.gen_f64() < 0.5 { Some(rng.gen_range_usize(0, 9) as u64) } else { None };

        // every message kind once, with randomized contents
        let msgs = vec![
            Msg::Req(Request::Prepare { id: 1, name: format!("m{n}"), coo: coo.clone() }),
            Msg::Req(Request::PrepareReplace { id: 2, handle, name: "r".into(), coo }),
            Msg::Req(Request::Release { id: 3, handle }),
            Msg::Req(Request::Spmv { id: 4, handle, x: vecf(rng, n), backend }),
            Msg::Req(Request::SpmvBatch { id: 5, handle, xs: xs.clone(), backend }),
            Msg::Req(Request::Solve {
                id: 6,
                handle,
                b: vecf(rng, n),
                opts: opts.clone(),
                backend,
            }),
            Msg::Req(Request::SolveBatch { id: 7, handle, bs: xs.clone(), opts, backend }),
            Msg::Req(Request::Describe { id: 8, handle }),
            Msg::Req(Request::CacheStats { id: 9, shard: shard_sel }),
            Msg::Req(Request::Stop { id: 10 }),
            Msg::Resp(Response::Handle { id: 11, handle }),
            Msg::Resp(Response::Unit { id: 12 }),
            Msg::Resp(Response::Vec { id: 13, y: vecf(rng, n) }),
            Msg::Resp(Response::Batch { id: 14, ys: xs }),
            Msg::Resp(Response::Solve { id: 15, result: mrs.clone() }),
            Msg::Resp(Response::SolveBatch { id: 16, results: vec![mrs] }),
            Msg::Resp(Response::Info { id: 17, info: info.clone() }),
            Msg::Resp(Response::Stats {
                id: 18,
                stats: vec![CacheStats { shard: 0, cached: 1, built: 2, queue_depth: 3 }],
            }),
            Msg::Resp(Response::Error { id: 19, err }),
        ];

        let mut wire = Vec::new();
        for m in &msgs {
            let (tag, payload) = match m {
                Msg::Req(r) => r.encode(),
                Msg::Resp(r) => r.encode(),
            };
            write_frame(&mut wire, tag, &payload).unwrap();
        }

        let byte_mode = rng.gen_f64() < 0.25;
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut i = 0;
        while i < wire.len() {
            let step = if byte_mode { 1 } else { 1 + rng.gen_range_usize(0, 301) };
            let j = (i + step).min(wire.len());
            dec.feed(&wire[i..j]);
            i = j;
            while let Some((tag, payload)) = dec.next_frame().unwrap() {
                got.push(if tag < 0x80 {
                    Msg::Req(Request::decode(tag, &payload).unwrap())
                } else {
                    Msg::Resp(Response::decode(tag, &payload).unwrap())
                });
            }
        }
        assert_eq!(got, msgs);
        assert_eq!(dec.pending(), 0, "no bytes left behind");
    });
    svc.shutdown();
}
